//! RL policy-search consensus (Fig. 3(c,d) style): generate double
//! cart-pole rollouts with the built-in simulator, distribute the
//! reward-weighted regression across a processor graph, solve it with
//! SDD-Newton, and evaluate the learned consensus policy in the
//! simulator.
//!
//!     cargo run --release --example rl_consensus

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::algorithms::{run, ConsensusAlgorithm, RunOptions};
use sddnewton::dcp;
use sddnewton::graph::generate;
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn mean_reward(policy: &dcp::GaussianPolicy, episodes: usize, rng: &mut Pcg64) -> f64 {
    let params = dcp::DcpParams::default();
    dcp::generate_rollouts(&params, policy, episodes, 100, rng)
        .iter()
        .map(|r| r.reward)
        .sum::<f64>()
        / episodes as f64
}

fn main() {
    let mut rng = Pcg64::new(7);
    let n = 10;
    let g = generate::random_connected(n, 25, &mut rng);
    let problem = datasets::rl_dcp(n, 400, 50, 0.6, 0.05, &mut rng);

    let solver = sddm_for_graph(&g, 0.1, &mut rng);
    let backend = NativeBackend;
    let mut alg = SddNewton::new(&problem, &backend, &solver, StepSize::Fixed(1.0));
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &problem,
        &mut comm,
        &RunOptions { max_iters: 12, ..Default::default() },
    );
    println!("iter  objective        consensus error");
    for r in trace.records.iter().step_by(3) {
        println!("{:>4}  {:>14.6e}  {:>12.4e}", r.iter, r.objective, r.consensus_error);
    }

    // The consensus policy = the (shared) primal iterate.
    let learned = dcp::GaussianPolicy {
        theta: problem.mean_iterate(alg.thetas()),
        sigma: 0.0,
    };
    let zero = dcp::GaussianPolicy { theta: vec![0.0; 6], sigma: 0.0 };
    let r_learned = mean_reward(&learned, 50, &mut rng);
    let r_zero = mean_reward(&zero, 50, &mut rng);
    println!("\nlearned consensus policy θ = {:?}", learned.theta);
    println!("mean reward: learned {r_learned:.2}  vs  zero policy {r_zero:.2}");
    assert!(
        r_learned > r_zero,
        "learned policy should control the DCP better than no control"
    );
    assert!(trace.final_consensus_error() < 1e-4 * trace.records[0].consensus_error.max(1.0));
    println!("rl_consensus OK");
}
