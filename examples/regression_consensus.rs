//! Fig. 1(a,b)-style regression consensus: the full algorithm roster on a
//! synthetic regression task, with an ASCII convergence plot.
//!
//!     cargo run --release --example regression_consensus

use sddnewton::config::ExperimentConfig;
use sddnewton::harness::{report, run_experiment};

fn main() {
    let mut cfg = ExperimentConfig::preset("fig1-synthetic").unwrap();
    // Example-sized: smaller than the bench preset so it finishes in
    // seconds (the bench regenerates the full figure).
    cfg.nodes = 30;
    cfg.edges = 75;
    cfg.max_iters = 40;
    if let sddnewton::config::ProblemKind::SyntheticRegression { ref mut p, ref mut m_total, .. } =
        cfg.problem
    {
        *p = 20;
        *m_total = 3_000;
    }
    let res = run_experiment(&cfg);
    print!("{}", report::summary_table(&res));
    println!();
    println!("{}", report::ascii_plot(&res.traces, res.f_star, 72, 18));

    // The paper's headline: SDD-Newton converges in a fraction of the
    // iterations of the best first-order method.
    let iters = report::iters_table(&res, 1e-4);
    let sdd = iters[0].1;
    println!("iterations to 1e-4: {iters:?}");
    assert!(sdd.is_some(), "SDD-Newton must converge");
}
