//! Quickstart: distributed SDD-Newton on a small synthetic regression
//! consensus problem, in ~30 lines of user code.
//!
//!     cargo run --release --example quickstart

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::graph::generate;
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn main() {
    let mut rng = Pcg64::new(42);

    // 1. A network of 20 processors with 50 random links.
    let g = generate::random_connected(20, 50, &mut rng);

    // 2. A linear-regression consensus task split across them.
    let problem = datasets::synthetic_regression(20, 10, 2_000, 0.3, 0.05, &mut rng);
    let (_, f_star) = problem.centralized_optimum(60, 1e-10);

    // 3. The SDD-Newton algorithm: ε-approximate dual Newton directions
    //    from the distributed SDDM solver.
    let solver = sddm_for_graph(&g, 0.1, &mut rng);
    let backend = NativeBackend;
    let mut alg = SddNewton::new(&problem, &backend, &solver, StepSize::Fixed(1.0));

    // 4. Run and report.
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &problem,
        &mut comm,
        &RunOptions { max_iters: 20, ..Default::default() },
    );
    println!("iter  objective        consensus error   messages");
    for r in &trace.records {
        println!(
            "{:>4}  {:>14.8e}  {:>14.8e}  {:>10}",
            r.iter, r.objective, r.consensus_error, r.comm.messages
        );
    }
    let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
    println!("\ncentralized optimum {f_star:.8e}; final relative gap {gap:.2e}");
    assert!(gap < 1e-6, "quickstart did not converge");
    println!("quickstart OK");
}
