//! End-to-end system driver — proves all layers compose on a real
//! workload:
//!
//!   L1/L2: AOT JAX+Pallas artifacts loaded and executed via PJRT
//!          (falls back to native with a warning if `make artifacts`
//!          hasn't been run);
//!   L3:    graph + message-passing simulation + distributed SDDM solver
//!          + the full algorithm roster on the paper's Fig. 1(a,b)
//!          configuration (100 nodes / 250 edges / p = 80), logging the
//!          convergence curves;
//!   plus a true multi-threaded leader/worker run (std::thread + channels)
//!   of a distributed-averaging node program, demonstrating the node
//!   programs are honestly local.
//!
//!     cargo run --release --example end_to_end
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use sddnewton::config::{AlgoKind, ExperimentConfig};
use sddnewton::graph::generate;
use sddnewton::harness::{report, run_experiment};
use sddnewton::net::threaded::{run_threaded, NodeCtx};
use sddnewton::util::{Pcg64, Timer};

fn main() {
    let t_total = Timer::start();

    // ---- Phase 1: full Fig. 1(a,b) workload through the PJRT backend ----
    let mut cfg = ExperimentConfig::preset("fig1-synthetic").unwrap();
    cfg.backend = "pjrt".into();
    cfg.max_iters = 40;
    cfg.algorithms = vec![
        AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
        AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
        AlgoKind::Admm { beta: 1.0 },
        AlgoKind::Gradient { alpha: 0.01 },
    ];
    println!("phase 1: fig1-synthetic (n=100, m=250, p=80) via PJRT artifacts");
    let res = run_experiment(&cfg);
    print!("{}", report::summary_table(&res));
    println!("\nconvergence (log10 relative gap):");
    println!("{}", report::ascii_plot(&res.traces, res.f_star, 72, 16));
    std::fs::create_dir_all("results").ok();
    report::write_csv(&res, "results/end_to_end.csv").expect("write csv");
    println!("wrote results/end_to_end.csv  (backend used: {})", res.backend_used);
    let sdd_gap = (res.traces[0].final_objective() - res.f_star).abs() / res.f_star.abs();
    assert!(sdd_gap < 1e-6, "SDD-Newton gap {sdd_gap}");

    // ---- Phase 2: threaded leader/worker consensus on real threads ----
    println!("\nphase 2: threaded distributed averaging (real std::thread workers)");
    let mut rng = Pcg64::new(5);
    let g = generate::random_connected(12, 30, &mut rng);
    // Each node holds a private scalar; the program averages them with
    // only neighbor messages + one final all-reduce for verification.
    let values: Vec<f64> = (0..12).map(|i| (i * i) as f64).collect();
    let true_mean = values.iter().sum::<f64>() / 12.0;
    let vclone = values.clone();
    let out = run_threaded(&g, move |ctx: NodeCtx| {
        let mut x = vclone[ctx.id];
        // Round 0: learn neighbor degrees for Metropolis weights (the
        // symmetric weights preserve the average, so the consensus value
        // is the true mean).
        let my_deg = ctx.neighbors.len() as f64;
        ctx.send_all(&[my_deg]);
        let degs: std::collections::HashMap<usize, f64> =
            ctx.recv_round().into_iter().map(|(j, p)| (j, p[0])).collect();
        for _ in 0..400 {
            ctx.send_all(&[x]);
            let mut delta = 0.0;
            for (j, p) in ctx.recv_round() {
                delta += (p[0] - x) / (1.0 + my_deg.max(degs[&j]));
            }
            x += delta;
        }
        x
    });
    let worst = out
        .per_node
        .iter()
        .map(|v| (v - true_mean).abs())
        .fold(0.0f64, f64::max);
    println!("12 workers agreed on {:.6} (true mean {:.6}, worst dev {:.2e})",
        out.per_node[0], true_mean, worst);
    assert!(worst < 1e-6, "threaded consensus failed");

    println!("\nend_to_end OK in {:.1}s", t_total.secs());
}
