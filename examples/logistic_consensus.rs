//! Logistic-regression consensus (Fig. 1(c–f) style): MNIST-like blobs
//! with both L2 and smoothed-L1 regularization, using the PJRT backend
//! when artifacts are available (falling back to native).
//!
//!     cargo run --release --example logistic_consensus

use sddnewton::config::{AlgoKind, ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    for l1 in [false, true] {
        let name = if l1 { "fig1-mnist-l1" } else { "fig1-mnist-l2" };
        let mut cfg = ExperimentConfig::preset(name).unwrap();
        // Example-sized shrink (the bench runs the full preset).
        cfg.nodes = 6;
        cfg.edges = 12;
        cfg.max_iters = 15;
        cfg.problem = ProblemKind::MnistLike { p: 30, m_total: 600, l1, mu: 0.01 };
        cfg.algorithms = vec![
            AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
            AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
            AlgoKind::Admm { beta: 1.0 },
        ];
        let res = run_experiment(&cfg);
        println!("--- {} (reg = {})", cfg.name, if l1 { "smooth-L1" } else { "L2" });
        print!("{}", report::summary_table(&res));
        let gap = (res.traces[0].final_objective() - res.f_star).abs() / res.f_star.abs();
        assert!(gap < 1e-3, "SDD-Newton gap too large: {gap}");
        println!();
    }
    println!("logistic_consensus OK");
}
