//! Wire-truth suite for the host-aware hybrid transport.
//!
//! Three layers of the contract:
//!
//! 1. **Bit parity**: an algorithm driven over the hybrid transport —
//!    in-process channels between co-located ranks, real loopback TCP
//!    across "hosts" — must produce bit-for-bit the iterates,
//!    per-iteration objectives, and modeled comm ledger of the bulk
//!    `CommGraph`, channel `ShardExchange`, and pure-TCP transports,
//!    under *every* hostfile placement.
//! 2. **Split wire truth**: the comm ledger splits by placement. The
//!    intra-host and inter-host legs must sum back to the
//!    placement-agnostic totals, and observed socket payload bytes must
//!    equal `inter_floats × 8` exactly — co-located traffic never hits a
//!    socket, so a single-host placement ships zero payload bytes while a
//!    fully-split placement degenerates to the pure-TCP accounting.
//! 3. **Robustness**: a mesh connection dropped mid-run reconnects (the
//!    higher rank redials the lower rank's listener), replays the retained
//!    round window, and completes bit-identically — with the reconnect
//!    visible in the transport's counter, never in the results.
//!
//! The frame-codec and hostfile-parser unit suites live with their code in
//! `net::tcp::frame` and `net::hybrid`; these tests exercise real sockets.

use sddnewton::algorithms::ConsensusAlgorithm as _;
use sddnewton::coordinator::run_partitioned_baseline;
use sddnewton::coordinator::tcp::{run_leader_with_hosts, TcpLeader};
use sddnewton::graph::laplacian_csr;
use sddnewton::net::Exchange as _;
use sddnewton::harness::deploy::{run_hybrid_cross_transport, HybridParity, TcpJobSpec};
use sddnewton::harness::experiments::{make_inner_solver, make_sharded_algorithm};
use sddnewton::net::hybrid::{local_links, parse_hostfile, HybridExchange, Placement};
use sddnewton::net::partitioned::build_shard_plans;
use sddnewton::net::tcp::frame;
use sddnewton::net::tcp::WorkerNetConfig;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;
use std::sync::Arc;

/// Spec for one algorithm of the smoke preset on a loopback hybrid pool.
fn smoke_spec(algo: &str, workers: usize, iters: usize) -> TcpJobSpec {
    TcpJobSpec {
        experiment: "smoke".to_string(),
        config_path: None,
        algorithms: Some(algo.to_string()),
        seed: None,
        algo_index: 0,
        iters,
        workers,
        partitioning: "contiguous".to_string(),
        solver_seed: 0x51D0,
        hostfile: None,
        stale_tau: 0,
    }
}

/// Run one spec in thread mode under the given hostfile text and assert
/// the full parity + split-accounting contract, returning the verdict for
/// placement-specific follow-up assertions.
fn assert_hybrid_parity(spec: TcpJobSpec, hostfile: &str) -> HybridParity {
    let placement = parse_hostfile(hostfile).expect("test hostfile must parse");
    let parity = run_hybrid_cross_transport(&spec, &placement, "127.0.0.1:0", None)
        .unwrap_or_else(|e| panic!("hybrid run failed for {spec:?} under {hostfile:?}: {e}"));
    assert!(
        parity.thetas_match_bulk,
        "{}: hybrid iterate drifted from the bulk reference under {hostfile:?}",
        parity.algorithm
    );
    assert!(
        parity.thetas_match_shard,
        "{}: hybrid iterate drifted from the in-process shard reference under {hostfile:?}",
        parity.algorithm
    );
    assert!(
        parity.objectives_match,
        "{}: per-iteration objectives drifted across transports under {hostfile:?}",
        parity.algorithm
    );
    assert!(parity.ledger_ok, "{}: modeled comm ledger drifted", parity.algorithm);
    // Placement-agnostic totals: the hybrid pool must ship exactly what
    // the wire model and the channel transport ship, however its traffic
    // splits between channels and sockets.
    assert_eq!(
        parity.hybrid.cross_messages, parity.modeled_cross,
        "{}: payload count drifted from the wire model",
        parity.algorithm
    );
    assert_eq!(
        parity.hybrid.cross_messages, parity.shard.cross_messages,
        "{}: payload count drifted from the channel transport",
        parity.algorithm
    );
    assert_eq!(
        parity.hybrid.cross_floats, parity.shard.cross_floats,
        "{}: float count drifted from the channel transport",
        parity.algorithm
    );
    // The split: intra + inter must sum back to the totals, and socket
    // bytes must cover exactly the inter-host leg.
    assert_eq!(
        parity.hybrid.intra_cross + parity.hybrid.inter_cross,
        parity.hybrid.cross_messages,
        "{}: intra/inter payload split does not sum to the total",
        parity.algorithm
    );
    assert_eq!(
        parity.hybrid.intra_floats + parity.hybrid.inter_floats,
        parity.hybrid.cross_floats,
        "{}: intra/inter float split does not sum to the total",
        parity.algorithm
    );
    assert_eq!(
        parity.hybrid.payload_bytes,
        parity.hybrid.inter_floats * 8,
        "{}: observed socket payload bytes are not inter_floats × 8",
        parity.algorithm
    );
    assert_eq!(
        parity.hybrid.header_bytes % 16,
        0,
        "{}: header overhead is not a whole number of frame headers",
        parity.algorithm
    );
    assert!(parity.ok(), "{}: parity verdict not ok under {hostfile:?}", parity.algorithm);
    parity
}

#[test]
fn sdd_newton_hybrid_k2_fully_split_is_all_inter_host() {
    let parity = assert_hybrid_parity(smoke_spec("sdd", 2, 3), "alpha slots=1\nbeta slots=1\n");
    // One rank per host: every boundary payload crosses hosts.
    assert_eq!(parity.hybrid.intra_cross, 0, "no co-located pair exists");
    assert!(parity.hybrid.inter_cross > 0, "a split pool must ship socket traffic");
    assert!(parity.hybrid.payload_bytes > 0, "socket traffic must account payload bytes");
}

#[test]
fn sdd_newton_hybrid_k2_single_host_ships_zero_socket_bytes() {
    let parity = assert_hybrid_parity(smoke_spec("sdd", 2, 3), "alpha slots=2\n");
    // Both ranks co-located: everything rides the channel path.
    assert!(parity.hybrid.intra_cross > 0, "a multi-worker pool must ship boundary traffic");
    assert_eq!(parity.hybrid.inter_cross, 0, "no cross-host pair exists");
    assert_eq!(parity.hybrid.payload_bytes, 0, "co-located traffic must never hit a socket");
}

#[test]
fn sdd_newton_hybrid_k4_two_hosts_splits_both_ways() {
    let parity =
        assert_hybrid_parity(smoke_spec("sdd", 4, 3), "alpha slots=2\nbeta slots=2\n");
    // Contiguous shards 0,1 on alpha and 2,3 on beta: the 0–1 and 2–3
    // boundaries are intra-host, the 1–2 boundary is inter-host.
    assert!(parity.hybrid.intra_cross > 0, "co-located boundaries must ride channels");
    assert!(parity.hybrid.inter_cross > 0, "the cross-host boundary must ride sockets");
}

#[test]
fn sdd_newton_hybrid_k4_three_hosts() {
    assert_hybrid_parity(
        smoke_spec("sdd", 4, 3),
        "alpha slots=1\nbeta slots=2\ngamma slots=1\n",
    );
}

#[test]
fn admm_hybrid_k2_fully_split() {
    assert_hybrid_parity(smoke_spec("admm", 2, 3), "alpha slots=1\nbeta slots=1\n");
}

#[test]
fn admm_hybrid_k4_two_hosts() {
    assert_hybrid_parity(smoke_spec("admm", 4, 3), "alpha slots=2\nbeta slots=2\n");
}

#[test]
fn gradient_hybrid_round_robin_two_hosts() {
    // Round-robin maximizes the cut — every neighbor is a remote shard,
    // so both legs of the split carry near-balanced traffic.
    let mut spec = smoke_spec("grad", 4, 3);
    spec.partitioning = "round_robin".to_string();
    let parity = assert_hybrid_parity(spec, "alpha slots=2\nbeta slots=2\n");
    assert!(parity.hybrid.intra_cross > 0);
    assert!(parity.hybrid.inter_cross > 0);
}

/// A mesh connection killed mid-run must reconnect (higher rank redials
/// the lower rank's listener), replay the retained rounds, and finish
/// bit-identically to the in-process shard reference — with the repair
/// visible only in the transport's reconnect counter.
#[test]
fn dropped_mesh_connection_reconnects_and_matches_bit_for_bit() {
    let spec = smoke_spec("sdd", 2, 4);
    let placement = parse_hostfile("alpha slots=1\nbeta slots=1\n").expect("hostfile");
    let job = spec.build().expect("spec must build");
    let iters = spec.iters;

    // In-process shard reference on the same deterministic solver seed.
    let backend = NativeBackend;
    let solver = make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
    let solver_ref = solver.as_deref();
    let shard = run_partitioned_baseline(&job.problem, &job.g, &job.part, iters, &|owned| {
        make_sharded_algorithm(&job.kind, &job.problem, &job.g, &backend, solver_ref, owned)
    });

    let leader = TcpLeader::bind("127.0.0.1:0", 2).expect("bind leader");
    let addr = leader.addr().expect("leader addr").to_string();
    let owned_of: Vec<Vec<usize>> = (0..2).map(|w| job.part.nodes_of(w)).collect();
    let hosts: Vec<String> = vec!["alpha".to_string(), "beta".to_string()];

    let mut host_links = Vec::new();
    for host in ["alpha", "beta"] {
        for link in local_links(&placement, host) {
            host_links.push((host, link));
        }
    }
    let (led, reconnects) = std::thread::scope(|scope| {
        let mut workers = Vec::new();
        for (host, link) in host_links {
            let spec = spec.clone();
            let placement = &placement;
            let addr = addr.clone();
            workers.push(scope.spawn(move || -> Result<u64, String> {
                let rank = link.rank();
                let job = spec.build()?;
                let backend = NativeBackend;
                let solver =
                    make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
                let solver_ref = solver.as_deref();
                let lap = Arc::new(laplacian_csr(&job.g));
                let plan = build_shard_plans(&job.g, &job.part).swap_remove(rank);
                let net = WorkerNetConfig::from_env(rank, 2, &addr);
                let mut exch = HybridExchange::connect(
                    &net,
                    placement,
                    link,
                    job.g.n,
                    job.g.m(),
                    lap,
                    plan,
                )
                .map_err(|e| format!("host {host} connect: {e}"))?;
                let mut alg = make_sharded_algorithm(
                    &job.kind,
                    &job.problem,
                    &job.g,
                    &backend,
                    solver_ref,
                    exch.owned().to_vec(),
                );
                for it in 0..spec.iters {
                    // Kill the only mesh connection from the low side,
                    // mid-run: rank 1 must redial rank 0's listener and
                    // both sides must replay.
                    if rank == 0 && it == 2 {
                        exch.drop_mesh_connection(1);
                    }
                    alg.step(&job.problem, &mut exch);
                    exch.send_metrics(it as u64, alg.thetas())
                        .map_err(|e| format!("host {host} metrics: {e}"))?;
                }
                Ok(exch.reconnects())
            }));
        }
        let led = run_leader_with_hosts(
            leader,
            &job.problem,
            owned_of,
            iters,
            frame::default_timeout(),
            Some(&hosts),
        );
        let mut reconnects = 0u64;
        for w in workers {
            reconnects += w
                .join()
                .expect("worker thread must not panic")
                .unwrap_or_else(|e| panic!("worker failed: {e}"));
        }
        (led, reconnects)
    });

    let run = led.expect("leader must complete despite the dropped connection");
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
    assert_eq!(
        bits(&run.thetas),
        bits(&shard.thetas),
        "reconnect+replay changed the iterate"
    );
    assert!(
        reconnects >= 1,
        "the dropped mesh connection was never repaired (reconnects = {reconnects})"
    );
    // First-transmission accounting: replayed frames must not be
    // double-counted, so the byte invariant still holds exactly.
    assert_eq!(run.payload_bytes, run.inter_floats * 8);
    assert_eq!(run.header_bytes % 16, 0);
}

/// Full process deployment through the CLI: one `worker --host H` process
/// per hostfile host over loopback, and the parity table must report ok
/// (exit zero, split columns present, no DRIFT).
#[test]
fn partitioned_cli_hybrid_transport_end_to_end() {
    let path = std::env::temp_dir().join(format!("sddn_hostfile_{}.txt", std::process::id()));
    std::fs::write(&path, "hostA slots=2\nhostB slots=2\n").expect("write hostfile");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_sddnewton"))
        .args([
            "partitioned",
            "--transport",
            "hybrid",
            "--hostfile",
            path.to_str().expect("utf8 temp path"),
            "--experiment",
            "smoke",
            "--iters",
            "2",
            "--workers",
            "4",
            "--algorithms",
            "sdd,admm",
        ])
        .output()
        .expect("sddnewton binary should run");
    let _ = std::fs::remove_file(&path);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "exit nonzero\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("hostA[2] hostB[2]"), "missing host roster line:\n{stdout}");
    assert!(stdout.contains("intra"), "missing intra split column:\n{stdout}");
    assert!(stdout.contains("inter"), "missing inter split column:\n{stdout}");
    assert!(!stdout.contains("DRIFT"), "hybrid parity table reported drift:\n{stdout}");
    for name in ["SDD-Newton", "Distributed ADMM"] {
        let row = stdout
            .lines()
            .find(|l| l.contains(name))
            .unwrap_or_else(|| panic!("missing row for {name}:\n{stdout}"));
        assert!(row.contains("ok"), "{name} not ok:\n{row}");
    }
}
