//! Wire-truth suite: what the partitioned transport actually puts on the
//! channels must equal what the communication model says it should.
//!
//! The paper's comparison (Fig. 2(c)) rests on modeled per-node message
//! counts; the deployment pays real cross-worker traffic. With
//! plan-driven sparse shipping the two are linked by a structural model
//! (`harness::experiments::modeled_cross_messages`, built on
//! `net::partitioned::plan_cross_rows`): this suite asserts real
//! `ShardExchange::cross_messages` == model for **every** `AlgoKind`
//! across contiguous/round-robin/BFS partitionings and k ∈ {1, 2, 5} —
//! the regression net for the ADMM stage-count over-shipping bug — plus
//! the overlay-plan properties that let `SquaredChain` levels ride the
//! transport, a barrier-free reorder-buffer stress test, and the
//! reorder-buffer high-water contract (legitimate skew passes; a racer
//! beyond the bound trips a loud panic instead of buffering unboundedly).

use sddnewton::algorithms::admm::sweep_stages;
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::Partition;
use sddnewton::graph::laplacian::adjacency_csr;
use sddnewton::graph::{generate, laplacian_csr, Graph};
use sddnewton::harness::experiments::{modeled_cross_messages, run_cross_transport};
use sddnewton::net::partitioned::{
    build_shard_plans, derive_exchange_plan, plan_cross_rows, run_reducer, ReduceMsg,
    ShardExchange, WireMsg,
};
use sddnewton::net::Exchange;
use sddnewton::sddm::{ChainOptions, SquaredChain};
use sddnewton::util::Pcg64;
use std::sync::mpsc::channel;
use std::sync::Mutex;

/// The three partitionings the wire suite sweeps for a worker count.
fn partitionings(g: &Graph, k: usize) -> [Partition; 3] {
    [
        Partition::contiguous(g.n, k),
        Partition::round_robin(g.n, k),
        Partition::bfs_blocks(g, k),
    ]
}

/// The acceptance property of this PR: for all 9 `AlgoKind`s — including
/// the pipelined ADMM wavefront and the comm-avoiding local-step Newton —
/// the real cross-worker channel payloads equal the modeled ledger mapped
/// through the partition — no algorithm over- or under-ships relative to
/// its communication model (ADMM used to over-ship the full halo once per
/// sweep stage). Iterates stay bit-for-bit equal on the side.
#[test]
fn real_cross_messages_equal_modeled_ledger_for_all_algokinds() {
    let mut rng = Pcg64::new(9200);
    let n = 11;
    let g = generate::random_connected(n, 24, &mut rng);
    let prob =
        sddnewton::problems::datasets::synthetic_regression(n, 3, 165, 0.2, 0.05, &mut rng);
    let iters = 3;
    let kinds = [
        AlgoKind::SddNewton { eps: 1e-5, alpha: 1.0 },
        AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
        AlgoKind::ExactNewton { alpha: 1.0 },
        AlgoKind::Admm { beta: 1.0 },
        AlgoKind::AdmmPipelined { beta: 1.0 },
        AlgoKind::Gradient { alpha: 0.01 },
        AlgoKind::Averaging { beta: 0.005 },
        AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 },
        AlgoKind::LocalNewton { eta: 0.5, local_steps: 3, comm_rounds: 2 },
    ];
    for kind in &kinds {
        for k in [1usize, 2, 5] {
            for part in partitionings(&g, k) {
                let (trace, out) = run_cross_transport(kind, &prob, &g, &part, iters, &mut rng);
                let tag = format!("{} k={k}", trace.algorithm);
                let bulk = trace.records.last().map(|r| r.comm).unwrap();
                let model = modeled_cross_messages(kind, &g, &part, iters, &bulk);
                assert_eq!(
                    out.cross_messages, model,
                    "{tag}: real wire traffic drifted from the modeled ledger"
                );
                assert_eq!(out.thetas, trace.final_thetas, "{tag}: iterate drifted");
                if k > 1 {
                    assert!(
                        out.cross_floats >= out.cross_messages,
                        "{tag}: floats must cover payload rows"
                    );
                }
            }
        }
    }
}

/// The ADMM regression pinned down: one iteration ships exactly `2B`
/// boundary rows (full refresh + every node's update crossing once) —
/// not `(S+1)·B` as whole-halo shipping per sweep stage did.
#[test]
fn admm_ships_2b_per_iteration_not_stage_count_times_b() {
    let mut rng = Pcg64::new(9201);
    let g = generate::random_connected(10, 22, &mut rng);
    let prob =
        sddnewton::problems::datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
    let part = Partition::round_robin(10, 3);
    let adj = adjacency_csr(&g);
    let b = plan_cross_rows(&adj, &part.assignment, None);
    let stages = sweep_stages(&g).iter().max().unwrap() + 1;
    assert!(stages >= 2, "need a multi-stage sweep to expose over-shipping");
    assert!(b > 0, "round-robin shards must have a boundary");

    let kind = AlgoKind::Admm { beta: 1.0 };
    let (_, out) = run_cross_transport(&kind, &prob, &g, &part, 2, &mut rng);
    let per_iter_real = out.records[0].cross_messages;
    assert_eq!(per_iter_real, 2 * b, "one ADMM iteration must ship exactly 2B rows");
    assert_eq!(
        out.records[1].cross_messages - out.records[0].cross_messages,
        2 * b,
        "every subsequent iteration ships the same 2B"
    );
    let old_over_shipping = (stages as u64 + 1) * b;
    assert!(
        per_iter_real < old_over_shipping,
        "sparse stage shipping must beat whole-halo-per-stage ({per_iter_real} vs \
         {old_over_shipping})"
    );
}

/// Overlay-plan property: for random graphs, every `SquaredChain` level's
/// CSR support is covered by its derived overlay plan on every
/// partitioning, and the k per-worker plans are mutually consistent
/// (send/recv mirror each other).
#[test]
fn squared_chain_levels_are_covered_by_their_overlay_plans() {
    for seed in [9301u64, 9302, 9303] {
        let mut rng = Pcg64::new(seed);
        let n = 10 + rng.next_below(10) as usize;
        let m = n + rng.next_below(2 * n as u64) as usize;
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let sq = SquaredChain::build(&l, &ChainOptions::default(), 0.0, &mut rng).unwrap();
        for k in [2usize, 3, 5] {
            for part in partitionings(&g, k) {
                for level in &sq.levels {
                    let plans: Vec<_> = (0..k)
                        .map(|w| derive_exchange_plan("level", level, &part.assignment, w))
                        .collect();
                    for (w, plan) in plans.iter().enumerate() {
                        // Support coverage: every column an owned row
                        // reads is available after one plan round.
                        for v in 0..n {
                            if part.assignment[v] != w {
                                continue;
                            }
                            for kk in level.indptr[v]..level.indptr[v + 1] {
                                assert!(
                                    plan.covered[level.indices[kk]],
                                    "seed {seed} k={k}: worker {w} misses support of row {v}"
                                );
                            }
                        }
                        // Mutual consistency: send[w→q] == recv[q←w].
                        for (peer, rows) in &plan.send {
                            let back = plans[*peer]
                                .recv
                                .iter()
                                .find(|(from, _)| *from == w)
                                .map(|(_, ns)| ns.clone())
                                .unwrap_or_default();
                            assert_eq!(
                                &back, rows,
                                "seed {seed} k={k}: asymmetric plan {w} → {peer}"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// An operator escaping the graph halo *without* a registered overlay
/// plan still panics with the existing diagnostic — overlay shipping is
/// an explicit opt-in, not a silent widening.
#[test]
#[should_panic(expected = "escapes the halo")]
fn unregistered_overlay_operator_panics_with_the_halo_diagnostic() {
    let g = generate::path(8);
    let lap = laplacian_csr(&g);
    let two_hop = lap.matmul(&lap); // support beyond the edge set
    let part = Partition::contiguous(8, 2);
    let plan0 = build_shard_plans(&g, &part).swap_remove(0);

    let (tx0, rx0) = channel::<WireMsg>();
    let (tx1, _rx1) = channel::<WireMsg>();
    let (red_tx, _red_rx) = channel::<ReduceMsg>();
    let (_down_tx, down_rx) = channel::<Vec<f64>>();
    let mut ex = ShardExchange::new(&g, &lap, 2, plan0, vec![tx0, tx1], rx0, red_tx, down_rx);
    let ln = ex.local_n();
    let x = vec![0.0; ln];
    let mut out = vec![0.0; ln];
    // Plan validation runs before any channel traffic, so this panics
    // immediately instead of deadlocking on a phantom peer.
    ex.exchange_apply(&two_hop, 1, &x, 1, &mut out);
}

/// Reorder-buffer stress: a barrier-free schedule where first worker 0,
/// then worker 1 races N sparse rounds ahead of the sleeping others. The
/// reorder buffer must neither reorder nor drop nor double-count the
/// sparse payloads: every worker's per-round outputs match a bulk
/// reference bit for bit, and the summed channel traffic equals the plan
/// model exactly.
#[test]
fn racing_workers_cannot_corrupt_sparse_rounds() {
    let mut rng = Pcg64::new(9400);
    let n = 12;
    let g = generate::random_connected(n, 26, &mut rng);
    let adj = adjacency_csr(&g);
    let lap = laplacian_csr(&g);
    let part = Partition::round_robin(n, 3);
    let k = part.k;
    let rounds = 16usize;

    let masks: Vec<Vec<bool>> = (0..k)
        .map(|w| part.assignment.iter().map(|&a| a == w).collect())
        .collect();
    let all_mask = vec![true; n];
    let base = |u: usize| (u as f64 + 1.0) * 0.25;
    let upd = |u: usize, t: usize| base(u) + (t as f64 + 1.0) * 0.001 * (u as f64 + 1.0);

    // Bulk reference: the same update schedule on co-located state.
    let mut x_ref: Vec<f64> = (0..n).map(base).collect();
    let mut ref_outs: Vec<Vec<f64>> = Vec::new();
    {
        let mut comm = sddnewton::net::CommGraph::new(&g);
        let mut out = vec![0.0; n];
        comm.exchange_apply(&adj, 1, &x_ref, 1, &mut out);
        ref_outs.push(out.clone());
        for phase in 0..2 {
            for t in 0..rounds {
                for u in 0..n {
                    if masks[phase][u] {
                        x_ref[u] = upd(u, t + phase * rounds);
                    }
                }
                comm.exchange_apply(&adj, 1, &x_ref, 1, &mut out);
                ref_outs.push(out.clone());
            }
        }
        comm.exchange_apply(&adj, 1, &x_ref, 1, &mut out);
        ref_outs.push(out.clone());
    }

    // Partitioned run, adversarially scheduled via sleeps (no barriers).
    let plans = build_shard_plans(&g, &part);
    let owned_of: Vec<Vec<usize>> = plans.iter().map(|p| p.owned.clone()).collect();
    let mut wire_tx = Vec::new();
    let mut wire_rx = Vec::new();
    for _ in 0..k {
        let (tx, rx) = channel::<WireMsg>();
        wire_tx.push(tx);
        wire_rx.push(Some(rx));
    }
    let (red_tx, red_rx) = channel::<ReduceMsg>();
    let mut red_out_tx = Vec::new();
    let mut red_out_rx = Vec::new();
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<f64>>();
        red_out_tx.push(tx);
        red_out_rx.push(Some(rx));
    }
    let results = Mutex::new(vec![(Vec::<Vec<f64>>::new(), 0u64); k]);
    std::thread::scope(|scope| {
        {
            let owned_of = owned_of.clone();
            let txs = red_out_tx.clone();
            scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
        }
        for (wid, plan) in plans.into_iter().enumerate() {
            let peer_txs = wire_tx.clone();
            let inbox = wire_rx[wid].take().unwrap();
            let from_red = red_out_rx[wid].take().unwrap();
            let red = red_tx.clone();
            let (g, adj, lap, masks, all_mask, results) =
                (&g, &adj, &lap, &masks, &all_mask, &results);
            scope.spawn(move || {
                let mut ex = ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                let owned = ex.owned().to_vec();
                let ln = owned.len();
                let mut xl: Vec<f64> = owned.iter().map(|&u| base(u)).collect();
                let mut out = vec![0.0; ln];
                let mut outs = Vec::new();
                ex.exchange_apply_fresh(adj, all_mask, 1, &xl, 1, &mut out);
                outs.push(out.clone());
                for phase in 0..2 {
                    // Workers behind the racing one start late — their
                    // inboxes already hold the racer's future rounds.
                    if wid != phase {
                        std::thread::sleep(std::time::Duration::from_millis(
                            30 * (wid as u64 + 1),
                        ));
                    }
                    for t in 0..rounds {
                        for (li, &u) in owned.iter().enumerate() {
                            if masks[phase][u] {
                                xl[li] = upd(u, t + phase * rounds);
                            }
                        }
                        ex.exchange_apply_fresh(adj, &masks[phase], 1, &xl, 1, &mut out);
                        outs.push(out.clone());
                    }
                }
                ex.exchange_apply_fresh(adj, all_mask, 1, &xl, 1, &mut out);
                outs.push(out.clone());
                results.lock().unwrap()[wid] = (outs, ex.cross_messages());
            });
        }
        drop(red_tx);
        drop(red_out_tx);
    });

    let results = results.into_inner().unwrap();
    let mut cross_total = 0u64;
    for (wid, (outs, cross)) in results.iter().enumerate() {
        assert_eq!(outs.len(), ref_outs.len(), "worker {wid} lost a round");
        for (r, (got, want)) in outs.iter().zip(&ref_outs).enumerate() {
            for (li, &u) in owned_of[wid].iter().enumerate() {
                assert_eq!(got[li], want[u], "worker {wid} round {r} row {u} corrupted");
            }
        }
        cross_total += cross;
    }
    let expected = 2 * plan_cross_rows(&adj, &part.assignment, None)
        + rounds as u64 * plan_cross_rows(&adj, &part.assignment, Some(masks[0].as_slice()))
        + rounds as u64 * plan_cross_rows(&adj, &part.assignment, Some(masks[1].as_slice()));
    assert_eq!(cross_total, expected, "sparse payloads were dropped or double-counted");
}

/// Fixture where two workers can race masked rounds arbitrarily far ahead
/// of a third. Workers 1 (node 2) and 2 (node 3) ship fresh rows that only
/// worker 0 consumes — nodes 2 and 3 are not adjacent, so the racers'
/// masked receive sets are empty and nothing throttles them. Worker 0
/// needs both racers' rows every round, so when worker 1 sleeps, worker
/// 2's future rounds pile into worker 0's reorder buffer.
fn skew_fixture() -> (Graph, Partition) {
    let g = Graph::from_edges(4, vec![(0, 1), (0, 2), (0, 3), (1, 2), (1, 3)]);
    let part = Partition { assignment: vec![0, 0, 1, 2], k: 3 };
    (g, part)
}

/// Drive the skew fixture: one initial full exchange, then `races` rounds
/// masked to the racers' nodes {2, 3}, with worker 1 sleeping after the
/// full round so worker 2 runs ahead. `bound` is installed as worker 0's
/// reorder high-water mark. Panics are caught per worker so a deliberate
/// high-water trip does not tear down sibling threads mid-scope; returns
/// each worker's panic message (if any) and worker 0's final outputs.
fn run_skewed_rounds(bound: Option<u64>, races: usize) -> (Vec<Option<String>>, Vec<f64>) {
    let (g, part) = skew_fixture();
    let adj = adjacency_csr(&g);
    let lap = laplacian_csr(&g);
    let k = part.k;
    let n = g.n;
    let mask: Vec<bool> = vec![false, false, true, true];
    let all_mask = vec![true; n];
    let base = |u: usize| (u as f64 + 1.0) * 0.5;
    let upd = |u: usize, t: usize| base(u) + (t as f64 + 1.0) * 0.01 * (u as f64 + 1.0);

    let plans = build_shard_plans(&g, &part);
    let owned_of: Vec<Vec<usize>> = plans.iter().map(|p| p.owned.clone()).collect();
    let mut wire_tx = Vec::new();
    let mut wire_rx = Vec::new();
    for _ in 0..k {
        let (tx, rx) = channel::<WireMsg>();
        wire_tx.push(tx);
        wire_rx.push(Some(rx));
    }
    let (red_tx, red_rx) = channel::<ReduceMsg>();
    let mut red_out_tx = Vec::new();
    let mut red_out_rx = Vec::new();
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<f64>>();
        red_out_tx.push(tx);
        red_out_rx.push(Some(rx));
    }
    let panics = Mutex::new(vec![None::<String>; k]);
    let final_out = Mutex::new(Vec::<f64>::new());
    std::thread::scope(|scope| {
        {
            let owned_of = owned_of.clone();
            let txs = red_out_tx.clone();
            scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
        }
        for (wid, plan) in plans.into_iter().enumerate() {
            let peer_txs = wire_tx.clone();
            let inbox = wire_rx[wid].take().unwrap();
            let from_red = red_out_rx[wid].take().unwrap();
            let red = red_tx.clone();
            let (g, adj, lap, mask, all_mask, panics, final_out) =
                (&g, &adj, &lap, &mask, &all_mask, &panics, &final_out);
            scope.spawn(move || {
                let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    let mut ex =
                        ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                    if wid == 0 {
                        if let Some(b) = bound {
                            ex.set_reorder_high_water(b);
                        }
                    }
                    let owned = ex.owned().to_vec();
                    let mut xl: Vec<f64> = owned.iter().map(|&u| base(u)).collect();
                    let mut out = vec![0.0; owned.len()];
                    ex.exchange_apply_fresh(adj, all_mask, 1, &xl, 1, &mut out);
                    if wid == 1 {
                        // The slow racer: by the time it ships round 1,
                        // worker 2 has shipped every masked round into
                        // worker 0's inbox.
                        std::thread::sleep(std::time::Duration::from_millis(200));
                    }
                    for t in 0..races {
                        for (li, &u) in owned.iter().enumerate() {
                            if mask[u] {
                                xl[li] = upd(u, t);
                            }
                        }
                        ex.exchange_apply_fresh(adj, mask, 1, &xl, 1, &mut out);
                    }
                    if wid == 0 {
                        *final_out.lock().unwrap() = out;
                    }
                }));
                if let Err(payload) = run {
                    let msg = payload
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "opaque panic payload".to_string());
                    panics.lock().unwrap()[wid] = Some(msg);
                }
            });
        }
        drop(red_tx);
        drop(red_out_tx);
    });
    (panics.into_inner().unwrap(), final_out.into_inner().unwrap())
}

/// A high-water mark that admits the worst legitimate skew must not fire:
/// every worker completes, and worker 0's last masked round reflects both
/// racers' final values exactly.
#[test]
fn reorder_high_water_within_bound_tolerates_racing_workers() {
    let races = 6;
    let (panics, out) = run_skewed_rounds(Some(races as u64 + 1), races);
    for (wid, p) in panics.iter().enumerate() {
        assert!(p.is_none(), "worker {wid} panicked under a generous bound: {p:?}");
    }
    let base = |u: usize| (u as f64 + 1.0) * 0.5;
    let upd = |u: usize, t: usize| base(u) + (t as f64 + 1.0) * 0.01 * (u as f64 + 1.0);
    // Worker 0 owns nodes 0 and 1; each neighbors 2 and 3 plus the other
    // owned node. Sum order matches the CSR row sweep (ascending column).
    let want0 = base(1) + upd(2, races - 1) + upd(3, races - 1);
    let want1 = base(0) + upd(2, races - 1) + upd(3, races - 1);
    assert_eq!(out, vec![want0, want1], "stale or reordered halo rows leaked into the matvec");
}

/// A racer more than `bound + 1` rounds ahead of the round worker 0 is
/// still assembling must trip the reorder buffer's high-water panic — the
/// loud-failure contract of `SDDN_REORDER_BOUND` — rather than buffering
/// unboundedly.
#[test]
fn reorder_high_water_overflow_fails_loudly() {
    let (panics, _) = run_skewed_rounds(Some(1), 6);
    let msg = panics[0]
        .as_deref()
        .expect("worker 0 must trip the high-water bound when a racer runs 6 rounds ahead");
    assert!(
        msg.contains("reorder buffer high-water exceeded"),
        "expected the high-water panic, got: {msg}"
    );
}
