//! Property tests for the scoped-thread parallel substrate (`par`):
//! every parallel kernel must be **bit-for-bit** identical to its serial
//! sweep — row blocks are owned by exactly one thread and each output
//! element is produced by the same scalar operations in the same order.

use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::linalg::Csr;
use sddnewton::net::CommStats;
use sddnewton::sddm::{Chain, ChainOptions, SddmSolver, SolverOptions};
use sddnewton::util::Pcg64;

fn random_csr(rows: usize, cols: usize, nnz: usize, rng: &mut Pcg64) -> Csr {
    let mut trips = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        trips.push((
            rng.next_below(rows as u64) as usize,
            rng.next_below(cols as u64) as usize,
            rng.normal(),
        ));
    }
    Csr::from_triplets(rows, cols, &trips)
}

#[test]
fn prop_parallel_matvec_bit_for_bit() {
    for seed in 0..40u64 {
        let mut rng = Pcg64::new(seed);
        let rows = 1 + rng.next_below(300) as usize;
        let cols = 1 + rng.next_below(300) as usize;
        let nnz = 1 + rng.next_below((rows * cols / 2 + 1) as u64) as usize;
        let a = random_csr(rows, cols, nnz, &mut rng);
        let x = rng.normal_vec(cols);
        let mut serial = vec![0.0; rows];
        a.matvec_into_threads(&x, &mut serial, 1);
        for threads in [2usize, 3, 4, 7, 16] {
            let mut par = vec![0.0; rows];
            a.matvec_into_threads(&x, &mut par, threads);
            assert_eq!(serial, par, "seed={seed} threads={threads}");
        }
    }
}

#[test]
fn prop_parallel_matvec_multi_bit_for_bit() {
    for seed in 100..130u64 {
        let mut rng = Pcg64::new(seed);
        let rows = 1 + rng.next_below(200) as usize;
        let cols = 1 + rng.next_below(200) as usize;
        let w = 1 + rng.next_below(9) as usize;
        let nnz = 1 + rng.next_below((rows * cols / 2 + 1) as u64) as usize;
        let a = random_csr(rows, cols, nnz, &mut rng);
        let x = rng.normal_vec(cols * w);
        let mut serial = vec![0.0; rows * w];
        a.matvec_multi_into_threads(&x, w, &mut serial, 1);
        for threads in [2usize, 4, 5, 11] {
            let mut par = vec![0.0; rows * w];
            a.matvec_multi_into_threads(&x, w, &mut par, threads);
            assert_eq!(serial, par, "seed={seed} w={w} threads={threads}");
        }
    }
}

#[test]
fn prop_auto_matvec_matches_explicit_serial() {
    // The auto (global-budget, work-thresholded) entry point must agree
    // with the forced-serial and forced-parallel paths.
    let mut rng = Pcg64::new(77);
    let g = generate::random_connected(120, 360, &mut rng);
    let l = laplacian_csr(&g);
    let x = rng.normal_vec(120);
    let auto = l.matvec(&x);
    let mut serial = vec![0.0; 120];
    l.matvec_into_threads(&x, &mut serial, 1);
    assert_eq!(auto, serial);
}

#[test]
fn sddm_crude_solve_is_thread_count_invariant() {
    // A 20k-node chain at w=8 puts both the matvec (nnz·w ≈ 480k ops)
    // and the per-level row sweeps (n·w = 160k ops) over the
    // MIN_WORK_PER_THREAD bar, so the parallel paths genuinely engage
    // when the global budget allows; depth is pinned to keep the
    // implicit X^{2^i} round count debug-fast.
    let mut rng = Pcg64::new(2024);
    let n = 20_000;
    let w = 8;
    let g = generate::path(n);
    let l = laplacian_csr(&g);
    let chain =
        Chain::build(&l, &ChainOptions { depth: Some(2), ..Default::default() }, &mut rng)
            .unwrap();
    let solver = SddmSolver::new(chain, SolverOptions::default());
    let mut b = vec![0.0; n * w];
    for j in 0..w {
        let z = rng.normal_vec(n);
        let col = l.matvec(&z);
        for i in 0..n {
            b[i * w + j] = col[i];
        }
    }
    let crude_with = |threads: usize| {
        sddnewton::par::set_threads(threads);
        let mut stats = CommStats::default();
        let x = solver.crude_solve(&b, w, &mut stats);
        sddnewton::par::set_threads(0);
        (x, stats)
    };
    let (x1, stats1) = crude_with(1);
    for threads in [2usize, 4] {
        let (xt, statst) = crude_with(threads);
        assert_eq!(x1, xt, "threads={threads}: solution drifted");
        assert_eq!(stats1, statst, "threads={threads}: message accounting drifted");
    }
}

#[test]
fn native_backend_batches_are_thread_count_invariant() {
    use sddnewton::problems::datasets;
    use sddnewton::runtime::{LocalBackend, NativeBackend};
    let mut rng = Pcg64::new(31);
    // n·p·p = 256·32·32 clears MIN_WORK_PER_THREAD so the per-node
    // fan-out genuinely engages when the budget allows.
    let (n, p) = (256usize, 32usize);
    let prob = datasets::synthetic_regression(n, p, 8 * n, 0.2, 0.05, &mut rng);
    let v = rng.normal_vec(n * p);
    let run_with = |threads: usize| {
        sddnewton::par::set_threads(threads);
        let mut out = vec![0.0; n * p];
        NativeBackend.primal_recover_all(&prob, &v, &mut out);
        let z = rng.clone().normal_vec(n * p);
        let mut hz = vec![0.0; n * p];
        NativeBackend.hess_apply_all(&prob, &out, &z, &mut hz);
        sddnewton::par::set_threads(0);
        (out, hz)
    };
    let (y1, h1) = run_with(1);
    let (y4, h4) = run_with(4);
    assert_eq!(y1, y4);
    assert_eq!(h1, h4);
}
