//! Property tests for the scoped-thread parallel substrate (`par`):
//! every parallel kernel must be **bit-for-bit** identical to its serial
//! sweep — row blocks are owned by exactly one thread and each output
//! element is produced by the same scalar operations in the same order.

use sddnewton::algorithms::incremental::IncrementalSddNewton;
use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::{sddm_for_graph, NeumannSolver};
use sddnewton::algorithms::{run, ConsensusAlgorithm, RunOptions};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, run_partitioned_newton, Partition};
use sddnewton::graph::{generate, laplacian_csr, Graph};
use sddnewton::harness::experiments::run_cross_transport;
use sddnewton::linalg::Csr;
use sddnewton::net::CommGraph;
use sddnewton::runtime::NativeBackend;
use sddnewton::sddm::{Chain, ChainOptions, SddmSolver, SolverOptions};
use sddnewton::util::Pcg64;

fn random_csr(rows: usize, cols: usize, nnz: usize, rng: &mut Pcg64) -> Csr {
    let mut trips = Vec::with_capacity(nnz);
    for _ in 0..nnz {
        trips.push((
            rng.next_below(rows as u64) as usize,
            rng.next_below(cols as u64) as usize,
            rng.normal(),
        ));
    }
    Csr::from_triplets(rows, cols, &trips)
}

#[test]
fn prop_parallel_matvec_bit_for_bit() {
    for seed in 0..40u64 {
        let mut rng = Pcg64::new(seed);
        let rows = 1 + rng.next_below(300) as usize;
        let cols = 1 + rng.next_below(300) as usize;
        let nnz = 1 + rng.next_below((rows * cols / 2 + 1) as u64) as usize;
        let a = random_csr(rows, cols, nnz, &mut rng);
        let x = rng.normal_vec(cols);
        let mut serial = vec![0.0; rows];
        a.matvec_into_threads(&x, &mut serial, 1);
        for threads in [2usize, 3, 4, 7, 16] {
            let mut par = vec![0.0; rows];
            a.matvec_into_threads(&x, &mut par, threads);
            assert_eq!(serial, par, "seed={seed} threads={threads}");
        }
    }
}

#[test]
fn prop_parallel_matvec_multi_bit_for_bit() {
    for seed in 100..130u64 {
        let mut rng = Pcg64::new(seed);
        let rows = 1 + rng.next_below(200) as usize;
        let cols = 1 + rng.next_below(200) as usize;
        let w = 1 + rng.next_below(9) as usize;
        let nnz = 1 + rng.next_below((rows * cols / 2 + 1) as u64) as usize;
        let a = random_csr(rows, cols, nnz, &mut rng);
        let x = rng.normal_vec(cols * w);
        let mut serial = vec![0.0; rows * w];
        a.matvec_multi_into_threads(&x, w, &mut serial, 1);
        for threads in [2usize, 4, 5, 11] {
            let mut par = vec![0.0; rows * w];
            a.matvec_multi_into_threads(&x, w, &mut par, threads);
            assert_eq!(serial, par, "seed={seed} w={w} threads={threads}");
        }
    }
}

#[test]
fn prop_auto_matvec_matches_explicit_serial() {
    // The auto (global-budget, work-thresholded) entry point must agree
    // with the forced-serial and forced-parallel paths.
    let mut rng = Pcg64::new(77);
    let g = generate::random_connected(120, 360, &mut rng);
    let l = laplacian_csr(&g);
    let x = rng.normal_vec(120);
    let auto = l.matvec(&x);
    let mut serial = vec![0.0; 120];
    l.matvec_into_threads(&x, &mut serial, 1);
    assert_eq!(auto, serial);
}

#[test]
fn sddm_crude_solve_is_thread_count_invariant() {
    // A 20k-node chain at w=8 puts both the matvec (nnz·w ≈ 480k ops)
    // and the per-level row sweeps (n·w = 160k ops) over the
    // MIN_WORK_PER_THREAD bar, so the parallel paths genuinely engage
    // when the global budget allows; depth is pinned to keep the
    // implicit X^{2^i} round count debug-fast.
    let mut rng = Pcg64::new(2024);
    let n = 20_000;
    let w = 8;
    let g = generate::path(n);
    let l = laplacian_csr(&g);
    let chain =
        Chain::build(&l, &ChainOptions { depth: Some(2), ..Default::default() }, &mut rng)
            .unwrap();
    let solver = SddmSolver::new(chain, SolverOptions::default());
    let mut b = vec![0.0; n * w];
    for j in 0..w {
        let z = rng.normal_vec(n);
        let col = l.matvec(&z);
        for i in 0..n {
            b[i * w + j] = col[i];
        }
    }
    let crude_with = |threads: usize| {
        sddnewton::par::set_threads(threads);
        let mut comm = CommGraph::new(&g);
        let x = solver.crude_solve(&b, w, &mut comm);
        sddnewton::par::set_threads(0);
        (x, *comm.stats())
    };
    let (x1, stats1) = crude_with(1);
    for threads in [2usize, 4] {
        let (xt, statst) = crude_with(threads);
        assert_eq!(x1, xt, "threads={threads}: solution drifted");
        assert_eq!(stats1, statst, "threads={threads}: message accounting drifted");
    }
}

/// The acceptance property of the partitioned runtime: `run_partitioned_newton`
/// must produce **bit-for-bit** identical iterates to the bulk-synchronous
/// `SddNewton` + `CommGraph` path across contiguous, round-robin and BFS
/// partitionings — same primal stack, same dual stack, same per-iteration
/// objectives, same modeled communication ledger.
#[test]
fn partitioned_newton_bit_for_bit_across_partitionings() {
    let mut rng = Pcg64::new(9001);
    let n = 14;
    let g = generate::random_connected(n, 30, &mut rng);
    let prob = sddnewton::problems::datasets::synthetic_regression(n, 4, 280, 0.2, 0.05, &mut rng);
    let solver = sddm_for_graph(&g, 1e-6, &mut rng);
    let backend = NativeBackend;
    let iters = 5;
    let step = StepSize::Fixed(1.0);

    // Bulk-synchronous reference.
    let mut alg = SddNewton::new(&prob, &backend, &solver, step);
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &prob,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );

    for part in [
        Partition::contiguous(n, 3),
        Partition::round_robin(n, 4),
        Partition::bfs_blocks(&g, 2),
    ] {
        let out = run_partitioned_newton(&prob, &g, &part, &solver, step, iters);
        assert_eq!(out.thetas, trace.final_thetas, "k={}: primal iterate drifted", part.k);
        assert_eq!(out.lambda, alg.lambda(), "k={}: dual iterate drifted", part.k);
        assert_eq!(out.comm, *comm.stats(), "k={}: modeled comm ledger drifted", part.k);
        assert_eq!(out.records.len(), iters);
        for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
            assert_eq!(r.iter, ref_r.iter);
            assert_eq!(r.objective, ref_r.objective, "iter {} objective drifted", r.iter);
            assert_eq!(
                r.consensus_error, ref_r.consensus_error,
                "iter {} consensus drifted",
                r.iter
            );
            assert_eq!(r.comm, ref_r.comm, "iter {} ledger drifted", r.iter);
        }
    }
}

/// Same property with the ADD-style Neumann inner solver: the exchange
/// refactor must keep every inner solver transport-agnostic.
#[test]
fn partitioned_add_newton_matches_bulk() {
    let mut rng = Pcg64::new(9002);
    let n = 12;
    let g = generate::random_connected(n, 26, &mut rng);
    let prob = sddnewton::problems::datasets::synthetic_regression(n, 3, 180, 0.2, 0.05, &mut rng);
    let solver = NeumannSolver::from_graph(&g, 2);
    let backend = NativeBackend;
    let iters = 4;
    let step = StepSize::Fixed(1.0);

    let mut alg = SddNewton::new(&prob, &backend, &solver, step);
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &prob,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );
    let part = Partition::round_robin(n, 3);
    let out = run_partitioned_newton(&prob, &g, &part, &solver, step, iters);
    assert_eq!(out.thetas, trace.final_thetas);
    assert_eq!(out.comm, *comm.stats());
}

/// The three partitionings the parity suite sweeps for a worker count.
fn partitionings(g: &Graph, k: usize) -> [Partition; 3] {
    [
        Partition::contiguous(g.n, k),
        Partition::round_robin(g.n, k),
        Partition::bfs_blocks(g, k),
    ]
}

/// The acceptance property of this PR: **every** `ConsensusAlgorithm` —
/// not just SDD-Newton — produces bit-for-bit identical traces (final
/// iterate, per-iteration objectives and consensus errors, and the
/// modeled `CommStats` ledger) on the bulk-synchronous `CommGraph` and
/// the channel-based `ShardExchange`, across contiguous, round-robin and
/// BFS partitionings and k ∈ {1, 2, 5} workers. Each comparison shares
/// the inner solver instance (`run_cross_transport`), so the only moving
/// part is the transport.
#[test]
fn every_algorithm_bit_for_bit_across_transports() {
    let mut rng = Pcg64::new(9100);
    let n = 11;
    let g = generate::random_connected(n, 24, &mut rng);
    let prob =
        sddnewton::problems::datasets::synthetic_regression(n, 3, 165, 0.2, 0.05, &mut rng);
    let iters = 3;
    let kinds = [
        AlgoKind::SddNewton { eps: 1e-5, alpha: 1.0 },
        AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
        AlgoKind::ExactNewton { alpha: 1.0 },
        AlgoKind::Admm { beta: 1.0 },
        AlgoKind::Gradient { alpha: 0.01 },
        AlgoKind::Averaging { beta: 0.005 },
        AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 },
    ];
    for kind in &kinds {
        for k in [1usize, 2, 5] {
            for part in partitionings(&g, k) {
                let (trace, out) =
                    run_cross_transport(kind, &prob, &g, &part, iters, &mut rng);
                let tag = format!("{} k={k}", trace.algorithm);
                assert_eq!(out.thetas, trace.final_thetas, "{tag}: iterate drifted");
                assert_eq!(out.comm, *trace.records.last().map(|r| &r.comm).unwrap(),
                    "{tag}: modeled comm ledger drifted");
                assert_eq!(out.records.len(), iters, "{tag}: record count");
                for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
                    assert_eq!(r.iter, ref_r.iter, "{tag}");
                    assert_eq!(r.objective, ref_r.objective, "{tag}: iter {} objective", r.iter);
                    assert_eq!(
                        r.consensus_error, ref_r.consensus_error,
                        "{tag}: iter {} consensus",
                        r.iter
                    );
                    assert_eq!(r.comm, ref_r.comm, "{tag}: iter {} ledger", r.iter);
                }
            }
        }
    }
}

/// Incremental SDD-Newton has no `AlgoKind`; its parity is asserted
/// directly: the partial-refresh window is keyed to *global* node ids, so
/// a shard refreshes exactly its slice of the window and the mixed
/// fresh/stale primal matches the bulk path bit for bit.
#[test]
fn incremental_newton_bit_for_bit_across_transports() {
    let mut rng = Pcg64::new(9101);
    let n = 10;
    let g = generate::random_connected(n, 22, &mut rng);
    let prob =
        sddnewton::problems::datasets::synthetic_regression(n, 3, 150, 0.2, 0.05, &mut rng);
    let solver = sddm_for_graph(&g, 1e-4, &mut rng);
    let backend = NativeBackend;
    let iters = 4;

    let mut bulk = IncrementalSddNewton::new(&prob, &backend, &solver, 0.8, 0.4);
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut bulk,
        &prob,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );

    for k in [1usize, 2, 5] {
        for part in partitionings(&g, k) {
            let out = run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
                Box::new(IncrementalSddNewton::new_sharded(
                    &prob, &backend, &solver, 0.8, 0.4, owned,
                )) as Box<dyn ConsensusAlgorithm + '_>
            });
            assert_eq!(out.thetas, trace.final_thetas, "k={k}: primal drifted");
            assert_eq!(out.comm, *comm.stats(), "k={k}: ledger drifted");
            for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
                assert_eq!(r.objective, ref_r.objective, "k={k}: iter {} drifted", r.iter);
            }
        }
    }
}

#[test]
fn native_backend_batches_are_thread_count_invariant() {
    use sddnewton::problems::datasets;
    use sddnewton::runtime::{LocalBackend, NativeBackend};
    let mut rng = Pcg64::new(31);
    // n·p·p = 256·32·32 clears MIN_WORK_PER_THREAD so the per-node
    // fan-out genuinely engages when the budget allows.
    let (n, p) = (256usize, 32usize);
    let prob = datasets::synthetic_regression(n, p, 8 * n, 0.2, 0.05, &mut rng);
    let v = rng.normal_vec(n * p);
    let run_with = |threads: usize| {
        sddnewton::par::set_threads(threads);
        let mut out = vec![0.0; n * p];
        NativeBackend.primal_recover_all(&prob, &v, &mut out);
        let z = rng.clone().normal_vec(n * p);
        let mut hz = vec![0.0; n * p];
        NativeBackend.hess_apply_all(&prob, &out, &z, &mut hz);
        sddnewton::par::set_threads(0);
        (out, hz)
    };
    let (y1, h1) = run_with(1);
    let (y4, h4) = run_with(4);
    assert_eq!(y1, y4);
    assert_eq!(h1, h4);
}
