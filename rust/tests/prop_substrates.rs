//! Property-style tests over the substrates (proptest is unavailable
//! offline; randomized sweeps over many seeds play its role — failures
//! print the seed for reproduction).

use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::linalg::cg::{cg_solve, CgOptions};
use sddnewton::linalg::cholesky::{spd_solve, Cholesky};
use sddnewton::linalg::{Csr, Matrix};
use sddnewton::util::Pcg64;

fn random_matrix(r: usize, c: usize, rng: &mut Pcg64) -> Matrix {
    let mut m = Matrix::zeros(r, c);
    for v in m.data.iter_mut() {
        *v = rng.normal();
    }
    m
}

fn random_spd(n: usize, rng: &mut Pcg64) -> Matrix {
    let b = random_matrix(n, n, rng);
    let mut a = b.matmul(&b.transpose());
    for i in 0..n {
        a[(i, i)] += n as f64;
    }
    a
}

#[test]
fn prop_cholesky_solves_random_spd() {
    for seed in 0..40u64 {
        let mut rng = Pcg64::new(seed);
        let n = 2 + (rng.next_below(14) as usize);
        let a = random_spd(n, &mut rng);
        let x_true = rng.normal_vec(n);
        let b = a.matvec(&x_true);
        let x = spd_solve(&a, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8, "seed={seed} n={n}");
        }
    }
}

#[test]
fn prop_cholesky_factor_reconstructs() {
    for seed in 100..120u64 {
        let mut rng = Pcg64::new(seed);
        let n = 3 + (rng.next_below(10) as usize);
        let a = random_spd(n, &mut rng);
        let ch = Cholesky::factor(&a).unwrap();
        let rec = ch.l().matmul(&ch.l().transpose());
        assert!(rec.max_abs_diff(&a) < 1e-9, "seed={seed}");
    }
}

#[test]
fn prop_csr_roundtrip_and_ops() {
    for seed in 200..240u64 {
        let mut rng = Pcg64::new(seed);
        let n = 2 + rng.next_below(12) as usize;
        let mut trips = Vec::new();
        for _ in 0..(3 * n) {
            trips.push((
                rng.next_below(n as u64) as usize,
                rng.next_below(n as u64) as usize,
                rng.normal(),
            ));
        }
        let a = Csr::from_triplets(n, n, &trips);
        let dense = a.to_dense();
        let x = rng.normal_vec(n);
        let ys = a.matvec(&x);
        let yd = dense.matvec(&x);
        for (u, v) in ys.iter().zip(&yd) {
            assert!((u - v).abs() < 1e-10, "seed={seed}");
        }
        // matmul consistency
        let prod = a.matmul(&a).to_dense();
        let dprod = dense.matmul(&dense);
        assert!(prod.max_abs_diff(&dprod) < 1e-9, "seed={seed}");
    }
}

#[test]
fn prop_random_graphs_connected_with_exact_counts() {
    for seed in 300..360u64 {
        let mut rng = Pcg64::new(seed);
        let n = 3 + rng.next_below(60) as usize;
        let max_m = n * (n - 1) / 2;
        let m = (n - 1) + rng.next_below((max_m - n + 2) as u64) as usize;
        let g = generate::random_connected(n, m, &mut rng);
        assert_eq!(g.n, n, "seed={seed}");
        assert_eq!(g.m(), m, "seed={seed}");
        assert!(g.is_connected(), "seed={seed}");
        // Degree sum = 2m.
        let degsum: usize = (0..n).map(|i| g.degree(i)).sum();
        assert_eq!(degsum, 2 * m, "seed={seed}");
    }
}

#[test]
fn prop_laplacian_psd_and_kernel() {
    for seed in 400..420u64 {
        let mut rng = Pcg64::new(seed);
        let n = 4 + rng.next_below(30) as usize;
        let m = (n - 1) + rng.next_below(n as u64) as usize;
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        // xᵀLx = Σ_(u,v)∈E (x_u − x_v)² ≥ 0 and 0 only on constants.
        for _ in 0..5 {
            let x = rng.normal_vec(n);
            let quad = sddnewton::linalg::vector::dot(&x, &l.matvec(&x));
            let manual: f64 = g.edges.iter().map(|&(u, v)| (x[u] - x[v]).powi(2)).sum();
            assert!((quad - manual).abs() < 1e-8 * manual.max(1.0), "seed={seed}");
            assert!(quad >= -1e-12);
        }
    }
}

#[test]
fn prop_cg_matches_cholesky_on_spd() {
    for seed in 500..520u64 {
        let mut rng = Pcg64::new(seed);
        let n = 3 + rng.next_below(12) as usize;
        let a = random_spd(n, &mut rng);
        let b = rng.normal_vec(n);
        let direct = spd_solve(&a, &b).unwrap();
        let cg = cg_solve(&a, &b, &CgOptions::default());
        assert!(cg.converged, "seed={seed}");
        for (u, v) in cg.x.iter().zip(&direct) {
            assert!((u - v).abs() < 1e-6, "seed={seed}");
        }
    }
}

#[test]
fn prop_pcg64_uniformity_chi2() {
    // Coarse chi-squared test over 16 buckets, several seeds.
    for seed in [1u64, 77, 4242] {
        let mut rng = Pcg64::new(seed);
        let n = 32_000;
        let mut buckets = [0u32; 16];
        for _ in 0..n {
            buckets[(rng.next_u64() >> 60) as usize] += 1;
        }
        let expect = n as f64 / 16.0;
        let chi2: f64 = buckets
            .iter()
            .map(|&b| (b as f64 - expect).powi(2) / expect)
            .sum();
        // 15 dof: P(chi2 > 37.7) ≈ 0.001.
        assert!(chi2 < 37.7, "seed={seed} chi2={chi2}");
    }
}
