//! Schedule-exploration suite: the partitioned runtime must produce
//! bit-identical iterates under *every* boundary-payload delivery order,
//! not just the ones the OS scheduler happens to serve.
//!
//! `net::model::ModelExchange` records one concurrent run over the real
//! `ShardExchange` + reducer code paths, then replays each receiver
//! single-threaded under permuted per-sender stream merges — exhaustively
//! when the merge space is small (all delivery permutations at k = 3 over
//! the round window here), by seeded uniform sweeps above. This suite
//! pins the acceptance programs and a seed corpus so CI explores the same
//! adversarial schedules on every run.

use sddnewton::algorithms::gradient::{DistGradient, GradSchedule};
use sddnewton::algorithms::ConsensusAlgorithm;
use sddnewton::coordinator::Partition;
use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::net::model::{ExploreOptions, ModelExchange};
use sddnewton::net::partitioned::ShardExchange;
use sddnewton::net::Exchange;
use sddnewton::problems::datasets;
use sddnewton::sddm::{ChainOptions, SquaredChain};
use sddnewton::util::Pcg64;

/// A BSP step-function exercising both ordering defenses: per round, one
/// Laplacian halo exchange (reorder buffer) plus one all-reduce
/// (sequence-keyed reducer), mixed back into the local state.
fn lap_rounds_program(rounds: usize) -> impl Fn(usize, &mut ShardExchange<'_>) -> Vec<f64> + Sync {
    move |_i, ex| {
        let w = 2;
        let n = ex.n();
        let x_global = Pcg64::new(5).normal_vec(n * w);
        let owned = ex.owned().to_vec();
        let mut x: Vec<f64> = owned
            .iter()
            .flat_map(|&u| x_global[u * w..(u + 1) * w].to_vec())
            .collect();
        let mut y = vec![0.0; x.len()];
        for _ in 0..rounds {
            ex.laplacian_apply_into(&x, w, &mut y);
            let total = ex.allreduce_sum(&y, w);
            for (idx, v) in x.iter_mut().enumerate() {
                *v = y[idx] + total[idx % w] / n as f64;
            }
        }
        x
    }
}

/// Acceptance property: at k = 3 over a 3-round window the explorer
/// covers the *entire* schedule space — every merge of every receiver's
/// input streams — and every schedule reproduces the recorded iterates
/// bit for bit.
#[test]
fn k3_round_window_is_verified_exhaustively() {
    let mut rng = Pcg64::new(4101);
    let g = generate::random_connected(9, 16, &mut rng);
    let part = Partition::contiguous(9, 3);
    let model = ModelExchange::new(&g, &part);
    let report = model
        .explore(lap_rounds_program(3), &ExploreOptions::default())
        .expect("a delivery schedule broke bit-identity");
    assert!(report.exhaustive, "k=3 over 3 rounds must be exhaustively explored");
    assert_eq!(report.workers, 3);
    assert!(report.wire_messages > 0, "the program must actually cross shards");
    assert_eq!(report.reduce_messages, 9, "3 workers × 3 all-reduces");
    assert!(
        report.schedules_checked > report.reduce_messages as u64,
        "only {} schedules explored",
        report.schedules_checked
    );
}

/// A real algorithm on the explorer: three distributed-gradient steps
/// must be schedule-oblivious end to end.
#[test]
fn gradient_steps_are_bit_identical_under_all_schedules() {
    let mut rng = Pcg64::new(4102);
    let n = 10;
    let g = generate::random_connected(n, 20, &mut rng);
    let prob = datasets::synthetic_regression(n, 3, 140, 0.2, 0.05, &mut rng);
    let part = Partition::contiguous(n, 3);
    let model = ModelExchange::new(&g, &part);
    let report = model
        .explore(
            |_i, ex: &mut ShardExchange<'_>| {
                let owned = ex.owned().to_vec();
                let mut alg =
                    DistGradient::new_sharded(&prob, &g, GradSchedule::Constant(0.05), owned);
                for _ in 0..3 {
                    alg.step(&prob, ex);
                }
                alg.thetas().to_vec()
            },
            &ExploreOptions::default(),
        )
        .expect("a delivery schedule changed the gradient iterate");
    assert!(report.exhaustive, "3 gradient steps at k=3 fit the exhaustive budget");
}

/// The overlay path under exploration: `SquaredChain::crude_solve` ships
/// squared-level payloads through registered overlay plans; its sweeps
/// must be schedule-oblivious too. The merge space here is large, so this
/// runs the seeded uniform sweep rather than full enumeration.
#[test]
fn squared_chain_crude_solve_survives_adversarial_schedules() {
    let mut rng = Pcg64::new(4103);
    let n = 8;
    let g = generate::random_connected(n, 14, &mut rng);
    let lap = laplacian_csr(&g);
    let mut crng = Pcg64::new(31);
    let sq = SquaredChain::build(&lap, &ChainOptions::default(), 0.0, &mut crng)
        .expect("chain build on a connected Laplacian");
    let b_global = Pcg64::new(12).normal_vec(n);
    let part = Partition::contiguous(n, 3);
    let model = ModelExchange::new(&g, &part);
    let opts = ExploreOptions { exhaustive_limit: 2_000, random_schedules: 10, seed: 0xC0FFEE };
    let report = model
        .explore(
            |_i, ex: &mut ShardExchange<'_>| {
                let b: Vec<f64> = ex.owned().iter().map(|&u| b_global[u]).collect();
                sq.crude_solve(&b, 1, ex)
            },
            &opts,
        )
        .expect("a delivery schedule changed the crude solve");
    assert!(report.schedules_checked > 0);
    assert!(report.wire_messages > 0);
}

/// Pinned seed corpus: the same adversarial schedules are re-explored on
/// every CI run. Each seed drives its own graph, partition, and sweep
/// stream; extend the list when a schedule bug is found so the regression
/// stays pinned.
#[test]
fn pinned_seed_corpus_replays_clean() {
    const CORPUS: [u64; 4] = [1, 7, 42, 20_260_808];
    for &seed in &CORPUS {
        let mut rng = Pcg64::new(seed);
        let n = 13;
        let g = generate::random_connected(n, 24, &mut rng);
        let part = Partition::round_robin(n, 4);
        let model = ModelExchange::new(&g, &part);
        let opts = ExploreOptions { exhaustive_limit: 2_000, random_schedules: 16, seed };
        let report = model
            .explore(lap_rounds_program(2), &opts)
            .unwrap_or_else(|e| panic!("corpus seed {seed}: {e}"));
        assert!(report.schedules_checked > 0, "corpus seed {seed} explored nothing");
        assert_eq!(report.workers, 4);
    }
}
