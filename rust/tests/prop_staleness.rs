//! Bounded-staleness and local-step contracts, cross-transport.
//!
//! The relaxation PR's headline guarantees, asserted end to end:
//!
//! - **τ = 0 is BSP.** Building an algorithm through the staleness
//!   factory with `stale_tau = 0` is bit-for-bit the staleness-free
//!   construction — iterates, per-iteration objectives, and the full
//!   modeled ledger (savings counters stay zero).
//! - **Staleness is deterministic on every transport.** For τ > 0 the
//!   stale reconstruction is a pure function of the last refresh and the
//!   current local iterate, so bulk, in-process shards, the TCP pool,
//!   and the hybrid pool all agree bit for bit — across partitionings
//!   and worker counts — with identical ledgers *including* the savings
//!   counters.
//! - **The savings ledger is exact.** Skipped rounds equal the elided
//!   refresh cadence (`iters − ⌈iters/(τ+1)⌉`), and saved messages and
//!   floats equal precisely what the strict BSP contract would have
//!   shipped for those rounds.
//! - **Local steps split the ledger the same way.** Local-step Newton
//!   charges its elided mixing rounds to the savings counters on every
//!   transport, with `local_steps = 1` saving nothing.
//! - **The pipelined ADMM wavefront is a schedule change, not a math
//!   change.** Drained and pipelined runs produce bit-identical iterates
//!   on both the bulk and the partitioned transport.

use sddnewton::algorithms::{run, RunOptions};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::Partition;
use sddnewton::graph::generate;
use sddnewton::harness::deploy::{
    run_hybrid_cross_transport, run_tcp_cross_transport, TcpJobSpec,
};
use sddnewton::harness::experiments::{
    make_inner_solver, make_sharded_algorithm, make_sharded_algorithm_stale,
    run_cross_transport_stale,
};
use sddnewton::net::hybrid::parse_hostfile;
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// τ = 0 through the staleness factory is the staleness-free
/// construction, bit for bit, for every policy-eligible kind.
#[test]
fn tau_zero_is_bit_identical_to_the_bsp_construction() {
    let mut rng = Pcg64::new(9_001);
    let g = generate::random_connected(10, 22, &mut rng);
    let prob = datasets::synthetic_regression(10, 3, 120, 0.1, 0.05, &mut rng);
    let backend = NativeBackend;
    let kinds = [
        AlgoKind::Gradient { alpha: 0.01 },
        AlgoKind::Averaging { beta: 0.002 },
        AlgoKind::SddNewton { eps: 1e-4, alpha: 1.0 },
    ];
    for kind in &kinds {
        let solver = make_inner_solver(kind, &g, &mut Pcg64::new(77));
        let solver_ref = solver.as_deref();
        let all: Vec<usize> = (0..10).collect();
        let mut plain =
            make_sharded_algorithm(kind, &prob, &g, &backend, solver_ref, all.clone());
        let mut comm_plain = CommGraph::new(&g);
        let t_plain = run(
            &mut plain,
            &prob,
            &mut comm_plain,
            &RunOptions { max_iters: 6, ..Default::default() },
        );
        let solver2 = make_inner_solver(kind, &g, &mut Pcg64::new(77));
        let solver2_ref = solver2.as_deref();
        let mut stale =
            make_sharded_algorithm_stale(kind, &prob, &g, &backend, solver2_ref, all, 0);
        let mut comm_stale = CommGraph::new(&g);
        let t_stale = run(
            &mut stale,
            &prob,
            &mut comm_stale,
            &RunOptions { max_iters: 6, ..Default::default() },
        );
        let id = kind.id();
        assert_eq!(bits(&t_plain.final_thetas), bits(&t_stale.final_thetas), "{id} iterate");
        assert_eq!(t_plain.records.len(), t_stale.records.len());
        for (a, b) in t_plain.records.iter().zip(&t_stale.records) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{id} objective");
        }
        assert_eq!(comm_plain.stats(), comm_stale.stats(), "{id} ledger");
        assert_eq!(comm_stale.stats().skipped_rounds, 0, "{id} must skip nothing at tau=0");
        assert_eq!(comm_stale.stats().saved_messages, 0);
        assert_eq!(comm_stale.stats().saved_floats, 0);
    }
}

/// For τ > 0 the bulk and in-process shard transports agree bit for bit
/// — iterates, per-iteration objectives, and the full ledger including
/// the savings counters — across kinds, τ, partitionings, and worker
/// counts.
#[test]
fn stale_halos_are_bit_identical_across_bulk_and_shard_transports() {
    let mut rng = Pcg64::new(9_002);
    let n = 12;
    let g = generate::random_connected(n, 26, &mut rng);
    let prob = datasets::synthetic_regression(n, 3, 144, 0.1, 0.05, &mut rng);
    let iters = 6;
    let kinds = [
        AlgoKind::Gradient { alpha: 0.01 },
        AlgoKind::Averaging { beta: 0.002 },
        AlgoKind::SddNewton { eps: 1e-4, alpha: 1.0 },
    ];
    for kind in &kinds {
        for tau in [1u64, 3] {
            for k in [2usize, 4] {
                for part in [Partition::contiguous(n, k), Partition::round_robin(n, k)] {
                    let mut solver_rng = Pcg64::new(4_242);
                    let (trace, out) = run_cross_transport_stale(
                        kind,
                        &prob,
                        &g,
                        &part,
                        iters,
                        tau,
                        &mut solver_rng,
                    );
                    let id = kind.id();
                    assert_eq!(
                        bits(&trace.final_thetas),
                        bits(&out.thetas),
                        "{id} tau={tau} k={k}: iterate drifted"
                    );
                    for (a, b) in trace.records[1..].iter().zip(&out.records) {
                        assert_eq!(
                            a.objective.to_bits(),
                            b.objective.to_bits(),
                            "{id} tau={tau} k={k}: objective drifted"
                        );
                    }
                    let bulk_stats = trace.records.last().unwrap().comm;
                    assert_eq!(bulk_stats, out.comm, "{id} tau={tau} k={k}: ledger drifted");
                    assert!(
                        out.comm.skipped_rounds > 0,
                        "{id} tau={tau}: policy must actually skip rounds"
                    );
                }
            }
        }
    }
}

/// The savings counters model exactly what strict BSP would have
/// shipped for the elided rounds: one policy-eligible exchange per
/// iteration, refreshed every τ+1 rounds.
#[test]
fn savings_ledger_models_exactly_the_elided_rounds() {
    let mut rng = Pcg64::new(9_003);
    let g = generate::random_connected(9, 18, &mut rng);
    let m = g.m() as u64;
    let p = 3usize;
    let prob = datasets::synthetic_regression(9, p, 90, 0.1, 0.05, &mut rng);
    let backend = NativeBackend;
    let iters = 10usize;
    for tau in [1u64, 2, 3] {
        let kind = AlgoKind::Gradient { alpha: 0.01 };
        let mut alg = make_sharded_algorithm_stale(
            &kind,
            &prob,
            &g,
            &backend,
            None,
            (0..9).collect(),
            tau,
        );
        let mut comm = CommGraph::new(&g);
        run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: iters, ..Default::default() },
        );
        let refreshes =
            iters as u64 / (tau + 1) + u64::from(iters as u64 % (tau + 1) != 0);
        let skipped = iters as u64 - refreshes;
        let s = comm.stats();
        assert_eq!(s.skipped_rounds, skipped, "tau={tau}");
        assert_eq!(s.saved_messages, skipped * 2 * m, "tau={tau}");
        assert_eq!(s.saved_floats, skipped * 2 * m * p as u64, "tau={tau}");
        // The real counters cover exactly the refresh rounds.
        assert_eq!(s.rounds, refreshes);
        assert_eq!(s.messages, refreshes * 2 * m);
    }
}

/// Spec for one algorithm of the smoke preset on a loopback pool.
fn spec(algo: &str, workers: usize, iters: usize, stale_tau: u64) -> TcpJobSpec {
    TcpJobSpec {
        experiment: "smoke".to_string(),
        config_path: None,
        algorithms: Some(algo.to_string()),
        seed: None,
        algo_index: 0,
        iters,
        workers,
        partitioning: "contiguous".to_string(),
        solver_seed: 0x57A1E,
        hostfile: None,
        stale_tau,
    }
}

/// The TCP pool honors the staleness policy bit for bit: the three-way
/// parity harness (bulk, shards, sockets — iterates, objectives, full
/// ledger with savings, wire truth) passes for τ > 0, and the wire
/// carries strictly less than the τ = 0 run. Local-step Newton rides the
/// same pool with its modeled savings intact.
#[test]
fn tcp_parity_holds_under_staleness_and_local_steps() {
    for (algo, tau) in [("grad", 2u64), ("sdd", 1), ("local", 0)] {
        for k in [2usize, 4] {
            let parity = run_tcp_cross_transport(&spec(algo, k, 4, tau), "127.0.0.1:0", None)
                .unwrap_or_else(|e| panic!("tcp run failed for {algo} tau={tau} k={k}: {e}"));
            assert!(
                parity.ok(),
                "tcp parity failed for {algo} tau={tau} k={k}: {parity:?}"
            );
            let comm = parity.tcp.comm;
            if tau > 0 || algo == "local" {
                assert!(
                    comm.skipped_rounds > 0,
                    "{algo} tau={tau}: policy must skip rounds on the pool"
                );
                // Savings stay internally consistent (messages × a whole
                // payload width).
                assert!(comm.saved_messages > 0 && comm.saved_floats > 0);
                assert_eq!(comm.saved_floats % comm.saved_messages, 0);
            } else {
                assert_eq!(comm.skipped_rounds, 0);
            }
        }
    }
    // Strictly-fewer-wire-floats: same algorithm, growing τ.
    let base = run_tcp_cross_transport(&spec("grad", 2, 6, 0), "127.0.0.1:0", None).unwrap();
    let relaxed = run_tcp_cross_transport(&spec("grad", 2, 6, 2), "127.0.0.1:0", None).unwrap();
    assert!(base.ok() && relaxed.ok());
    assert!(
        relaxed.tcp.cross_floats < base.tcp.cross_floats,
        "tau=2 must ship strictly fewer floats: {} vs {}",
        relaxed.tcp.cross_floats,
        base.tcp.cross_floats
    );
}

/// The hybrid pool agrees too, with the placement-split wire accounting
/// intact under staleness (co-located savings are modeled identically).
#[test]
fn hybrid_parity_holds_under_staleness_and_local_steps() {
    let hostfile = "0 alpha\n1 alpha\n2 beta\n3 beta\n";
    let placement = parse_hostfile(hostfile).expect("test hostfile must parse");
    for (algo, tau) in [("grad", 2u64), ("local", 0)] {
        let parity =
            run_hybrid_cross_transport(&spec(algo, 4, 4, tau), &placement, "127.0.0.1:0", None)
                .unwrap_or_else(|e| panic!("hybrid run failed for {algo} tau={tau}: {e}"));
        assert!(parity.ok(), "hybrid parity failed for {algo} tau={tau}: {parity:?}");
        if tau > 0 || algo == "local" {
            assert!(parity.hybrid.comm.skipped_rounds > 0, "{algo} tau={tau}");
        }
    }
}

/// Drained and pipelined ADMM produce bit-identical iterates on both the
/// bulk and the partitioned transport — the wavefront reorders shipping,
/// never values.
#[test]
fn admm_pipelined_matches_drained_on_both_transports() {
    let mut rng = Pcg64::new(9_004);
    let n = 12;
    let g = generate::random_connected(n, 26, &mut rng);
    let prob = datasets::synthetic_regression(n, 3, 144, 0.1, 0.05, &mut rng);
    let iters = 8;
    let part = Partition::round_robin(n, 3);
    let mut rng_a = Pcg64::new(5);
    let (drained_trace, drained_out) = run_cross_transport_stale(
        &AlgoKind::Admm { beta: 1.0 },
        &prob,
        &g,
        &part,
        iters,
        0,
        &mut rng_a,
    );
    let mut rng_b = Pcg64::new(5);
    let (pipe_trace, pipe_out) = run_cross_transport_stale(
        &AlgoKind::AdmmPipelined { beta: 1.0 },
        &prob,
        &g,
        &part,
        iters,
        0,
        &mut rng_b,
    );
    // Each schedule is internally parity-clean across transports…
    assert_eq!(bits(&drained_trace.final_thetas), bits(&drained_out.thetas));
    assert_eq!(bits(&pipe_trace.final_thetas), bits(&pipe_out.thetas));
    // …and the two schedules agree with each other, iteration by
    // iteration.
    assert_eq!(
        bits(&drained_trace.final_thetas),
        bits(&pipe_trace.final_thetas),
        "pipelined wavefront drifted from the drained schedule"
    );
    for (a, b) in drained_trace.records.iter().zip(&pipe_trace.records) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    }
    // Both ship the same total volume (every boundary row exactly once
    // per sweep plus the dual round), just on different rounds.
    assert_eq!(drained_out.cross_floats, pipe_out.cross_floats);
}
