//! Property tests on the SDDM solver: Definition 1's ε-guarantee in the
//! M-norm against the CG oracle, across random graphs, topologies, batch
//! widths, and accuracies. Plus failure-injection checks.

use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::linalg::cg::{cg_solve, CgOptions};
use sddnewton::linalg::Csr;
use sddnewton::net::CommGraph;
use sddnewton::sddm::{Chain, ChainOptions, SddmSolver, SolverOptions};
use sddnewton::util::Pcg64;

fn m_norm(l: &Csr, v: &[f64]) -> f64 {
    sddnewton::linalg::vector::dot(v, &l.matvec(v)).max(0.0).sqrt()
}

/// Definition 1: ‖x* − x̃‖_M ≤ ε‖x*‖_M. The solver controls the residual
/// surrogate; verify the induced M-norm error is proportional (within the
/// κ(M) slack) and, importantly, decreases with ε.
#[test]
fn prop_def1_error_tracks_eps() {
    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed);
        let n = 10 + rng.next_below(40) as usize;
        let m = (n - 1) + rng.next_below((2 * n) as u64) as usize;
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let z = rng.normal_vec(n);
        let b = l.matvec(&z);
        let exact = cg_solve(&l, &b, &CgOptions { tol: 1e-14, project_kernel: true, max_iter: 100 * n, ..Default::default() });
        let xnorm = m_norm(&l, &exact.x).max(1e-300);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let mut prev_err = f64::INFINITY;
        for eps in [0.5, 1e-2, 1e-5] {
            let solver =
                SddmSolver::new(chain.clone(), SolverOptions { eps, max_richardson: 500 });
            let mut comm = CommGraph::new(&g);
            let out = solver.solve(&b, 1, &mut comm);
            assert!(out.converged, "seed={seed} eps={eps}");
            let diff: Vec<f64> =
                out.x.iter().zip(&exact.x).map(|(a, c)| a - c).collect();
            let err = m_norm(&l, &diff) / xnorm;
            assert!(err <= prev_err + 1e-12, "seed={seed}: err {err} > prev {prev_err}");
            prev_err = err;
        }
        assert!(prev_err < 1e-4, "seed={seed}: final err {prev_err}");
    }
}

#[test]
fn prop_batched_widths_consistent() {
    for seed in 20..26u64 {
        let mut rng = Pcg64::new(seed);
        let n = 12 + rng.next_below(20) as usize;
        let g = generate::random_connected(n, 2 * n, &mut rng);
        let l = laplacian_csr(&g);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-8, max_richardson: 300 });
        let w = 1 + rng.next_below(6) as usize;
        let mut b = vec![0.0; n * w];
        for j in 0..w {
            let z = rng.normal_vec(n);
            let col = l.matvec(&z);
            for i in 0..n {
                b[i * w + j] = col[i];
            }
        }
        let mut comm = CommGraph::new(&g);
        let multi = solver.solve(&b, w, &mut comm);
        for j in 0..w {
            let col: Vec<f64> = (0..n).map(|i| b[i * w + j]).collect();
            let mut c1 = CommGraph::new(&g);
            let single = solver.solve(&col, 1, &mut c1);
            for i in 0..n {
                assert!(
                    (multi.x[i * w + j] - single.x[i]).abs() < 1e-5,
                    "seed={seed} w={w}"
                );
            }
        }
    }
}

#[test]
fn prop_topologies_all_converge() {
    let mut rng = Pcg64::new(99);
    let graphs = vec![
        ("path", generate::path(17)),      // bipartite, badly conditioned
        ("cycle_even", generate::cycle(16)), // bipartite cycle
        ("cycle_odd", generate::cycle(17)),
        ("star", generate::star(20)),
        ("grid", generate::grid(4, 5)),
        ("complete", generate::complete(12)),
    ];
    for (name, g) in graphs {
        let l = laplacian_csr(&g);
        let z = rng.normal_vec(g.n);
        let b = l.matvec(&z);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-7, max_richardson: 3000 });
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged, "{name}: rel={}", out.rel_residual);
    }
}

#[test]
fn failure_injection_budget_too_small_reported() {
    let mut rng = Pcg64::new(7);
    let g = generate::cycle(40); // poorly conditioned
    let l = laplacian_csr(&g);
    let z = rng.normal_vec(40);
    let b = l.matvec(&z);
    let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
    // One Richardson sweep cannot reach 1e-12 on a cycle.
    let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-12, max_richardson: 1 });
    let mut comm = CommGraph::new(&g);
    let out = solver.solve(&b, 1, &mut comm);
    assert!(!out.converged, "must report non-convergence honestly");
    assert!(out.rel_residual > 1e-12);
}

#[test]
fn failure_injection_non_sdd_rejected() {
    let mut rng = Pcg64::new(8);
    // Positive off-diagonal entry.
    let m = Csr::from_triplets(
        3,
        3,
        &[(0, 0, 2.0), (0, 1, 0.5), (1, 0, 0.5), (1, 1, 2.0), (2, 2, 1.0)],
    );
    assert!(Chain::build(&m, &ChainOptions::default(), &mut rng).is_err());
    // Zero diagonal (isolated row).
    let m2 = Csr::from_triplets(2, 2, &[(0, 0, 1.0)]);
    assert!(Chain::build(&m2, &ChainOptions::default(), &mut rng).is_err());
}

#[test]
fn prop_nonsingular_sddm_systems() {
    // Laplacian + random positive diagonal: nonsingular SDDM, no kernel
    // projection involved.
    for seed in 30..38u64 {
        let mut rng = Pcg64::new(seed);
        let n = 10 + rng.next_below(30) as usize;
        let g = generate::random_connected(n, 2 * n, &mut rng);
        let l = laplacian_csr(&g);
        let mut trips = Vec::new();
        for i in 0..n {
            for k in l.indptr[i]..l.indptr[i + 1] {
                trips.push((i, l.indices[k], l.values[k]));
            }
            trips.push((i, i, 0.1 + rng.next_f64()));
        }
        let m = Csr::from_triplets(n, n, &trips);
        let chain = Chain::build(&m, &ChainOptions::default(), &mut rng).unwrap();
        assert!(!chain.singular, "seed={seed}");
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-9, max_richardson: 500 });
        let x_true = rng.normal_vec(n);
        let b = m.matvec(&x_true);
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged, "seed={seed}");
        for (a, c) in out.x.iter().zip(&x_true) {
            assert!((a - c).abs() < 1e-5, "seed={seed}: {a} vs {c}");
        }
    }
}

#[test]
fn message_accounting_deterministic() {
    let mut rng = Pcg64::new(55);
    let g = generate::random_connected(20, 50, &mut rng);
    let l = laplacian_csr(&g);
    let z = rng.normal_vec(20);
    let b = l.matvec(&z);
    let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
    let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 300 });
    let mut c1 = CommGraph::new(&g);
    let mut c2 = CommGraph::new(&g);
    let _ = solver.solve(&b, 1, &mut c1);
    let _ = solver.solve(&b, 1, &mut c2);
    assert_eq!(c1.stats(), c2.stats(), "same solve must cost the same messages");
}
