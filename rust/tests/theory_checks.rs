//! Numerical verification of the paper's structural lemmas on small
//! instances (exact solvers, finite differences).

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::ExactCgSolver;
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::linalg::cholesky::spd_inverse;
use sddnewton::linalg::Matrix;
use sddnewton::net::{CommGraph, Exchange};
use sddnewton::problems::{datasets, ConsensusProblem, LocalObjective};
use sddnewton::runtime::{LocalBackend, NativeBackend};
use sddnewton::util::Pcg64;

/// Lemma 1: the primal-recovery maps φ have partial derivatives bounded
/// by √p/γ. Finite-difference check on random quadratic locals.
#[test]
fn lemma1_bounded_partials() {
    let mut rng = Pcg64::new(1);
    let prob = datasets::synthetic_regression(4, 6, 120, 0.2, 0.05, &mut rng);
    // γ = min eigenvalue of the local Hessians.
    let thetas0 = vec![0.0; 4 * 6];
    let (gamma, _) = sddnewton::problems::assumption1_bounds(&prob, &thetas0);
    let bound = (6.0f64).sqrt() / gamma;
    let local = &prob.locals[0];
    let v0 = rng.normal_vec(6);
    let h = 1e-6;
    for r in 0..6 {
        let mut vp = v0.clone();
        vp[r] += h;
        let mut vm = v0.clone();
        vm[r] -= h;
        let yp = local.primal_recover(&vp);
        let ym = local.primal_recover(&vm);
        for k in 0..6 {
            let d = (yp[k] - ym[k]) / (2.0 * h);
            assert!(
                d.abs() <= bound * (1.0 + 1e-6),
                "∂φ_{k}/∂z_{r} = {d} exceeds √p/γ = {bound}"
            );
        }
    }
}

/// Lemma 2 (first part): dual gradient ∇q(λ) = M y(λ) and dual Hessian
/// H(λ) = −M (∇²f)⁻¹ M, checked by finite differences of the dual
/// function on a small quadratic instance.
#[test]
fn lemma2_dual_gradient_and_hessian() {
    let mut rng = Pcg64::new(2);
    let n = 4;
    let p = 2;
    let g = generate::complete(n);
    let prob = datasets::synthetic_regression(n, p, 40, 0.2, 0.05, &mut rng);
    let l = laplacian_csr(&g);

    // Dual function q(λ) = Σ inf_y [f_i(y_i) + y_i·(Lλ)_i] evaluated
    // numerically via primal recovery.
    let dual = |lambda: &[f64]| -> f64 {
        let mut comm = CommGraph::new(&g);
        let v = comm.laplacian_apply(lambda, p);
        let mut y = vec![0.0; n * p];
        NativeBackend.primal_recover_all(&prob, &v, &mut y);
        (0..n)
            .map(|i| {
                let yi = &y[i * p..(i + 1) * p];
                prob.locals[i].value(yi)
                    + sddnewton::linalg::vector::dot(yi, &v[i * p..(i + 1) * p])
            })
            .sum()
    };

    let lambda0 = rng.normal_vec(n * p);
    // Analytic gradient: M y(λ).
    let mut comm = CommGraph::new(&g);
    let v = comm.laplacian_apply(&lambda0, p);
    let mut y = vec![0.0; n * p];
    NativeBackend.primal_recover_all(&prob, &v, &mut y);
    let grad_analytic = comm.laplacian_apply(&y, p);

    let h = 1e-5;
    for idx in 0..n * p {
        let mut lp = lambda0.clone();
        lp[idx] += h;
        let mut lm = lambda0.clone();
        lm[idx] -= h;
        let fd = (dual(&lp) - dual(&lm)) / (2.0 * h);
        assert!(
            (fd - grad_analytic[idx]).abs() < 1e-4 * grad_analytic[idx].abs().max(1.0),
            "grad[{idx}]: fd {fd} vs analytic {}",
            grad_analytic[idx]
        );
    }

    // Analytic Hessian: −M (∇²f)⁻¹ M in the per-node stacked basis.
    // Build dense M = permuted I_p ⊗ L acting on stacked (node-major) vectors.
    let np = n * p;
    let mut m_dense = Matrix::zeros(np, np);
    let ld = l.to_dense();
    for i in 0..n {
        for j in 0..n {
            for r in 0..p {
                m_dense[(i * p + r, j * p + r)] = ld[(i, j)];
            }
        }
    }
    let mut winv = Matrix::zeros(np, np);
    for i in 0..n {
        let hi = prob.locals[i].hessian(&y[i * p..(i + 1) * p]);
        let hinv = spd_inverse(&hi).unwrap();
        for r in 0..p {
            for s in 0..p {
                winv[(i * p + r, i * p + s)] = hinv[(r, s)];
            }
        }
    }
    let h_analytic = {
        let mut hm = m_dense.matmul(&winv).matmul(&m_dense);
        for v in hm.data.iter_mut() {
            *v = -*v;
        }
        hm
    };
    for a_idx in 0..np {
        for b_idx in 0..np {
            let mut lpp = lambda0.clone();
            lpp[a_idx] += h;
            lpp[b_idx] += h;
            let mut lpm = lambda0.clone();
            lpm[a_idx] += h;
            lpm[b_idx] -= h;
            let mut lmp = lambda0.clone();
            lmp[a_idx] -= h;
            lmp[b_idx] += h;
            let mut lmm = lambda0.clone();
            lmm[a_idx] -= h;
            lmm[b_idx] -= h;
            let fd = (dual(&lpp) - dual(&lpm) - dual(&lmp) + dual(&lmm)) / (4.0 * h * h);
            let an = h_analytic[(a_idx, b_idx)];
            assert!(
                (fd - an).abs() < 5e-3 * an.abs().max(1.0),
                "H[{a_idx},{b_idx}]: fd {fd} vs analytic {an}"
            );
        }
    }
}

/// The Eq. 7 → Eq. 8/9 splitting: the d obtained from the two Laplacian
/// solves (with exact inner solver + kernel correction) equals the direct
/// pseudo-inverse Newton direction of the dual system.
#[test]
fn eq8_9_splitting_equals_direct_newton() {
    let mut rng = Pcg64::new(3);
    let n = 5;
    let p = 2;
    let g = generate::random_connected(n, 8, &mut rng);
    let prob = datasets::synthetic_regression(n, p, 60, 0.2, 0.05, &mut rng);
    let (_, f_star) = prob.centralized_optimum(50, 1e-12);

    // One exact SDD-Newton step from λ=0 must land (quadratic dual) on the
    // optimum: verified through convergence in ≤ 2 iterations.
    let backend = NativeBackend;
    let cg = ExactCgSolver::from_graph(&g, 1e-13);
    let mut alg = SddNewton::new(&prob, &backend, &cg, StepSize::Fixed(1.0));
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &prob,
        &mut comm,
        &RunOptions { max_iters: 2, ..Default::default() },
    );
    let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
    assert!(gap < 1e-9, "direct-vs-split mismatch: gap {gap}");
}

/// Theorem 1 flavor: with the theory step size the dual gradient norm is
/// non-increasing (strict decrease phase) on a quadratic instance.
#[test]
fn theorem1_strict_decrease_with_theory_step() {
    let mut rng = Pcg64::new(4);
    let n = 8;
    let p = 3;
    let g = generate::random_connected(n, 16, &mut rng);
    let prob = datasets::synthetic_regression(n, p, 120, 0.2, 0.05, &mut rng);
    let thetas0 = vec![0.0; n * p];
    let (gamma, big_gamma) = sddnewton::problems::assumption1_bounds(&prob, &thetas0);
    let l = laplacian_csr(&g);
    let mun = sddnewton::graph::spectral::mu_max(&l, 1e-10, 10_000, &mut rng).value;
    let mu2 = sddnewton::graph::spectral::mu_2(&l, 1e-10, 100_000, &mut rng).value;
    let step = StepSize::Theory { gamma, big_gamma, mu2, mun, eps: 0.05 };
    assert!(step.value() > 0.0 && step.value() <= 1.0);

    let solver = sddnewton::algorithms::solvers::sddm_for_graph(&g, 0.05, &mut rng);
    let backend = NativeBackend;
    let mut alg = SddNewton::new(&prob, &backend, &solver, step);
    let mut comm = CommGraph::new(&g);
    let mut prev = f64::INFINITY;
    for _ in 0..6 {
        sddnewton::algorithms::ConsensusAlgorithm::step(&mut alg, &prob, &mut comm);
        let thetas = sddnewton::algorithms::ConsensusAlgorithm::thetas(&alg).to_vec();
        let gn = comm.dual_grad_norm(&thetas, p);
        assert!(gn <= prev * (1.0 + 1e-9), "gradient norm increased: {gn} > {prev}");
        prev = gn;
    }
}

/// Primal-dual consistency: at the converged dual iterate the primal is
/// feasible (consensus) and optimal.
#[test]
fn primal_dual_consistency_all_problem_kinds() {
    let mut rng = Pcg64::new(5);
    let g = generate::random_connected(6, 12, &mut rng);
    let problems: Vec<(&str, ConsensusProblem)> = vec![
        ("regression", datasets::synthetic_regression(6, 4, 90, 0.2, 0.05, &mut rng)),
        (
            "logistic-l2",
            datasets::mnist_like(6, 5, 120, 0, sddnewton::problems::logistic::Reg::L2, 0.05, &mut rng),
        ),
        (
            "logistic-sl1",
            datasets::fmri_like(6, 8, 48, 3, 8.0, 0.05, &mut rng),
        ),
        ("london", datasets::london_like(6, 300, 0.05, &mut rng)),
        ("rl", datasets::rl_dcp(6, 60, 25, 0.5, 0.05, &mut rng)),
    ];
    for (name, prob) in problems {
        let (_, f_star) = prob.centralized_optimum(100, 1e-11);
        let solver = sddnewton::algorithms::solvers::sddm_for_graph(&g, 1e-3, &mut rng);
        let backend = NativeBackend;
        let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 25, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-5, "{name}: gap {gap}");
        assert!(
            trace.final_consensus_error() < 1e-4 * trace.records[0].consensus_error.max(1.0),
            "{name}: consensus {}",
            trace.final_consensus_error()
        );
    }
}
