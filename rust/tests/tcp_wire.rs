//! Socket wire-truth suite for the TCP process transport.
//!
//! Three layers of the contract:
//!
//! 1. **Bit parity**: an algorithm driven over real loopback TCP sockets
//!    must produce bit-for-bit the iterates, per-iteration objectives,
//!    and modeled comm ledger of both in-process transports (bulk
//!    `CommGraph` and channel `ShardExchange`).
//! 2. **Wire truth on real bytes**: the observed socket payload byte
//!    count must equal `cross_floats × 8` exactly — the plan-driven model
//!    (`plan_cross_rows`-composed `modeled_cross_messages`) priced in
//!    messages now verifiably prices bytes on a real wire — with frame
//!    header overhead accounted separately as a whole number of 16-byte
//!    headers.
//! 3. **Robustness**: a missing worker surfaces as a typed timeout error,
//!    never a hang; and the full process-deployment path (fork/exec of
//!    `sddnewton worker` ranks) works end to end through the CLI.
//!
//! The frame-codec unit suite lives with the codec in
//! `net::tcp::frame`; these tests exercise real sockets.

use sddnewton::coordinator::tcp::{run_leader, TcpLeader};
use sddnewton::harness::deploy::{run_tcp_cross_transport, TcpJobSpec};
use sddnewton::net::tcp::frame::TcpError;
use sddnewton::util::Pcg64;
use std::time::{Duration, Instant};

/// Spec for one algorithm of the smoke preset on a loopback pool.
fn smoke_spec(algo: &str, workers: usize, iters: usize) -> TcpJobSpec {
    TcpJobSpec {
        experiment: "smoke".to_string(),
        config_path: None,
        algorithms: Some(algo.to_string()),
        seed: None,
        algo_index: 0,
        iters,
        workers,
        partitioning: "contiguous".to_string(),
        solver_seed: 0x51D0,
        hostfile: None,
        stale_tau: 0,
    }
}

/// Run one spec in thread mode (in-process workers speaking real loopback
/// TCP sockets) and assert the full parity + byte wire-truth contract.
fn assert_tcp_parity(spec: TcpJobSpec) {
    let parity = run_tcp_cross_transport(&spec, "127.0.0.1:0", None)
        .unwrap_or_else(|e| panic!("tcp run failed for {spec:?}: {e}"));
    assert!(
        parity.thetas_match_bulk,
        "{}: TCP iterate drifted from the bulk reference",
        parity.algorithm
    );
    assert!(
        parity.thetas_match_shard,
        "{}: TCP iterate drifted from the in-process shard reference",
        parity.algorithm
    );
    assert!(
        parity.objectives_match,
        "{}: per-iteration objectives drifted across transports",
        parity.algorithm
    );
    assert!(parity.ledger_ok, "{}: modeled comm ledger drifted", parity.algorithm);
    // Real socket payloads == plan-driven wire model == channel payloads.
    assert_eq!(
        parity.tcp.cross_messages, parity.modeled_cross,
        "{}: socket payload count drifted from the wire model",
        parity.algorithm
    );
    assert_eq!(
        parity.tcp.cross_messages, parity.shard.cross_messages,
        "{}: socket payload count drifted from the channel transport",
        parity.algorithm
    );
    assert_eq!(
        parity.tcp.cross_floats, parity.shard.cross_floats,
        "{}: socket float count drifted from the channel transport",
        parity.algorithm
    );
    // The byte-level wire truth: payloads are raw f64s — 8 bytes per
    // float, nothing else — and framing overhead is whole 16-byte headers
    // accounted separately.
    assert_eq!(
        parity.tcp.payload_bytes,
        parity.tcp.cross_floats * 8,
        "{}: observed socket payload bytes are not cross_floats × 8",
        parity.algorithm
    );
    assert_eq!(
        parity.tcp.header_bytes % 16,
        0,
        "{}: header overhead is not a whole number of frame headers",
        parity.algorithm
    );
    if spec.workers > 1 {
        assert!(
            parity.tcp.cross_messages > 0,
            "{}: a multi-worker pool must ship boundary traffic",
            parity.algorithm
        );
        assert!(
            parity.tcp.header_bytes > 0,
            "{}: shipped frames must account header overhead",
            parity.algorithm
        );
    }
    assert!(parity.ok(), "{}: parity verdict not ok", parity.algorithm);
}

#[test]
fn sdd_newton_tcp_matches_both_transports_k2() {
    assert_tcp_parity(smoke_spec("sdd", 2, 3));
}

#[test]
fn sdd_newton_tcp_matches_both_transports_k4() {
    assert_tcp_parity(smoke_spec("sdd", 4, 3));
}

#[test]
fn admm_tcp_matches_both_transports_k2() {
    assert_tcp_parity(smoke_spec("admm", 2, 3));
}

#[test]
fn admm_tcp_matches_both_transports_k4() {
    assert_tcp_parity(smoke_spec("admm", 4, 3));
}

#[test]
fn gradient_tcp_matches_both_transports_round_robin() {
    // Round-robin maximizes the cut — every neighbor is remote.
    let mut spec = smoke_spec("grad", 4, 3);
    spec.partitioning = "round_robin".to_string();
    assert_tcp_parity(spec);
}

/// Ranks ride the wire as `u16`: a pool wider than `u16::MAX` must be
/// rejected at bind time with a typed error, never silently truncated
/// into colliding rank ids (the old `rank as u16` bug).
#[test]
fn leader_rejects_pools_wider_than_u16_ranks() {
    let err = TcpLeader::bind("127.0.0.1:0", 70_000)
        .expect_err("a 70000-rank pool cannot be addressed by u16 rank ids");
    assert!(
        matches!(err, TcpError::Protocol { .. }),
        "expected a typed protocol error, got: {err}"
    );
    // The boundary itself is fine.
    assert!(TcpLeader::bind("127.0.0.1:0", u16::MAX as usize).is_ok());
}

/// A worker that never shows up must surface as a typed rendezvous
/// timeout on the leader — quickly, and never as a hang.
#[test]
fn leader_times_out_on_missing_worker() {
    let mut rng = Pcg64::new(77);
    let prob = sddnewton::problems::datasets::synthetic_regression(4, 2, 40, 0.2, 0.05, &mut rng);
    let leader = TcpLeader::bind("127.0.0.1:0", 2).expect("bind leader");
    let owned_of = vec![vec![0usize, 1], vec![2usize, 3]];
    let started = Instant::now();
    let err = run_leader(leader, &prob, owned_of, 1, Duration::from_millis(300))
        .expect_err("a leader with no workers must error, not hang");
    assert!(
        matches!(err, TcpError::Timeout { .. }),
        "expected a rendezvous timeout, got: {err}"
    );
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "timeout took {:?} — deadline not enforced",
        started.elapsed()
    );
}

/// Full process deployment through the CLI: the leader forks `worker`
/// ranks of its own binary over loopback TCP, and the parity table must
/// report ok (exit zero, byte columns present, no DRIFT).
#[test]
fn partitioned_cli_tcp_transport_end_to_end() {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_sddnewton"))
        .args([
            "partitioned",
            "--transport",
            "tcp",
            "--experiment",
            "smoke",
            "--iters",
            "2",
            "--workers",
            "4",
            "--algorithms",
            "sdd,admm",
        ])
        .output()
        .expect("sddnewton binary should run");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "exit nonzero\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("payload B"), "missing payload byte column:\n{stdout}");
    assert!(stdout.contains("header B"), "missing header byte column:\n{stdout}");
    assert!(!stdout.contains("DRIFT"), "tcp parity table reported drift:\n{stdout}");
    for name in ["SDD-Newton", "Distributed ADMM"] {
        let row = stdout
            .lines()
            .find(|l| l.contains(name))
            .unwrap_or_else(|| panic!("missing row for {name}:\n{stdout}"));
        assert!(row.contains("ok"), "{name} not ok:\n{row}");
    }
}
