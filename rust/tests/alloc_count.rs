//! Hot-loop allocation accounting — the tentpole guard for the
//! de-allocation work (reusable solver workspaces, the `BufferPool`
//! free-list, the `ShardExchange` payload arena).
//!
//! A counting `#[global_allocator]` wraps `System` and tallies every
//! `alloc`/`realloc`. Two invariants are asserted:
//!
//! 1. A warmed `solve_ws` (caller-owned pool, second call) allocates
//!    strictly less than the allocating `solve` wrapper (fresh pool every
//!    call) on the identical system — the pool actually gets hits.
//! 2. The partitioned SDD-Newton runtime reaches an allocation **steady
//!    state**: the marginal allocations of iterations 5–6 do not exceed
//!    those of iterations 3–4 (modulo a small slack for hash-map growth
//!    and out-of-order channel arrivals) — nothing accumulates per round.
//!
//! Everything runs inside ONE `#[test]` so parallel test execution can't
//! interleave foreign allocations into a measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use sddnewton::algorithms::solvers::{sddm_for_graph, LaplacianSolver};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, Partition};
use sddnewton::graph::generate;
use sddnewton::harness::experiments::{make_inner_solver, make_sharded_algorithm};
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::{BufferPool, Pcg64};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Allocations performed while running `f`.
fn count<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = allocs();
    let r = f();
    (allocs() - before, r)
}

#[test]
fn hot_loops_reach_allocation_steady_state() {
    // ---- 1. Pooled solver workspaces get hits. -------------------------
    let mut rng = Pcg64::new(777);
    let g = generate::random_connected(60, 150, &mut rng);
    let l = sddnewton::graph::laplacian_csr(&g);
    let solver = sddm_for_graph(&g, 1e-6, &mut rng);
    let w = 4;
    let z = rng.normal_vec(60 * w);
    let mut b = vec![0.0; 60 * w];
    l.matvec_multi_into(&z, w, &mut b);

    // Allocating wrapper: fresh pool per call, every scratch buffer is a
    // new allocation.
    let mut comm = CommGraph::new(&g);
    let (cold, out_cold) = count(|| LaplacianSolver::solve(&solver, &b, w, &mut comm));

    // Caller-owned pool, warmed by one full solve.
    let mut pool = BufferPool::new();
    let mut comm = CommGraph::new(&g);
    let warm_up = LaplacianSolver::solve_ws(&solver, &b, w, &mut comm, &mut pool);
    pool.put(warm_up.x);
    let mut comm = CommGraph::new(&g);
    let (warm, out_warm) =
        count(|| LaplacianSolver::solve_ws(&solver, &b, w, &mut comm, &mut pool));

    // Identical math either way (the pool only recycles capacity).
    assert_eq!(out_cold.x, out_warm.x, "pooled solve must be bit-identical");
    assert!(
        warm < cold,
        "warmed solve_ws must allocate less than the allocating wrapper: \
         warm={warm} cold={cold}"
    );
    pool.put(out_warm.x);

    // ---- 2. Partitioned runtime allocation steady state. ---------------
    let mut rng = Pcg64::new(778);
    let n = 120;
    let g = generate::random_connected(n, 300, &mut rng);
    let prob = datasets::synthetic_regression(n, 3, 360, 0.1, 0.05, &mut rng);
    let kind = AlgoKind::SddNewton { eps: 1e-3, alpha: 1.0 };
    let inner = make_inner_solver(&kind, &g, &mut rng);
    let inner_ref = inner.as_deref();
    let backend = NativeBackend;
    let part = Partition::contiguous(n, 2);

    let mut run_iters = |iters: usize| {
        let (a, out) = count(|| {
            run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
                make_sharded_algorithm(&kind, &prob, &g, &backend, inner_ref, owned)
            })
        });
        assert!(!out.thetas.is_empty());
        a
    };
    let a2 = run_iters(2);
    let a4 = run_iters(4);
    let a6 = run_iters(6);
    let w1 = a4.saturating_sub(a2); // marginal allocs of iterations 3–4
    let w2 = a6.saturating_sub(a4); // marginal allocs of iterations 5–6
    assert!(
        w2 <= w1 + w1 / 4 + 256,
        "partitioned hot loop must not accumulate allocations per \
         iteration: iters 3-4 cost {w1} allocs, iters 5-6 cost {w2}"
    );
}
