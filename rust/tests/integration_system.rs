//! System-level integration: config → harness → coordinator → reports,
//! plus PJRT-vs-native backend equivalence at the algorithm level.

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::config::{AlgoKind, ExperimentConfig, Json};
use sddnewton::coordinator::Campaign;
use sddnewton::graph::generate;
use sddnewton::harness::{report, run_experiment};
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::{NativeBackend, PjrtBackend};
use sddnewton::util::Pcg64;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn full_pipeline_smoke_all_algorithms() {
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.max_iters = 12;
    let res = run_experiment(&cfg);
    assert_eq!(res.traces.len(), cfg.algorithms.len());
    // The contribution must be the most accurate method.
    let gaps: Vec<f64> = res
        .traces
        .iter()
        .map(|t| (t.final_objective() - res.f_star).abs() + t.final_consensus_error())
        .collect();
    let best = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
    assert_eq!(gaps[0], best, "SDD-Newton should lead: {gaps:?}");
    // Reports render.
    let table = report::summary_table(&res);
    assert!(table.contains("Distributed SDD-Newton"));
}

#[test]
fn pjrt_and_native_agree_on_full_run() {
    if !artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping (run `make artifacts`)");
        return;
    }
    // Shape must match the smoke artifact: n=8, p=5.
    let mut rng = Pcg64::new(61);
    let g = generate::random_connected(8, 16, &mut rng);
    let prob = datasets::synthetic_regression(8, 5, 160, 0.2, 0.05, &mut rng);
    let pjrt = match PjrtBackend::for_problem(&prob, artifacts_dir()) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let run_with = |backend: &dyn sddnewton::runtime::LocalBackend| {
        let mut rng2 = Pcg64::new(62);
        let solver = sddm_for_graph(&g, 1e-6, &mut rng2);
        let mut alg = SddNewton::new(&prob, backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        run(&mut alg, &prob, &mut comm, &RunOptions { max_iters: 8, ..Default::default() })
    };
    let t_native = run_with(&NativeBackend);
    let t_pjrt = run_with(&pjrt);
    for (a, b) in t_native.records.iter().zip(&t_pjrt.records) {
        assert!(
            (a.objective - b.objective).abs() < 1e-6 * a.objective.abs().max(1.0),
            "iter {}: native {} vs pjrt {}",
            a.iter,
            a.objective,
            b.objective
        );
        // Communication accounting is near-identical; the Richardson sweep
        // count may differ by ±1 when the residual sits at the ε threshold
        // (backend numerics differ in the last ulps).
        let (ma, mb) = (a.comm.messages as f64, b.comm.messages as f64);
        assert!(
            (ma - mb).abs() <= 0.1 * ma.max(1.0),
            "iter {}: native {} vs pjrt {} messages",
            a.iter,
            a.comm.messages,
            b.comm.messages
        );
    }
}

#[test]
fn campaign_writes_report_bundle() {
    let dir = std::env::temp_dir().join("sddn_it_campaign");
    let _ = std::fs::remove_dir_all(&dir);
    let mut campaign = Campaign::from_presets(&["smoke"], &dir).unwrap();
    campaign.jobs[0].max_iters = 4;
    campaign.jobs[0].algorithms =
        vec![AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 }, AlgoKind::Admm { beta: 1.0 }];
    let outcomes = campaign.run().unwrap();
    let text = std::fs::read_to_string(&outcomes[0].csv_path).unwrap();
    // header + 2 algorithms × 5 records.
    assert_eq!(text.lines().count(), 1 + 2 * 5);
}

#[test]
fn json_config_roundtrip_drives_harness() {
    let doc = Json::parse(
        r#"{"preset":"smoke","nodes":6,"edges":10,"max_iters":4,
            "algorithms":["sdd","grad"],"seed":99}"#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_json(&doc).unwrap();
    let res = run_experiment(&cfg);
    assert_eq!(res.traces.len(), 2);
    assert_eq!(res.config.nodes, 6);
    assert!(res.traces[0].final_objective().is_finite());
}

#[test]
fn divergent_steps_are_stabilized() {
    // A wildly too-large gradient step must be rescued by the harness's
    // grid-search-like retry, not produce NaNs in the report.
    let mut cfg = ExperimentConfig::preset("smoke").unwrap();
    cfg.max_iters = 30;
    cfg.algorithms = vec![AlgoKind::Gradient { alpha: 10.0 }];
    let res = run_experiment(&cfg);
    assert!(res.traces[0].final_objective().is_finite());
    let o0 = res.traces[0].records[0].objective;
    assert!(res.traces[0].final_objective() < o0 * 2.0 + 1.0);
}

#[test]
fn comm_graph_is_the_only_window() {
    // Algorithms never exceed the graph's edge budget per round: for one
    // gradient step the message count is exactly 2m·1 round.
    let mut rng = Pcg64::new(71);
    let g = generate::random_connected(9, 14, &mut rng);
    let prob = datasets::synthetic_regression(9, 3, 90, 0.2, 0.05, &mut rng);
    let mut comm = CommGraph::new(&g);
    let mut alg = sddnewton::algorithms::gradient::DistGradient::new(
        &prob,
        &g,
        sddnewton::algorithms::gradient::GradSchedule::Constant(1e-3),
    );
    sddnewton::algorithms::ConsensusAlgorithm::step(&mut alg, &prob, &mut comm);
    assert_eq!(comm.stats().messages, 2 * 14);
    assert_eq!(comm.stats().rounds, 1);
}
