//! Integration test driving the real `sddnewton` binary: the
//! `partitioned` subcommand's parity table must include the
//! real-vs-modeled wire columns, report `ok` for every algorithm, and
//! exit zero — the nonzero-on-drift contract the CI gate relies on.

use std::process::Command;

fn run_partitioned(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_sddnewton"))
        .arg("partitioned")
        .args(args)
        .output()
        .expect("sddnewton binary should run")
}

#[test]
fn partitioned_cli_reports_wire_parity_and_exits_zero() {
    let out = run_partitioned(&[
        "--experiment",
        "smoke",
        "--iters",
        "2",
        "--workers",
        "3",
        "--algorithms",
        "grad,admm",
    ]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "exit nonzero\nstdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stdout.contains("wire real"), "missing real wire column:\n{stdout}");
    assert!(stdout.contains("wire model"), "missing modeled wire column:\n{stdout}");
    assert!(!stdout.contains("DRIFT"), "parity table reported drift:\n{stdout}");
    // Both requested algorithms made it into the table with an ok verdict.
    for name in ["Distributed ADMM", "Distributed Gradients"] {
        let row = stdout
            .lines()
            .find(|l| l.contains(name))
            .unwrap_or_else(|| panic!("missing row for {name}:\n{stdout}"));
        assert!(row.contains("ok"), "{name} not ok:\n{row}");
    }
}

#[test]
fn partitioned_cli_rejects_unknown_partitioning() {
    let out = run_partitioned(&["--partitioning", "voronoi"]);
    assert!(!out.status.success(), "unknown partitioning must exit nonzero");
}
