//! Regenerates Fig. 3(a,b): objective and consensus error on the
//! London-Schools-like regression task (15 362 instances, 139 school
//! blocks, 27 features).
//!
//!     cargo bench --bench fig3_london

use sddnewton::benchkit::{bench, is_smoke, result_row, section, BenchOpts};
use sddnewton::config::{ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    section("Fig 3(a,b): London Schools regression, n=50 m=150 p=27");
    let mut cfg = ExperimentConfig::preset("fig3-london").unwrap();
    cfg.max_iters = 60;
    if is_smoke() {
        cfg.nodes = 8;
        cfg.edges = 16;
        cfg.max_iters = 5;
        cfg.problem = ProblemKind::LondonLike { m_total: 400, mu: 0.05 };
        cfg.algorithms.truncate(2);
    }
    let mut res = None;
    bench("fig3_london/all-algorithms", &BenchOpts { warmup_iters: 0, sample_iters: 1 }, || {
        res = Some(run_experiment(&cfg));
    });
    let res = res.unwrap();
    print!("{}", report::summary_table(&res));
    std::fs::create_dir_all("results").ok();
    report::write_csv(&res, "results/fig3_london.csv").unwrap();
    println!("{}", report::ascii_plot(&res.traces, res.f_star, 72, 16));
    for (alg, iters) in report::iters_table(&res, 1e-4) {
        result_row(
            &format!("fig3ab/iters_to_1e-4/{alg}"),
            iters.map(|i| i.to_string()).unwrap_or_else(|| "not reached".into()),
        );
    }
}
