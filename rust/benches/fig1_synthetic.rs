//! Regenerates Fig. 1(a,b): objective value and consensus error vs
//! iterations on the synthetic regression dataset (100 nodes, 250 edges,
//! p = 80), all six algorithms.
//!
//! Paper shape to reproduce: SDD-Newton reaches the optimum in ≈40
//! iterations; the second-best needs ≈200; distributed gradients and
//! NN-1/2 are worst.
//!
//!     cargo bench --bench fig1_synthetic

use sddnewton::benchkit::{bench, is_smoke, result_row, section, BenchOpts};
use sddnewton::config::{ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    section("Fig 1(a,b): synthetic regression, n=100 m=250 p=80");
    let mut cfg = ExperimentConfig::preset("fig1-synthetic").unwrap();
    cfg.max_iters = 60;
    if is_smoke() {
        cfg.nodes = 12;
        cfg.edges = 30;
        cfg.max_iters = 6;
        cfg.problem =
            ProblemKind::SyntheticRegression { p: 8, m_total: 480, noise: 0.5, mu: 0.05 };
        cfg.algorithms.truncate(3);
    }
    let mut res = None;
    bench("fig1_synthetic/all-algorithms", &BenchOpts { warmup_iters: 0, sample_iters: 1 }, || {
        res = Some(run_experiment(&cfg));
    });
    let res = res.unwrap();
    print!("{}", report::summary_table(&res));

    // Figure 1(a): objective vs iterations (CSV written for plotting).
    std::fs::create_dir_all("results").ok();
    report::write_csv(&res, "results/fig1_synthetic.csv").unwrap();
    println!("series → results/fig1_synthetic.csv");
    println!("{}", report::ascii_plot(&res.traces, res.f_star, 72, 18));

    // Headline rows.
    for tol in [1e-3, 1e-5] {
        for (name, iters) in report::iters_table(&res, tol) {
            result_row(
                &format!("iters_to_{tol:.0e}/{name}"),
                iters.map(|i| i.to_string()).unwrap_or_else(|| "not reached".into()),
            );
        }
    }
}
