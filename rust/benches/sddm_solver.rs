//! SDDM-solver scaling study (supporting material for Section 2):
//! solve time / message complexity vs graph size, accuracy, and topology.
//!
//!     cargo bench --bench sddm_solver

use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::benchkit::{bench, result_row, section, BenchOpts};
use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::net::CommStats;
use sddnewton::util::Pcg64;

fn main() {
    section("SDDM solver scaling: random graphs, eps = 1e-6");
    for &(n, m) in &[(50usize, 125usize), (100, 250), (200, 500), (400, 1000)] {
        let mut rng = Pcg64::new(n as u64);
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let solver = sddm_for_graph(&g, 1e-6, &mut rng);
        let z = rng.normal_vec(n);
        let b = l.matvec(&z);
        let mut msgs = 0u64;
        let s = bench(
            &format!("sddm/n{n}_m{m}"),
            &BenchOpts { warmup_iters: 1, sample_iters: 5 },
            || {
                let mut stats = CommStats::default();
                let out = solver.solve(&b, 1, &mut stats);
                assert!(out.converged);
                msgs = stats.messages;
            },
        );
        result_row(&format!("sddm/n{n}/depth"), solver.chain.depth);
        result_row(&format!("sddm/n{n}/lambda2"), format!("{:.4}", solver.chain.lambda2));
        result_row(&format!("sddm/n{n}/messages"), msgs);
        result_row(&format!("sddm/n{n}/median_s"), format!("{:.5}", s.median));
    }

    section("SDDM solver vs accuracy (n=100, m=250)");
    let mut rng = Pcg64::new(77);
    let g = generate::random_connected(100, 250, &mut rng);
    let l = laplacian_csr(&g);
    let z = rng.normal_vec(100);
    let b = l.matvec(&z);
    for eps in [1e-1, 1e-2, 1e-4, 1e-6, 1e-8] {
        let solver = sddm_for_graph(&g, eps, &mut rng);
        let mut stats = CommStats::default();
        let out = solver.solve(&b, 1, &mut stats);
        assert!(out.converged);
        result_row(
            &format!("sddm/eps{eps:.0e}"),
            format!("{} messages, {} sweeps", stats.messages, out.sweeps),
        );
    }

    section("SDDM solver vs topology (n=64, eps=1e-6)");
    for (name, g) in [
        ("complete", generate::complete(64)),
        ("random", generate::random_connected(64, 160, &mut rng)),
        ("grid8x8", generate::grid(8, 8)),
        ("cycle", generate::cycle(64)),
    ] {
        let l = laplacian_csr(&g);
        let solver = sddm_for_graph(&g, 1e-6, &mut rng);
        let z = rng.normal_vec(64);
        let b = l.matvec(&z);
        let mut stats = CommStats::default();
        let t = sddnewton::util::Timer::start();
        let out = solver.solve(&b, 1, &mut stats);
        result_row(
            &format!("sddm/topology/{name}"),
            format!(
                "depth {} λ₂ {:.4} → {} messages, {} sweeps, {:.1} ms (converged={})",
                solver.chain.depth,
                solver.chain.lambda2,
                stats.messages,
                out.sweeps,
                t.millis(),
                out.converged
            ),
        );
    }

    section("Batched multi-RHS solves (n=100, m=250, eps=1e-6)");
    let solver = sddm_for_graph(&g_random(), 1e-6, &mut rng);
    for w in [1usize, 8, 32, 80] {
        let n = 100;
        let l = laplacian_csr(&g_random());
        let mut bm = vec![0.0; n * w];
        for j in 0..w {
            let zc = rng.normal_vec(n);
            let col = l.matvec(&zc);
            for i in 0..n {
                bm[i * w + j] = col[i];
            }
        }
        let mut stats = CommStats::default();
        let s = bench(
            &format!("sddm/multirhs_w{w}"),
            &BenchOpts { warmup_iters: 1, sample_iters: 3 },
            || {
                let mut st = CommStats::default();
                let out = solver.solve(&bm, w, &mut st);
                assert!(out.converged);
                stats = st;
            },
        );
        result_row(
            &format!("sddm/multirhs_w{w}"),
            format!("{} messages, {:.5}s median", stats.messages, s.median),
        );
    }
}

fn g_random() -> sddnewton::graph::Graph {
    let mut rng = Pcg64::new(4242);
    generate::random_connected(100, 250, &mut rng)
}
