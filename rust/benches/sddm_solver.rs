//! SDDM-solver scaling study (supporting material for Section 2):
//! solve time / message complexity vs graph size, accuracy, and topology,
//! plus the serial-vs-parallel speedup table for the CSR matvec hot path.
//!
//!     cargo bench --bench sddm_solver
//!     cargo bench --bench sddm_solver -- --smoke      # CI smoke run
//!     cargo bench --bench sddm_solver -- --threads 4  # pin the pool

use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section};
use sddnewton::graph::{generate, laplacian_csr};
use sddnewton::net::CommGraph;
use sddnewton::util::Pcg64;

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    result_row("parallelism/threads", sddnewton::par::threads());

    section("SDDM solver scaling: random graphs, eps = 1e-6");
    let sizes: &[(usize, usize)] = if smoke {
        &[(50, 125), (100, 250)]
    } else {
        &[(50, 125), (100, 250), (200, 500), (400, 1000)]
    };
    for &(n, m) in sizes {
        let mut rng = Pcg64::new(n as u64);
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let solver = sddm_for_graph(&g, 1e-6, &mut rng);
        let z = rng.normal_vec(n);
        let b = l.matvec(&z);
        let mut msgs = 0u64;
        let s = bench(&format!("sddm/n{n}_m{m}"), &opts, || {
            let mut comm = CommGraph::new(&g);
            let out = solver.solve(&b, 1, &mut comm);
            assert!(out.converged);
            msgs = comm.stats().messages;
        });
        result_row(&format!("sddm/n{n}/depth"), solver.chain.depth);
        result_row(&format!("sddm/n{n}/lambda2"), format!("{:.4}", solver.chain.lambda2));
        result_row(&format!("sddm/n{n}/messages"), msgs);
        result_row(&format!("sddm/n{n}/median_s"), format!("{:.5}", s.median));
    }

    section("SDDM solver vs accuracy (n=100, m=250)");
    let mut rng = Pcg64::new(77);
    let g = generate::random_connected(100, 250, &mut rng);
    let l = laplacian_csr(&g);
    let z = rng.normal_vec(100);
    let b = l.matvec(&z);
    let eps_list: &[f64] = if smoke { &[1e-2, 1e-6] } else { &[1e-1, 1e-2, 1e-4, 1e-6, 1e-8] };
    for &eps in eps_list {
        let solver = sddm_for_graph(&g, eps, &mut rng);
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged);
        result_row(
            &format!("sddm/eps{eps:.0e}"),
            format!("{} messages, {} sweeps", comm.stats().messages, out.sweeps),
        );
    }

    section("SDDM solver vs topology (n=64, eps=1e-6)");
    for (name, g) in [
        ("complete", generate::complete(64)),
        ("random", generate::random_connected(64, 160, &mut rng)),
        ("grid8x8", generate::grid(8, 8)),
        ("cycle", generate::cycle(64)),
    ] {
        let l = laplacian_csr(&g);
        let solver = sddm_for_graph(&g, 1e-6, &mut rng);
        let z = rng.normal_vec(64);
        let b = l.matvec(&z);
        let mut comm = CommGraph::new(&g);
        let t = sddnewton::util::Timer::start();
        let out = solver.solve(&b, 1, &mut comm);
        result_row(
            &format!("sddm/topology/{name}"),
            format!(
                "depth {} λ₂ {:.4} → {} messages, {} sweeps, {:.1} ms (converged={})",
                solver.chain.depth,
                solver.chain.lambda2,
                comm.stats().messages,
                out.sweeps,
                t.millis(),
                out.converged
            ),
        );
    }

    section("Batched multi-RHS solves (n=100, m=250, eps=1e-6)");
    let g_batch = g_random();
    let solver = sddm_for_graph(&g_batch, 1e-6, &mut rng);
    let widths: &[usize] = if smoke { &[1, 8] } else { &[1, 8, 32, 80] };
    for &w in widths {
        let n = 100;
        let l = laplacian_csr(&g_batch);
        let mut bm = vec![0.0; n * w];
        for j in 0..w {
            let zc = rng.normal_vec(n);
            let col = l.matvec(&zc);
            for i in 0..n {
                bm[i * w + j] = col[i];
            }
        }
        let mut msgs = 0u64;
        let s = bench(&format!("sddm/multirhs_w{w}"), &opts, || {
            let mut comm = CommGraph::new(&g_batch);
            let out = solver.solve(&bm, w, &mut comm);
            assert!(out.converged);
            msgs = comm.stats().messages;
        });
        result_row(
            &format!("sddm/multirhs_w{w}"),
            format!("{} messages, {:.5}s median", msgs, s.median),
        );
    }

    // ---- Parallel execution substrate: serial vs parallel speedup ------
    // The L3 hot path of the SDD solver is the multi-RHS CSR matvec; on a
    // 10k-node chain (path) graph the row blocks are perfectly
    // independent, so the speedup table below is the headline number for
    // the `par` substrate. Results are bit-for-bit identical across
    // thread counts (see tests/prop_parallel.rs).
    section("Parallel multi-RHS CSR matvec: 10k-node chain");
    let n = 10_000;
    let w = if smoke { 16 } else { 64 };
    let reps = if smoke { 4 } else { 32 };
    let chain_g = generate::path(n);
    let lc = laplacian_csr(&chain_g);
    let mut rng2 = Pcg64::new(4321);
    let x: Vec<f64> = (0..n * w).map(|_| rng2.normal()).collect();
    let mut y = vec![0.0; n * w];
    let mut medians: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let s = bench(&format!("matvec_multi/n{n}_w{w}_t{threads}"), &opts, || {
            for _ in 0..reps {
                lc.matvec_multi_into_threads(&x, w, &mut y, threads);
            }
        });
        medians.push((threads, s.median));
    }
    let t1 = medians[0].1.max(1e-12);
    for &(t, med) in &medians[1..] {
        result_row(
            &format!("matvec_multi/speedup_t{t}"),
            format!("{:.2}x (serial {:.5}s vs {:.5}s)", t1 / med.max(1e-12), t1, med),
        );
    }

    // Solver-level effect: a wide crude solve on the same chain graph.
    // Depth is pinned: the implicit chain applies X^{2^i} as 2^i rounds,
    // and a 10k path's walk spectrum would otherwise drive the auto depth
    // (and with it the round count) through the roof.
    section("Parallel crude solve: 10k-node chain, batched RHS");
    let wide_w = if smoke { 4 } else { 16 };
    let chain = sddnewton::sddm::Chain::build(
        &lc,
        &sddnewton::sddm::ChainOptions { depth: Some(3), ..Default::default() },
        &mut rng2,
    )
    .expect("path Laplacian is SDD");
    let solver_chain =
        sddnewton::sddm::SddmSolver::new(chain, sddnewton::sddm::SolverOptions::default());
    let mut bw = vec![0.0; n * wide_w];
    for j in 0..wide_w {
        let zc = rng2.normal_vec(n);
        let col = lc.matvec(&zc);
        for i in 0..n {
            bw[i * wide_w + j] = col[i];
        }
    }
    let mut solve_medians: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 4] {
        sddnewton::par::set_threads(threads);
        let s = bench(&format!("crude_solve/n{n}_w{wide_w}_t{threads}"), &opts, || {
            let mut comm = CommGraph::new(&chain_g);
            let _ = solver_chain.crude_solve(&bw, wide_w, &mut comm);
        });
        solve_medians.push((threads, s.median));
    }
    sddnewton::par::set_threads(0);
    result_row(
        "crude_solve/speedup_t4",
        format!("{:.2}x", solve_medians[0].1.max(1e-12) / solve_medians[1].1.max(1e-12)),
    );
}

fn g_random() -> sddnewton::graph::Graph {
    let mut rng = Pcg64::new(4242);
    generate::random_connected(100, 250, &mut rng)
}
