//! Staleness sweep: what bounded-staleness halos and ADAPD-style local
//! steps buy on the wire, and what they cost in convergence.
//!
//! Two sections, both emitting trajectory points into the
//! `BENCH_staleness_sweep_*.json` report:
//!
//! 1. **Convergence vs τ** — distributed gradient and SDD-Newton under
//!    `StaleState` halo reuse for τ ∈ {0, 1, 2, 4}: final objective,
//!    real cross-worker wire bytes (asserted *strictly decreasing* in
//!    τ), and the savings ledger (asserted to model exactly the elided
//!    rounds: `skipped = iters − ⌈iters/(τ+1)⌉`). The τ = 0 sample is
//!    asserted bit-for-bit identical to the staleness-free construction.
//!
//! 2. **Iterations vs comm rounds** — local-step Newton at a fixed
//!    local-work budget: `local_steps ∈ {1, 2, 4}` with outer iteration
//!    counts scaled so every sample performs the same number of local
//!    solves, so the wire bytes (asserted strictly decreasing in
//!    `local_steps`) buy comparable compute.
//!
//!     cargo bench --bench staleness_sweep
//!     cargo bench --bench staleness_sweep -- --smoke    # CI smoke run

use sddnewton::algorithms::solvers::LaplacianSolver;
use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section, BenchReport};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, Partition};
use sddnewton::graph::generate;
use sddnewton::harness::experiments::{make_inner_solver, make_sharded_algorithm_stale};
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    result_row("parallelism/threads", sddnewton::par::threads());

    let (n, m_edges, p, samples, iters, k) =
        if smoke { (16, 32, 3, 120, 4, 2) } else { (64, 160, 6, 1_280, 8, 4) };
    let taus: &[u64] = if smoke { &[0, 1] } else { &[0, 1, 2, 4] };
    let mut report = BenchReport::new("staleness_sweep");
    report.config_num("n", n as f64);
    report.config_num("m", m_edges as f64);
    report.config_num("p", p as f64);
    report.config_num("iters", iters as f64);
    report.config_num("workers", k as f64);

    let mut rng = Pcg64::new(3141);
    let g = generate::random_connected(n, m_edges, &mut rng);
    let prob = datasets::synthetic_regression(n, p, samples, 0.1, 0.05, &mut rng);
    let backend = NativeBackend;
    let part = Partition::contiguous(n, k);

    section(&format!(
        "Convergence vs staleness bound: n={n}, m={m_edges}, p={p}, k={k}, {iters} iterations"
    ));
    let kinds: [(&str, AlgoKind); 2] = [
        ("grad", AlgoKind::Gradient { alpha: 0.01 }),
        ("sdd_newton", AlgoKind::SddNewton { eps: 1e-4, alpha: 1.0 }),
    ];
    for (name, kind) in &kinds {
        let kind_timer = sddnewton::util::Timer::start();
        let solver = make_inner_solver(kind, &g, &mut rng);
        let solver_ref: Option<&dyn LaplacianSolver> = solver.as_deref();
        // Staleness-free reference — the τ = 0 sample must reproduce it
        // bit for bit (iterates and full modeled ledger).
        let reference = run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
            make_sharded_algorithm_stale(kind, &prob, &g, &backend, solver_ref, owned, 0)
        });
        let mut prev_floats: Option<u64> = None;
        for &tau in taus {
            let mut last = None;
            let s = bench(&format!("{name}/tau{tau}"), &opts, || {
                last = Some(run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
                    make_sharded_algorithm_stale(kind, &prob, &g, &backend, solver_ref, owned, tau)
                }));
            });
            let out = last.unwrap();
            if tau == 0 {
                assert_eq!(
                    out.thetas, reference.thetas,
                    "{name}: tau=0 must be bit-identical to the staleness-free path"
                );
                assert_eq!(out.comm, reference.comm, "{name}: tau=0 ledger drifted");
                assert_eq!(out.cross_floats, reference.cross_floats);
            }
            // The savings ledger models exactly the elided refresh rounds:
            // one policy-eligible exchange per iteration, refreshed every
            // τ+1 rounds.
            let refreshes = iters as u64 / (tau + 1)
                + u64::from(iters as u64 % (tau + 1) != 0);
            assert_eq!(
                out.comm.skipped_rounds,
                iters as u64 - refreshes,
                "{name}/tau{tau}: skipped-round ledger drifted from the refresh cadence"
            );
            assert_eq!(out.comm.saved_floats, out.comm.saved_messages * p as u64);
            // Staleness must actually take traffic off the wire.
            if let Some(prev) = prev_floats {
                assert!(
                    out.cross_floats < prev,
                    "{name}/tau{tau}: cross floats {} not strictly below {prev}",
                    out.cross_floats
                );
            }
            prev_floats = Some(out.cross_floats);
            let objective = out.records.last().map(|r| r.objective).unwrap_or(f64::NAN);
            report.metric(&format!("{name}/tau{tau}/final_objective"), objective);
            report.metric(&format!("{name}/tau{tau}/wire_bytes"), (8 * out.cross_floats) as f64);
            report.metric(
                &format!("{name}/tau{tau}/skipped_rounds"),
                out.comm.skipped_rounds as f64,
            );
            result_row(
                &format!("{name}/tau{tau}"),
                format!(
                    "objective {objective:.6e} | {} wire bytes | {} skipped rounds | \
                     {:.5}s median",
                    8 * out.cross_floats,
                    out.comm.skipped_rounds,
                    s.median
                ),
            );
        }
        report.phase(name, kind_timer.secs());
    }

    // Fixed local-work budget: every sample performs `budget` local
    // solves; more local steps per outer iteration ⇒ fewer outer
    // iterations ⇒ fewer real exchange rounds for the same compute.
    let budget = if smoke { 4 } else { 16 };
    section(&format!("Iterations vs comm rounds: local-step Newton, budget {budget} solves"));
    let mut prev_floats: Option<u64> = None;
    for &steps in &[1usize, 2, 4] {
        let outer = budget / steps;
        if outer == 0 {
            continue;
        }
        let kind = AlgoKind::LocalNewton { eta: 0.5, local_steps: steps, comm_rounds: 1 };
        let mut last = None;
        let s = bench(&format!("local/steps{steps}"), &opts, || {
            last = Some(run_partitioned_baseline(&prob, &g, &part, outer, &|owned| {
                make_sharded_algorithm_stale(&kind, &prob, &g, &backend, None, owned, 0)
            }));
        });
        let out = last.unwrap();
        if let Some(prev) = prev_floats {
            assert!(
                out.cross_floats < prev,
                "local/steps{steps}: cross floats {} not strictly below {prev} at equal \
                 local work",
                out.cross_floats
            );
        }
        prev_floats = Some(out.cross_floats);
        // The ledger splits real rounds from modeled savings: per outer
        // iteration, 1 real mixing round and steps−1 skipped rounds.
        assert_eq!(out.comm.skipped_rounds, (outer * (steps - 1)) as u64);
        let objective = out.records.last().map(|r| r.objective).unwrap_or(f64::NAN);
        report.metric(&format!("local/steps{steps}/final_objective"), objective);
        report.metric(&format!("local/steps{steps}/wire_bytes"), (8 * out.cross_floats) as f64);
        result_row(
            &format!("local/steps{steps}"),
            format!(
                "{outer} outer iters | objective {objective:.6e} | {} wire bytes | \
                 {} skipped rounds | {:.5}s median",
                8 * out.cross_floats,
                out.comm.skipped_rounds,
                s.median
            ),
        );
    }

    let path = report.write().expect("bench report must be writable");
    result_row("report", path.display());
}
