//! Regenerates Fig. 1(c–f): MNIST-like logistic regression with L1
//! (smoothed) and L2 regularizers, 10 nodes / 20 edges / p = 150.
//!
//!     cargo bench --bench fig1_mnist

use sddnewton::benchkit::{bench, is_smoke, result_row, section, BenchOpts};
use sddnewton::config::{ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    for name in ["fig1-mnist-l2", "fig1-mnist-l1"] {
        section(&format!("Fig 1({}): {name}, n=10 m=20 p=150",
            if name.ends_with("l2") { "e,f" } else { "c,d" }));
        let mut cfg = ExperimentConfig::preset(name).unwrap();
        cfg.max_iters = 30;
        // The paper keeps "the most successful algorithms from previous
        // experiments" for this figure.
        cfg.algorithms.truncate(4);
        if is_smoke() {
            cfg.nodes = 6;
            cfg.edges = 12;
            cfg.max_iters = 5;
            cfg.problem = ProblemKind::MnistLike {
                p: 20,
                m_total: 240,
                l1: name.ends_with("l1"),
                mu: 0.01,
            };
            cfg.algorithms.truncate(2);
        }
        let mut res = None;
        bench(&format!("{name}/all-algorithms"), &BenchOpts { warmup_iters: 0, sample_iters: 1 }, || {
            res = Some(run_experiment(&cfg));
        });
        let res = res.unwrap();
        print!("{}", report::summary_table(&res));
        std::fs::create_dir_all("results").ok();
        report::write_csv(&res, format!("results/{name}.csv")).unwrap();
        for (alg, iters) in report::iters_table(&res, 1e-3) {
            result_row(
                &format!("{name}/iters_to_1e-3/{alg}"),
                iters.map(|i| i.to_string()).unwrap_or_else(|| "not reached".into()),
            );
        }
        println!();
    }
}
