//! Regenerates Fig. 3(c,d): reinforcement-learning policy search on the
//! double cart-pole via reward-weighted regression (H.3), rollouts from
//! the built-in DCP simulator.
//!
//!     cargo bench --bench fig3_rl

use sddnewton::benchkit::{bench, is_smoke, result_row, section, BenchOpts};
use sddnewton::config::{ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    section("Fig 3(c,d): RL double cart-pole, n=20 m=50, 2000 rollouts × 50 steps");
    let mut cfg = ExperimentConfig::preset("fig3-rl").unwrap();
    cfg.max_iters = 40;
    if is_smoke() {
        cfg.nodes = 6;
        cfg.edges = 12;
        cfg.max_iters = 5;
        cfg.problem = ProblemKind::RlDcp { rollouts: 60, t_len: 25, sigma: 0.5, mu: 0.05 };
        cfg.algorithms.truncate(2);
    }
    let mut res = None;
    bench("fig3_rl/all-algorithms", &BenchOpts { warmup_iters: 0, sample_iters: 1 }, || {
        res = Some(run_experiment(&cfg));
    });
    let res = res.unwrap();
    print!("{}", report::summary_table(&res));
    std::fs::create_dir_all("results").ok();
    report::write_csv(&res, "results/fig3_rl.csv").unwrap();
    println!("{}", report::ascii_plot(&res.traces, res.f_star, 72, 16));
    for (alg, iters) in report::iters_table(&res, 1e-4) {
        result_row(
            &format!("fig3cd/iters_to_1e-4/{alg}"),
            iters.map(|i| i.to_string()).unwrap_or_else(|| "not reached".into()),
        );
    }
}
