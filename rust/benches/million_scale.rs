//! Million-node SDD-Newton on the partitioned worker runtime.
//!
//! The scale target the hot-loop de-allocation work exists for: a
//! 10⁶-node / ~10⁷-edge expander, k = 16 workers, the full SDD-Newton
//! pipeline (streaming graph generation → SDDM chain build → serial
//! bulk-synchronous reference → partitioned run). Every phase is timed
//! and persisted to `BENCH_million_scale_<date>.json` (see
//! `docs/BENCHMARKS.md`), and the partitioned run is held to the same
//! two contracts the small benches enforce: bit-for-bit equality with
//! the serial path, and real wire traffic equal to the plan-driven
//! model.
//!
//!     cargo bench --bench million_scale              # full scale (slow)
//!     cargo bench --bench million_scale -- --smoke   # CI-sized run
//!     cargo bench --bench million_scale -- --threads 4

use sddnewton::algorithms::{run, RunOptions};
use sddnewton::benchkit::{cli_opts, is_smoke, result_row, section, BenchReport};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, Partition};
use sddnewton::graph::generate;
use sddnewton::harness::experiments::{
    make_inner_solver, make_sharded_algorithm, modeled_cross_messages,
};
use sddnewton::net::CommGraph;
use sddnewton::problems::datasets;
use sddnewton::runtime::NativeBackend;
use sddnewton::util::{Pcg64, Timer};

fn main() {
    let _opts = cli_opts();
    let smoke = is_smoke();
    // Smoke shrinks every axis so CI proves the pipeline end to end in
    // seconds; the full shape is the committed trajectory point.
    let (n, cycles, k, p, iters) =
        if smoke { (1_000, 3, 4, 2, 1) } else { (1_000_000, 11, 16, 4, 2) };
    let eps = 1e-2;
    let mut rng = Pcg64::new(4242);
    let mut report = BenchReport::new("million_scale");
    report.config_str("algorithm", "sdd_newton");
    report.config_str("graph", "expander");
    report.config_num("cycles", cycles as f64);
    report.config_num("k_workers", k as f64);
    report.config_num("p", p as f64);
    report.config_num("iters", iters as f64);
    report.config_num("eps", eps);

    section(&format!(
        "Million-scale SDD-Newton: n={n}, {cycles}-cycle expander, k={k} workers, \
         p={p}, {iters} iterations, eps={eps}"
    ));

    let t = Timer::start();
    let g = generate::expander(n, cycles, &mut rng);
    report.phase("graph_generate", t.secs());
    report.config_num("n", g.n as f64);
    report.config_num("m", g.m() as f64);
    result_row("graph", format!("n={} m={} max_degree={}", g.n, g.m(), g.max_degree()));

    let t = Timer::start();
    let prob = datasets::synthetic_regression(n, p, 2 * n, 0.1, 0.05, &mut rng);
    report.phase("problem_generate", t.secs());

    let kind = AlgoKind::SddNewton { eps, alpha: 1.0 };
    let t = Timer::start();
    let solver = make_inner_solver(&kind, &g, &mut rng);
    report.phase("chain_build", t.secs());
    let solver_ref = solver.as_deref();
    let backend = NativeBackend;

    // Serial bulk-synchronous reference — one instance owns every node.
    // Its wall time is the speedup denominator; its iterates and modeled
    // ledger are the correctness oracle for the partitioned run.
    let t = Timer::start();
    let mut alg =
        make_sharded_algorithm(&kind, &prob, &g, &backend, solver_ref, (0..n).collect());
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &prob,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );
    let serial_secs = t.secs();
    report.phase("serial_reference", serial_secs);
    let serial_stats = *comm.stats();
    result_row(
        "serial",
        format!("{} modeled msgs | {:.3}s", serial_stats.messages, serial_secs),
    );

    // Partitioned run across k workers.
    let part = Partition::contiguous(n, k);
    let t = Timer::start();
    let out = run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
        make_sharded_algorithm(&kind, &prob, &g, &backend, solver_ref, owned)
    });
    let partitioned_secs = t.secs();
    report.phase("partitioned_run", partitioned_secs);

    // Contract 1: bit-for-bit equality with the serial path.
    assert_eq!(
        out.thetas, trace.final_thetas,
        "partitioned run drifted from the serial path"
    );
    assert_eq!(out.comm, serial_stats, "modeled ledger drifted");
    // Contract 2: real wire traffic equals the plan-driven model.
    let wire_model = modeled_cross_messages(&kind, &g, &part, iters, &serial_stats);
    assert_eq!(
        out.cross_messages, wire_model,
        "real wire traffic drifted from the modeled ledger"
    );

    let speedup = serial_secs.max(1e-12) / partitioned_secs.max(1e-12);
    report.metric("wire_messages", out.cross_messages as f64);
    report.metric("wire_bytes", (8 * out.cross_floats) as f64);
    report.metric("cut_edges", part.cut_edges(&g) as f64);
    report.metric("speedup_vs_serial", speedup);
    report.metric("secs_per_iter_partitioned", partitioned_secs / iters as f64);
    result_row(
        "partitioned",
        format!(
            "{speedup:.2}x vs serial | {} wire msgs (= model) | {} wire bytes | {:.3}s",
            out.cross_messages,
            8 * out.cross_floats,
            partitioned_secs
        ),
    );

    let path = report.write().expect("bench report must be writable");
    result_row("report", path.display());
}
