//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. inner-solver accuracy ε vs outer iteration count (the ε knob of
//!    the paper's Lemma 3 / Theorem 1 trade-off);
//! 2. Eq.-8 first-system strategy: SDDM solve (paper-faithful) vs the
//!    closed-form centering;
//! 3. kernel-consistency correction on/off;
//! 4. chain splitting: lazy (robust) vs faithful (paper Eq. 2);
//! 5. step size: grid-searched fixed α vs Theorem 1's conservative α*.
//!
//!     cargo bench --bench ablations

use sddnewton::algorithms::sdd_newton::{FirstSolve, SddNewton, StepSize};
use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::benchkit::{result_row, section};
use sddnewton::graph::generate;
use sddnewton::net::CommGraph;
use sddnewton::problems::{assumption1_bounds, datasets};
use sddnewton::runtime::NativeBackend;
use sddnewton::sddm::{Chain, ChainOptions, SddmSolver, SolverOptions, Splitting};
use sddnewton::util::Pcg64;

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    let smoke = sddnewton::benchkit::is_smoke();
    let (n_nodes, n_edges, p, m_total, max_iters) =
        if smoke { (12, 30, 4, 240, 8) } else { (40, 100, 16, 4_000, 30) };
    let mut rng = Pcg64::new(31);
    let g = generate::random_connected(n_nodes, n_edges, &mut rng);
    let problem = datasets::synthetic_regression(n_nodes, p, m_total, 0.3, 0.05, &mut rng);
    let (_, f_star) = problem.centralized_optimum(60, 1e-11);
    let backend = NativeBackend;
    let opts = RunOptions { max_iters, ..Default::default() };

    // --- 1. solver ε vs outer iterations --------------------------------
    section("ablation 1: inner-solver ε vs outer iterations (tol 1e-6)");
    for eps in [0.5, 0.1, 1e-2, 1e-4] {
        let solver = sddm_for_graph(&g, eps, &mut rng);
        let mut alg = SddNewton::new(&problem, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        let trace = run(&mut alg, &problem, &mut comm, &opts);
        let iters = trace.iters_to_gap(f_star, 1e-6);
        result_row(
            &format!("eps{eps:.0e}"),
            format!(
                "{} outer iters, {} messages",
                iters.map(|i| i.to_string()).unwrap_or("—".into()),
                trace.messages_to_gap(f_star, 1e-6).map(|m| m.to_string()).unwrap_or("—".into())
            ),
        );
    }

    // --- 2. first-system strategy ---------------------------------------
    section("ablation 2: Eq.-8 first system — SDDM solve vs closed-form centering");
    for (name, fs) in [("solver", FirstSolve::Solver), ("centering", FirstSolve::Centering)] {
        let solver = sddm_for_graph(&g, 1e-4, &mut rng);
        let mut alg = SddNewton::new(&problem, &backend, &solver, StepSize::Fixed(1.0))
            .with_first_solve(fs);
        let mut comm = CommGraph::new(&g);
        let trace = run(&mut alg, &problem, &mut comm, &opts);
        result_row(
            &format!("first_solve/{name}"),
            format!(
                "final gap {:.2e}, {} messages",
                (trace.final_objective() - f_star).abs() / f_star.abs(),
                comm.stats().messages
            ),
        );
    }

    // --- 3. kernel correction -------------------------------------------
    section("ablation 3: kernel-consistency correction");
    for on in [true, false] {
        let solver = sddm_for_graph(&g, 1e-4, &mut rng);
        let mut alg = SddNewton::new(&problem, &backend, &solver, StepSize::Fixed(1.0))
            .with_kernel_correction(on);
        let mut comm = CommGraph::new(&g);
        let trace = run(&mut alg, &problem, &mut comm, &opts);
        result_row(
            &format!("kernel_correction/{on}"),
            format!(
                "iters to 1e-6: {}, final gap {:.2e}",
                trace.iters_to_gap(f_star, 1e-6).map(|i| i.to_string()).unwrap_or("—".into()),
                (trace.final_objective() - f_star).abs() / f_star.abs()
            ),
        );
    }

    // --- 4. chain splitting ----------------------------------------------
    section("ablation 4: chain splitting (lazy vs faithful) on a bipartite grid");
    let grid = generate::grid(6, 6);
    let l = sddnewton::graph::laplacian_csr(&grid);
    let z = rng.normal_vec(36);
    let b = l.matvec(&z);
    for (name, sp) in [("lazy", Splitting::Lazy), ("faithful", Splitting::Faithful)] {
        let chain = Chain::build(
            &l,
            &ChainOptions { splitting: sp, ..Default::default() },
            &mut rng,
        )
        .unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 400 });
        let mut comm = CommGraph::new(&grid);
        let out = solver.solve(&b, 1, &mut comm);
        result_row(
            &format!("splitting/{name}"),
            format!(
                "depth {} λ₂ {:.4} converged={} rel={:.1e} msgs={}",
                solver.chain.depth, solver.chain.lambda2, out.converged, out.rel_residual,
                comm.stats().messages
            ),
        );
    }

    // --- 5. step size ------------------------------------------------------
    section("ablation 5: fixed α vs Theorem 1's α*");
    let thetas0 = vec![0.0; n_nodes * p];
    let (gamma, big_gamma) = assumption1_bounds(&problem, &thetas0);
    let lcsr = sddnewton::graph::laplacian_csr(&g);
    let mun = sddnewton::graph::spectral::mu_max(&lcsr, 1e-9, 5000, &mut rng).value;
    let mu2 = sddnewton::graph::spectral::mu_2(&lcsr, 1e-9, 50_000, &mut rng).value;
    let theory = StepSize::Theory { gamma, big_gamma, mu2, mun, eps: 0.1 };
    result_row("alpha_star", format!("{:.3e} (γ={gamma:.2} Γ={big_gamma:.2} μ₂={mu2:.3} μₙ={mun:.3})", theory.value()));
    for (name, step) in [("fixed_1.0", StepSize::Fixed(1.0)), ("theory", theory)] {
        let solver = sddm_for_graph(&g, 0.1, &mut rng);
        let mut alg = SddNewton::new(&problem, &backend, &solver, step);
        let mut comm = CommGraph::new(&g);
        let trace = run(&mut alg, &problem, &mut comm, &RunOptions { max_iters: 20, ..Default::default() });
        result_row(
            &format!("step/{name}"),
            format!("final gap {:.2e}", (trace.final_objective() - f_star).abs() / f_star.abs()),
        );
    }
}
