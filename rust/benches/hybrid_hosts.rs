//! Two-host-shaped hybrid transport benchmark over loopback sockets.
//!
//! Places a 4-rank pool across simulated hosts via MPI-style hostfiles
//! and drives SDD-Newton and ADMM through the hybrid transport —
//! in-process channels within a host, framed TCP across hosts — the
//! deployment shape a real cluster pays for. Three placements of the
//! same pool bracket the cost spectrum: all ranks co-located (zero
//! socket bytes), the canonical 2+2 two-host split, and one rank per
//! host (every boundary edge rides a socket).
//!
//! Every sample is asserted bit-for-bit identical to the bulk and
//! in-process shard references, and the split ledger is re-checked:
//! `intra + inter` sums to the placement-agnostic totals and socket
//! payload bytes cover exactly the inter-host leg
//! (`payload_bytes == inter_floats × 8`).
//!
//!     cargo bench --bench hybrid_hosts
//!     cargo bench --bench hybrid_hosts -- --smoke    # CI smoke run

use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section, BenchReport};
use sddnewton::harness::deploy::{run_hybrid_cross_transport, TcpJobSpec};
use sddnewton::net::hybrid::parse_hostfile;

/// Spec for one algorithm of the smoke preset on a loopback hybrid pool
/// (thread mode — no hostfile path needed, the placement is passed
/// directly).
fn smoke_spec(algo: &str, workers: usize, iters: usize) -> TcpJobSpec {
    TcpJobSpec {
        experiment: "smoke".to_string(),
        config_path: None,
        algorithms: Some(algo.to_string()),
        seed: None,
        algo_index: 0,
        iters,
        workers,
        partitioning: "contiguous".to_string(),
        solver_seed: 0x51D0,
        hostfile: None,
        stale_tau: 0,
    }
}

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    let workers = 4;
    let iters = if smoke { 2 } else { 4 };
    let mut report = BenchReport::new("hybrid_hosts");
    report.config_num("workers", workers as f64);
    report.config_num("iters", iters as f64);
    result_row("parallelism/threads", sddnewton::par::threads());

    // Same 4-rank pool, three placements: the socket leg shrinks from
    // "every boundary edge" to zero as ranks co-locate.
    let placements: [(&str, &str); 3] = [
        ("single_host", "alpha slots=4\n"),
        ("two_hosts_2p2", "alpha slots=2\nbeta slots=2\n"),
        ("fully_split", "alpha slots=1\nbeta slots=1\ngamma slots=1\ndelta slots=1\n"),
    ];

    section(&format!(
        "Hybrid transport by placement: {workers} ranks, {iters} iterations, loopback sockets"
    ));

    for (algo, label) in [("sdd", "sdd_newton"), ("admm", "admm")] {
        let algo_timer = sddnewton::util::Timer::start();
        for (pname, hostfile) in &placements {
            let placement = parse_hostfile(hostfile).expect("bench hostfile must parse");
            let spec = smoke_spec(algo, workers, iters);
            let mut last = None;
            let s = bench(&format!("{label}/hybrid/{pname}"), &opts, || {
                last = Some(
                    run_hybrid_cross_transport(&spec, &placement, "127.0.0.1:0", None)
                        .expect("hybrid loopback run must succeed"),
                );
            });
            let parity = last.unwrap();
            assert!(
                parity.ok(),
                "{label}/{pname}: hybrid run drifted from the reference transports"
            );
            let run = &parity.hybrid;
            assert_eq!(
                run.intra_cross + run.inter_cross,
                run.cross_messages,
                "{label}/{pname}: placement split does not sum to the payload total"
            );
            assert_eq!(
                run.intra_floats + run.inter_floats,
                run.cross_floats,
                "{label}/{pname}: placement split does not sum to the float total"
            );
            assert_eq!(
                run.payload_bytes,
                run.inter_floats * 8,
                "{label}/{pname}: socket bytes must cover exactly the inter-host leg"
            );
            assert_eq!(
                run.header_bytes % 16,
                0,
                "{label}/{pname}: header overhead is not a whole number of frame headers"
            );
            report.metric(&format!("{label}/{pname}/intra_msgs"), run.intra_cross as f64);
            report.metric(&format!("{label}/{pname}/inter_msgs"), run.inter_cross as f64);
            report.metric(
                &format!("{label}/{pname}/socket_payload_bytes"),
                run.payload_bytes as f64,
            );
            report.metric(
                &format!("{label}/{pname}/socket_header_bytes"),
                run.header_bytes as f64,
            );
            result_row(
                &format!("{label}/hybrid/{pname}"),
                format!(
                    "{} intra + {} inter msgs | {} socket payload B | {:.5}s median",
                    run.intra_cross, run.inter_cross, run.payload_bytes, s.median
                ),
            );
        }
        report.phase(label, algo_timer.secs());
    }

    let path = report.write().expect("bench report must be writable");
    result_row("report", path.display());
}
