//! Regenerates Fig. 2(a,b): sparse fMRI-like logistic regression with
//! smoothed-L1 regularization — the m ≪ p regime (240 samples, p = 512
//! here standing in for the paper's 43 720 voxels; see DESIGN.md §5).
//!
//! Paper shape: SDD-Newton best; ADD-Newton second; ADMM and averaging
//! worst.
//!
//!     cargo bench --bench fig2_fmri

use sddnewton::benchkit::{bench, is_smoke, result_row, section, BenchOpts};
use sddnewton::config::{ExperimentConfig, ProblemKind};
use sddnewton::harness::{report, run_experiment};

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    section("Fig 2(a,b): fMRI-like sparse logistic (m ≪ p), n=8 m=16 p=512");
    let mut cfg = ExperimentConfig::preset("fig2-fmri").unwrap();
    cfg.max_iters = 20;
    if is_smoke() {
        cfg.max_iters = 4;
        cfg.problem = ProblemKind::FmriLike { p: 48, m_total: 48, k_sparse: 6, mu: 0.02 };
        cfg.algorithms.truncate(2);
    }
    let mut res = None;
    bench("fig2_fmri/all-algorithms", &BenchOpts { warmup_iters: 0, sample_iters: 1 }, || {
        res = Some(run_experiment(&cfg));
    });
    let res = res.unwrap();
    print!("{}", report::summary_table(&res));
    std::fs::create_dir_all("results").ok();
    report::write_csv(&res, "results/fig2_fmri.csv").unwrap();

    // Ranking by final gap — the paper's qualitative claim.
    let mut gaps: Vec<(String, f64)> = res
        .traces
        .iter()
        .map(|t| {
            (
                t.algorithm.clone(),
                ((t.final_objective() - res.f_star).abs() / res.f_star.abs())
                    .max(t.final_consensus_error() / res.traces[0].records[0].consensus_error.max(1.0)),
            )
        })
        .collect();
    gaps.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (i, (name, gap)) in gaps.iter().enumerate() {
        result_row(&format!("fig2_fmri/rank{}", i + 1), format!("{name} (score {gap:.2e})"));
    }
}
