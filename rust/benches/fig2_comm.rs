//! Regenerates Fig. 2(c,d): communication overhead vs accuracy demand,
//! and running time till convergence, on the London-Schools-like task.
//!
//! Paper shape: SDD-Newton's message growth tracks the graph condition
//! number (slow growth in log(1/ε)) while first-order methods' message
//! counts blow up much faster as ε tightens; SDD-Newton has the fastest
//! wall-clock to convergence.
//!
//!     cargo bench --bench fig2_comm

use sddnewton::benchkit::{is_smoke, result_row, section};
use sddnewton::config::{AlgoKind, ExperimentConfig, ProblemKind};
use sddnewton::harness::experiments::comm_overhead_experiment;
use sddnewton::harness::{report, run_experiment};
use sddnewton::util::Timer;

fn main() {
    let _ = sddnewton::benchkit::cli_opts();
    // --- Fig 2(c): messages to reach accuracy ε -------------------------
    section("Fig 2(c): communication overhead vs accuracy (London Schools)");
    let mut cfg = ExperimentConfig::preset("fig2-comm").unwrap();
    // First-order methods need O(1/ε) iterations; give them room.
    cfg.max_iters = 20_000;
    // Reduced instance keeps the 20k-iteration first-order runs tractable.
    cfg.nodes = 30;
    cfg.edges = 90;
    cfg.algorithms = vec![
        AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 },
        AlgoKind::AddNewton { terms: 2, alpha: 1.0 },
        AlgoKind::Admm { beta: 1.0 },
        AlgoKind::Gradient { alpha: 0.02 },
        AlgoKind::Averaging { beta: 0.002 },
    ];
    let mut targets = vec![1e-1, 1e-2, 1e-3, 1e-4, 1e-5];
    if is_smoke() {
        cfg.nodes = 10;
        cfg.edges = 20;
        cfg.max_iters = 200;
        cfg.problem = ProblemKind::LondonLike { m_total: 400, mu: 0.05 };
        cfg.algorithms.truncate(2);
        targets = vec![1e-1, 1e-2];
    }
    let rows = comm_overhead_experiment(&cfg, &targets);
    println!(
        "{:<28} {}",
        "algorithm",
        targets.iter().map(|t| format!("{t:>12.0e}")).collect::<String>()
    );
    for (name, cells) in &rows {
        let mut line = format!("{name:<28} ");
        for (_, msgs) in cells {
            match msgs {
                Some(m) => line.push_str(&format!("{m:>12}")),
                None => line.push_str(&format!("{:>12}", "—")),
            }
        }
        println!("{line}");
        if let (Some(first), Some(last)) = (cells.first().and_then(|c| c.1), cells.last().and_then(|c| c.1)) {
            result_row(
                &format!("fig2c/growth/{name}"),
                format!("{first} → {last} ({}x)", last / first.max(1)),
            );
        }
    }
    std::fs::create_dir_all("results").ok();
    report::write_comm_csv(&rows, "results/fig2_comm.csv").unwrap();

    // --- Fig 2(d): running time till convergence ------------------------
    section("Fig 2(d): running time till convergence (gap ≤ 1e-5)");
    let mut tcfg = cfg.clone();
    tcfg.max_iters = if is_smoke() { 100 } else { 1200 };
    let t = Timer::start();
    let res = run_experiment(&tcfg);
    let _total = t.secs();
    for trace in &res.traces {
        // Wall-clock at the first converged iterate.
        let conv = trace
            .records
            .iter()
            .find(|r| {
                (r.objective - res.f_star).abs() / res.f_star.abs().max(1.0) <= 1e-5
                    && r.consensus_error
                        <= 1e-5 * trace.records[0].consensus_error.max(1.0)
            })
            .map(|r| r.elapsed);
        match conv {
            Some(s) => result_row(&format!("fig2d/time_s/{}", trace.algorithm), format!("{s:.3}")),
            None => result_row(
                &format!("fig2d/time_s/{}", trace.algorithm),
                format!("not converged in {} iters ({:.1}s)",
                    trace.records.len() - 1,
                    trace.records.last().unwrap().elapsed),
            ),
        }
    }
}
