//! Partitioned baselines: serial (bulk-synchronous) vs sharded worker
//! runtime for **all six algorithms** of the paper's comparison —
//! wall-clock speedup, modeled message ledger, and the cross-worker
//! channel traffic (the MPI cost a real deployment pays, by partitioning
//! strategy), plus the bytes each algorithm actually puts on the wire.
//!
//! Every partitioned sample is asserted bit-for-bit identical to the
//! serial path (iterates *and* modeled comm ledger), **and** its real
//! cross-worker message count is asserted equal to the plan-driven wire
//! model (`modeled_cross_messages`) — the bench-smoke guard that keeps
//! both the cross-transport equality contract and the wire-truth
//! contract from bit-rotting. A final section runs SDD-Newton with the
//! preprocessed SquaredChain solver through its overlay halo plans.
//!
//!     cargo bench --bench partitioned_baselines
//!     cargo bench --bench partitioned_baselines -- --smoke    # CI smoke run
//!     cargo bench --bench partitioned_baselines -- --threads 4

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::{squared_sddm_for_graph, LaplacianSolver};
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section, BenchReport};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, run_partitioned_newton, Partition};
use sddnewton::graph::generate;
use sddnewton::harness::experiments::{
    make_inner_solver, make_sharded_algorithm, modeled_cross_messages,
};
use sddnewton::net::CommGraph;
use sddnewton::problems::{datasets, logistic::Reg};
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    result_row("parallelism/threads", sddnewton::par::threads());

    // Logistic locals: the per-node oracles (primal recovery, ADMM's
    // inner argmin, NN's block solves) are inner Newton loops, so the
    // compute the shards divide actually dominates.
    let (n, m_edges, p, m_total, iters) =
        if smoke { (24, 60, 4, 480, 2) } else { (96, 240, 10, 7_680, 4) };
    let mut report = BenchReport::new("partitioned_baselines");
    report.config_num("n", n as f64);
    report.config_num("m", m_edges as f64);
    report.config_num("p", p as f64);
    report.config_num("iters", iters as f64);
    let mut rng = Pcg64::new(2718);
    let g = generate::random_connected(n, m_edges, &mut rng);
    let prob = datasets::mnist_like(n, p, m_total, 0, Reg::L2, 0.05, &mut rng);
    let backend = NativeBackend;

    section(&format!(
        "Partitioned baselines: n={n} nodes, m={m_edges} edges, p={p}, {iters} iterations"
    ));

    let kinds: [(&str, AlgoKind); 6] = [
        ("sdd_newton", AlgoKind::SddNewton { eps: 1e-4, alpha: 1.0 }),
        ("add_newton", AlgoKind::AddNewton { terms: 2, alpha: 1.0 }),
        ("admm", AlgoKind::Admm { beta: 1.0 }),
        ("gradient", AlgoKind::Gradient { alpha: 0.01 }),
        ("averaging", AlgoKind::Averaging { beta: 0.002 }),
        ("network_newton_2", AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 }),
    ];
    let ks: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let all: Vec<usize> = (0..n).collect();

    for (name, kind) in &kinds {
        let kind_timer = sddnewton::util::Timer::start();
        // The inner solver (dual-Newton kinds) is built once and shared
        // by the serial reference and every sharded worker — the SDDM
        // chain is randomized, so sharing is what makes the bit-equality
        // assertion meaningful.
        let solver = make_inner_solver(kind, &g, &mut rng);
        let solver_ref: Option<&dyn LaplacianSolver> = solver.as_deref();

        // Serial bulk-synchronous baseline.
        let mut serial_thetas: Vec<f64> = Vec::new();
        let mut serial_stats = *CommGraph::new(&g).stats();
        let s_serial = bench(&format!("{name}/serial"), &opts, || {
            let mut alg =
                make_sharded_algorithm(kind, &prob, &g, &backend, solver_ref, all.clone());
            let mut comm = CommGraph::new(&g);
            let trace = run(
                &mut alg,
                &prob,
                &mut comm,
                &RunOptions { max_iters: iters, ..Default::default() },
            );
            serial_thetas = trace.final_thetas;
            serial_stats = *comm.stats();
        });
        result_row(
            &format!("{name}/serial"),
            format!("{} modeled msgs | {:.5}s median", serial_stats.messages, s_serial.median),
        );

        // Sharded workers, by worker count × partitioning strategy.
        for &k in ks {
            for (pname, part) in [
                ("contiguous", Partition::contiguous(n, k)),
                ("round_robin", Partition::round_robin(n, k)),
                ("bfs_blocks", Partition::bfs_blocks(&g, k)),
            ] {
                let mut last = None;
                let s = bench(&format!("{name}/partitioned/{pname}_k{k}"), &opts, || {
                    last = Some(run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
                        make_sharded_algorithm(kind, &prob, &g, &backend, solver_ref, owned)
                    }));
                });
                let out = last.unwrap();
                assert_eq!(
                    out.thetas, serial_thetas,
                    "{name}/{pname}/k{k}: partitioned run drifted from the serial path"
                );
                assert_eq!(
                    out.comm, serial_stats,
                    "{name}/{pname}/k{k}: modeled ledger drifted"
                );
                // Bytes-on-wire assertion: real channel traffic must equal
                // the plan-driven wire model composed from the modeled
                // ledger — runs in smoke mode too (CI).
                let wire_model = modeled_cross_messages(kind, &g, &part, iters, &serial_stats);
                assert_eq!(
                    out.cross_messages, wire_model,
                    "{name}/{pname}/k{k}: real wire traffic drifted from the modeled ledger"
                );
                let speedup = s_serial.median.max(1e-12) / s.median.max(1e-12);
                report.metric(&format!("{name}/{pname}_k{k}/speedup_vs_serial"), speedup);
                report.metric(
                    &format!("{name}/{pname}_k{k}/wire_bytes"),
                    (8 * out.cross_floats) as f64,
                );
                result_row(
                    &format!("{name}/partitioned/{pname}_k{k}"),
                    format!(
                        "{speedup:.2}x vs serial | {} cut edges | {} wire msgs (= model) | \
                         {} wire bytes | {:.5}s median",
                        part.cut_edges(&g),
                        out.cross_messages,
                        8 * out.cross_floats,
                        s.median
                    ),
                );
            }
        }
        report.phase(name, kind_timer.secs());
    }

    // Overlay halo plans: SDD-Newton with the preprocessed SquaredChain
    // solver — level supports exceed the graph edges, so every level round
    // rides a registered overlay plan instead of being bulk-only.
    section("Overlay halo plans: preprocessed SDD-Newton (SquaredChain levels sharded)");
    let sq = squared_sddm_for_graph(&g, 1e-4, 0.0, &mut rng);
    let iters_sq = iters.min(2);
    let mut alg = SddNewton::new(&prob, &backend, &sq, StepSize::Fixed(1.0));
    let mut comm = CommGraph::new(&g);
    let trace = run(
        &mut alg,
        &prob,
        &mut comm,
        &RunOptions { max_iters: iters_sq, ..Default::default() },
    );
    result_row(
        "sdd_newton_squared/serial",
        format!("{} modeled msgs", comm.stats().messages),
    );
    for &k in ks {
        let part = Partition::contiguous(n, k);
        let mut last = None;
        let s = bench(&format!("sdd_newton_squared/partitioned/contiguous_k{k}"), &opts, || {
            last = Some(run_partitioned_newton(
                &prob,
                &g,
                &part,
                &sq,
                StepSize::Fixed(1.0),
                iters_sq,
            ));
        });
        let out = last.unwrap();
        assert_eq!(
            out.thetas, trace.final_thetas,
            "sdd_newton_squared/k{k}: overlay run drifted from the serial path"
        );
        assert_eq!(out.comm, *comm.stats(), "sdd_newton_squared/k{k}: modeled ledger drifted");
        report.metric(
            &format!("sdd_newton_squared/contiguous_k{k}/wire_bytes"),
            (8 * out.cross_floats) as f64,
        );
        result_row(
            &format!("sdd_newton_squared/partitioned/contiguous_k{k}"),
            format!(
                "{} wire msgs | {} wire bytes | {:.5}s median",
                out.cross_messages,
                8 * out.cross_floats,
                s.median
            ),
        );
    }

    let path = report.write().expect("bench report must be writable");
    result_row("report", path.display());
}
