//! Partitioned baselines: serial (bulk-synchronous) vs sharded worker
//! runtime for **all six algorithms** of the paper's comparison —
//! wall-clock speedup, modeled message ledger, and the cross-worker
//! channel traffic (the MPI cost a real deployment pays, by partitioning
//! strategy).
//!
//! Every partitioned sample is asserted bit-for-bit identical to the
//! serial path (iterates *and* modeled comm ledger), so the tables
//! isolate pure runtime cost: channel latency + sharded compute vs one
//! big sweep. This is the bench-smoke guard that keeps the
//! cross-transport equality contract for the baselines from bit-rotting.
//!
//!     cargo bench --bench partitioned_baselines
//!     cargo bench --bench partitioned_baselines -- --smoke    # CI smoke run
//!     cargo bench --bench partitioned_baselines -- --threads 4

use sddnewton::algorithms::solvers::LaplacianSolver;
use sddnewton::algorithms::{run, RunOptions};
use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section};
use sddnewton::config::AlgoKind;
use sddnewton::coordinator::{run_partitioned_baseline, Partition};
use sddnewton::graph::generate;
use sddnewton::harness::experiments::{make_inner_solver, make_sharded_algorithm};
use sddnewton::net::CommGraph;
use sddnewton::problems::{datasets, logistic::Reg};
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    result_row("parallelism/threads", sddnewton::par::threads());

    // Logistic locals: the per-node oracles (primal recovery, ADMM's
    // inner argmin, NN's block solves) are inner Newton loops, so the
    // compute the shards divide actually dominates.
    let (n, m_edges, p, m_total, iters) =
        if smoke { (24, 60, 4, 480, 2) } else { (96, 240, 10, 7_680, 4) };
    let mut rng = Pcg64::new(2718);
    let g = generate::random_connected(n, m_edges, &mut rng);
    let prob = datasets::mnist_like(n, p, m_total, 0, Reg::L2, 0.05, &mut rng);
    let backend = NativeBackend;

    section(&format!(
        "Partitioned baselines: n={n} nodes, m={m_edges} edges, p={p}, {iters} iterations"
    ));

    let kinds: [(&str, AlgoKind); 6] = [
        ("sdd_newton", AlgoKind::SddNewton { eps: 1e-4, alpha: 1.0 }),
        ("add_newton", AlgoKind::AddNewton { terms: 2, alpha: 1.0 }),
        ("admm", AlgoKind::Admm { beta: 1.0 }),
        ("gradient", AlgoKind::Gradient { alpha: 0.01 }),
        ("averaging", AlgoKind::Averaging { beta: 0.002 }),
        ("network_newton_2", AlgoKind::NetworkNewton { k: 2, alpha: 0.1, epsilon: 1.0 }),
    ];
    let ks: &[usize] = if smoke { &[2] } else { &[2, 4] };
    let all: Vec<usize> = (0..n).collect();

    for (name, kind) in &kinds {
        // The inner solver (dual-Newton kinds) is built once and shared
        // by the serial reference and every sharded worker — the SDDM
        // chain is randomized, so sharing is what makes the bit-equality
        // assertion meaningful.
        let solver = make_inner_solver(kind, &g, &mut rng);
        let solver_ref: Option<&dyn LaplacianSolver> = solver.as_deref();

        // Serial bulk-synchronous baseline.
        let mut serial_thetas: Vec<f64> = Vec::new();
        let mut serial_stats = *CommGraph::new(&g).stats();
        let s_serial = bench(&format!("{name}/serial"), &opts, || {
            let mut alg =
                make_sharded_algorithm(kind, &prob, &g, &backend, solver_ref, all.clone());
            let mut comm = CommGraph::new(&g);
            let trace = run(
                &mut alg,
                &prob,
                &mut comm,
                &RunOptions { max_iters: iters, ..Default::default() },
            );
            serial_thetas = trace.final_thetas;
            serial_stats = *comm.stats();
        });
        result_row(
            &format!("{name}/serial"),
            format!("{} modeled msgs | {:.5}s median", serial_stats.messages, s_serial.median),
        );

        // Sharded workers, by worker count × partitioning strategy.
        for &k in ks {
            for (pname, part) in [
                ("contiguous", Partition::contiguous(n, k)),
                ("round_robin", Partition::round_robin(n, k)),
                ("bfs_blocks", Partition::bfs_blocks(&g, k)),
            ] {
                let mut last = None;
                let s = bench(&format!("{name}/partitioned/{pname}_k{k}"), &opts, || {
                    last = Some(run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
                        make_sharded_algorithm(kind, &prob, &g, &backend, solver_ref, owned)
                    }));
                });
                let out = last.unwrap();
                assert_eq!(
                    out.thetas, serial_thetas,
                    "{name}/{pname}/k{k}: partitioned run drifted from the serial path"
                );
                assert_eq!(
                    out.comm, serial_stats,
                    "{name}/{pname}/k{k}: modeled ledger drifted"
                );
                let speedup = s_serial.median.max(1e-12) / s.median.max(1e-12);
                result_row(
                    &format!("{name}/partitioned/{pname}_k{k}"),
                    format!(
                        "{speedup:.2}x vs serial | {} cut edges | {} cross-worker msgs | {:.5}s median",
                        part.cut_edges(&g),
                        out.cross_messages,
                        s.median
                    ),
                );
            }
        }
    }
}
