//! Partitioned SDD-Newton: serial (bulk-synchronous) vs sharded worker
//! runtime — wall-clock speedup plus the cross-worker message table (the
//! MPI traffic a real deployment pays, by partitioning strategy).
//!
//! The partitioned run is bit-for-bit identical to the serial path (the
//! bench asserts it every sample), so the table isolates pure runtime
//! cost: channel latency + sharded compute vs one big sweep.
//!
//!     cargo bench --bench partitioned_newton
//!     cargo bench --bench partitioned_newton -- --smoke    # CI smoke run
//!     cargo bench --bench partitioned_newton -- --threads 4

use sddnewton::algorithms::sdd_newton::{SddNewton, StepSize};
use sddnewton::algorithms::solvers::sddm_for_graph;
use sddnewton::algorithms::ConsensusAlgorithm;
use sddnewton::benchkit::{bench, cli_opts, is_smoke, result_row, section};
use sddnewton::coordinator::{run_partitioned_newton, Partition};
use sddnewton::graph::generate;
use sddnewton::net::CommGraph;
use sddnewton::problems::{datasets, logistic::Reg};
use sddnewton::runtime::NativeBackend;
use sddnewton::util::Pcg64;

fn main() {
    let opts = cli_opts();
    let smoke = is_smoke();
    result_row("parallelism/threads", sddnewton::par::threads());

    // Logistic locals: per-node primal recovery is an inner Newton loop,
    // so the compute the shards divide actually dominates.
    let (n, m_edges, p, m_total, iters) =
        if smoke { (24, 60, 4, 480, 2) } else { (96, 240, 10, 7_680, 4) };
    let mut rng = Pcg64::new(2718);
    let g = generate::random_connected(n, m_edges, &mut rng);
    let prob = datasets::mnist_like(n, p, m_total, 0, Reg::L2, 0.05, &mut rng);
    let solver = sddm_for_graph(&g, 1e-4, &mut rng);
    let backend = NativeBackend;
    let step = StepSize::Fixed(1.0);

    section(&format!(
        "Partitioned SDD-Newton: n={n} nodes, m={m_edges} edges, p={p}, {iters} iterations"
    ));

    // Serial bulk-synchronous baseline.
    let mut serial_thetas: Vec<f64> = Vec::new();
    let mut serial_msgs = 0u64;
    let s_serial = bench("newton/serial", &opts, || {
        let mut alg = SddNewton::new(&prob, &backend, &solver, step);
        let mut comm = CommGraph::new(&g);
        for _ in 0..iters {
            alg.step(&prob, &mut comm);
        }
        serial_thetas = alg.thetas().to_vec();
        serial_msgs = comm.stats().messages;
    });
    result_row("newton/serial/modeled_messages", serial_msgs);
    result_row("newton/serial/median_s", format!("{:.5}", s_serial.median));

    // Sharded workers, by worker count × partitioning strategy.
    let ks: &[usize] = if smoke { &[2] } else { &[2, 4] };
    section("worker table: partitioning | speedup | cut edges | cross-worker msgs");
    for &k in ks {
        for (pname, part) in [
            ("contiguous", Partition::contiguous(n, k)),
            ("round_robin", Partition::round_robin(n, k)),
            ("bfs_blocks", Partition::bfs_blocks(&g, k)),
        ] {
            let mut last = None;
            let s = bench(&format!("newton/partitioned/{pname}_k{k}"), &opts, || {
                last = Some(run_partitioned_newton(&prob, &g, &part, &solver, step, iters));
            });
            let out = last.unwrap();
            assert_eq!(
                out.thetas, serial_thetas,
                "{pname}/k{k}: partitioned run drifted from the serial path"
            );
            assert_eq!(out.comm.messages, serial_msgs, "modeled ledger drifted");
            let speedup = s_serial.median.max(1e-12) / s.median.max(1e-12);
            result_row(
                &format!("newton/partitioned/{pname}_k{k}"),
                format!(
                    "{speedup:.2}x vs serial | {} cut edges | {} cross-worker msgs | {:.5}s median",
                    part.cut_edges(&g),
                    out.cross_messages,
                    s.median
                ),
            );
        }
    }
}
