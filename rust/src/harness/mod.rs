//! Experiment harness: builds problems/graphs from configs, runs the
//! algorithm roster, and produces the traces behind every figure.

pub mod deploy;
pub mod experiments;
pub mod report;

pub use deploy::{
    hybrid_host_main, hybrid_host_with_placement, run_hybrid_cross_transport,
    run_tcp_cross_transport, tcp_worker_main, HybridParity, TcpJobSpec, TcpParity,
};
pub use experiments::{build_graph, build_problem, run_experiment, run_single, ExperimentResult};
