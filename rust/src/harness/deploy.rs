//! Multi-process deployment harness for the TCP transport.
//!
//! A TCP run spans several OS processes that share no memory, so every
//! rank must rebuild the *identical* experiment — graph, problem,
//! partition, and (for the dual-Newton kinds) the randomized inner SDDM
//! solver — from seeds alone. [`TcpJobSpec`] is that seed bundle: it
//! round-trips through `sddnewton worker` CLI flags
//! ([`TcpJobSpec::to_worker_args`]) and builds deterministically on every
//! side ([`TcpJobSpec::build`]), which is what makes the TCP pool
//! bit-for-bit comparable to the in-process transports.
//!
//! [`run_tcp_cross_transport`] is the three-way parity harness behind the
//! `--transport tcp` CLI and `tests/tcp_wire.rs`: it runs the bulk
//! [`CommGraph`](crate::net::CommGraph) reference and the in-process
//! [`ShardExchange`](crate::net::partitioned::ShardExchange) reference,
//! then the same algorithm over a real TCP pool (worker OS processes, or
//! in-process threads speaking real loopback sockets for tests), and
//! checks iterates, objectives, the modeled ledger, and the wire truth —
//! extended to observed socket bytes.
//!
//! [`run_hybrid_cross_transport`] is the same harness for the host-aware
//! hybrid transport (`--transport hybrid`): one
//! [`HybridExchange`](crate::net::hybrid::HybridExchange) pool deployed
//! per the hostfile placement, with the wire check split into intra-host
//! (channel) and inter-host (socket) ledgers — socket bytes must cover
//! exactly the inter-host floats, and the two splits must sum back to the
//! placement-agnostic totals of the other transports.

use super::experiments::{
    build_graph, build_problem, make_inner_solver, make_sharded_algorithm_stale,
    modeled_cross_messages,
};
use crate::algorithms::{run, RunOptions, Trace};
use crate::config::{AlgoKind, ExperimentConfig, Json};
use crate::coordinator::tcp::{
    run_hybrid_host, run_leader, run_leader_with_hosts, run_tcp_worker, HybridHostConfig,
    TcpLeader, TcpPartitionedRun,
};
use crate::coordinator::{run_partitioned_baseline, Partition, PartitionedRun};
use crate::graph::Graph;
use crate::net::hybrid::{parse_hostfile, Placement};
use crate::net::tcp::frame::{self, HEADER_BYTES};
use crate::net::tcp::WorkerNetConfig;
use crate::net::CommGraph;
use crate::problems::ConsensusProblem;
use crate::runtime::NativeBackend;
use crate::util::Pcg64;
use std::path::Path;

/// Everything a worker process needs to rebuild its rank's share of a TCP
/// run deterministically. Round-trips through `sddnewton worker` flags.
#[derive(Debug, Clone)]
pub struct TcpJobSpec {
    /// Experiment preset name (ignored when `config_path` is set).
    pub experiment: String,
    /// JSON config file overriding the preset.
    pub config_path: Option<String>,
    /// Comma-separated algorithm-id override (as `--algorithms`).
    pub algorithms: Option<String>,
    /// Seed override for the experiment config.
    pub seed: Option<u64>,
    /// Which entry of the config's algorithm roster this run drives.
    pub algo_index: usize,
    /// Iterations to run.
    pub iters: usize,
    /// Pool size `k`.
    pub workers: usize,
    /// Partitioning scheme: `contiguous`, `round_robin`, or `bfs`.
    pub partitioning: String,
    /// Seed for the inner-solver construction. Every side of a parity
    /// comparison (bulk reference, shard reference, each worker process)
    /// builds its solver from a fresh `Pcg64::new(solver_seed)`, so the
    /// randomized SDDM chain is bit-identical everywhere.
    pub solver_seed: u64,
    /// Hostfile path for the hybrid transport (`None` for plain TCP).
    /// When set, worker processes run the per-host hybrid driver and the
    /// leader broadcasts the rank→host placement at rendezvous.
    pub hostfile: Option<String>,
    /// Bounded-staleness bound τ for halo exchanges (`0` = exact BSP).
    /// Applied identically on every side of a parity comparison — the
    /// bulk reference, the in-process shard reference, and each worker
    /// process — so the three-way bit-for-bit checks hold for any τ.
    pub stale_tau: u64,
}

/// A spec resolved into the concrete experiment objects (identical on
/// every rank by construction).
pub struct TcpJob {
    /// The resolved experiment config.
    pub cfg: ExperimentConfig,
    /// The processor graph.
    pub g: Graph,
    /// The consensus problem instance.
    pub problem: ConsensusProblem,
    /// The algorithm this run drives.
    pub kind: AlgoKind,
    /// The node partition over `workers` shards.
    pub part: Partition,
}

impl TcpJobSpec {
    /// Resolve the spec: load/override the config, then rebuild graph,
    /// problem, and partition from the config seed. The graph is drawn
    /// before the problem from one rng stream — the same order as every
    /// other driver — so all artifacts are bit-identical across processes.
    pub fn build(&self) -> Result<TcpJob, String> {
        let mut cfg = if let Some(path) = &self.config_path {
            let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
            let doc = Json::parse(&text).map_err(|e| e.to_string())?;
            ExperimentConfig::from_json(&doc)?
        } else {
            ExperimentConfig::preset(&self.experiment)
                .ok_or(format!("unknown preset '{}'", self.experiment))?
        };
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        if let Some(list) = &self.algorithms {
            cfg.algorithms = list
                .split(',')
                .map(|id| AlgoKind::from_id(id.trim()).ok_or(format!("unknown algorithm '{id}'")))
                .collect::<Result<_, _>>()?;
        }
        let kind = cfg
            .algorithms
            .get(self.algo_index)
            .cloned()
            .ok_or_else(|| {
                format!(
                    "algorithm index {} out of range (roster has {})",
                    self.algo_index,
                    cfg.algorithms.len()
                )
            })?;
        let mut rng = Pcg64::new(cfg.seed);
        let g = build_graph(&cfg, &mut rng);
        let problem = build_problem(&cfg, &mut rng);
        let part = match self.partitioning.as_str() {
            "contiguous" => Partition::contiguous(g.n, self.workers),
            "round_robin" => Partition::round_robin(g.n, self.workers),
            "bfs" | "bfs_blocks" => Partition::bfs_blocks(&g, self.workers),
            other => return Err(format!("unknown partitioning '{other}'")),
        };
        Ok(TcpJob { cfg, g, problem, kind, part })
    }

    /// The `sddnewton worker` flags a worker process needs to rebuild this
    /// spec (everything but `--rank`/`--connect`, which are per-process).
    pub fn to_worker_args(&self) -> Vec<String> {
        let mut a: Vec<String> = Vec::new();
        if let Some(path) = &self.config_path {
            a.extend(["--config".to_string(), path.clone()]);
        } else {
            a.extend(["--experiment".to_string(), self.experiment.clone()]);
        }
        if let Some(list) = &self.algorithms {
            a.extend(["--algorithms".to_string(), list.clone()]);
        }
        if let Some(s) = self.seed {
            a.extend(["--seed".to_string(), s.to_string()]);
        }
        a.extend(["--algo-index".to_string(), self.algo_index.to_string()]);
        a.extend(["--iters".to_string(), self.iters.to_string()]);
        a.extend(["--workers".to_string(), self.workers.to_string()]);
        a.extend(["--partitioning".to_string(), self.partitioning.clone()]);
        a.extend(["--solver-seed".to_string(), self.solver_seed.to_string()]);
        if self.stale_tau > 0 {
            a.extend(["--stale-tau".to_string(), self.stale_tau.to_string()]);
        }
        if let Some(path) = &self.hostfile {
            a.extend(["--hostfile".to_string(), path.clone()]);
        }
        a
    }
}

/// Worker-process entry point: rebuild the job from the spec and drive
/// this rank's shard against the TCP pool at `net`.
pub fn tcp_worker_main(spec: &TcpJobSpec, net: &WorkerNetConfig) -> Result<(), String> {
    let job = spec.build()?;
    let backend = NativeBackend;
    let solver = make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
    let solver_ref = solver.as_deref();
    run_tcp_worker(&job.problem, &job.g, &job.part, spec.iters, net, &|owned| {
        make_sharded_algorithm_stale(
            &job.kind,
            &job.problem,
            &job.g,
            &backend,
            solver_ref,
            owned,
            spec.stale_tau,
        )
    })
    .map_err(|e| e.to_string())
}

/// Host-process entry point for the hybrid transport: parse the spec's
/// hostfile and drive every rank it places on `host` (spawned by
/// `sddnewton worker --host NAME --hostfile F`, or started by hand on
/// each machine of a multi-host deployment).
pub fn hybrid_host_main(spec: &TcpJobSpec, host: &str, leader_addr: &str) -> Result<(), String> {
    let path = spec
        .hostfile
        .as_ref()
        .ok_or("hybrid host needs --hostfile (the rank→host placement)")?;
    let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let placement = parse_hostfile(&text).map_err(|e| format!("{path}: {e}"))?;
    hybrid_host_with_placement(spec, &placement, host, leader_addr)
}

/// [`hybrid_host_main`] with an already-parsed placement (the in-process
/// thread mode of [`run_hybrid_cross_transport`] skips the hostfile I/O).
pub fn hybrid_host_with_placement(
    spec: &TcpJobSpec,
    placement: &Placement,
    host: &str,
    leader_addr: &str,
) -> Result<(), String> {
    if placement.k() != spec.workers {
        return Err(format!(
            "hostfile places {} ranks but the pool has {}",
            placement.k(),
            spec.workers
        ));
    }
    let job = spec.build()?;
    let backend = NativeBackend;
    let solver = make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
    let solver_ref = solver.as_deref();
    let cfg = HybridHostConfig { placement, host, leader_addr, iters: spec.iters };
    run_hybrid_host(&job.problem, &job.g, &job.part, &cfg, &|owned| {
        make_sharded_algorithm_stale(
            &job.kind,
            &job.problem,
            &job.g,
            &backend,
            solver_ref,
            owned,
            spec.stale_tau,
        )
    })
    .map_err(|e| e.to_string())
}

/// Three-way parity verdict of one algorithm run on the TCP pool against
/// both in-process references. The headline invariant is
/// [`ok`](Self::ok): iterates and per-iteration objectives bit-identical
/// to bulk *and* shard, modeled ledger identical, real socket payload
/// count equal to the plan-driven wire model, and observed payload bytes
/// exactly `cross_floats × 8` with header overhead a whole number of
/// 16-byte frame headers.
#[derive(Debug)]
pub struct TcpParity {
    /// Algorithm display name (from the bulk trace).
    pub algorithm: String,
    /// The TCP pool's run.
    pub tcp: TcpPartitionedRun,
    /// Bulk-synchronous reference trace.
    pub bulk: Trace,
    /// In-process sharded reference run.
    pub shard: PartitionedRun,
    /// Plan-driven wire model of the cross-worker payload count.
    pub modeled_cross: u64,
    /// TCP final iterate bit-identical to the bulk reference.
    pub thetas_match_bulk: bool,
    /// TCP final iterate bit-identical to the in-process shard reference.
    pub thetas_match_shard: bool,
    /// Per-iteration objectives bit-identical to both references.
    pub objectives_match: bool,
    /// Modeled comm ledger identical to both references.
    pub ledger_ok: bool,
    /// Real socket payloads == wire model == in-process channel payloads
    /// (and the same for floats).
    pub wire_ok: bool,
    /// `payload_bytes == cross_floats × 8` and `header_bytes` a whole
    /// number of frame headers.
    pub bytes_ok: bool,
}

impl TcpParity {
    /// All parity and wire-truth checks passed.
    pub fn ok(&self) -> bool {
        self.thetas_match_bulk
            && self.thetas_match_shard
            && self.objectives_match
            && self.ledger_ok
            && self.wire_ok
            && self.bytes_ok
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Run `spec` three ways — bulk reference, in-process shard reference,
/// and a real TCP pool — and report the parity verdict.
///
/// With `bin = Some(path)` the workers are separate OS *processes*
/// (`path worker --rank R --connect ADDR …`); with `bin = None` they are
/// in-process threads speaking real loopback TCP sockets (the CI-friendly
/// single-machine mode — same frames, same rendezvous, no fork/exec).
/// `listen` is the leader bind address (use `127.0.0.1:0` for an
/// ephemeral loopback port).
pub fn run_tcp_cross_transport(
    spec: &TcpJobSpec,
    listen: &str,
    bin: Option<&Path>,
) -> Result<TcpParity, String> {
    let job = spec.build()?;
    let k = spec.workers;
    let iters = spec.iters;

    // References, both built on a solver from the same deterministic seed
    // the worker processes use.
    let backend = NativeBackend;
    let solver = make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
    let solver_ref = solver.as_deref();
    let mut alg = make_sharded_algorithm_stale(
        &job.kind,
        &job.problem,
        &job.g,
        &backend,
        solver_ref,
        (0..job.problem.n()).collect(),
        spec.stale_tau,
    );
    let mut comm = CommGraph::new(&job.g);
    let bulk = run(
        &mut alg,
        &job.problem,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );
    let shard = run_partitioned_baseline(&job.problem, &job.g, &job.part, iters, &|owned| {
        make_sharded_algorithm_stale(
            &job.kind,
            &job.problem,
            &job.g,
            &backend,
            solver_ref,
            owned,
            spec.stale_tau,
        )
    });

    // The TCP pool: leader here, workers as processes or socket threads.
    let leader = TcpLeader::bind(listen, k).map_err(|e| e.to_string())?;
    let addr = leader.addr().map_err(|e| e.to_string())?.to_string();
    let timeout = frame::default_timeout();
    let owned_of: Vec<Vec<usize>> = (0..k).map(|w| job.part.nodes_of(w)).collect();

    let mut children: Vec<std::process::Child> = Vec::new();
    let mut threads: Vec<std::thread::JoinHandle<Result<(), String>>> = Vec::new();
    match bin {
        Some(path) => {
            for rank in 0..k {
                let child = std::process::Command::new(path)
                    .arg("worker")
                    .args(spec.to_worker_args())
                    .args(["--rank".to_string(), rank.to_string()])
                    .args(["--connect".to_string(), addr.clone()])
                    .spawn()
                    .map_err(|e| format!("spawn worker {rank}: {e}"))?;
                children.push(child);
            }
        }
        None => {
            for rank in 0..k {
                let spec = spec.clone();
                let net = WorkerNetConfig::from_env(rank, k, &addr);
                threads.push(std::thread::spawn(move || tcp_worker_main(&spec, &net)));
            }
        }
    }

    let led = run_leader(leader, &job.problem, owned_of, iters, timeout);
    // Reap the pool before judging the leader outcome, so a leader error
    // never leaks worker processes.
    let mut worker_err: Option<String> = None;
    for (rank, child) in children.iter_mut().enumerate() {
        if led.is_err() {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                worker_err.get_or_insert(format!("worker {rank} exited with {status}"));
            }
            Err(e) => {
                worker_err.get_or_insert(format!("worker {rank} wait failed: {e}"));
            }
        }
    }
    for (rank, handle) in threads.into_iter().enumerate() {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                worker_err.get_or_insert(format!("worker {rank} failed: {e}"));
            }
            Err(_) => {
                worker_err.get_or_insert(format!("worker {rank} panicked"));
            }
        }
    }
    let tcp = match led {
        Ok(out) => out,
        Err(e) => {
            let extra = worker_err.map(|w| format!(" ({w})")).unwrap_or_default();
            return Err(format!("leader failed: {e}{extra}"));
        }
    };
    if let Some(w) = worker_err {
        return Err(w);
    }

    // Parity verdict.
    let bulk_stats = bulk.records.last().map(|r| r.comm).unwrap_or_default();
    let modeled_cross = modeled_cross_messages(&job.kind, &job.g, &job.part, iters, &bulk_stats);
    let thetas_match_bulk = bits(&tcp.thetas) == bits(&bulk.final_thetas);
    let thetas_match_shard = bits(&tcp.thetas) == bits(&shard.thetas);
    // trace.records[0] is the starting point; partitioned records begin at
    // iteration 1.
    let objectives_match = tcp.records.len() == iters
        && shard.records.len() == iters
        && bulk.records.len() == iters + 1
        && tcp.records.iter().zip(&bulk.records[1..]).all(|(a, b)| {
            a.objective.to_bits() == b.objective.to_bits()
        })
        && tcp.records.iter().zip(&shard.records).all(|(a, b)| {
            a.objective.to_bits() == b.objective.to_bits()
        });
    let ledger_ok = tcp.comm == bulk_stats && tcp.comm == shard.comm;
    let wire_ok = tcp.cross_messages == modeled_cross
        && tcp.cross_messages == shard.cross_messages
        && tcp.cross_floats == shard.cross_floats;
    let bytes_ok =
        tcp.payload_bytes == tcp.cross_floats * 8 && tcp.header_bytes % HEADER_BYTES == 0;

    Ok(TcpParity {
        algorithm: bulk.algorithm.clone(),
        tcp,
        bulk,
        shard,
        modeled_cross,
        thetas_match_bulk,
        thetas_match_shard,
        objectives_match,
        ledger_ok,
        wire_ok,
        bytes_ok,
    })
}

/// Parity verdict of one algorithm run on the hybrid transport against
/// both in-process references — the [`TcpParity`] checks, with the wire
/// truth refined by host placement: the intra/inter splits must sum back
/// to the placement-agnostic totals, and observed socket bytes must cover
/// exactly the inter-host floats (co-located traffic never hits a socket).
#[derive(Debug)]
pub struct HybridParity {
    /// Algorithm display name (from the bulk trace).
    pub algorithm: String,
    /// The hybrid pool's run (leader-side gather).
    pub hybrid: TcpPartitionedRun,
    /// Bulk-synchronous reference trace.
    pub bulk: Trace,
    /// In-process sharded reference run.
    pub shard: PartitionedRun,
    /// Plan-driven wire model of the cross-worker payload count.
    pub modeled_cross: u64,
    /// Hybrid final iterate bit-identical to the bulk reference.
    pub thetas_match_bulk: bool,
    /// Hybrid final iterate bit-identical to the in-process shard run.
    pub thetas_match_shard: bool,
    /// Per-iteration objectives bit-identical to both references.
    pub objectives_match: bool,
    /// Modeled comm ledger identical to both references.
    pub ledger_ok: bool,
    /// Placement-agnostic totals preserved: cross payloads/floats equal
    /// the wire model and the in-process shard run.
    pub wire_ok: bool,
    /// The placement split is internally consistent:
    /// `intra + inter == cross` for both payload counts and floats.
    pub split_ok: bool,
    /// Socket bytes cover exactly the inter-host leg:
    /// `payload_bytes == inter_floats × 8` and `header_bytes` a whole
    /// number of frame headers.
    pub bytes_ok: bool,
}

impl HybridParity {
    /// All parity, split-accounting, and wire-truth checks passed.
    pub fn ok(&self) -> bool {
        self.thetas_match_bulk
            && self.thetas_match_shard
            && self.objectives_match
            && self.ledger_ok
            && self.wire_ok
            && self.split_ok
            && self.bytes_ok
    }
}

/// Run `spec` on the hybrid transport under `placement` — bulk reference,
/// in-process shard reference, then one hybrid pool with co-located ranks
/// on channels and cross-host edges on TCP — and report the parity
/// verdict.
///
/// With `bin = Some(path)` each *host* becomes an OS process
/// (`path worker --host H --hostfile F …`; `spec.hostfile` must point at
/// the file `placement` was parsed from). With `bin = None` each host is
/// an in-process thread (which still drives one OS thread per local rank
/// and speaks real loopback sockets across "hosts" — the CI-friendly
/// single-machine mode). `listen` is the leader bind address.
pub fn run_hybrid_cross_transport(
    spec: &TcpJobSpec,
    placement: &Placement,
    listen: &str,
    bin: Option<&Path>,
) -> Result<HybridParity, String> {
    let k = spec.workers;
    if placement.k() != k {
        return Err(format!("hostfile places {} ranks but the pool has {k}", placement.k()));
    }
    if bin.is_some() && spec.hostfile.is_none() {
        return Err("process mode needs spec.hostfile so workers can re-parse the placement"
            .to_string());
    }
    let job = spec.build()?;
    let iters = spec.iters;

    // References, built on a solver from the same deterministic seed the
    // host processes use.
    let backend = NativeBackend;
    let solver = make_inner_solver(&job.kind, &job.g, &mut Pcg64::new(spec.solver_seed));
    let solver_ref = solver.as_deref();
    let mut alg = make_sharded_algorithm_stale(
        &job.kind,
        &job.problem,
        &job.g,
        &backend,
        solver_ref,
        (0..job.problem.n()).collect(),
        spec.stale_tau,
    );
    let mut comm = CommGraph::new(&job.g);
    let bulk = run(
        &mut alg,
        &job.problem,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );
    let shard = run_partitioned_baseline(&job.problem, &job.g, &job.part, iters, &|owned| {
        make_sharded_algorithm_stale(
            &job.kind,
            &job.problem,
            &job.g,
            &backend,
            solver_ref,
            owned,
            spec.stale_tau,
        )
    });

    // The hybrid pool: leader here (broadcasting the placement), one
    // "host" per distinct hostfile name.
    let leader = TcpLeader::bind(listen, k).map_err(|e| e.to_string())?;
    let addr = leader.addr().map_err(|e| e.to_string())?.to_string();
    let timeout = frame::default_timeout();
    let owned_of: Vec<Vec<usize>> = (0..k).map(|w| job.part.nodes_of(w)).collect();
    let rank_hosts: Vec<String> = (0..k).map(|r| placement.host(r).to_string()).collect();
    let host_names: Vec<String> = placement.hosts().iter().map(|h| h.to_string()).collect();

    let mut children: Vec<std::process::Child> = Vec::new();
    let mut threads: Vec<std::thread::JoinHandle<Result<(), String>>> = Vec::new();
    match bin {
        Some(path) => {
            for host in &host_names {
                let child = std::process::Command::new(path)
                    .arg("worker")
                    .args(spec.to_worker_args())
                    .args(["--host".to_string(), host.clone()])
                    .args(["--connect".to_string(), addr.clone()])
                    .spawn()
                    .map_err(|e| format!("spawn host {host}: {e}"))?;
                children.push(child);
            }
        }
        None => {
            for host in &host_names {
                let spec = spec.clone();
                let placement = placement.clone();
                let host = host.clone();
                let addr = addr.clone();
                threads.push(std::thread::spawn(move || {
                    hybrid_host_with_placement(&spec, &placement, &host, &addr)
                }));
            }
        }
    }

    let led =
        run_leader_with_hosts(leader, &job.problem, owned_of, iters, timeout, Some(&rank_hosts));
    // Reap the pool before judging the leader outcome, so a leader error
    // never leaks host processes.
    let mut worker_err: Option<String> = None;
    for (host, child) in host_names.iter().zip(children.iter_mut()) {
        if led.is_err() {
            let _ = child.kill();
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                worker_err.get_or_insert(format!("host {host} exited with {status}"));
            }
            Err(e) => {
                worker_err.get_or_insert(format!("host {host} wait failed: {e}"));
            }
        }
    }
    for (host, handle) in host_names.iter().zip(threads) {
        match handle.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                worker_err.get_or_insert(format!("host {host} failed: {e}"));
            }
            Err(_) => {
                worker_err.get_or_insert(format!("host {host} panicked"));
            }
        }
    }
    let hybrid = match led {
        Ok(out) => out,
        Err(e) => {
            let extra = worker_err.map(|w| format!(" ({w})")).unwrap_or_default();
            return Err(format!("leader failed: {e}{extra}"));
        }
    };
    if let Some(w) = worker_err {
        return Err(w);
    }

    // Parity verdict.
    let bulk_stats = bulk.records.last().map(|r| r.comm).unwrap_or_default();
    let modeled_cross = modeled_cross_messages(&job.kind, &job.g, &job.part, iters, &bulk_stats);
    let thetas_match_bulk = bits(&hybrid.thetas) == bits(&bulk.final_thetas);
    let thetas_match_shard = bits(&hybrid.thetas) == bits(&shard.thetas);
    let objectives_match = hybrid.records.len() == iters
        && shard.records.len() == iters
        && bulk.records.len() == iters + 1
        && hybrid.records.iter().zip(&bulk.records[1..]).all(|(a, b)| {
            a.objective.to_bits() == b.objective.to_bits()
        })
        && hybrid.records.iter().zip(&shard.records).all(|(a, b)| {
            a.objective.to_bits() == b.objective.to_bits()
        });
    let ledger_ok = hybrid.comm == bulk_stats && hybrid.comm == shard.comm;
    let wire_ok = hybrid.cross_messages == modeled_cross
        && hybrid.cross_messages == shard.cross_messages
        && hybrid.cross_floats == shard.cross_floats;
    let split_ok = hybrid.intra_cross + hybrid.inter_cross == hybrid.cross_messages
        && hybrid.intra_floats + hybrid.inter_floats == hybrid.cross_floats;
    let bytes_ok = hybrid.payload_bytes == hybrid.inter_floats * 8
        && hybrid.header_bytes % HEADER_BYTES == 0;

    Ok(HybridParity {
        algorithm: bulk.algorithm.clone(),
        hybrid,
        bulk,
        shard,
        modeled_cross,
        thetas_match_bulk,
        thetas_match_shard,
        objectives_match,
        ledger_ok,
        wire_ok,
        split_ok,
        bytes_ok,
    })
}
