//! Report writers: CSV traces and human-readable summaries.

use super::experiments::ExperimentResult;
use crate::algorithms::Trace;
use std::io::Write;
use std::path::Path;

/// Write all traces of an experiment as one CSV:
/// `algorithm,iter,objective,consensus_error,messages,floats,rounds,elapsed_s`.
pub fn write_csv(res: &ExperimentResult, path: impl AsRef<Path>) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "algorithm,iter,objective,consensus_error,messages,floats,rounds,elapsed_s")?;
    for t in &res.traces {
        for r in &t.records {
            writeln!(
                f,
                "{},{},{:.12e},{:.12e},{},{},{},{:.6}",
                t.algorithm,
                r.iter,
                r.objective,
                r.consensus_error,
                r.comm.messages,
                r.comm.floats,
                r.comm.rounds,
                r.elapsed
            )?;
        }
    }
    Ok(())
}

/// Render a plain-text summary table (shown by the CLI and the benches).
pub fn summary_table(res: &ExperimentResult) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "experiment {}  (n={} m={} backend={} μ₂={:.4} μ_n={:.4} f*={:.6e})\n",
        res.config.name,
        res.config.nodes,
        res.config.edges,
        res.backend_used,
        res.mu2,
        res.mun,
        res.f_star
    ));
    out.push_str(&format!(
        "{:<28} {:>6} {:>14} {:>12} {:>12} {:>10}\n",
        "algorithm", "iters", "final gap", "consensus", "messages", "time (s)"
    ));
    for t in &res.traces {
        let last = t.records.last().unwrap();
        let gap = (last.objective - res.f_star) / res.f_star.abs().max(1.0);
        out.push_str(&format!(
            "{:<28} {:>6} {:>14.4e} {:>12.4e} {:>12} {:>10.3}\n",
            t.algorithm,
            last.iter,
            gap,
            last.consensus_error,
            last.comm.messages,
            last.elapsed
        ));
    }
    out
}

/// Iterations each algorithm needs to reach a relative gap (for the
/// "~40 vs ~200 iterations" headline of Fig. 1).
pub fn iters_table(res: &ExperimentResult, tol: f64) -> Vec<(String, Option<usize>)> {
    res.traces
        .iter()
        .map(|t| (t.algorithm.clone(), t.iters_to_gap(res.f_star, tol)))
        .collect()
}

/// CSV for the Fig. 2(c) communication-overhead rows.
pub fn write_comm_csv(
    rows: &[(String, Vec<(f64, Option<u64>)>)],
    path: impl AsRef<Path>,
) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "algorithm,accuracy,messages")?;
    for (name, cells) in rows {
        for (acc, msgs) in cells {
            match msgs {
                Some(m) => writeln!(f, "{name},{acc:e},{m}")?,
                None => writeln!(f, "{name},{acc:e},")?,
            }
        }
    }
    Ok(())
}

/// Simple ASCII convergence plot (objective gap vs iteration, log-y), so
/// figure shapes are visible without matplotlib.
pub fn ascii_plot(traces: &[Trace], f_star: f64, width: usize, height: usize) -> String {
    let scale = f_star.abs().max(1.0);
    // Gather log10 gaps.
    let series: Vec<(String, Vec<f64>)> = traces
        .iter()
        .map(|t| {
            let g: Vec<f64> = t
                .records
                .iter()
                .map(|r| ((r.objective - f_star).abs() / scale).max(1e-16).log10())
                .collect();
            (t.algorithm.clone(), g)
        })
        .collect();
    let ymax = series
        .iter()
        .flat_map(|(_, g)| g.iter())
        .cloned()
        .fold(f64::MIN, f64::max);
    let ymin = series
        .iter()
        .flat_map(|(_, g)| g.iter())
        .cloned()
        .fold(f64::MAX, f64::min);
    let span = (ymax - ymin).max(1e-9);
    let max_iter = series.iter().map(|(_, g)| g.len()).max().unwrap_or(1);
    let mut grid = vec![vec![b' '; width]; height];
    let marks = [b'*', b'+', b'o', b'x', b'#', b'@', b'%', b'&'];
    for (si, (_, g)) in series.iter().enumerate() {
        for (i, &v) in g.iter().enumerate() {
            // Map indices 0..max_iter-1 onto columns 0..width-1 inclusive,
            // so the final iterate reaches the right edge; a single-point
            // series lands on column 0.
            let x = i * (width - 1) / max_iter.saturating_sub(1).max(1);
            let y = ((ymax - v) / span * (height - 1) as f64).round() as usize;
            let y = y.min(height - 1);
            grid[y][x] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("log10(relative gap): {ymax:.1} (top) … {ymin:.1} (bottom)\n"));
    for row in grid {
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()] as char, name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::IterRecord;
    use crate::config::ExperimentConfig;
    use crate::harness::run_experiment;
    use crate::net::CommStats;

    fn trace_of(name: &str, objectives: &[f64]) -> Trace {
        Trace {
            algorithm: name.to_string(),
            records: objectives
                .iter()
                .enumerate()
                .map(|(i, &objective)| IterRecord {
                    iter: i,
                    objective,
                    consensus_error: 0.0,
                    comm: CommStats::default(),
                    elapsed: 0.0,
                })
                .collect(),
            final_thetas: Vec::new(),
        }
    }

    #[test]
    fn ascii_plot_reaches_right_edge() {
        // A strictly-decreasing series must place its final iterate in
        // the LAST column, not one-or-more columns short.
        let traces = [trace_of("dec", &[10.0, 8.0, 6.0, 4.0, 2.0])];
        let plot = ascii_plot(&traces, 0.0, 20, 5);
        let rows: Vec<&str> = plot.lines().skip(1).take(5).collect();
        let right_edge_hit = rows
            .iter()
            .any(|row| row.as_bytes().get(19).is_some_and(|&b| b == b'*'));
        assert!(right_edge_hit, "final iterate missing from last column:\n{plot}");
    }

    #[test]
    fn ascii_plot_single_point_series() {
        // One record: the point lands in column 0 and nothing panics.
        let traces = [trace_of("single", &[3.0])];
        let plot = ascii_plot(&traces, 1.0, 12, 4);
        let rows: Vec<&str> = plot.lines().skip(1).take(4).collect();
        let col0_hit = rows.iter().any(|row| row.as_bytes()[0] == b'*');
        assert!(col0_hit, "single-point series missing from column 0:\n{plot}");
    }

    #[test]
    fn ascii_plot_constant_series_spans_width() {
        // A constant series draws a horizontal line from the first to the
        // LAST column.
        let traces = [trace_of("const", &[5.0; 8])];
        let plot = ascii_plot(&traces, 1.0, 16, 3);
        let rows: Vec<&str> = plot.lines().skip(1).take(3).collect();
        let line_row = rows
            .iter()
            .find(|row| row.contains('*'))
            .expect("constant series row");
        let b = line_row.as_bytes();
        assert_eq!(b[0], b'*', "missing left edge:\n{plot}");
        assert_eq!(b[15], b'*', "missing right edge:\n{plot}");
    }

    #[test]
    fn csv_and_summary_roundtrip() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.max_iters = 3;
        cfg.algorithms.truncate(2);
        let res = run_experiment(&cfg);
        let dir = std::env::temp_dir().join("sddn_test_report");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.csv");
        write_csv(&res, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().count() >= 1 + 2 * 4);
        assert!(text.starts_with("algorithm,iter"));
        let table = summary_table(&res);
        assert!(table.contains("SDD-Newton"));
        let plot = ascii_plot(&res.traces, res.f_star, 40, 10);
        assert!(plot.lines().count() >= 10);
    }
}
