//! Experiment drivers.

use crate::algorithms::admm::Admm;
use crate::algorithms::averaging::DistAveraging;
use crate::algorithms::gradient::{DistGradient, GradSchedule};
use crate::algorithms::local_steps::LocalNewton;
use crate::algorithms::network_newton::NetworkNewton;
use crate::algorithms::sdd_newton::{SddNewton, StepSize};
use crate::algorithms::solvers::{sddm_for_graph, ExactCgSolver, LaplacianSolver, NeumannSolver};
use crate::algorithms::{run, ConsensusAlgorithm, RunOptions, Trace};
use crate::config::{AlgoKind, ExperimentConfig, ProblemKind};
use crate::coordinator::{run_partitioned_baseline, Partition, PartitionedRun};
use crate::graph::{generate, Graph};
use crate::net::CommGraph;
use crate::problems::logistic::Reg;
use crate::problems::{datasets, ConsensusProblem};
use crate::runtime::{LocalBackend, NativeBackend, PjrtBackend};
use crate::util::Pcg64;

/// Everything an experiment run produced.
pub struct ExperimentResult {
    pub config: ExperimentConfig,
    pub f_star: f64,
    pub traces: Vec<Trace>,
    pub mu2: f64,
    pub mun: f64,
    pub backend_used: &'static str,
}

/// Build the processor graph for a config.
pub fn build_graph(cfg: &ExperimentConfig, rng: &mut Pcg64) -> Graph {
    generate::random_connected(cfg.nodes, cfg.edges, rng)
}

/// Build the consensus problem for a config.
pub fn build_problem(cfg: &ExperimentConfig, rng: &mut Pcg64) -> ConsensusProblem {
    match cfg.problem {
        ProblemKind::SyntheticRegression { p, m_total, noise, mu } => {
            datasets::synthetic_regression(cfg.nodes, p, m_total, noise, mu, rng)
        }
        ProblemKind::MnistLike { p, m_total, l1, mu } => {
            let reg = if l1 { Reg::SmoothL1 { alpha: 8.0 } } else { Reg::L2 };
            datasets::mnist_like(cfg.nodes, p, m_total, 0, reg, mu, rng)
        }
        ProblemKind::FmriLike { p, m_total, k_sparse, mu } => {
            datasets::fmri_like(cfg.nodes, p, m_total, k_sparse, 8.0, mu, rng)
        }
        ProblemKind::LondonLike { m_total, mu } => {
            datasets::london_like(cfg.nodes, m_total, mu, rng)
        }
        ProblemKind::RlDcp { rollouts, t_len, sigma, mu } => {
            datasets::rl_dcp(cfg.nodes, rollouts, t_len, sigma, mu, rng)
        }
    }
}

/// Locate the artifacts directory (next to Cargo.toml).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Pick the backend per config, falling back to native with a warning.
pub fn make_backend(cfg: &ExperimentConfig, problem: &ConsensusProblem) -> Box<dyn LocalBackend> {
    if cfg.backend == "pjrt" {
        match PjrtBackend::for_problem(problem, artifacts_dir()) {
            Ok(b) => return Box::new(b),
            Err(e) => {
                crate::warn_!("pjrt backend unavailable ({e}); falling back to native");
            }
        }
    }
    Box::new(NativeBackend)
}

/// Run one algorithm on a prepared problem/graph.
pub fn run_single(
    kind: &AlgoKind,
    problem: &ConsensusProblem,
    g: &Graph,
    backend: &dyn LocalBackend,
    opts: &RunOptions,
    rng: &mut Pcg64,
) -> Trace {
    let mut comm = CommGraph::new(g);
    match *kind {
        AlgoKind::SddNewton { eps, alpha } => {
            let solver = sddm_for_graph(g, eps, rng);
            let mut a = SddNewton::new(problem, backend, &solver, StepSize::Fixed(alpha));
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::AddNewton { terms, alpha } => {
            let solver = NeumannSolver::from_graph(g, terms);
            let mut a = SddNewton::new(problem, backend, &solver, StepSize::Fixed(alpha));
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::ExactNewton { alpha } => {
            let solver = ExactCgSolver::from_graph(g, 1e-12);
            let mut a = SddNewton::new(problem, backend, &solver, StepSize::Fixed(alpha));
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::Admm { beta } => {
            let mut a = Admm::new(problem, g, beta);
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::AdmmPipelined { beta } => {
            let mut a = Admm::new_pipelined(problem, g, beta);
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::LocalNewton { eta, local_steps, comm_rounds } => {
            let mut a = LocalNewton::new(problem, g, eta, local_steps, comm_rounds);
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::Gradient { alpha } => {
            let mut a = DistGradient::new(problem, g, GradSchedule::Constant(alpha));
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::Averaging { beta } => {
            let mut a = DistAveraging::new(problem, g, beta);
            run(&mut a, problem, &mut comm, opts)
        }
        AlgoKind::NetworkNewton { k, alpha, epsilon } => {
            let mut a = NetworkNewton::new(problem, g, k, alpha, epsilon);
            run(&mut a, problem, &mut comm, opts)
        }
    }
}

/// Build the inner Laplacian solver a dual-Newton kind needs (`None` for
/// the first-order/ADMM baselines). Bulk and partitioned runs of one
/// comparison must share a single instance — the SDDM chain construction
/// is randomized, so rebuilding it would break bit-for-bit parity.
pub fn make_inner_solver(
    kind: &AlgoKind,
    g: &Graph,
    rng: &mut Pcg64,
) -> Option<Box<dyn LaplacianSolver>> {
    match *kind {
        AlgoKind::SddNewton { eps, .. } => Some(Box::new(sddm_for_graph(g, eps, rng))),
        AlgoKind::AddNewton { terms, .. } => Some(Box::new(NeumannSolver::from_graph(g, terms))),
        AlgoKind::ExactNewton { .. } => Some(Box::new(ExactCgSolver::from_graph(g, 1e-12))),
        _ => None,
    }
}

/// Build a shard-local instance of `kind` owning the given global nodes —
/// the factory consumed by [`run_partitioned_baseline`] (and, with
/// `owned = 0..n`, the bulk-path construction). Dual-Newton kinds borrow
/// the caller's shared inner `solver`. Strict BSP: equivalent to
/// [`make_sharded_algorithm_stale`] with `stale_tau = 0`.
pub fn make_sharded_algorithm<'a>(
    kind: &AlgoKind,
    problem: &'a ConsensusProblem,
    g: &Graph,
    backend: &'a NativeBackend,
    solver: Option<&'a dyn LaplacianSolver>,
    owned: Vec<usize>,
) -> Box<dyn ConsensusAlgorithm + 'a> {
    make_sharded_algorithm_stale(kind, problem, g, backend, solver, owned, 0)
}

/// [`make_sharded_algorithm`] under a bounded-staleness policy: boundary
/// data consumed by the kind's policy-eligible halo exchange may be up to
/// `stale_tau` rounds old ([`crate::net::Exchange::exchange_apply_stale`]).
/// `stale_tau = 0` is bit-for-bit the strict BSP construction. The policy
/// applies to the mixing/diffusion exchange of the first-order baselines
/// and to the dual-Newton kinds' outer dual-gradient read; ADMM (either
/// schedule — its Gauss–Seidel sweep *requires* fresh predecessor
/// values), Network Newton, and the local-step method (already
/// communication-avoiding by construction) ignore it.
pub fn make_sharded_algorithm_stale<'a>(
    kind: &AlgoKind,
    problem: &'a ConsensusProblem,
    g: &Graph,
    backend: &'a NativeBackend,
    solver: Option<&'a dyn LaplacianSolver>,
    owned: Vec<usize>,
    stale_tau: u64,
) -> Box<dyn ConsensusAlgorithm + 'a> {
    match *kind {
        AlgoKind::SddNewton { alpha, .. }
        | AlgoKind::AddNewton { alpha, .. }
        | AlgoKind::ExactNewton { alpha } => {
            let solver = solver.expect("dual-Newton kinds need the shared inner solver");
            let alg = SddNewton::new_sharded(problem, backend, solver, StepSize::Fixed(alpha), owned)
                .with_staleness(crate::graph::laplacian_csr(g), stale_tau);
            Box::new(alg)
        }
        AlgoKind::Admm { beta } => Box::new(Admm::new_sharded(problem, g, beta, owned)),
        AlgoKind::AdmmPipelined { beta } => {
            Box::new(Admm::new_sharded_pipelined(problem, g, beta, owned))
        }
        AlgoKind::Gradient { alpha } => Box::new(
            DistGradient::new_sharded(problem, g, GradSchedule::Constant(alpha), owned)
                .with_staleness(stale_tau),
        ),
        AlgoKind::Averaging { beta } => Box::new(
            DistAveraging::new_sharded(problem, g, beta, owned).with_staleness(stale_tau),
        ),
        AlgoKind::NetworkNewton { k, alpha, epsilon } => {
            Box::new(NetworkNewton::new_sharded(problem, g, k, alpha, epsilon, owned))
        }
        AlgoKind::LocalNewton { eta, local_steps, comm_rounds } => {
            Box::new(LocalNewton::new_sharded(problem, g, eta, local_steps, comm_rounds, owned))
        }
    }
}

/// Wire model of a partitioned run: the cross-worker payload count a
/// plan-driven `ShardExchange` ships for `iters` iterations of `kind`,
/// composed from the bulk path's modeled [`CommStats`] ledger and the
/// partition — the "modeled messages" side of the real-vs-modeled checks
/// in `tests/prop_wire.rs`, the `partitioned_baselines` bench and the
/// `sddnewton partitioned` CLI.
///
/// Two facts make the composition exact. Every exchange round of the
/// non-ADMM algorithms applies an operator with *full edge support*
/// (Metropolis/diffusion mixing, Laplacian, adjacency, the chain walk
/// matrix), so each round ships exactly the graph-halo boundary —
/// [`plan_cross_rows`](crate::net::partitioned::plan_cross_rows) of the
/// Laplacian — and the round count is read off
/// the ledger (`rounds − 2·allreduces`). ADMM's wavefront instead ships
/// per-stage fresh rows, mirrored here stage by stage from the same
/// coloring schedule the algorithm uses. Each all-reduce moves one up and
/// one down payload per worker through the leader (`2k` when `k > 1`).
pub fn modeled_cross_messages(
    kind: &AlgoKind,
    g: &Graph,
    part: &Partition,
    iters: usize,
    bulk: &crate::net::CommStats,
) -> u64 {
    use crate::net::partitioned::plan_cross_rows;
    if part.k <= 1 {
        return 0;
    }
    let owner = &part.assignment;
    let allreduce_wire = 2 * part.k as u64 * bulk.allreduces;
    match kind {
        AlgoKind::Admm { .. } => {
            let stage_of = crate::algorithms::admm::sweep_stages(g);
            let stages = stage_of.iter().max().map(|&s| s + 1).unwrap_or(0);
            let adj = crate::graph::laplacian::adjacency_csr(g);
            let lap = crate::graph::laplacian_csr(g);
            let mask = |s: usize| -> Vec<bool> { stage_of.iter().map(|&t| t == s).collect() };
            let mut per_iter = plan_cross_rows(&adj, owner, None);
            for s in 1..stages {
                per_iter += plan_cross_rows(&adj, owner, Some(mask(s - 1).as_slice()));
            }
            if stages > 0 {
                per_iter += plan_cross_rows(&lap, owner, Some(mask(stages - 1).as_slice()));
            }
            iters as u64 * per_iter + allreduce_wire
        }
        AlgoKind::AdmmPipelined { .. } => {
            // Mirror the pipelined ship masks round by round from the
            // same schedule the algorithm precomputes.
            let stage_of = crate::algorithms::admm::sweep_stages(g);
            let (masks, _, dual_mask, _) =
                crate::algorithms::admm::pipelined_ship_schedule(g, &stage_of);
            let adj = crate::graph::laplacian::adjacency_csr(g);
            let lap = crate::graph::laplacian_csr(g);
            let mut per_iter = plan_cross_rows(&adj, owner, None);
            for mask in &masks[1..] {
                per_iter += plan_cross_rows(&adj, owner, Some(mask.as_slice()));
            }
            per_iter += plan_cross_rows(&lap, owner, Some(dual_mask.as_slice()));
            iters as u64 * per_iter + allreduce_wire
        }
        _ => {
            let exchange_rounds = bulk.rounds - 2 * bulk.allreduces;
            let boundary = plan_cross_rows(&crate::graph::laplacian_csr(g), owner, None);
            exchange_rounds * boundary + allreduce_wire
        }
    }
}

/// Run `kind` on both transports — the bulk-synchronous [`CommGraph`]
/// reference and the partitioned worker runtime over `part` — sharing the
/// inner solver instance, so callers can assert the bit-for-bit parity
/// contract (iterates, per-iteration objectives, modeled comm ledger).
pub fn run_cross_transport(
    kind: &AlgoKind,
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    iters: usize,
    rng: &mut Pcg64,
) -> (Trace, PartitionedRun) {
    run_cross_transport_stale(kind, problem, g, part, iters, 0, rng)
}

/// [`run_cross_transport`] under a bounded-staleness policy
/// (`stale_tau`, see [`make_sharded_algorithm_stale`]). The parity
/// contract holds for *every* τ — stale rounds are a pure function of
/// the last refresh output and the current local iterate, so both
/// transports reconstruct identical halos and tally identical ledgers
/// (savings counters included).
pub fn run_cross_transport_stale(
    kind: &AlgoKind,
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    iters: usize,
    stale_tau: u64,
    rng: &mut Pcg64,
) -> (Trace, PartitionedRun) {
    let backend = NativeBackend;
    let solver = make_inner_solver(kind, g, rng);
    let solver_ref: Option<&dyn LaplacianSolver> = solver.as_deref();
    // Bulk-synchronous reference.
    let mut alg = make_sharded_algorithm_stale(
        kind,
        problem,
        g,
        &backend,
        solver_ref,
        (0..problem.n()).collect(),
        stale_tau,
    );
    let mut comm = CommGraph::new(g);
    let trace = run(
        // `Box<dyn ConsensusAlgorithm>` implements the trait itself, so
        // `&mut alg` unsizes from a concrete type (no object-lifetime
        // shortening behind `&mut`, which invariance would reject).
        &mut alg,
        problem,
        &mut comm,
        &RunOptions { max_iters: iters, ..Default::default() },
    );
    // Partitioned run over the same shared state.
    let out = run_partitioned_baseline(problem, g, part, iters, &|owned| {
        make_sharded_algorithm_stale(kind, problem, g, &backend, solver_ref, owned, stale_tau)
    });
    (trace, out)
}

/// The paper's step-size protocol: "Step-sizes were determined separately
/// for each algorithm using a grid-search-like-technique". Try a grid of
/// multipliers on the algorithm's step-like knob over a short horizon and
/// keep the best. Scoring uses `f(θ̄) − f* surrogate + consensus error`
/// — `f` at the mean iterate is always ≥ f*, so smaller is better.
pub fn tune_step(
    kind: &AlgoKind,
    problem: &ConsensusProblem,
    g: &Graph,
    backend: &dyn LocalBackend,
    rng: &mut Pcg64,
) -> AlgoKind {
    // Dual Newton methods take α = 1 on these problems; tuning them costs
    // full SDDM solves. The grid applies to the step-sensitive baselines.
    if matches!(
        kind,
        AlgoKind::SddNewton { .. } | AlgoKind::AddNewton { .. } | AlgoKind::ExactNewton { .. }
    ) {
        return kind.clone();
    }
    let horizon = RunOptions { max_iters: 12, ..Default::default() };
    let mut best = kind.clone();
    let mut best_score = f64::INFINITY;
    for &mult in &[10.0, 3.0, 1.0, 0.3, 0.1, 0.03, 0.01, 0.003] {
        let cand = kind.scale_step(mult);
        let trace = run_single(&cand, problem, g, backend, &horizon, rng);
        let last = trace.records.last().unwrap();
        if !last.objective.is_finite() || !last.consensus_error.is_finite() {
            continue;
        }
        // f(θ̄) ≥ f* always, so it is a sound progress score; add the
        // consensus error so near-ties break toward feasibility.
        let mean = problem.mean_iterate(&trace.final_thetas);
        let f_mean = problem.objective_at(&mean);
        if !f_mean.is_finite() {
            continue;
        }
        let score = f_mean + last.consensus_error;
        if score < best_score {
            best_score = score;
            best = cand;
        }
    }
    best
}

/// Run one algorithm with the paper's grid-search-like step protocol:
/// if a run diverges (non-finite or worse than the starting point), retry
/// with a 10× smaller step, up to 5 times.
pub fn run_single_stable(
    kind: &AlgoKind,
    problem: &ConsensusProblem,
    g: &Graph,
    backend: &dyn LocalBackend,
    opts: &RunOptions,
    rng: &mut Pcg64,
) -> Trace {
    let mut k = kind.clone();
    let mut last = None;
    for attempt in 0..5 {
        let trace = run_single(&k, problem, g, backend, opts, rng);
        let o0 = trace.records[0].objective;
        let of = trace.final_objective();
        let healthy = of.is_finite()
            && trace.final_consensus_error().is_finite()
            && of <= o0.abs() * 2.0 + o0 + 1.0;
        if healthy {
            return trace;
        }
        crate::warn_!(
            "{} diverged (attempt {attempt}); retrying with step × 0.1",
            trace.algorithm
        );
        last = Some(trace);
        k = k.scale_step(0.1);
    }
    last.unwrap()
}

/// Run a full experiment: all configured algorithms on the same problem
/// instance and graph, plus the centralized optimum for gap reporting.
pub fn run_experiment(cfg: &ExperimentConfig) -> ExperimentResult {
    // Only an explicit config value overrides the process-wide knob —
    // auto (0) must not clobber a --threads / SDDN_THREADS pin.
    if cfg.parallelism.threads != 0 {
        crate::par::set_threads(cfg.parallelism.threads);
    }
    let mut rng = Pcg64::new(cfg.seed);
    let g = build_graph(cfg, &mut rng);
    let problem = build_problem(cfg, &mut rng);
    let backend = make_backend(cfg, &problem);
    let (_, f_star) = problem.centralized_optimum(120, 1e-11);

    let l = crate::graph::laplacian_csr(&g);
    // Lanczos pins both extremal eigenvalues in ~40 Krylov steps (see
    // linalg::lanczos tests vs plain power iteration).
    let (mu2, mun) =
        crate::linalg::lanczos::laplacian_spectrum(&l, 40.min(g.n), &mut rng);

    let opts = RunOptions { max_iters: cfg.max_iters, ..Default::default() };
    let mut traces = Vec::new();
    for kind in &cfg.algorithms {
        crate::info!("tuning + running {} on {}", kind.id(), cfg.name);
        let tuned = tune_step(kind, &problem, &g, backend.as_ref(), &mut rng);
        traces.push(run_single_stable(&tuned, &problem, &g, backend.as_ref(), &opts, &mut rng));
    }
    ExperimentResult { config: cfg.clone(), f_star, traces, mu2, mun, backend_used: backend.name() }
}

/// Fig. 2(c): message count needed to reach each accuracy target, per
/// algorithm. Runs each algorithm long enough (budgeted) and reads the
/// trace.
pub fn comm_overhead_experiment(
    cfg: &ExperimentConfig,
    targets: &[f64],
) -> Vec<(String, Vec<(f64, Option<u64>)>)> {
    if cfg.parallelism.threads != 0 {
        crate::par::set_threads(cfg.parallelism.threads);
    }
    let mut rng = Pcg64::new(cfg.seed);
    let g = build_graph(cfg, &mut rng);
    let problem = build_problem(cfg, &mut rng);
    let backend = make_backend(cfg, &problem);
    let (_, f_star) = problem.centralized_optimum(120, 1e-11);
    let opts = RunOptions { max_iters: cfg.max_iters, ..Default::default() };

    let mut out = Vec::new();
    for kind in &cfg.algorithms {
        let tuned = tune_step(kind, &problem, &g, backend.as_ref(), &mut rng);
        let (name, rows) = match *kind {
            // For SDD-Newton the solver ε tracks the accuracy demand, as in
            // the paper's protocol — one run per target.
            AlgoKind::SddNewton { alpha, .. } => {
                let mut rows = Vec::new();
                let mut name = String::new();
                for &t in targets {
                    let kind_t =
                        AlgoKind::SddNewton { eps: (t * 0.5).clamp(1e-9, 0.1), alpha };
                    let trace =
                        run_single(&kind_t, &problem, &g, backend.as_ref(), &opts, &mut rng);
                    rows.push((t, trace.messages_to_gap(f_star, t)));
                    name = trace.algorithm;
                }
                (name, rows)
            }
            // Everyone else: one long tuned run; read every target's message
            // count from the single trace.
            _ => {
                let trace =
                    run_single_stable(&tuned, &problem, &g, backend.as_ref(), &opts, &mut rng);
                let rows =
                    targets.iter().map(|&t| (t, trace.messages_to_gap(f_star, t))).collect();
                (trace.algorithm, rows)
            }
        };
        out.push((name, rows));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_experiment_runs_all_algorithms() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.max_iters = 8;
        let res = run_experiment(&cfg);
        assert_eq!(res.traces.len(), cfg.algorithms.len());
        for t in &res.traces {
            assert_eq!(t.records.len(), 9);
            assert!(t.final_objective().is_finite());
        }
        // SDD-Newton (trace 0) should be closest to f*.
        let gaps: Vec<f64> = res
            .traces
            .iter()
            .map(|t| (t.final_objective() - res.f_star).abs())
            .collect();
        let min = gaps.iter().cloned().fold(f64::INFINITY, f64::min);
        assert_eq!(gaps[0], min, "SDD-Newton not best: {gaps:?}");
    }

    #[test]
    fn comm_overhead_monotone_for_sdd() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.max_iters = 30;
        cfg.algorithms = vec![AlgoKind::SddNewton { eps: 0.1, alpha: 1.0 }];
        let rows = comm_overhead_experiment(&cfg, &[1e-1, 1e-3, 1e-5]);
        let sdd = &rows[0].1;
        let msgs: Vec<u64> = sdd.iter().filter_map(|(_, m)| *m).collect();
        assert_eq!(msgs.len(), 3, "SDD-Newton failed to reach targets: {sdd:?}");
        assert!(msgs[0] <= msgs[1] && msgs[1] <= msgs[2], "{msgs:?}");
    }
}
