//! The "crude" (Algorithm 1) and "exact" (Algorithm 2) SDD solvers.

use super::chain::Chain;
use crate::net::CommStats;

/// Solver options.
#[derive(Debug, Clone)]
pub struct SolverOptions {
    /// Relative residual target ‖b − My‖₂ / ‖b‖₂ for the exact solver.
    /// (Def. 1's ε in the M-norm is bounded by √κ(M)·this; the residual is
    /// the distributedly computable surrogate.)
    pub eps: f64,
    /// Cap on Richardson sweeps (q = O(log 1/ε) expected).
    pub max_richardson: usize,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions { eps: 0.1, max_richardson: 200 }
    }
}

/// Result of a solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Stacked solution (`n × w` row-major).
    pub x: Vec<f64>,
    /// Richardson sweeps used.
    pub sweeps: usize,
    /// Final relative residual (max over the `w` columns).
    pub rel_residual: f64,
    /// Whether `eps` was reached within the sweep budget.
    pub converged: bool,
}

/// SDDM solver bundling a chain with solve options.
#[derive(Debug, Clone)]
pub struct SddmSolver {
    pub chain: Chain,
    pub opts: SolverOptions,
}

impl SddmSolver {
    /// Wrap a chain.
    pub fn new(chain: Chain, opts: SolverOptions) -> Self {
        SddmSolver { chain, opts }
    }

    /// "Crude" solve (Algorithm 1): one forward/backward sweep of the
    /// chain, returning `x ≈ Z₀ b` with a constant-factor error.
    /// `b` is stacked `n × w`. Communication is recorded in `stats`.
    pub fn crude_solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> Vec<f64> {
        let c = &self.chain;
        let n = c.n;
        assert_eq!(b.len(), n * w);
        let d = c.depth;
        let len = n * w;

        let mut scratch_a = vec![0.0; len];
        let mut scratch_b = vec![0.0; len];

        // Forward: b_{i+1} = (I + A_i D̃^{-1}) b_i,  A_i D̃^{-1} v = D̃ X^{2^i} D̃^{-1} v.
        // The per-level row sweeps are independent across the n rows (and
        // the w RHS columns), so they run on the par substrate; each row
        // is owned by exactly one thread → bit-for-bit serial-identical.
        let mut bs: Vec<Vec<f64>> = Vec::with_capacity(d + 1);
        let mut cur = b.to_vec();
        c.project(&mut cur, w, stats);
        bs.push(cur.clone());
        let mut tmp = vec![0.0; len];
        for i in 0..d {
            // tmp = D̃^{-1} cur
            diag_mul_into(&c.dinv, &cur, w, &mut tmp);
            c.apply_x_pow(i, &tmp, w, &mut scratch_a, &mut scratch_b, stats);
            // cur = cur + D̃ * scratch_a
            diag_axpy(&c.dvec, &scratch_a, w, &mut cur);
            c.project(&mut cur, w, stats);
            bs.push(cur.clone());
        }

        // Last level: x_d = D̃^{-1} b_d.
        let mut x = vec![0.0; len];
        diag_mul_into(&c.dinv, &bs[d], w, &mut x);
        c.project(&mut x, w, stats);

        // Backward: x_i = ½ [D̃^{-1} b_i + x_{i+1} + X^{2^i} x_{i+1}].
        for i in (0..d).rev() {
            c.apply_x_pow(i, &x, w, &mut scratch_a, &mut scratch_b, stats);
            backward_combine(&c.dinv, &bs[i], &scratch_a, w, &mut x);
            c.project(&mut x, w, stats);
        }
        x
    }

    /// "Exact" solve (Algorithm 2): Richardson iteration preconditioned by
    /// the crude solver, run until the relative residual falls below
    /// `opts.eps` (or the sweep budget is exhausted).
    pub fn solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> SolveOutcome {
        let c = &self.chain;
        let n = c.n;
        assert_eq!(b.len(), n * w);
        let len = n * w;

        let mut b0 = b.to_vec();
        c.project(&mut b0, w, stats);
        let bnorms = col_norms(&b0, n, w);

        // y₀ = crude(b).
        let mut y = self.crude_solve(&b0, w, stats);
        let mut residual = vec![0.0; len];
        let mut my = vec![0.0; len];
        let mut sweeps = 0;
        let mut rel = f64::INFINITY;

        for k in 0..=self.opts.max_richardson {
            // r = b − M y.
            c.apply_m(&y, w, &mut my, stats);
            sub_into(&b0, &my, w, &mut residual);
            c.project(&mut residual, w, stats);
            rel = max_rel(&residual, &bnorms, n, w);
            // Residual norm check is an accounted all-reduce.
            stats.record_allreduce(n, 1);
            if rel <= self.opts.eps {
                sweeps = k;
                break;
            }
            if k == self.opts.max_richardson {
                sweeps = k;
                break;
            }
            // y ← y + Z₀ r.
            let dz = self.crude_solve(&residual, w, stats);
            for i in 0..len {
                y[i] += dz[i];
            }
            sweeps = k + 1;
        }
        SolveOutcome { x: y, sweeps, rel_residual: rel, converged: rel <= self.opts.eps }
    }
}

/// dst[r,·] = diag[r] · src[r,·] over a stacked `n × w` buffer, row blocks
/// split across the par substrate.
fn diag_mul_into(diag: &[f64], src: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = diag[r];
            let s = &src[r * w..(r + 1) * w];
            for (o, v) in row.iter_mut().zip(s) {
                *o = d * v;
            }
        }
    });
}

/// dst[r,·] += diag[r] · src[r,·].
fn diag_axpy(diag: &[f64], src: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = diag[r];
            let s = &src[r * w..(r + 1) * w];
            for (o, v) in row.iter_mut().zip(s) {
                *o += d * v;
            }
        }
    });
}

/// Backward-sweep combine: x[r,·] = ½ (dinv[r]·b[r,·] + x[r,·] + xpow[r,·]).
fn backward_combine(dinv: &[f64], b: &[f64], xpow: &[f64], w: usize, x: &mut [f64]) {
    let threads = crate::par::plan_for(x.len());
    crate::par::par_chunks_mut(x, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = dinv[r];
            let off = r * w;
            for (j, o) in row.iter_mut().enumerate() {
                *o = 0.5 * (d * b[off + j] + *o + xpow[off + j]);
            }
        }
    });
}

/// dst = a − b, row blocks split across the par substrate.
fn sub_into(a: &[f64], b: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        let off = r0 * w;
        for (k, o) in block.iter_mut().enumerate() {
            *o = a[off + k] - b[off + k];
        }
    });
}

fn col_norms(v: &[f64], n: usize, w: usize) -> Vec<f64> {
    let mut out = vec![0.0; w];
    for i in 0..n {
        for j in 0..w {
            out[j] += v[i * w + j] * v[i * w + j];
        }
    }
    for o in out.iter_mut() {
        *o = o.sqrt().max(1e-300);
    }
    out
}

fn max_rel(res: &[f64], bnorms: &[f64], n: usize, w: usize) -> f64 {
    let rn = col_norms(res, n, w);
    rn.iter().zip(bnorms).map(|(r, b)| r / b).fold(0.0f64, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian::laplacian_csr};
    use crate::linalg::cg::{cg_solve, CgOptions};
    use crate::sddm::chain::{ChainOptions, Splitting};
    use crate::util::Pcg64;

    fn setup(n: usize, m: usize, seed: u64) -> (crate::linalg::Csr, SddmSolver, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-8, max_richardson: 500 });
        (l, solver, rng)
    }

    #[test]
    fn exact_solve_matches_cg() {
        let (l, solver, mut rng) = setup(30, 70, 21);
        // RHS in range(L).
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let mut stats = CommStats::default();
        let out = solver.solve(&b, 1, &mut stats);
        assert!(out.converged, "rel={}", out.rel_residual);
        let cg = cg_solve(&l, &b, &CgOptions { project_kernel: true, ..Default::default() });
        for (a, c) in out.x.iter().zip(&cg.x) {
            assert!((a - c).abs() < 1e-5, "{a} vs {c}");
        }
        assert!(stats.messages > 0);
    }

    #[test]
    fn crude_solve_is_constant_factor() {
        let (l, solver, mut rng) = setup(25, 60, 22);
        let z = rng.normal_vec(25);
        let b = l.matvec(&z);
        let mut stats = CommStats::default();
        let x = solver.crude_solve(&b, 1, &mut stats);
        // Residual should be noticeably reduced vs the zero guess.
        let mut lx = vec![0.0; 25];
        l.matvec_into(&x, &mut lx);
        let mut r: Vec<f64> = b.iter().zip(&lx).map(|(a, c)| a - c).collect();
        crate::linalg::vector::center(&mut r);
        let rel = crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b);
        assert!(rel < 0.9, "crude rel residual {rel}");
    }

    #[test]
    fn multi_rhs_matches_single() {
        let (l, solver, mut rng) = setup(20, 45, 23);
        let w = 3;
        let mut b = vec![0.0; 20 * w];
        for j in 0..w {
            let z = rng.normal_vec(20);
            let col = l.matvec(&z);
            for i in 0..20 {
                b[i * w + j] = col[i];
            }
        }
        let mut s_multi = CommStats::default();
        let multi = solver.solve(&b, w, &mut s_multi);
        assert!(multi.converged);
        for j in 0..w {
            let col: Vec<f64> = (0..20).map(|i| b[i * w + j]).collect();
            let mut s1 = CommStats::default();
            let single = solver.solve(&col, 1, &mut s1);
            for i in 0..20 {
                assert!(
                    (multi.x[i * w + j] - single.x[i]).abs() < 1e-5,
                    "col {j} row {i}: {} vs {}",
                    multi.x[i * w + j],
                    single.x[i]
                );
            }
        }
        // Batched solve should use fewer messages than w separate solves
        // would (same rounds, wider payloads).
        let mut s_sep = CommStats::default();
        for j in 0..w {
            let col: Vec<f64> = (0..20).map(|i| b[i * w + j]).collect();
            let _ = solver.solve(&col, 1, &mut s_sep);
        }
        assert!(s_multi.messages < s_sep.messages);
    }

    #[test]
    fn eps_controls_accuracy() {
        let (l, solver, mut rng) = setup(30, 80, 24);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        for eps in [0.3, 1e-2, 1e-6] {
            let s = SddmSolver::new(solver.chain.clone(), SolverOptions { eps, max_richardson: 500 });
            let mut stats = CommStats::default();
            let out = s.solve(&b, 1, &mut stats);
            assert!(out.converged);
            assert!(out.rel_residual <= eps);
        }
    }

    #[test]
    fn tighter_eps_costs_more_messages() {
        let (l, solver, mut rng) = setup(30, 80, 25);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let mut msgs = Vec::new();
        for eps in [1e-1, 1e-6, 1e-10] {
            let s = SddmSolver::new(solver.chain.clone(), SolverOptions { eps, max_richardson: 500 });
            let mut stats = CommStats::default();
            let _ = s.solve(&b, 1, &mut stats);
            msgs.push(stats.messages);
        }
        assert!(msgs[0] <= msgs[1] && msgs[1] <= msgs[2], "{msgs:?}");
        assert!(msgs[0] < msgs[2], "{msgs:?}");
    }

    #[test]
    fn faithful_splitting_on_nonbipartite() {
        // Random graph with triangles — faithful splitting also works.
        let mut rng = Pcg64::new(26);
        let g = generate::random_connected(20, 60, &mut rng);
        let l = laplacian_csr(&g);
        let opts = ChainOptions { splitting: Splitting::Faithful, ..Default::default() };
        let chain = Chain::build(&l, &opts, &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 500 });
        let z = rng.normal_vec(20);
        let b = l.matvec(&z);
        let mut stats = CommStats::default();
        let out = solver.solve(&b, 1, &mut stats);
        assert!(out.converged, "rel={}", out.rel_residual);
    }

    #[test]
    fn works_on_path_graph_with_lazy() {
        // Path graphs are bipartite — the lazy splitting must still converge.
        let mut rng = Pcg64::new(27);
        let g = generate::path(16);
        let l = laplacian_csr(&g);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 2000 });
        let z = rng.normal_vec(16);
        let b = l.matvec(&z);
        let mut stats = CommStats::default();
        let out = solver.solve(&b, 1, &mut stats);
        assert!(out.converged, "rel={}", out.rel_residual);
    }
}
