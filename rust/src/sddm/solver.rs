//! The "crude" (Algorithm 1) and "exact" (Algorithm 2) SDD solvers.
//!
//! Both run against the [`Exchange`] trait: on the bulk-synchronous
//! [`crate::net::CommGraph`] they behave as the single-process simulation,
//! on [`crate::net::partitioned::ShardExchange`] the same code executes
//! sharded across worker threads, bit-for-bit identically.

use super::chain::Chain;
use crate::net::Exchange;
use crate::util::BufferPool;

/// Solver options.
#[derive(Debug, Clone)]
pub struct SolverOptions {
    /// Relative residual target ‖b − My‖₂ / ‖b‖₂ for the exact solver.
    /// (Def. 1's ε in the M-norm is bounded by √κ(M)·this; the residual is
    /// the distributedly computable surrogate.)
    pub eps: f64,
    /// Cap on Richardson sweeps (q = O(log 1/ε) expected).
    pub max_richardson: usize,
}

impl Default for SolverOptions {
    fn default() -> Self {
        SolverOptions { eps: 0.1, max_richardson: 200 }
    }
}

/// Result of a solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Stacked solution (shard-local `local_n × w` row-major).
    pub x: Vec<f64>,
    /// Richardson sweeps used.
    pub sweeps: usize,
    /// Final relative residual (max over the `w` columns).
    pub rel_residual: f64,
    /// Whether `eps` was reached within the sweep budget.
    pub converged: bool,
}

/// SDDM solver bundling a chain with solve options.
#[derive(Debug, Clone)]
pub struct SddmSolver {
    /// The inverse approximated chain the sweeps run over.
    pub chain: Chain,
    /// Accuracy / budget options.
    pub opts: SolverOptions,
}

impl SddmSolver {
    /// Wrap a chain.
    pub fn new(chain: Chain, opts: SolverOptions) -> Self {
        SddmSolver { chain, opts }
    }

    /// "Crude" solve (Algorithm 1): one forward/backward sweep of the
    /// chain, returning `x ≈ Z₀ b` with a constant-factor error.
    /// `b` is stacked shard-local `local_n × w`. Communication is recorded
    /// in the exchange's ledger.
    pub fn crude_solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> Vec<f64> {
        let mut pool = BufferPool::new();
        self.crude_solve_ws(b, w, exch, &mut pool)
    }

    /// [`Self::crude_solve`] with an explicit workspace pool: every
    /// scratch buffer (and the returned solution) is drawn from `pool`,
    /// so a warmed pool makes repeated solves allocation-free. Callers
    /// should `pool.put` the returned vector back once consumed.
    /// Bit-for-bit identical to the allocating form.
    // sddn-lint: hot-path
    pub fn crude_solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> Vec<f64> {
        let c = &self.chain;
        let ln = exch.local_n();
        assert_eq!(b.len(), ln * w);
        let d = c.depth;
        let len = ln * w;

        let mut scratch_a = pool.take(len);
        let mut scratch_b = pool.take(len);

        // Forward: b_{i+1} = (I + A_i D̃^{-1}) b_i,  A_i D̃^{-1} v = D̃ X^{2^i} D̃^{-1} v.
        // The per-level row sweeps are independent across the owned rows
        // (and the w RHS columns), so they run on the par substrate; each
        // row is owned by exactly one thread → bit-for-bit serial-identical.
        let mut bs: Vec<Vec<f64>> = Vec::with_capacity(d + 1);
        let mut cur = pool.take_copy(b);
        c.project(&mut cur, w, exch);
        bs.push(pool.take_copy(&cur));
        let mut tmp = pool.take(len);
        for i in 0..d {
            // tmp = D̃^{-1} cur
            diag_mul_into(&c.dinv, exch.owned(), &cur, w, &mut tmp);
            c.apply_x_pow(i, &tmp, w, &mut scratch_a, &mut scratch_b, exch);
            // cur = cur + D̃ * scratch_a
            diag_axpy(&c.dvec, exch.owned(), &scratch_a, w, &mut cur);
            c.project(&mut cur, w, exch);
            bs.push(pool.take_copy(&cur));
        }

        // Last level: x_d = D̃^{-1} b_d.
        let mut x = pool.take(len);
        diag_mul_into(&c.dinv, exch.owned(), &bs[d], w, &mut x);
        c.project(&mut x, w, exch);

        // Backward: x_i = ½ [D̃^{-1} b_i + x_{i+1} + X^{2^i} x_{i+1}].
        for i in (0..d).rev() {
            c.apply_x_pow(i, &x, w, &mut scratch_a, &mut scratch_b, exch);
            backward_combine(&c.dinv, exch.owned(), &bs[i], &scratch_a, w, &mut x);
            c.project(&mut x, w, exch);
        }
        pool.put(scratch_a);
        pool.put(scratch_b);
        pool.put(cur);
        pool.put(tmp);
        for buf in bs {
            pool.put(buf);
        }
        x
    }

    /// "Exact" solve (Algorithm 2): Richardson iteration preconditioned by
    /// the crude solver, run until the relative residual falls below
    /// `opts.eps` (or the sweep budget is exhausted).
    pub fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome {
        let mut pool = BufferPool::new();
        self.solve_ws(b, w, exch, &mut pool)
    }

    /// [`Self::solve`] with an explicit workspace pool (see
    /// [`Self::crude_solve_ws`]); the outcome's `x` is pool-drawn — put it
    /// back after use to keep the steady state allocation-free.
    // sddn-lint: hot-path
    pub fn solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> SolveOutcome {
        let c = &self.chain;
        let ln = exch.local_n();
        assert_eq!(b.len(), ln * w);
        let len = ln * w;

        let mut b0 = pool.take_copy(b);
        c.project(&mut b0, w, exch);
        // Per-column RHS norms: one accounted all-reduce of width w.
        let bnorms = col_norms(&b0, w, exch, pool);

        // y₀ = crude(b).
        let mut y = self.crude_solve_ws(&b0, w, exch, pool);
        let mut residual = pool.take(len);
        let mut my = pool.take(len);
        let mut sweeps = 0;
        let mut rel = f64::INFINITY;

        for k in 0..=self.opts.max_richardson {
            // r = b − M y.
            c.apply_m(&y, w, &mut my, exch);
            sub_into(&b0, &my, w, &mut residual);
            c.project(&mut residual, w, exch);
            // Residual norm check: an accounted all-reduce of the w
            // per-column squared norms (width w — a multi-RHS solve moves
            // w floats per message here, not 1).
            let rn = col_norms(&residual, w, exch, pool);
            rel = rn
                .iter()
                .zip(&bnorms)
                .map(|(r, b)| r / b)
                .fold(0.0f64, f64::max);
            if rel <= self.opts.eps {
                sweeps = k;
                break;
            }
            if k == self.opts.max_richardson {
                sweeps = k;
                break;
            }
            // y ← y + Z₀ r.
            let dz = self.crude_solve_ws(&residual, w, exch, pool);
            for i in 0..len {
                y[i] += dz[i];
            }
            pool.put(dz);
            sweeps = k + 1;
        }
        pool.put(b0);
        pool.put(residual);
        pool.put(my);
        SolveOutcome { x: y, sweeps, rel_residual: rel, converged: rel <= self.opts.eps }
    }
}

/// dst[r,·] = diag[owned[r]] · src[r,·] over a shard-local `local_n × w`
/// buffer, row blocks split across the par substrate.
fn diag_mul_into(diag: &[f64], owned: &[usize], src: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = diag[owned[r]];
            let s = &src[r * w..(r + 1) * w];
            for (o, v) in row.iter_mut().zip(s) {
                *o = d * v;
            }
        }
    });
}

/// dst[r,·] += diag[owned[r]] · src[r,·].
fn diag_axpy(diag: &[f64], owned: &[usize], src: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = diag[owned[r]];
            let s = &src[r * w..(r + 1) * w];
            for (o, v) in row.iter_mut().zip(s) {
                *o += d * v;
            }
        }
    });
}

/// Backward-sweep combine: x[r,·] = ½ (dinv[owned[r]]·b[r,·] + x[r,·] + xpow[r,·]).
fn backward_combine(
    dinv: &[f64],
    owned: &[usize],
    b: &[f64],
    xpow: &[f64],
    w: usize,
    x: &mut [f64],
) {
    let threads = crate::par::plan_for(x.len());
    crate::par::par_chunks_mut(x, w, threads, |r0, block| {
        for (k, row) in block.chunks_mut(w).enumerate() {
            let r = r0 + k;
            let d = dinv[owned[r]];
            let off = r * w;
            for (j, o) in row.iter_mut().enumerate() {
                *o = 0.5 * (d * b[off + j] + *o + xpow[off + j]);
            }
        }
    });
}

/// dst = a − b, row blocks split across the par substrate.
fn sub_into(a: &[f64], b: &[f64], w: usize, dst: &mut [f64]) {
    let threads = crate::par::plan_for(dst.len());
    crate::par::par_chunks_mut(dst, w, threads, |r0, block| {
        let off = r0 * w;
        for (k, o) in block.iter_mut().enumerate() {
            *o = a[off + k] - b[off + k];
        }
    });
}

/// Global per-column 2-norms of a shard-local stack: one all-reduce of the
/// per-node squared contributions (width `w`), summed in global node order
/// on every transport. The squared-contribution scratch is pool-drawn.
fn col_norms(v: &[f64], w: usize, exch: &mut dyn Exchange, pool: &mut BufferPool) -> Vec<f64> {
    let mut locals = pool.take(v.len());
    for (loc, val) in locals.iter_mut().zip(v) {
        *loc = val * val;
    }
    let mut out = exch.allreduce_sum(&locals, w);
    pool.put(locals);
    for o in out.iter_mut() {
        *o = o.sqrt().max(1e-300);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian::laplacian_csr, Graph};
    use crate::linalg::cg::{cg_solve, CgOptions};
    use crate::net::CommGraph;
    use crate::sddm::chain::{ChainOptions, Splitting};
    use crate::util::Pcg64;

    fn setup(n: usize, m: usize, seed: u64) -> (Graph, crate::linalg::Csr, SddmSolver, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-8, max_richardson: 500 });
        (g, l, solver, rng)
    }

    #[test]
    fn exact_solve_matches_cg() {
        let (g, l, solver, mut rng) = setup(30, 70, 21);
        // RHS in range(L).
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged, "rel={}", out.rel_residual);
        let cg = cg_solve(&l, &b, &CgOptions { project_kernel: true, ..Default::default() });
        for (a, c) in out.x.iter().zip(&cg.x) {
            assert!((a - c).abs() < 1e-5, "{a} vs {c}");
        }
        assert!(comm.stats().messages > 0);
    }

    #[test]
    fn crude_solve_is_constant_factor() {
        let (g, l, solver, mut rng) = setup(25, 60, 22);
        let z = rng.normal_vec(25);
        let b = l.matvec(&z);
        let mut comm = CommGraph::new(&g);
        let x = solver.crude_solve(&b, 1, &mut comm);
        // Residual should be noticeably reduced vs the zero guess.
        let mut lx = vec![0.0; 25];
        l.matvec_into(&x, &mut lx);
        let mut r: Vec<f64> = b.iter().zip(&lx).map(|(a, c)| a - c).collect();
        crate::linalg::vector::center(&mut r);
        let rel = crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b);
        assert!(rel < 0.9, "crude rel residual {rel}");
    }

    #[test]
    fn multi_rhs_matches_single() {
        let (g, l, solver, mut rng) = setup(20, 45, 23);
        let w = 3;
        let mut b = vec![0.0; 20 * w];
        for j in 0..w {
            let z = rng.normal_vec(20);
            let col = l.matvec(&z);
            for i in 0..20 {
                b[i * w + j] = col[i];
            }
        }
        let mut c_multi = CommGraph::new(&g);
        let multi = solver.solve(&b, w, &mut c_multi);
        assert!(multi.converged);
        for j in 0..w {
            let col: Vec<f64> = (0..20).map(|i| b[i * w + j]).collect();
            let mut c1 = CommGraph::new(&g);
            let single = solver.solve(&col, 1, &mut c1);
            for i in 0..20 {
                assert!(
                    (multi.x[i * w + j] - single.x[i]).abs() < 1e-5,
                    "col {j} row {i}: {} vs {}",
                    multi.x[i * w + j],
                    single.x[i]
                );
            }
        }
        // Batched solve should use fewer messages than w separate solves
        // would (same rounds, wider payloads).
        let mut c_sep = CommGraph::new(&g);
        for j in 0..w {
            let col: Vec<f64> = (0..20).map(|i| b[i * w + j]).collect();
            let _ = solver.solve(&col, 1, &mut c_sep);
        }
        assert!(c_multi.stats().messages < c_sep.stats().messages);
    }

    /// Regression for the residual-check accounting: a width-w solve must
    /// charge its norm all-reduces at width w. With identical replicated
    /// columns the solve performs the same rounds as the single-RHS solve,
    /// so the message count matches exactly and every float counter scales
    /// by exactly w. (Before the fix the residual checks were recorded at
    /// width 1, so floats_multi < w · floats_single.)
    #[test]
    fn multi_rhs_allreduce_floats_scale_with_width() {
        let (g, l, solver, mut rng) = setup(24, 55, 29);
        let z = rng.normal_vec(24);
        let col = l.matvec(&z);
        let w = 4;
        let mut b = vec![0.0; 24 * w];
        for i in 0..24 {
            for j in 0..w {
                b[i * w + j] = col[i];
            }
        }
        let mut c1 = CommGraph::new(&g);
        let single = solver.solve(&col, 1, &mut c1);
        let mut cw = CommGraph::new(&g);
        let multi = solver.solve(&b, w, &mut cw);
        assert_eq!(single.sweeps, multi.sweeps, "identical columns must sweep identically");
        let (s1, sw) = (c1.stats(), cw.stats());
        assert_eq!(s1.messages, sw.messages, "same rounds, wider payloads");
        assert_eq!(s1.rounds, sw.rounds);
        assert_eq!(s1.allreduces, sw.allreduces);
        assert_eq!(
            sw.floats,
            w as u64 * s1.floats,
            "width-{w} solve must move exactly {w}× the floats (residual checks included)"
        );
    }

    #[test]
    fn eps_controls_accuracy() {
        let (g, l, solver, mut rng) = setup(30, 80, 24);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        for eps in [0.3, 1e-2, 1e-6] {
            let s = SddmSolver::new(solver.chain.clone(), SolverOptions { eps, max_richardson: 500 });
            let mut comm = CommGraph::new(&g);
            let out = s.solve(&b, 1, &mut comm);
            assert!(out.converged);
            assert!(out.rel_residual <= eps);
        }
    }

    #[test]
    fn tighter_eps_costs_more_messages() {
        let (g, l, solver, mut rng) = setup(30, 80, 25);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let mut msgs = Vec::new();
        for eps in [1e-1, 1e-6, 1e-10] {
            let s = SddmSolver::new(solver.chain.clone(), SolverOptions { eps, max_richardson: 500 });
            let mut comm = CommGraph::new(&g);
            let _ = s.solve(&b, 1, &mut comm);
            msgs.push(comm.stats().messages);
        }
        assert!(msgs[0] <= msgs[1] && msgs[1] <= msgs[2], "{msgs:?}");
        assert!(msgs[0] < msgs[2], "{msgs:?}");
    }

    #[test]
    fn faithful_splitting_on_nonbipartite() {
        // Random graph with triangles — faithful splitting also works.
        let mut rng = Pcg64::new(26);
        let g = generate::random_connected(20, 60, &mut rng);
        let l = laplacian_csr(&g);
        let opts = ChainOptions { splitting: Splitting::Faithful, ..Default::default() };
        let chain = Chain::build(&l, &opts, &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 500 });
        let z = rng.normal_vec(20);
        let b = l.matvec(&z);
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged, "rel={}", out.rel_residual);
    }

    #[test]
    fn works_on_path_graph_with_lazy() {
        // Path graphs are bipartite — the lazy splitting must still converge.
        let mut rng = Pcg64::new(27);
        let g = generate::path(16);
        let l = laplacian_csr(&g);
        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-6, max_richardson: 2000 });
        let z = rng.normal_vec(16);
        let b = l.matvec(&z);
        let mut comm = CommGraph::new(&g);
        let out = solver.solve(&b, 1, &mut comm);
        assert!(out.converged, "rel={}", out.rel_residual);
    }
}
