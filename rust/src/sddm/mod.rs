//! Distributed SDDM solver (Section 2 of the paper).
//!
//! Implements the Peng–Spielman parallel solver [11] in the distributed
//! formulation of Tutunov–Bou Ammar–Jadbabaie [12]:
//!
//! 1. split `M = D₀ − A₀` (standard) or the *lazy* variant
//!    `M = 2D₀ − (D₀ + A₀)` which keeps the walk spectrum in `[0, 1]` on
//!    any graph (the standard splitting fails to decay on bipartite
//!    topologies where `D₀⁻¹A₀` has eigenvalue −1);
//! 2. build the inverse approximated chain `C = {D_i, A_i}` with
//!    `D_i = D̃`, `A_i = D̃ X^{2^i}`, `X = D̃⁻¹Ã` (Eq. 2's recursion);
//! 3. "crude" solve by the forward/backward sweeps of Algorithm 1;
//! 4. refine to any ε by Richardson preconditioned iteration
//!    (Algorithm 2): `y ← y + Z₀(b − M y)`.
//!
//! Every operator application is expressed through neighbor-exchange
//! rounds so communication is accounted exactly (`net::CommStats`): an
//! `X`-application costs one round of `2m` messages; `X^{2^i}` costs `2^i`
//! rounds (the distributed solver repeats local averaging — no node ever
//! materializes a multi-hop matrix).
//!
//! Consensus Laplacians are singular with kernel `span{1}`; the solver
//! detects this and works on the mean-zero subspace (each projection is an
//! accounted all-reduce).

#![warn(missing_docs)]

pub mod chain;
pub mod solver;
pub mod squared;

pub use chain::{Chain, ChainOptions, Splitting};
pub use solver::{SddmSolver, SolveOutcome, SolverOptions};
pub use squared::{SquaredChain, SquaredSddmSolver};
