//! Inverse approximated chain `C = {D_i, A_i}` (Section 2, Eq. 2).

use crate::linalg::vector::{center, norm2, scale};
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::util::Pcg64;

/// Which standard splitting `M = D − A` to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Splitting {
    /// `D̃ = D₀`, `Ã = A₀` — the paper's Eq. 2 as written. The walk matrix
    /// `X = D₀⁻¹A₀` has spectrum in `[−1, 1]`; on bipartite graphs the −1
    /// eigenvalue makes `X^{2^i}` non-decaying.
    Faithful,
    /// `D̃ = 2D₀`, `Ã = D₀ + A₀` — "lazy walk" variant with spectrum in
    /// `[0, 1]`; decays on every connected graph. Default.
    Lazy,
}

/// Chain construction options.
#[derive(Debug, Clone)]
pub struct ChainOptions {
    /// Which splitting `M = D̃ − Ã` the walk matrix is built from.
    pub splitting: Splitting,
    /// Chain depth `d`; `None` = auto from the walk's subdominant
    /// eigenvalue so that `λ₂^{2^d} ≤ crude_decay`.
    pub depth: Option<usize>,
    /// Target decay of the last chain level (drives auto-depth).
    pub crude_decay: f64,
    /// Hard cap on auto depth.
    pub max_depth: usize,
}

impl Default for ChainOptions {
    fn default() -> Self {
        ChainOptions {
            splitting: Splitting::Lazy,
            depth: None,
            crude_decay: 0.05,
            max_depth: 24,
        }
    }
}

/// The chain: all levels share `D̃`; level `i`'s `A_i = D̃ X^{2^i}` is
/// applied implicitly by `2^i` repeated X-matvecs (the distributed
/// execution model of [12] — each X-application is one exchange round).
#[derive(Debug, Clone)]
pub struct Chain {
    /// Problem size (nodes).
    pub n: usize,
    /// Depth `d` (levels `0..=d`).
    pub depth: usize,
    /// D̃ diagonal.
    pub dvec: Vec<f64>,
    /// D̃⁻¹ diagonal.
    pub dinv: Vec<f64>,
    /// Walk matrix `X = D̃⁻¹Ã` in CSR.
    pub x: Csr,
    /// Estimated subdominant eigenvalue of X (decay rate on the subspace).
    pub lambda2: f64,
    /// Whether M is singular (Laplacian) — work on mean-zero subspace.
    pub singular: bool,
    /// Undirected edge count of the support (for message accounting).
    pub m_edges: usize,
}

/// Errors in chain construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChainError {
    /// Matrix is not square (rows, cols).
    NotSquare(usize, usize),
    /// Positive off-diagonal or diagonal dominance violated at the row.
    NotSdd(usize),
    /// Zero diagonal at the row — isolated node or invalid SDD matrix.
    ZeroDiagonal(usize),
}

impl std::fmt::Display for ChainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChainError::NotSquare(r, c) => write!(f, "matrix is not square: {r}x{c}"),
            ChainError::NotSdd(i) => write!(
                f,
                "matrix is not SDD (positive off-diagonal or dominance violated at row {i})"
            ),
            ChainError::ZeroDiagonal(i) => {
                write!(f, "zero diagonal at row {i} — isolated node or invalid SDD matrix")
            }
        }
    }
}

impl std::error::Error for ChainError {}

impl Chain {
    /// Build the chain from an SDD matrix `M` (typically a graph
    /// Laplacian). Validates SDD structure row by row.
    pub fn build(m: &Csr, opts: &ChainOptions, rng: &mut Pcg64) -> Result<Chain, ChainError> {
        if m.rows != m.cols {
            return Err(ChainError::NotSquare(m.rows, m.cols));
        }
        let n = m.rows;
        // Extract D0 (diagonal) and A0 (negated off-diagonal), validating.
        let mut d0 = vec![0.0; n];
        let mut off_trips: Vec<(usize, usize, f64)> = Vec::new();
        let mut row_off_sum = vec![0.0; n];
        let mut m_edges = 0usize;
        for i in 0..n {
            for k in m.indptr[i]..m.indptr[i + 1] {
                let j = m.indices[k];
                let v = m.values[k];
                if j == i {
                    d0[i] += v;
                } else {
                    if v > 1e-12 {
                        return Err(ChainError::NotSdd(i));
                    }
                    if v != 0.0 {
                        off_trips.push((i, j, -v)); // A0 entries are ≥ 0
                        row_off_sum[i] += -v;
                        if j > i {
                            m_edges += 1;
                        }
                    }
                }
            }
        }
        let mut singular = true;
        for i in 0..n {
            if d0[i] <= 0.0 {
                return Err(ChainError::ZeroDiagonal(i));
            }
            if d0[i] + 1e-9 * d0[i] < row_off_sum[i] {
                return Err(ChainError::NotSdd(i));
            }
            if (d0[i] - row_off_sum[i]).abs() > 1e-9 * d0[i].max(1.0) {
                singular = false; // strictly dominant row → nonsingular SDDM
            }
        }

        // Splitting.
        let (dvec, x) = match opts.splitting {
            Splitting::Faithful => {
                let dinv: Vec<f64> = d0.iter().map(|v| 1.0 / v).collect();
                let a0 = Csr::from_triplets(n, n, &off_trips);
                (d0.clone(), a0.scale_rows(&dinv))
            }
            Splitting::Lazy => {
                let dt: Vec<f64> = d0.iter().map(|v| 2.0 * v).collect();
                let dtinv: Vec<f64> = dt.iter().map(|v| 1.0 / v).collect();
                let mut trips = off_trips.clone();
                for i in 0..n {
                    trips.push((i, i, d0[i]));
                }
                let at = Csr::from_triplets(n, n, &trips);
                (dt, at.scale_rows(&dtinv))
            }
        };
        let dinv: Vec<f64> = dvec.iter().map(|v| 1.0 / v).collect();

        // Estimate the subdominant eigenvalue of X by power iteration on the
        // relevant subspace (mean-zero for singular M, whole space else).
        let lambda2 = estimate_decay(&x, singular, rng);

        let depth = opts.depth.unwrap_or_else(|| {
            if lambda2 <= 0.0 {
                1
            } else {
                // smallest d with lambda2^(2^d) <= crude_decay
                let need = (opts.crude_decay.ln() / lambda2.ln()).max(1.0);
                (need.log2().ceil() as usize).clamp(1, opts.max_depth)
            }
        });

        Ok(Chain { n, depth, dvec, dinv, x, lambda2, singular, m_edges })
    }

    /// One X-application (one exchange round of width `w`). `v` and `out`
    /// are stacked shard-local (`local_n × w` row-major, all rows on the
    /// bulk transport).
    pub fn apply_x(&self, v: &[f64], w: usize, out: &mut [f64], exch: &mut dyn Exchange) {
        // sddn-lint: graph-support walk matrix X sparsity is exactly the comm graph plus diagonal
        exch.exchange_apply(&self.x, 2 * self.m_edges as u64, v, w, out);
    }

    /// Apply `X^{2^i}` by repeated application (2^i rounds).
    pub fn apply_x_pow(
        &self,
        level: usize,
        v: &[f64],
        w: usize,
        out: &mut [f64],
        scratch: &mut [f64],
        exch: &mut dyn Exchange,
    ) {
        let reps = 1usize << level;
        debug_assert_eq!(v.len(), out.len());
        debug_assert_eq!(v.len(), scratch.len());
        // Ping-pong between out and scratch.
        self.apply_x(v, w, out, exch);
        for _ in 1..reps {
            scratch.copy_from_slice(out);
            self.apply_x(scratch, w, out, exch);
        }
    }

    /// Apply `M = D̃(I − X)` (one round). The per-row combine is
    /// independent across rows and runs on the par substrate.
    pub fn apply_m(&self, v: &[f64], w: usize, out: &mut [f64], exch: &mut dyn Exchange) {
        self.apply_x(v, w, out, exch);
        let owned = exch.owned();
        let threads = crate::par::plan_for(out.len());
        crate::par::par_chunks_mut(out, w, threads, |r0, block| {
            for (k, row) in block.chunks_mut(w).enumerate() {
                let r = r0 + k;
                let d = self.dvec[owned[r]];
                for (j, o) in row.iter_mut().enumerate() {
                    *o = d * (v[r * w + j] - *o);
                }
            }
        });
    }

    /// Project onto the working subspace (mean-zero per column) when the
    /// matrix is singular. Costs one all-reduce of width `w`.
    pub fn project(&self, v: &mut [f64], w: usize, exch: &mut dyn Exchange) {
        if !self.singular {
            return;
        }
        let totals = exch.allreduce_sum(v, w);
        let n = self.n as f64;
        let threads = crate::par::plan_for(v.len());
        crate::par::par_chunks_mut(v, w, threads, |_, block| {
            for row in block.chunks_mut(w) {
                for (j, val) in row.iter_mut().enumerate() {
                    *val -= totals[j] / n;
                }
            }
        });
    }
}

/// Power iteration estimating the decay rate of X on the working subspace.
fn estimate_decay(x: &Csr, singular: bool, rng: &mut Pcg64) -> f64 {
    let n = x.rows;
    let mut v = rng.normal_vec(n);
    if singular {
        center(&mut v);
    }
    let nv = norm2(&v).max(1e-300);
    scale(&mut v, 1.0 / nv);
    let mut y = vec![0.0; n];
    let mut lambda = 0.0;
    for _ in 0..300 {
        x.matvec_into(&v, &mut y);
        if singular {
            center(&mut y);
        }
        let ny = norm2(&y);
        if ny < 1e-300 {
            return 0.0;
        }
        let newl = ny;
        for i in 0..n {
            v[i] = y[i] / ny;
        }
        if (newl - lambda).abs() < 1e-10 * newl {
            return newl.min(1.0 - 1e-12);
        }
        lambda = newl;
    }
    lambda.min(1.0 - 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian::laplacian_csr};

    fn chain_for(n: usize, m: usize, seed: u64) -> Chain {
        let mut rng = Pcg64::new(seed);
        let g = generate::random_connected(n, m, &mut rng);
        let l = laplacian_csr(&g);
        Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap()
    }

    #[test]
    fn laplacian_detected_singular() {
        let c = chain_for(20, 40, 1);
        assert!(c.singular);
        assert!(c.lambda2 > 0.0 && c.lambda2 < 1.0, "lambda2={}", c.lambda2);
        assert!(c.depth >= 1);
    }

    #[test]
    fn lazy_walk_rowsums_one() {
        let c = chain_for(10, 20, 2);
        // Lazy X is row-stochastic: X·1 = 1.
        let ones = vec![1.0; 10];
        let y = c.x.matvec(&ones);
        for v in y {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn apply_m_matches_laplacian() {
        let mut rng = Pcg64::new(3);
        let g = generate::random_connected(15, 30, &mut rng);
        let l = laplacian_csr(&g);
        let c = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let v = rng.normal_vec(15);
        let mut out = vec![0.0; 15];
        let mut comm = crate::net::CommGraph::new(&g);
        c.apply_m(&v, 1, &mut out, &mut comm);
        let expect = l.matvec(&v);
        for (a, b) in out.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-10, "{a} vs {b}");
        }
        assert_eq!(comm.stats().rounds, 1);
    }

    #[test]
    fn apply_x_pow_is_repeated_apply() {
        let mut rng0 = Pcg64::new(4);
        let g = generate::random_connected(12, 24, &mut rng0);
        let l = laplacian_csr(&g);
        let c = Chain::build(&l, &ChainOptions::default(), &mut rng0).unwrap();
        let mut rng = Pcg64::new(5);
        let v = rng.normal_vec(12);
        let mut comm = crate::net::CommGraph::new(&g);
        let mut out = vec![0.0; 12];
        let mut scratch = vec![0.0; 12];
        c.apply_x_pow(2, &v, 1, &mut out, &mut scratch, &mut comm); // X^4
        // Reference: apply X four times.
        let mut r = v.clone();
        let mut tmp = vec![0.0; 12];
        let mut c2 = crate::net::CommGraph::new(&g);
        for _ in 0..4 {
            c.apply_x(&r, 1, &mut tmp, &mut c2);
            r.copy_from_slice(&tmp);
        }
        for (a, b) in out.iter().zip(&r) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(comm.stats().rounds, 4);
    }

    #[test]
    fn nonsingular_sddm_detected() {
        // Laplacian + I is strictly dominant.
        let mut rng = Pcg64::new(6);
        let g = generate::random_connected(10, 20, &mut rng);
        let l = laplacian_csr(&g);
        let mut trips: Vec<(usize, usize, f64)> = Vec::new();
        for i in 0..10 {
            for k in l.indptr[i]..l.indptr[i + 1] {
                trips.push((i, l.indices[k], l.values[k]));
            }
            trips.push((i, i, 1.0));
        }
        let m = Csr::from_triplets(10, 10, &trips);
        let c = Chain::build(&m, &ChainOptions::default(), &mut rng).unwrap();
        assert!(!c.singular);
    }

    #[test]
    fn rejects_positive_offdiagonal() {
        let mut rng = Pcg64::new(7);
        let m = Csr::from_triplets(2, 2, &[(0, 0, 2.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 2.0)]);
        assert!(Chain::build(&m, &ChainOptions::default(), &mut rng).is_err());
    }

    #[test]
    fn faithful_splitting_builds() {
        let mut rng = Pcg64::new(8);
        let g = generate::random_connected(10, 25, &mut rng);
        let l = laplacian_csr(&g);
        let opts = ChainOptions { splitting: Splitting::Faithful, ..Default::default() };
        let c = Chain::build(&l, &opts, &mut rng).unwrap();
        // Faithful X = D0^{-1} A0 has zero diagonal; row sums equal 1.
        let ones = vec![1.0; 10];
        let y = c.x.matvec(&ones);
        for v in y {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }
}
