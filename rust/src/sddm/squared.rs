//! Explicit-squaring chain (the preprocessed variant of [12]).
//!
//! [`super::chain::Chain`] applies `X^{2^i}` *implicitly* as `2^i`
//! neighbor-exchange rounds. The distributed solver of Tutunov et al.
//! [12] instead precomputes the level matrices
//! `X_{i+1} = X_i²` once (each node learns its 2^i-hop neighborhood
//! weights) so that every level application is a *single* exchange round
//! over the denser support. This module implements that mode:
//!
//! - per-level CSR matrices `X_i = X^{2^i}` built by repeated sparse
//!   squaring (with optional pruning of tiny entries);
//! - message accounting charges one round of `nnz(X_i) − n` directed
//!   messages (the extended-neighborhood exchange);
//! - trade-off: far fewer *rounds* (latency) at the cost of denser
//!   messages and a preprocessing phase — the `ablations` bench compares
//!   both modes.
//!
//! The level supports exceed the graph edges for `level ≥ 1`, so each
//! level is registered as an **overlay halo plan**
//! ([`Exchange::register_plan`]): the partitioned transport derives, from
//! the level's actual CSR support, exactly which rows cross each worker
//! boundary, and the preprocessed solver runs shard-local like every
//! other operator ([`SquaredSddmSolver`] plugs it into the Newton
//! pipeline). On co-located transports the registration is a no-op.

use super::chain::{Chain, ChainError, ChainOptions};
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::util::{BufferPool, Pcg64};

/// A chain with explicitly squared level matrices.
#[derive(Debug, Clone)]
pub struct SquaredChain {
    /// The base chain (provides D̃, splitting, depth, singularity).
    pub base: Chain,
    /// `levels[i] = X^{2^i}` for `i ∈ 0..=depth`.
    pub levels: Vec<Csr>,
    /// Prune threshold used during squaring (0 = exact).
    pub prune_tol: f64,
}

impl SquaredChain {
    /// Build by repeated squaring of the base chain's walk matrix.
    /// `prune_tol` drops entries with |v| ≤ tol after each squaring
    /// (introducing a controlled approximation; 0 keeps everything).
    pub fn build(
        m: &Csr,
        opts: &ChainOptions,
        prune_tol: f64,
        rng: &mut Pcg64,
    ) -> Result<SquaredChain, ChainError> {
        let base = Chain::build(m, opts, rng)?;
        let mut levels = Vec::with_capacity(base.depth + 1);
        levels.push(base.x.clone());
        for i in 0..base.depth {
            let sq = levels[i].matmul(&levels[i]);
            let sq = if prune_tol > 0.0 { sq.prune(prune_tol) } else { sq };
            levels.push(sq);
        }
        Ok(SquaredChain { base, levels, prune_tol })
    }

    /// Apply `X^{2^level}` in ONE extended-neighborhood round.
    ///
    /// Message model: each stored off-diagonal entry is one directed
    /// message of `w` floats in the preprocessed overlay network. The
    /// overlay support exceeds the graph edges for `level ≥ 1`; the
    /// partitioned transport ships it through the level's registered
    /// overlay plan — exactly the rows each peer's support reads.
    pub fn apply_level(
        &self,
        level: usize,
        v: &[f64],
        w: usize,
        out: &mut [f64],
        exch: &mut dyn Exchange,
    ) {
        let x = &self.levels[level];
        exch.register_plan("squared-chain level", x);
        let offdiag = x.nnz().saturating_sub(self.base.n) as u64;
        exch.exchange_apply(x, offdiag, v, w, out);
    }

    /// "Crude" solve (Algorithm 1) with single-round level applications.
    pub fn crude_solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> Vec<f64> {
        let mut pool = BufferPool::new();
        self.crude_solve_ws(b, w, exch, &mut pool)
    }

    /// [`Self::crude_solve`] with an explicit workspace pool: scratch and
    /// the returned solution are pool-drawn (put the result back after
    /// use). Bit-for-bit identical to the allocating form.
    // sddn-lint: hot-path
    pub fn crude_solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> Vec<f64> {
        let c = &self.base;
        let ln = exch.local_n();
        assert_eq!(b.len(), ln * w);
        let d = c.depth;
        let len = ln * w;
        let mut scratch = pool.take(len);

        let mut bs: Vec<Vec<f64>> = Vec::with_capacity(d + 1);
        let mut cur = pool.take_copy(b);
        c.project(&mut cur, w, exch);
        bs.push(pool.take_copy(&cur));
        let mut tmp = pool.take(len);
        for i in 0..d {
            for (r, &u) in exch.owned().iter().enumerate() {
                for j in 0..w {
                    tmp[r * w + j] = c.dinv[u] * cur[r * w + j];
                }
            }
            self.apply_level(i, &tmp, w, &mut scratch, exch);
            for (r, &u) in exch.owned().iter().enumerate() {
                for j in 0..w {
                    cur[r * w + j] += c.dvec[u] * scratch[r * w + j];
                }
            }
            c.project(&mut cur, w, exch);
            bs.push(pool.take_copy(&cur));
        }

        let mut x = pool.take(len);
        for (r, &u) in exch.owned().iter().enumerate() {
            for j in 0..w {
                x[r * w + j] = c.dinv[u] * bs[d][r * w + j];
            }
        }
        c.project(&mut x, w, exch);

        for i in (0..d).rev() {
            self.apply_level(i, &x, w, &mut scratch, exch);
            for (r, &u) in exch.owned().iter().enumerate() {
                for j in 0..w {
                    let idx = r * w + j;
                    x[idx] = 0.5 * (c.dinv[u] * bs[i][idx] + x[idx] + scratch[idx]);
                }
            }
            c.project(&mut x, w, exch);
        }
        pool.put(scratch);
        pool.put(cur);
        pool.put(tmp);
        for buf in bs {
            pool.put(buf);
        }
        x
    }

    /// Richardson-refined solve to relative residual `eps`.
    pub fn solve(
        &self,
        b: &[f64],
        w: usize,
        eps: f64,
        max_sweeps: usize,
        exch: &mut dyn Exchange,
    ) -> super::solver::SolveOutcome {
        let mut pool = BufferPool::new();
        self.solve_ws(b, w, eps, max_sweeps, exch, &mut pool)
    }

    /// [`Self::solve`] with an explicit workspace pool (the outcome's `x`
    /// is pool-drawn — put it back after use).
    // sddn-lint: hot-path
    pub fn solve_ws(
        &self,
        b: &[f64],
        w: usize,
        eps: f64,
        max_sweeps: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> super::solver::SolveOutcome {
        let c = &self.base;
        let len = exch.local_n() * w;
        assert_eq!(b.len(), len);
        let mut b0 = pool.take_copy(b);
        c.project(&mut b0, w, exch);
        let bnorm = exch.norm2_sq(&b0, w).sqrt().max(1e-300);

        let mut y = self.crude_solve_ws(&b0, w, exch, pool);
        let mut my = pool.take(len);
        let mut residual = pool.take(len);
        let mut rel = f64::INFINITY;
        let mut sweeps = 0;
        for k in 0..=max_sweeps {
            c.apply_m(&y, w, &mut my, exch);
            for i in 0..len {
                residual[i] = b0[i] - my[i];
            }
            c.project(&mut residual, w, exch);
            // Residual norm check is an accounted all-reduce.
            rel = exch.norm2_sq(&residual, w).sqrt() / bnorm;
            if rel <= eps || k == max_sweeps {
                sweeps = k;
                break;
            }
            let dz = self.crude_solve_ws(&residual, w, exch, pool);
            for i in 0..len {
                y[i] += dz[i];
            }
            pool.put(dz);
            sweeps = k + 1;
        }
        pool.put(b0);
        pool.put(my);
        pool.put(residual);
        super::solver::SolveOutcome { x: y, sweeps, rel_residual: rel, converged: rel <= eps }
    }

    /// Total stored entries across levels (preprocessing memory).
    pub fn total_nnz(&self) -> usize {
        self.levels.iter().map(Csr::nnz).sum()
    }
}

/// The preprocessed chain as a pluggable inner Laplacian solver (the
/// `LaplacianSolver` impl lives with the other solvers in
/// `algorithms::solvers`): SDD-Newton with this solver pays one
/// extended-neighborhood round per level application instead of `2^i`
/// edge rounds — and, through the overlay halo plans, runs on the
/// partitioned worker runtime bit-for-bit identically to the bulk path.
#[derive(Debug, Clone)]
pub struct SquaredSddmSolver {
    /// The explicitly squared chain the sweeps run over.
    pub chain: SquaredChain,
    /// Accuracy / budget options.
    pub opts: super::solver::SolverOptions,
}

impl SquaredSddmSolver {
    /// Wrap a squared chain with solve options.
    pub fn new(chain: SquaredChain, opts: super::solver::SolverOptions) -> SquaredSddmSolver {
        SquaredSddmSolver { chain, opts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian_csr};
    use crate::sddm::{SddmSolver, SolverOptions};

    #[test]
    fn squared_levels_match_implicit_application() {
        let mut rng = Pcg64::new(301);
        let g = generate::random_connected(18, 40, &mut rng);
        let l = laplacian_csr(&g);
        let sq = SquaredChain::build(&l, &ChainOptions::default(), 0.0, &mut rng).unwrap();
        let v = rng.normal_vec(18);
        for level in 0..=sq.base.depth.min(3) {
            let mut out_sq = vec![0.0; 18];
            let mut c1 = crate::net::CommGraph::new(&g);
            sq.apply_level(level, &v, 1, &mut out_sq, &mut c1);
            let mut out_im = vec![0.0; 18];
            let mut scratch = vec![0.0; 18];
            let mut c2 = crate::net::CommGraph::new(&g);
            sq.base.apply_x_pow(level, &v, 1, &mut out_im, &mut scratch, &mut c2);
            for (a, b) in out_sq.iter().zip(&out_im) {
                assert!((a - b).abs() < 1e-10, "level {level}");
            }
            // Squared mode: always exactly 1 round; implicit: 2^level rounds.
            assert_eq!(c1.stats().rounds, 1);
            assert_eq!(c2.stats().rounds, 1 << level);
        }
    }

    #[test]
    fn squared_solve_matches_implicit_solver() {
        let mut rng = Pcg64::new(302);
        let g = generate::random_connected(25, 60, &mut rng);
        let l = laplacian_csr(&g);
        let z = rng.normal_vec(25);
        let b = l.matvec(&z);

        let sq = SquaredChain::build(&l, &ChainOptions::default(), 0.0, &mut rng).unwrap();
        let mut c1 = crate::net::CommGraph::new(&g);
        let out_sq = sq.solve(&b, 1, 1e-8, 300, &mut c1);
        assert!(out_sq.converged);

        let chain = Chain::build(&l, &ChainOptions::default(), &mut rng).unwrap();
        let solver = SddmSolver::new(chain, SolverOptions { eps: 1e-8, max_richardson: 300 });
        let mut c2 = crate::net::CommGraph::new(&g);
        let out_im = solver.solve(&b, 1, &mut c2);

        for (a, c) in out_sq.x.iter().zip(&out_im.x) {
            assert!((a - c).abs() < 1e-5);
        }
        // Squared mode needs far fewer rounds (latency) at denser messages.
        assert!(
            c1.stats().rounds < c2.stats().rounds,
            "rounds: squared {} vs implicit {}",
            c1.stats().rounds,
            c2.stats().rounds
        );
    }

    #[test]
    fn pruning_trades_accuracy_for_sparsity() {
        let mut rng = Pcg64::new(303);
        let g = generate::random_connected(30, 70, &mut rng);
        let l = laplacian_csr(&g);
        let exact = SquaredChain::build(&l, &ChainOptions::default(), 0.0, &mut rng).unwrap();
        let pruned =
            SquaredChain::build(&l, &ChainOptions::default(), 1e-3, &mut rng).unwrap();
        assert!(pruned.total_nnz() <= exact.total_nnz());
        // Pruned chain still solves (Richardson absorbs the perturbation).
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let mut comm = crate::net::CommGraph::new(&g);
        let out = pruned.solve(&b, 1, 1e-6, 500, &mut comm);
        assert!(out.converged, "rel={}", out.rel_residual);
    }
}
