//! Generic partitioned runtime: run *any* [`ConsensusAlgorithm`] on `k`
//! worker OS threads owning node shards — the deployment shape of the
//! paper's 8-worker MatlabMPI pool, for the baselines as well as the
//! contribution.
//!
//! Each worker wires up a
//! [`crate::net::partitioned::ShardExchange`] and drives an unmodified
//! shard-local algorithm instance against it; the leader aggregates
//! per-iteration metrics strictly keyed by iteration tag
//! ([`super::gather_by_iteration`]). Because every algorithm steps
//! through the same [`Exchange`] primitives on both transports, the
//! result — iterates, per-iteration objectives, and the modeled comm
//! ledger — is bit-for-bit identical to the bulk-synchronous
//! `run(alg, …, CommGraph, …)` path (asserted for every algorithm in
//! `tests/prop_parallel.rs`).

use super::partition::Partition;
use crate::algorithms::ConsensusAlgorithm;
use crate::graph::{laplacian_csr, Graph};
use crate::net::partitioned::{build_shard_plans, run_reducer, ReduceMsg, ShardExchange, WireMsg};
use crate::net::{CommStats, Exchange};
use crate::problems::ConsensusProblem;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

/// Per-iteration metric row from a partitioned run, aggregated by the
/// leader keyed on the iteration tag (a fast worker's iteration `t+1`
/// snapshot is buffered, never blended into iteration `t`).
#[derive(Debug, Clone)]
pub struct PartitionedIter {
    pub iter: usize,
    /// Global objective Σ f_i(θ_i) at the stacked iterate.
    pub objective: f64,
    /// Consensus error at the stacked iterate.
    pub consensus_error: f64,
    /// Cumulative real cross-worker channel payloads (the MPI traffic of
    /// the deployment), summed over workers. Plan-driven shipping makes
    /// this equal the wire model (`net::partitioned::plan_cross_rows`
    /// composed per algorithm by
    /// `harness::experiments::modeled_cross_messages`).
    pub cross_messages: u64,
    /// Cumulative real floats moved over the channels (×8 for bytes on
    /// the wire), summed over workers.
    pub cross_floats: u64,
    /// Modeled per-node communication — identical on every worker, and
    /// identical to what the bulk-synchronous path records.
    pub comm: CommStats,
}

/// Outcome of a partitioned run.
#[derive(Debug, Clone)]
pub struct PartitionedRun {
    pub records: Vec<PartitionedIter>,
    /// Final stacked iterate (global `n × p`).
    pub thetas: Vec<f64>,
    /// Final modeled communication counters.
    pub comm: CommStats,
    /// Final cumulative cross-worker channel payloads.
    pub cross_messages: u64,
    /// Final cumulative cross-worker floats (×8 for bytes on the wire).
    pub cross_floats: u64,
}

/// Metric message: (iteration, worker, owned θ rows, cumulative cross
/// messages, cumulative cross floats, modeled stats snapshot).
type MetricMsg = (usize, usize, Vec<f64>, u64, u64, CommStats);

/// Statically-typed core of the partitioned runtime. `make_alg(worker,
/// owned)` builds each worker's shard-local instance (called on the
/// worker's own thread); `finish(worker, owned, alg)` observes the final
/// instance before it is dropped, letting callers extract extra state
/// (e.g. SDD-Newton's dual iterate).
pub fn run_partitioned_with<A, F, G>(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    iters: usize,
    make_alg: F,
    finish: G,
) -> PartitionedRun
where
    A: ConsensusAlgorithm,
    F: Fn(usize, Vec<usize>) -> A + Sync,
    G: Fn(usize, &[usize], &A) + Sync,
{
    let n = g.n;
    let p = problem.p;
    let k = part.k;
    assert_eq!(problem.n(), n, "problem/graph size mismatch");
    let lap = laplacian_csr(g);
    let plans = build_shard_plans(g, part);
    let owned_lists: Vec<Vec<usize>> = plans.iter().map(|pl| pl.owned.clone()).collect();

    // Worker↔worker boundary channels.
    let mut wire_tx: Vec<Sender<WireMsg>> = Vec::with_capacity(k);
    let mut wire_rx: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<WireMsg>();
        wire_tx.push(tx);
        wire_rx.push(Some(rx));
    }
    // All-reduce channels through the reducer.
    let (red_tx, red_rx) = channel::<ReduceMsg>();
    let mut red_out_tx: Vec<Sender<Vec<f64>>> = Vec::with_capacity(k);
    let mut red_out_rx: Vec<Option<Receiver<Vec<f64>>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<f64>>();
        red_out_tx.push(tx);
        red_out_rx.push(Some(rx));
    }
    // Worker→leader metrics.
    let (met_tx, met_rx) = channel::<MetricMsg>();

    let final_thetas = Mutex::new(vec![0.0; n * p]);
    let mut records = Vec::with_capacity(iters);

    std::thread::scope(|scope| {
        {
            let owned_of = owned_lists.clone();
            let txs = red_out_tx.clone();
            scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
        }
        for (wid, plan) in plans.into_iter().enumerate() {
            // All-to-all senders (indexed by worker id): overlay exchange
            // plans may reach workers beyond the graph-halo neighbors.
            let peer_txs: Vec<Sender<WireMsg>> = wire_tx.clone();
            let inbox = wire_rx[wid].take().unwrap();
            let from_red = red_out_rx[wid].take().unwrap();
            let red = red_tx.clone();
            let met = met_tx.clone();
            let lap = &lap;
            let final_thetas = &final_thetas;
            let make_alg = &make_alg;
            let finish = &finish;
            scope.spawn(move || {
                let mut exch =
                    ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                // Opt-in reorder-buffer bound: with SDDN_REORDER_BOUND=R a
                // parked payload more than R rounds ahead of the awaited
                // round dies loudly instead of growing the buffer (set R
                // to τ+1 under a bounded-staleness policy with halo age τ;
                // leave unset for sparse masked schedules).
                if let Some(bound) = std::env::var("SDDN_REORDER_BOUND")
                    .ok()
                    .and_then(|v| v.parse::<u64>().ok())
                {
                    exch.set_reorder_high_water(bound);
                }
                let mut alg = make_alg(wid, exch.owned().to_vec());
                for it in 0..iters {
                    alg.step(problem, &mut exch);
                    met.send((
                        it,
                        wid,
                        alg.thetas().to_vec(),
                        exch.cross_messages(),
                        exch.cross_floats(),
                        *exch.stats(),
                    ))
                    .expect("leader died");
                }
                let owned = exch.owned().to_vec();
                {
                    let mut ft = final_thetas.lock().unwrap();
                    for (li, &u) in owned.iter().enumerate() {
                        ft[u * p..(u + 1) * p]
                            .copy_from_slice(&alg.thetas()[li * p..(li + 1) * p]);
                    }
                }
                finish(wid, &owned, &alg);
            });
        }
        drop(red_tx);
        drop(red_out_tx);
        drop(met_tx);

        // Leader: aggregate metrics strictly by iteration tag (see
        // `gather_by_iteration`).
        let mut stacked = vec![0.0; n * p];
        super::gather_by_iteration(&met_rx, k, iters, |m: &MetricMsg| m.0, |it, got| {
            let mut cross_total = 0u64;
            let mut cross_floats_total = 0u64;
            let mut comm = CommStats::default();
            for (_, wid, snapshot, cross, cfloats, stats) in got {
                for (li, &u) in owned_lists[wid].iter().enumerate() {
                    stacked[u * p..(u + 1) * p]
                        .copy_from_slice(&snapshot[li * p..(li + 1) * p]);
                }
                cross_total += cross;
                cross_floats_total += cfloats;
                // Every worker tallies the identical modeled ledger.
                debug_assert!(comm == CommStats::default() || comm == stats);
                comm = stats;
            }
            records.push(PartitionedIter {
                iter: it + 1,
                objective: problem.objective(&stacked),
                consensus_error: problem.consensus_error(&stacked),
                cross_messages: cross_total,
                cross_floats: cross_floats_total,
                comm,
            });
        });
    });

    let comm = records.last().map(|r| r.comm).unwrap_or_default();
    let cross_messages = records.last().map(|r| r.cross_messages).unwrap_or(0);
    let cross_floats = records.last().map(|r| r.cross_floats).unwrap_or(0);
    PartitionedRun {
        records,
        thetas: final_thetas.into_inner().unwrap(),
        comm,
        cross_messages,
        cross_floats,
    }
}

fn no_finish<A>(_wid: usize, _owned: &[usize], _alg: &A) {}

/// Run any consensus algorithm on `k` worker threads owning the
/// partition's shards. `make_alg` receives each worker's owned global
/// node ids (ascending) and returns the worker's shard-local instance;
/// it is called once per worker, on the worker's thread.
pub fn run_partitioned_baseline<'a>(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    iters: usize,
    make_alg: &(dyn Fn(Vec<usize>) -> Box<dyn ConsensusAlgorithm + 'a> + Sync),
) -> PartitionedRun {
    run_partitioned_with(problem, g, part, iters, |_wid, owned| make_alg(owned), no_finish)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::gradient::{DistGradient, GradSchedule};
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::net::CommGraph;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn baseline_harness_matches_bulk_for_gradient() {
        let mut rng = Pcg64::new(801);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let iters = 5;

        let mut reference = DistGradient::new(&prob, &g, GradSchedule::Constant(1e-3));
        let mut comm = CommGraph::new(&g);
        let trace = run(
            &mut reference,
            &prob,
            &mut comm,
            &RunOptions { max_iters: iters, ..Default::default() },
        );

        let part = Partition::round_robin(10, 3);
        let out = run_partitioned_baseline(&prob, &g, &part, iters, &|owned| {
            Box::new(DistGradient::new_sharded(
                &prob,
                &g,
                GradSchedule::Constant(1e-3),
                owned,
            )) as Box<dyn crate::algorithms::ConsensusAlgorithm>
        });
        assert_eq!(out.thetas, trace.final_thetas, "iterate drifted");
        assert_eq!(out.comm, *comm.stats(), "ledger drifted");
        assert_eq!(out.records.len(), iters);
        for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
            assert_eq!(r.objective, ref_r.objective, "iter {} drifted", r.iter);
        }
        assert!(out.cross_messages > 0, "round-robin shards must talk");
    }

    #[test]
    fn single_worker_has_zero_cross_traffic() {
        let mut rng = Pcg64::new(802);
        let g = generate::cycle(8);
        let prob = datasets::synthetic_regression(8, 3, 80, 0.2, 0.05, &mut rng);
        let part = Partition::contiguous(8, 1);
        let out = run_partitioned_baseline(&prob, &g, &part, 3, &|owned| {
            Box::new(DistGradient::new_sharded(
                &prob,
                &g,
                GradSchedule::Constant(1e-3),
                owned,
            )) as Box<dyn crate::algorithms::ConsensusAlgorithm>
        });
        assert_eq!(out.cross_messages, 0);
        assert!(out.records[2].objective.is_finite());
    }
}
