//! Coordinator: leader-side orchestration of experiment campaigns.
//!
//! The paper's runtime is a leader (the Matlab driver) plus node workers;
//! here the leader schedules experiment jobs, runs them (optionally with
//! the threaded per-node runtime for the averaging-style methods), and
//! writes the report bundle (CSV traces + summary) per experiment.

pub mod scheduler;
pub mod partition;
pub mod worker;
pub mod baseline;
pub mod newton;
pub mod tcp;

pub use baseline::{run_partitioned_baseline, run_partitioned_with, PartitionedIter, PartitionedRun};
pub use tcp::{run_leader, run_tcp_worker, TcpLeader, TcpPartitionedRun};
pub use newton::{run_partitioned_newton, NewtonIter, PartitionedNewtonRun};
pub use partition::Partition;
pub use scheduler::{Campaign, JobOutcome};
pub use worker::run_partitioned_gradient;

/// Leader-side aggregation discipline shared by the partitioned runtimes:
/// collect exactly `k` messages tagged with each iteration `0..iters` (in
/// order), parking messages from workers that have raced ahead until
/// their iteration comes up. This keying — never popping by count — is
/// what keeps a fast worker's iteration `t+1` snapshot out of iteration
/// `t`'s metrics.
pub(crate) fn gather_by_iteration<T>(
    rx: &std::sync::mpsc::Receiver<T>,
    k: usize,
    iters: usize,
    tag_of: impl Fn(&T) -> usize,
    mut per_iteration: impl FnMut(usize, Vec<T>),
) {
    let mut early: Vec<Vec<T>> = (0..iters).map(|_| Vec::new()).collect();
    for it in 0..iters {
        let mut got: Vec<T> = std::mem::take(&mut early[it]);
        while got.len() < k {
            let msg = rx.recv().expect("worker died");
            let tag = tag_of(&msg);
            if tag == it {
                got.push(msg);
            } else {
                early[tag].push(msg);
            }
        }
        per_iteration(it, got);
    }
}
