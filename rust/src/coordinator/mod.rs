//! Coordinator: leader-side orchestration of experiment campaigns.
//!
//! The paper's runtime is a leader (the Matlab driver) plus node workers;
//! here the leader schedules experiment jobs, runs them (optionally with
//! the threaded per-node runtime for the averaging-style methods), and
//! writes the report bundle (CSV traces + summary) per experiment.

pub mod scheduler;
pub mod partition;
pub mod worker;

pub use partition::Partition;
pub use scheduler::{Campaign, JobOutcome};
pub use worker::run_partitioned_gradient;
