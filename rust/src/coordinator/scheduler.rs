//! Campaign scheduler: run a queue of experiment configs, persist results.

use crate::config::ExperimentConfig;
use crate::harness::report;
use crate::harness::run_experiment;
use std::path::{Path, PathBuf};

/// A batch of experiments plus an output directory.
pub struct Campaign {
    pub jobs: Vec<ExperimentConfig>,
    pub out_dir: PathBuf,
}

/// Result of one scheduled job.
pub struct JobOutcome {
    pub name: String,
    pub csv_path: PathBuf,
    pub summary: String,
    pub seconds: f64,
}

impl Campaign {
    /// Create a campaign from preset names (unknown names are errors).
    pub fn from_presets(names: &[&str], out_dir: impl AsRef<Path>) -> Result<Campaign, String> {
        let jobs = names
            .iter()
            .map(|n| ExperimentConfig::preset(n).ok_or_else(|| format!("unknown preset '{n}'")))
            .collect::<Result<Vec<_>, _>>()?;
        Ok(Campaign { jobs, out_dir: out_dir.as_ref().to_path_buf() })
    }

    /// Run every job sequentially (the sandbox has one core; jobs are
    /// internally bulk-synchronous anyway), writing `<name>.csv` and
    /// returning summaries.
    pub fn run(&self) -> std::io::Result<Vec<JobOutcome>> {
        std::fs::create_dir_all(&self.out_dir)?;
        let mut outcomes = Vec::with_capacity(self.jobs.len());
        for cfg in &self.jobs {
            let t = crate::util::Timer::start();
            let res = run_experiment(cfg);
            let csv_path = self.out_dir.join(format!("{}.csv", cfg.name));
            report::write_csv(&res, &csv_path)?;
            let summary = report::summary_table(&res);
            outcomes.push(JobOutcome {
                name: cfg.name.clone(),
                csv_path,
                summary,
                seconds: t.secs(),
            });
        }
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn campaign_runs_and_writes() {
        let dir = std::env::temp_dir().join("sddn_campaign_test");
        let mut campaign = Campaign::from_presets(&["smoke"], &dir).unwrap();
        campaign.jobs[0].max_iters = 3;
        campaign.jobs[0].algorithms.truncate(2);
        let outcomes = campaign.run().unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].csv_path.exists());
        assert!(outcomes[0].summary.contains("algorithm"));
    }

    #[test]
    fn unknown_preset_rejected() {
        assert!(Campaign::from_presets(&["nope"], "/tmp").is_err());
    }
}
