//! Partitioned SDD-Newton: the full dual Newton pipeline (primal
//! recovery, dual gradient, two inner Laplacian solves, kernel
//! correction, dual ascent) executed on `k` worker OS threads that own
//! node shards — the deployment shape of the paper's 8-worker MatlabMPI
//! pool. Mirrors [`super::worker::run_partitioned_gradient`], but where
//! the gradient runtime hand-rolls its exchange, this one drives the
//! *unmodified* [`SddNewton::step_ex`] over a
//! [`crate::net::partitioned::ShardExchange`] per worker: every chain
//! X-application and all-reduce of the inner SDDM solver rides the
//! channel transport, and the result is bit-for-bit identical to the
//! bulk-synchronous `SddNewton` + `CommGraph` path (asserted in
//! `tests/prop_parallel.rs`).

use super::partition::Partition;
use crate::algorithms::sdd_newton::{SddNewton, StepSize};
use crate::algorithms::solvers::LaplacianSolver;
use crate::algorithms::ConsensusAlgorithm;
use crate::graph::{laplacian_csr, Graph};
use crate::net::partitioned::{build_shard_plans, run_reducer, ReduceMsg, ShardExchange, WireMsg};
use crate::net::{CommStats, Exchange};
use crate::problems::ConsensusProblem;
use crate::runtime::NativeBackend;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

/// Per-iteration metric row from a partitioned Newton run, aggregated by
/// the leader keyed on the iteration tag (a fast worker's iteration `t+1`
/// snapshot is buffered, never blended into iteration `t`).
#[derive(Debug, Clone)]
pub struct NewtonIter {
    pub iter: usize,
    /// Global objective Σ f_i(y_i) at the stacked primal iterate.
    pub objective: f64,
    /// Consensus error at the stacked primal iterate.
    pub consensus_error: f64,
    /// Cumulative real cross-worker channel payloads (the MPI traffic of
    /// the deployment), summed over workers.
    pub cross_messages: u64,
    /// Modeled per-node communication — identical on every worker, and
    /// identical to what the bulk-synchronous path records.
    pub comm: CommStats,
}

/// Outcome of a partitioned Newton run.
#[derive(Debug, Clone)]
pub struct PartitionedNewtonRun {
    pub records: Vec<NewtonIter>,
    /// Final stacked primal iterate (global `n × p`).
    pub thetas: Vec<f64>,
    /// Final stacked dual iterate (global `n × p`).
    pub lambda: Vec<f64>,
    /// Final modeled communication counters.
    pub comm: CommStats,
    /// Final cumulative cross-worker channel payloads.
    pub cross_messages: u64,
}

/// Metric message: (iteration, worker, owned y rows, cumulative cross
/// messages, modeled stats snapshot).
type MetricMsg = (usize, usize, Vec<f64>, u64, CommStats);

/// Run SDD-Newton on `k` worker threads owning the partition's shards.
///
/// Each worker constructs a sharded [`SddNewton`] over a
/// [`NativeBackend`] and steps it against its [`ShardExchange`]; the
/// inner `solver` (SDDM chain, Neumann, or lockstep CG) is shared
/// read-only across workers. The leader aggregates per-iteration metrics
/// keyed by iteration.
pub fn run_partitioned_newton(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    solver: &dyn LaplacianSolver,
    step: StepSize,
    iters: usize,
) -> PartitionedNewtonRun {
    let n = g.n;
    let p = problem.p;
    let k = part.k;
    assert_eq!(problem.n(), n, "problem/graph size mismatch");
    let lap = laplacian_csr(g);
    let plans = build_shard_plans(g, part);
    let owned_lists: Vec<Vec<usize>> = plans.iter().map(|pl| pl.owned.clone()).collect();

    // Worker↔worker boundary channels.
    let mut wire_tx: Vec<Sender<WireMsg>> = Vec::with_capacity(k);
    let mut wire_rx: Vec<Option<Receiver<WireMsg>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<WireMsg>();
        wire_tx.push(tx);
        wire_rx.push(Some(rx));
    }
    // All-reduce channels through the reducer.
    let (red_tx, red_rx) = channel::<ReduceMsg>();
    let mut red_out_tx: Vec<Sender<Vec<f64>>> = Vec::with_capacity(k);
    let mut red_out_rx: Vec<Option<Receiver<Vec<f64>>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<Vec<f64>>();
        red_out_tx.push(tx);
        red_out_rx.push(Some(rx));
    }
    // Worker→leader metrics.
    let (met_tx, met_rx) = channel::<MetricMsg>();

    let final_thetas = Mutex::new(vec![0.0; n * p]);
    let final_lambda = Mutex::new(vec![0.0; n * p]);
    let mut records = Vec::with_capacity(iters);

    std::thread::scope(|scope| {
        {
            let owned_of = owned_lists.clone();
            let txs = red_out_tx.clone();
            scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
        }
        for (wid, plan) in plans.into_iter().enumerate() {
            let peer_txs: Vec<Sender<WireMsg>> =
                plan.send.iter().map(|(peer, _)| wire_tx[*peer].clone()).collect();
            let inbox = wire_rx[wid].take().unwrap();
            let from_red = red_out_rx[wid].take().unwrap();
            let red = red_tx.clone();
            let met = met_tx.clone();
            let lap = &lap;
            let (final_thetas, final_lambda) = (&final_thetas, &final_lambda);
            scope.spawn(move || {
                let mut exch =
                    ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                let backend = NativeBackend;
                let mut alg = SddNewton::new_sharded(
                    problem,
                    &backend,
                    solver,
                    step,
                    exch.owned().to_vec(),
                );
                for it in 0..iters {
                    alg.step_ex(problem, &mut exch);
                    met.send((it, wid, alg.thetas().to_vec(), exch.cross_messages(), *exch.stats()))
                        .expect("leader died");
                }
                let mut ft = final_thetas.lock().unwrap();
                let mut fl = final_lambda.lock().unwrap();
                for (li, &u) in alg.owned().iter().enumerate() {
                    ft[u * p..(u + 1) * p].copy_from_slice(&alg.thetas()[li * p..(li + 1) * p]);
                    fl[u * p..(u + 1) * p].copy_from_slice(&alg.lambda()[li * p..(li + 1) * p]);
                }
            });
        }
        drop(red_tx);
        drop(red_out_tx);
        drop(met_tx);

        // Leader: aggregate metrics strictly by iteration tag (see
        // `gather_by_iteration`).
        let mut stacked = vec![0.0; n * p];
        super::gather_by_iteration(&met_rx, k, iters, |m: &MetricMsg| m.0, |it, got| {
            let mut cross_total = 0u64;
            let mut comm = CommStats::default();
            for (_, wid, snapshot, cross, stats) in got {
                for (li, &u) in owned_lists[wid].iter().enumerate() {
                    stacked[u * p..(u + 1) * p]
                        .copy_from_slice(&snapshot[li * p..(li + 1) * p]);
                }
                cross_total += cross;
                // Every worker tallies the identical modeled ledger.
                debug_assert!(comm == CommStats::default() || comm == stats);
                comm = stats;
            }
            records.push(NewtonIter {
                iter: it + 1,
                objective: problem.objective(&stacked),
                consensus_error: problem.consensus_error(&stacked),
                cross_messages: cross_total,
                comm,
            });
        });
    });

    let comm = records.last().map(|r| r.comm).unwrap_or_default();
    let cross_messages = records.last().map(|r| r.cross_messages).unwrap_or(0);
    PartitionedNewtonRun {
        records,
        thetas: final_thetas.into_inner().unwrap(),
        lambda: final_lambda.into_inner().unwrap(),
        comm,
        cross_messages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::solvers::sddm_for_graph;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::net::CommGraph;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn partitioned_newton_smoke_matches_bulk() {
        let mut rng = Pcg64::new(701);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-5, &mut rng);
        let backend = crate::runtime::NativeBackend;
        let iters = 4;

        let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: iters, ..Default::default() },
        );

        let part = Partition::contiguous(10, 3);
        let out =
            run_partitioned_newton(&prob, &g, &part, &solver, StepSize::Fixed(1.0), iters);
        assert_eq!(out.records.len(), iters);
        assert_eq!(out.thetas, trace.final_thetas, "partitioned iterate drifted");
        assert_eq!(out.lambda, alg.lambda(), "partitioned dual drifted");
        assert_eq!(out.comm, *comm.stats(), "modeled comm drifted");
        for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
            assert_eq!(r.objective, ref_r.objective, "iter {} metrics drifted", r.iter);
        }
        assert!(out.cross_messages > 0, "3 shards on a connected graph must talk");
    }

    #[test]
    fn single_worker_is_the_bulk_path_with_zero_traffic() {
        let mut rng = Pcg64::new(702);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 3, 120, 0.2, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-4, &mut rng);
        let part = Partition::contiguous(8, 1);
        let out = run_partitioned_newton(&prob, &g, &part, &solver, StepSize::Fixed(1.0), 3);
        assert_eq!(out.cross_messages, 0);
        assert!(out.records[2].objective.is_finite());
    }
}
