//! Partitioned SDD-Newton: the full dual Newton pipeline (primal
//! recovery, dual gradient, two inner Laplacian solves, kernel
//! correction, dual ascent) executed on `k` worker OS threads that own
//! node shards — the deployment shape of the paper's 8-worker MatlabMPI
//! pool. A thin wrapper over the generic
//! [`super::baseline::run_partitioned_with`] harness that additionally
//! collects the final dual iterate: every chain X-application and
//! all-reduce of the inner SDDM solver rides the channel transport, and
//! the result is bit-for-bit identical to the bulk-synchronous
//! `SddNewton` + `CommGraph` path (asserted in `tests/prop_parallel.rs`).

use super::baseline::{run_partitioned_with, PartitionedIter, PartitionedRun};
use super::partition::Partition;
use crate::algorithms::sdd_newton::{SddNewton, StepSize};
use crate::algorithms::solvers::LaplacianSolver;
use crate::graph::Graph;
use crate::net::CommStats;
use crate::problems::ConsensusProblem;
use crate::runtime::NativeBackend;
use std::sync::Mutex;

/// Per-iteration metric row from a partitioned Newton run (the generic
/// harness row).
pub type NewtonIter = PartitionedIter;

/// Outcome of a partitioned Newton run: the generic [`PartitionedRun`]
/// plus the final dual iterate.
#[derive(Debug, Clone)]
pub struct PartitionedNewtonRun {
    pub records: Vec<NewtonIter>,
    /// Final stacked primal iterate (global `n × p`).
    pub thetas: Vec<f64>,
    /// Final stacked dual iterate (global `n × p`).
    pub lambda: Vec<f64>,
    /// Final modeled communication counters.
    pub comm: CommStats,
    /// Final cumulative cross-worker channel payloads.
    pub cross_messages: u64,
    /// Final cumulative cross-worker floats (×8 for bytes on the wire).
    pub cross_floats: u64,
}

/// Run SDD-Newton on `k` worker threads owning the partition's shards.
///
/// Each worker constructs a sharded [`SddNewton`] over a
/// [`NativeBackend`] and steps it against its shard exchange; the inner
/// `solver` (SDDM chain, Neumann, or lockstep CG) is shared read-only
/// across workers. The leader aggregates per-iteration metrics keyed by
/// iteration.
pub fn run_partitioned_newton(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    solver: &dyn LaplacianSolver,
    step: StepSize,
    iters: usize,
) -> PartitionedNewtonRun {
    static BACKEND: NativeBackend = NativeBackend;
    let p = problem.p;
    let final_lambda = Mutex::new(vec![0.0; g.n * p]);
    let run: PartitionedRun = run_partitioned_with(
        problem,
        g,
        part,
        iters,
        |_wid, owned| SddNewton::new_sharded(problem, &BACKEND, solver, step, owned),
        |_wid, owned, alg| {
            let mut fl = final_lambda.lock().unwrap();
            for (li, &u) in owned.iter().enumerate() {
                fl[u * p..(u + 1) * p].copy_from_slice(&alg.lambda()[li * p..(li + 1) * p]);
            }
        },
    );
    PartitionedNewtonRun {
        records: run.records,
        thetas: run.thetas,
        lambda: final_lambda.into_inner().unwrap(),
        comm: run.comm,
        cross_messages: run.cross_messages,
        cross_floats: run.cross_floats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::solvers::sddm_for_graph;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::net::CommGraph;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn partitioned_newton_smoke_matches_bulk() {
        let mut rng = Pcg64::new(701);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-5, &mut rng);
        let backend = crate::runtime::NativeBackend;
        let iters = 4;

        let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: iters, ..Default::default() },
        );

        let part = Partition::contiguous(10, 3);
        let out =
            run_partitioned_newton(&prob, &g, &part, &solver, StepSize::Fixed(1.0), iters);
        assert_eq!(out.records.len(), iters);
        assert_eq!(out.thetas, trace.final_thetas, "partitioned iterate drifted");
        assert_eq!(out.lambda, alg.lambda(), "partitioned dual drifted");
        assert_eq!(out.comm, *comm.stats(), "modeled comm drifted");
        for (r, ref_r) in out.records.iter().zip(&trace.records[1..]) {
            assert_eq!(r.objective, ref_r.objective, "iter {} metrics drifted", r.iter);
        }
        assert!(out.cross_messages > 0, "3 shards on a connected graph must talk");
    }

    /// The last bulk-only path is gone: the preprocessed SquaredChain
    /// solver — whose level supports exceed the graph edges — rides the
    /// partitioned transport through its registered overlay halo plans,
    /// bit-for-bit identical to the bulk path.
    #[test]
    fn partitioned_newton_with_preprocessed_solver_matches_bulk() {
        use crate::algorithms::solvers::squared_sddm_for_graph;
        let mut rng = Pcg64::new(703);
        let g = generate::random_connected(12, 26, &mut rng);
        let prob = datasets::synthetic_regression(12, 3, 180, 0.2, 0.05, &mut rng);
        let solver = squared_sddm_for_graph(&g, 1e-5, 0.0, &mut rng);
        let backend = crate::runtime::NativeBackend;
        let iters = 3;

        let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: iters, ..Default::default() },
        );
        assert_eq!(trace.algorithm, "Distributed SDD-Newton (preprocessed)");

        for part in [Partition::contiguous(12, 3), Partition::round_robin(12, 4)] {
            let out =
                run_partitioned_newton(&prob, &g, &part, &solver, StepSize::Fixed(1.0), iters);
            assert_eq!(out.thetas, trace.final_thetas, "k={}: overlay iterate drifted", part.k);
            assert_eq!(out.lambda, alg.lambda(), "k={}: overlay dual drifted", part.k);
            assert_eq!(out.comm, *comm.stats(), "k={}: overlay ledger drifted", part.k);
            assert!(out.cross_messages > 0, "sharded overlay runs must talk");
            assert!(out.cross_floats >= out.cross_messages, "floats cover payload rows");
        }
    }

    #[test]
    fn single_worker_is_the_bulk_path_with_zero_traffic() {
        let mut rng = Pcg64::new(702);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 3, 120, 0.2, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-4, &mut rng);
        let part = Partition::contiguous(8, 1);
        let out = run_partitioned_newton(&prob, &g, &part, &solver, StepSize::Fixed(1.0), 3);
        assert_eq!(out.cross_messages, 0);
        assert!(out.records[2].objective.is_finite());
    }
}
