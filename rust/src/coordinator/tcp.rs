//! Leader side of the TCP process transport: rendezvous, the all-reduce
//! service, and iteration-keyed metric aggregation over sockets.
//!
//! Mirrors [`super::baseline`]'s leader exactly — same iteration-keyed
//! gather discipline, same per-iteration aggregation (stack owned rows,
//! sum real cross counters, assert the modeled ledger is identical on
//! every worker) — but the workers are OS *processes* reached through
//! [`crate::net::tcp`] frames instead of scoped threads on channels. The
//! all-reduce service re-uses the in-process
//! [`run_reducer`](crate::net::partitioned::run_reducer) verbatim
//! (summation in global node order), which is what keeps TCP runs
//! bit-for-bit identical to both in-process transports.
//!
//! Robustness: every leader-side read has a timeout, so a worker process
//! that dies mid-run surfaces as a typed [`TcpError`] naming the rank and
//! the missing message — never a hang.

use super::PartitionedIter;
use crate::algorithms::ConsensusAlgorithm;
use crate::coordinator::Partition;
use crate::graph::{laplacian_csr, Graph};
use crate::net::hybrid::{local_links, HybridExchange, Placement};
use crate::net::partitioned::{build_shard_plans, run_reducer, ReduceMsg};
use crate::net::tcp::frame::{
    bytes_to_f64s, put_f64s, read_frame, split_u64s, write_frame, FrameKind, TcpError,
};
use crate::net::tcp::{TcpExchange, WorkerNetConfig, METRIC_COUNTERS};
use crate::net::CommStats;
use crate::problems::ConsensusProblem;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Outcome of a TCP partitioned run: the in-process
/// [`PartitionedRun`](super::PartitionedRun) ledger plus the observed
/// socket byte counters.
#[derive(Debug, Clone)]
pub struct TcpPartitionedRun {
    /// Per-iteration metric rows (identical semantics to the in-process
    /// partitioned runtime).
    pub records: Vec<PartitionedIter>,
    /// Final stacked iterate (global `n × p`).
    pub thetas: Vec<f64>,
    /// Final modeled communication counters.
    pub comm: CommStats,
    /// Final cumulative real cross-worker socket payloads.
    pub cross_messages: u64,
    /// Final cumulative real floats moved over the sockets.
    pub cross_floats: u64,
    /// Cross-worker payloads between co-located ranks (rode in-process
    /// channels on the hybrid transport; always 0 on pure TCP).
    pub intra_cross: u64,
    /// Floats moved between co-located ranks.
    pub intra_floats: u64,
    /// Cross-worker payloads between ranks on different hosts (the only
    /// ones that pay socket bytes on the hybrid transport).
    pub inter_cross: u64,
    /// Floats moved between ranks on different hosts.
    pub inter_floats: u64,
    /// Observed data-plane payload bytes — the wire-truth invariant is
    /// `payload_bytes == cross_floats × 8`.
    pub payload_bytes: u64,
    /// Observed fixed framing overhead (16 bytes per data frame),
    /// accounted separately from payloads.
    pub header_bytes: u64,
}

/// The leader's rendezvous listener, bound before workers launch so their
/// connect-with-retry loops have something to dial.
pub struct TcpLeader {
    listener: TcpListener,
    k: usize,
}

/// What the per-worker reader threads forward to the metric gather loop.
enum LeaderMsg {
    /// One worker's iteration snapshot: counters + owned θ rows.
    Metric { iter: usize, rank: usize, counters: Vec<u64>, thetas: Vec<f64> },
    /// A worker connection failed mid-run.
    WorkerFailed { rank: usize, err: TcpError },
}

impl TcpLeader {
    /// Bind the rendezvous listener for a `k`-worker pool. Use port 0 for
    /// an ephemeral loopback port (tests, single-machine runs) and read
    /// the actual address back with [`addr`](Self::addr).
    ///
    /// `k` must fit the frame header's `u16` rank field — a pool beyond
    /// 65 535 ranks would silently alias ranks on the wire, so it is
    /// rejected here with a typed error (the worker side enforces the
    /// same bound in `TcpExchange::connect`).
    pub fn bind(addr: &str, k: usize) -> Result<TcpLeader, TcpError> {
        if k == 0 || k > u16::MAX as usize {
            return Err(TcpError::Protocol {
                msg: format!("pool size {k} outside the u16 rank space 1..=65535"),
            });
        }
        let listener = TcpListener::bind(addr)
            .map_err(|err| TcpError::Io { ctx: format!("bind leader listener {addr}"), err })?;
        Ok(TcpLeader { listener, k })
    }

    /// The bound rendezvous address (what workers must `--connect` to).
    pub fn addr(&self) -> Result<SocketAddr, TcpError> {
        self.listener
            .local_addr()
            .map_err(|err| TcpError::Io { ctx: "leader local_addr".to_string(), err })
    }
}

/// Accept one rendezvous connection before `deadline`.
fn accept_one(listener: &TcpListener, deadline: Instant) -> Result<TcpStream, TcpError> {
    let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };
    listener.set_nonblocking(true).map_err(|e| io("leader set_nonblocking", e))?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                listener.set_nonblocking(false).map_err(|e| io("leader set_blocking", e))?;
                s.set_nonblocking(false).map_err(|e| io("worker socket set_blocking", e))?;
                return Ok(s);
            }
            Err(err) if err.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(TcpError::Timeout {
                        who: "leader".to_string(),
                        waiting_for: "worker rendezvous connections".to_string(),
                    });
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(err) => return Err(io("leader accept", err)),
        }
    }
}

/// Pump one worker's leader connection: route `ReduceUp` frames to the
/// reducer and `Metric` frames to the gather loop. Exits silently on a
/// clean close (the worker finished and dropped its exchange); anything
/// else is reported as a failure.
fn spawn_worker_reader(
    mut reader: BufReader<TcpStream>,
    rank: usize,
    red_tx: Sender<ReduceMsg>,
    met_tx: Sender<LeaderMsg>,
) {
    std::thread::spawn(move || {
        let ctx = format!("worker {rank}");
        loop {
            let frame = match read_frame(&mut reader, &ctx) {
                Ok(f) => f,
                Err(TcpError::PeerClosed { .. }) => return,
                Err(err) => {
                    let _ = met_tx.send(LeaderMsg::WorkerFailed { rank, err });
                    return;
                }
            };
            let fail = |err: TcpError, met_tx: &Sender<LeaderMsg>| {
                let _ = met_tx.send(LeaderMsg::WorkerFailed { rank, err });
            };
            match frame.kind {
                FrameKind::ReduceUp => match bytes_to_f64s(&frame.body, &ctx) {
                    Ok(vals) => {
                        if red_tx.send((rank, frame.tag, vals)).is_err() {
                            return; // reducer gone; run is over
                        }
                    }
                    Err(err) => {
                        fail(err, &met_tx);
                        return;
                    }
                },
                FrameKind::Metric => {
                    let decoded = split_u64s(&frame.body, METRIC_COUNTERS, &ctx)
                        .and_then(|(counters, tail)| {
                            bytes_to_f64s(tail, &ctx).map(|thetas| (counters, thetas))
                        });
                    match decoded {
                        Ok((counters, thetas)) => {
                            let msg = LeaderMsg::Metric {
                                iter: frame.tag as usize,
                                rank,
                                counters,
                                thetas,
                            };
                            if met_tx.send(msg).is_err() {
                                return;
                            }
                        }
                        Err(err) => {
                            fail(err, &met_tx);
                            return;
                        }
                    }
                }
                other => {
                    fail(
                        TcpError::Protocol {
                            msg: format!("unexpected {other:?} frame on the leader connection"),
                        },
                        &met_tx,
                    );
                    return;
                }
            }
        }
    });
}

/// Iteration-keyed metric gather over the socket inbox: the socket analogue
/// of [`super::gather_by_iteration`], with a timeout so a dead worker
/// surfaces as a typed error naming the missing iteration instead of a
/// hang.
fn gather_by_iteration_timeout(
    rx: &Receiver<LeaderMsg>,
    k: usize,
    iters: usize,
    timeout: Duration,
    mut per_iteration: impl FnMut(usize, Vec<LeaderMsg>) -> Result<(), TcpError>,
) -> Result<(), TcpError> {
    let mut early: Vec<Vec<LeaderMsg>> = (0..iters).map(|_| Vec::new()).collect();
    for it in 0..iters {
        let mut got: Vec<LeaderMsg> = std::mem::take(&mut early[it]);
        while got.len() < k {
            match rx.recv_timeout(timeout) {
                Ok(LeaderMsg::Metric { iter, rank, counters, thetas }) => {
                    if iter >= iters {
                        return Err(TcpError::Protocol {
                            msg: format!(
                                "worker {rank} reported metrics for iteration {iter}, \
                                 run has {iters}"
                            ),
                        });
                    }
                    let msg = LeaderMsg::Metric { iter, rank, counters, thetas };
                    if iter == it {
                        got.push(msg);
                    } else {
                        early[iter].push(msg);
                    }
                }
                Ok(LeaderMsg::WorkerFailed { rank, err }) => {
                    return Err(TcpError::Protocol {
                        msg: format!("worker {rank} died mid-run: {err}"),
                    });
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TcpError::Timeout {
                        who: "leader".to_string(),
                        waiting_for: format!("iteration {it} metrics ({}/{k} workers)", got.len()),
                    });
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(TcpError::PeerClosed {
                        who: "every worker metric connection".to_string(),
                    });
                }
            }
        }
        per_iteration(it, got)?;
    }
    Ok(())
}

/// Run the leader for a `k`-worker TCP pool: rendezvous, peer-table
/// broadcast, the all-reduce service, and iteration-keyed metric
/// aggregation. Returns once all `iters` iterations are accounted for.
///
/// `owned_of` must be the per-rank owned node lists of the same partition
/// the workers build (ascending, rank order) — it drives both the reduce
/// order and the θ stacking, exactly as in the in-process runtime.
pub fn run_leader(
    leader: TcpLeader,
    problem: &ConsensusProblem,
    owned_of: Vec<Vec<usize>>,
    iters: usize,
    timeout: Duration,
) -> Result<TcpPartitionedRun, TcpError> {
    run_leader_with_hosts(leader, problem, owned_of, iters, timeout, None)
}

/// [`run_leader`] with an optional per-rank host placement: when `hosts`
/// is given (hybrid deployments), the peer-table broadcast carries an
/// `ADDR\tHOST` column per line so every worker can cross-check its
/// hostfile against the placement the leader actually rendezvoused, and
/// route intra-host boundary traffic off the sockets.
pub fn run_leader_with_hosts(
    leader: TcpLeader,
    problem: &ConsensusProblem,
    owned_of: Vec<Vec<usize>>,
    iters: usize,
    timeout: Duration,
    hosts: Option<&[String]>,
) -> Result<TcpPartitionedRun, TcpError> {
    let k = leader.k;
    if owned_of.len() != k {
        return Err(TcpError::Protocol {
            msg: format!("owned lists cover {} ranks, pool has {k}", owned_of.len()),
        });
    }
    if hosts.is_some_and(|h| h.len() != k) {
        return Err(TcpError::Protocol {
            msg: format!(
                "host placement covers {} ranks, pool has {k}",
                hosts.map(|h| h.len()).unwrap_or(0)
            ),
        });
    }
    let n = problem.n();
    let p = problem.p;
    let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };

    // 1. Rendezvous: accept k connections, read each worker's Hello
    //    (rank + advertised mesh listener address).
    let deadline = Instant::now() + timeout;
    let mut conns: Vec<Option<(TcpStream, BufReader<TcpStream>)>> = (0..k).map(|_| None).collect();
    let mut mesh_addrs: Vec<String> = vec![String::new(); k];
    for _ in 0..k {
        let s = accept_one(&leader.listener, deadline)?;
        s.set_nodelay(true).map_err(|e| io("worker set_nodelay", e))?;
        s.set_read_timeout(Some(timeout)).map_err(|e| io("worker set timeout", e))?;
        let mut reader = BufReader::new(s.try_clone().map_err(|e| io("worker try_clone", e))?);
        let hello = read_frame(&mut reader, "worker rendezvous")?;
        if hello.kind != FrameKind::Hello {
            return Err(TcpError::Protocol {
                msg: format!("expected a rendezvous Hello, got a {:?} frame", hello.kind),
            });
        }
        let rank = hello.src as usize;
        if rank >= k {
            return Err(TcpError::Protocol { msg: format!("Hello from out-of-range rank {rank}") });
        }
        if conns[rank].is_some() {
            return Err(TcpError::Protocol { msg: format!("duplicate Hello from rank {rank}") });
        }
        mesh_addrs[rank] = String::from_utf8(hello.body)
            .map_err(|_| TcpError::BadFrame { msg: "mesh address is not UTF-8".to_string() })?;
        conns[rank] = Some((s, reader));
    }

    // 2. Broadcast the peer table; every mesh listener is already bound
    //    (each worker binds before saying Hello). With a placement, each
    //    line is `ADDR\tHOST` (plain TCP workers strip the host column).
    let table = match hosts {
        Some(h) => mesh_addrs
            .iter()
            .zip(h)
            .map(|(a, host)| format!("{a}\t{host}"))
            .collect::<Vec<String>>()
            .join("\n"),
        None => mesh_addrs.join("\n"),
    };
    for slot in conns.iter_mut() {
        let (s, _) = slot.as_mut().ok_or_else(|| TcpError::Protocol {
            msg: "rendezvous bookkeeping lost a worker".to_string(),
        })?;
        write_frame(s, FrameKind::PeerTable, 0, 0, table.as_bytes(), "worker")?;
    }

    // 3. Services: per-worker reader threads route ReduceUp → the shared
    //    in-process reducer and Metric → the gather loop; per-worker
    //    writer threads ship reduce totals back down, sequence-tagged in
    //    completion order (a worker only issues reduce s+1 after
    //    receiving total s, so completion order is the sequence order).
    let (red_tx, red_rx) = channel::<ReduceMsg>();
    let (met_tx, met_rx) = channel::<LeaderMsg>();
    let mut down_txs: Vec<Sender<Vec<f64>>> = Vec::with_capacity(k);
    let mut records: Vec<PartitionedIter> = Vec::with_capacity(iters);
    let mut thetas = vec![0.0; n * p];
    let mut payload_total = 0u64;
    let mut header_total = 0u64;
    let mut intra_cross_total = 0u64;
    let mut intra_floats_total = 0u64;
    let mut inter_cross_total = 0u64;
    let mut inter_floats_total = 0u64;

    let result: Result<(), TcpError> = std::thread::scope(|scope| {
        for (rank, slot) in conns.into_iter().enumerate() {
            let (stream, reader) = slot.ok_or_else(|| TcpError::Protocol {
                msg: "rendezvous bookkeeping lost a worker".to_string(),
            })?;
            spawn_worker_reader(reader, rank, red_tx.clone(), met_tx.clone());
            let (tx, rx) = channel::<Vec<f64>>();
            down_txs.push(tx);
            scope.spawn(move || {
                let mut stream = stream;
                let mut seq = 0u64;
                for total in rx.iter() {
                    seq += 1;
                    let mut body = Vec::with_capacity(total.len() * 8);
                    put_f64s(&mut body, &total);
                    let sent =
                        write_frame(&mut stream, FrameKind::ReduceDown, 0, seq, &body, "worker");
                    if sent.is_err() {
                        return; // the reader thread reports the failure
                    }
                }
            });
        }
        drop(red_tx);
        drop(met_tx);
        {
            let owned_of = owned_of.clone();
            let txs = down_txs;
            scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
        }

        // 4. Metric aggregation, identical to the in-process leader.
        gather_by_iteration_timeout(&met_rx, k, iters, timeout, |it, got| {
            let mut cross_total = 0u64;
            let mut cross_floats_total = 0u64;
            let mut intra_cross = 0u64;
            let mut intra_floats = 0u64;
            let mut inter_cross = 0u64;
            let mut inter_floats = 0u64;
            let mut payload = 0u64;
            let mut header = 0u64;
            let mut comm: Option<CommStats> = None;
            for msg in got {
                let LeaderMsg::Metric { rank, counters, thetas: snapshot, .. } = msg else {
                    continue; // unreachable: the gather loop only parks metrics
                };
                let owned = &owned_of[rank];
                if snapshot.len() != owned.len() * p {
                    return Err(TcpError::Protocol {
                        msg: format!(
                            "worker {rank} metric snapshot has {} floats, expected {}",
                            snapshot.len(),
                            owned.len() * p
                        ),
                    });
                }
                for (li, &u) in owned.iter().enumerate() {
                    thetas[u * p..(u + 1) * p].copy_from_slice(&snapshot[li * p..(li + 1) * p]);
                }
                cross_total += counters[0];
                cross_floats_total += counters[1];
                intra_cross += counters[2];
                intra_floats += counters[3];
                inter_cross += counters[4];
                inter_floats += counters[5];
                payload += counters[6];
                header += counters[7];
                let stats = CommStats {
                    messages: counters[8],
                    floats: counters[9],
                    rounds: counters[10],
                    allreduces: counters[11],
                    skipped_rounds: counters[12],
                    saved_messages: counters[13],
                    saved_floats: counters[14],
                };
                // Every worker tallies the identical modeled ledger.
                if comm.is_some_and(|c| c != stats) {
                    return Err(TcpError::Protocol {
                        msg: format!("worker {rank} modeled ledger drifted from the pool"),
                    });
                }
                comm = Some(stats);
            }
            payload_total = payload;
            header_total = header;
            intra_cross_total = intra_cross;
            intra_floats_total = intra_floats;
            inter_cross_total = inter_cross;
            inter_floats_total = inter_floats;
            records.push(PartitionedIter {
                iter: it + 1,
                objective: problem.objective(&thetas),
                consensus_error: problem.consensus_error(&thetas),
                cross_messages: cross_total,
                cross_floats: cross_floats_total,
                comm: comm.unwrap_or_default(),
            });
            Ok(())
        })
    });
    result?;

    let comm = records.last().map(|r| r.comm).unwrap_or_default();
    let cross_messages = records.last().map(|r| r.cross_messages).unwrap_or(0);
    let cross_floats = records.last().map(|r| r.cross_floats).unwrap_or(0);
    Ok(TcpPartitionedRun {
        records,
        thetas,
        comm,
        cross_messages,
        cross_floats,
        intra_cross: intra_cross_total,
        intra_floats: intra_floats_total,
        inter_cross: inter_cross_total,
        inter_floats: inter_floats_total,
        payload_bytes: payload_total,
        header_bytes: header_total,
    })
}

/// Worker-process driver: build the shard plan for `net.rank`, join the
/// pool over TCP, and drive the shard-local algorithm for `iters`
/// iterations, reporting each iteration's metrics to the leader. The
/// graph/partition/problem must be rebuilt identically on every rank
/// (deterministic seeds — see `harness::deploy`).
pub fn run_tcp_worker<'a>(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    iters: usize,
    net: &WorkerNetConfig,
    make_alg: &(dyn Fn(Vec<usize>) -> Box<dyn ConsensusAlgorithm + 'a> + Sync),
) -> Result<(), TcpError> {
    if part.k != net.k {
        return Err(TcpError::Protocol {
            msg: format!("partition has {} shards, pool has {}", part.k, net.k),
        });
    }
    let lap = laplacian_csr(g);
    let mut plans = build_shard_plans(g, part);
    let plan = plans.swap_remove(net.rank);
    let mut exch = TcpExchange::connect(net, g.n, g.m(), lap, plan)?;
    let mut alg = make_alg(exch.owned().to_vec());
    for it in 0..iters {
        alg.step(problem, &mut exch);
        exch.send_metrics(it as u64, alg.thetas())?;
    }
    Ok(())
}

/// Per-host configuration for [`run_hybrid_host`]: which hostfile placement
/// this process participates in, which named host it is, and where the
/// leader rendezvous listens.
pub struct HybridHostConfig<'h> {
    /// Rank→host placement parsed from the hostfile. Every participating
    /// process (and the leader) must be started from the same hostfile.
    pub placement: &'h Placement,
    /// The hostfile name this process runs as; its ranks are launched here.
    pub host: &'h str,
    /// Leader rendezvous address (`host:port`), as for the plain TCP pool.
    pub leader_addr: &'h str,
    /// Number of algorithm iterations to drive on every local rank.
    pub iters: usize,
}

/// Host-process driver for the hybrid transport: launch one worker thread
/// per rank the hostfile places on `cfg.host`, wiring co-located ranks
/// through in-process channels and cross-host edges over TCP (see
/// [`crate::net::hybrid`]). The Laplacian and shard plans are built once
/// and shared across the local ranks; the graph/partition/problem must be
/// rebuilt identically on every host (deterministic seeds — see
/// `harness::deploy`). The first worker error wins; remaining local ranks
/// are joined (their receives time out) before it is returned.
pub fn run_hybrid_host<'a>(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    cfg: &HybridHostConfig<'_>,
    make_alg: &(dyn Fn(Vec<usize>) -> Box<dyn ConsensusAlgorithm + 'a> + Sync),
) -> Result<(), TcpError> {
    let k = cfg.placement.k();
    if part.k != k {
        return Err(TcpError::Protocol {
            msg: format!("partition has {} shards, hostfile places {}", part.k, k),
        });
    }
    if cfg.placement.ranks_on(cfg.host).is_empty() {
        return Err(TcpError::Protocol {
            msg: format!("hostfile places no ranks on host {:?}", cfg.host),
        });
    }
    let lap = Arc::new(laplacian_csr(g));
    let plans = build_shard_plans(g, part);
    let links = local_links(cfg.placement, cfg.host);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for link in links {
            let rank = link.rank();
            let plan = plans[rank].clone();
            let lap = Arc::clone(&lap);
            let net = WorkerNetConfig::from_env(rank, k, cfg.leader_addr);
            handles.push(scope.spawn(move || -> Result<(), TcpError> {
                let mut exch =
                    HybridExchange::connect(&net, cfg.placement, link, g.n, g.m(), lap, plan)?;
                let mut alg = make_alg(exch.owned().to_vec());
                for it in 0..cfg.iters {
                    alg.step(problem, &mut exch);
                    exch.send_metrics(it as u64, alg.thetas())?;
                }
                Ok(())
            }));
        }
        let mut first_err = None;
        for handle in handles {
            match handle.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(TcpError::Protocol {
                            msg: "a hybrid worker thread panicked".to_string(),
                        });
                    }
                }
            }
        }
        first_err.map_or(Ok(()), Err)
    })
}
