//! Partitioned multi-threaded execution: graph nodes are divided among
//! `k` worker OS threads (as the paper divides 100 nodes over 8 Matlab
//! pool workers). Cross-worker edges exchange payloads over channels;
//! intra-worker edges are local memory. The leader thread aggregates
//! per-iteration metrics.
//!
//! The diffusion-style algorithms (distributed gradients here) map
//! directly onto this runtime; the result matches the bulk-synchronous
//! `algorithms::gradient::DistGradient` to floating-point tolerance (the
//! hand-rolled mixing sums neighbor terms in a different order than the
//! CSR operator the Exchange-generic algorithm applies). The *bit-exact*
//! sharded runtime for every algorithm is
//! [`super::baseline::run_partitioned_baseline`]; this module remains as
//! the minimal, dependency-free reference for the leader's
//! iteration-keyed metric aggregation discipline.

use super::partition::Partition;
use crate::algorithms::metropolis_weights;
use crate::graph::Graph;
use crate::problems::ConsensusProblem;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Per-iteration metric row from a partitioned run.
#[derive(Debug, Clone)]
pub struct WorkerIter {
    pub iter: usize,
    pub objective: f64,
    pub consensus_error: f64,
    /// Cross-worker messages so far (the MPI traffic of the deployment).
    pub cross_messages: u64,
}

/// Run distributed gradient descent on `k` worker threads.
/// Returns per-iteration metrics and the final stacked iterate.
pub fn run_partitioned_gradient(
    problem: &ConsensusProblem,
    g: &Graph,
    part: &Partition,
    alpha: f64,
    iters: usize,
) -> (Vec<WorkerIter>, Vec<f64>) {
    let n = g.n;
    let p = problem.p;
    let k = part.k;
    let weights = metropolis_weights(g);

    // Channels: worker→worker payload fan-in, worker→leader metrics.
    // Payloads carry their iteration number: a fast peer may run ahead, so
    // receivers buffer future-iteration payloads instead of consuming them
    // as the current round's.
    type Payload = (usize, Vec<(usize, Vec<f64>)>); // (iter, [(node, theta)])
    let mut to_worker_tx: Vec<Sender<Payload>> = Vec::with_capacity(k);
    let mut to_worker_rx: Vec<Option<Receiver<Payload>>> = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel();
        to_worker_tx.push(tx);
        to_worker_rx.push(Some(rx));
    }
    // Leader metrics carry the iteration tag: the leader aggregates keyed
    // on it, so a fast worker's iteration t+1 snapshot can never be
    // blended into iteration t's objective/consensus metrics.
    type LeaderMsg = (usize, Vec<(usize, Vec<f64>)>, u64); // (iter, [(node, theta)], cross)
    let (leader_tx, leader_rx) = channel::<LeaderMsg>();

    // Which peers each worker must hear from, and which boundary nodes it
    // must send where — precomputed from the cut edges.
    let mut send_plan: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); k]; // w -> [(peer, nodes)]
    let mut recv_count: Vec<usize> = vec![0; k];
    for w in 0..k {
        let mut per_peer: std::collections::BTreeMap<usize, std::collections::BTreeSet<usize>> =
            Default::default();
        for &u in &part.nodes_of(w) {
            for &v in g.neighbors(u) {
                let pw = part.assignment[v];
                if pw != w {
                    per_peer.entry(pw).or_default().insert(u);
                }
            }
        }
        for (peer, nodes) in per_peer {
            send_plan[w].push((peer, nodes.into_iter().collect()));
        }
    }
    for w in 0..k {
        recv_count[w] = (0..k)
            .filter(|&o| o != w && send_plan[o].iter().any(|(peer, _)| *peer == w))
            .count();
    }

    let final_thetas = std::sync::Mutex::new(vec![0.0; n * p]);
    let records = std::sync::Mutex::new(Vec::<WorkerIter>::new());

    // Divide the process-wide thread budget among the k workers for the
    // per-node local gradient evaluation (the compute hot spot of each
    // BSP superstep); every worker keeps at least its own thread.
    let inner_threads = (crate::par::threads() / k.max(1)).max(1);

    std::thread::scope(|scope| {
        for w in 0..k {
            let my_nodes = part.nodes_of(w);
            let my_rx = to_worker_rx[w].take().unwrap();
            let peer_tx: Vec<(usize, Sender<Payload>)> = send_plan[w]
                .iter()
                .map(|(peer, _)| (*peer, to_worker_tx[*peer].clone()))
                .collect();
            let send_nodes: Vec<(usize, Vec<usize>)> = send_plan[w].clone();
            let leader = leader_tx.clone();
            let weights = &weights;
            let expect_from = recv_count[w];
            let final_thetas = &final_thetas;
            scope.spawn(move || {
                // Worker-local state: θ for owned nodes + cache of remote
                // neighbor values.
                let mut theta: std::collections::HashMap<usize, Vec<f64>> =
                    my_nodes.iter().map(|&u| (u, vec![0.0; p])).collect();
                let mut remote: std::collections::HashMap<usize, Vec<f64>> = Default::default();
                let mut future: Vec<Payload> = Vec::new();
                let mut cross_msgs: u64 = 0;
                for it in 0..iters {
                    // 1. Ship boundary values to each peer, tagged with `it`.
                    for ((peer, tx), (_, nodes)) in peer_tx.iter().zip(&send_nodes) {
                        let _ = peer;
                        let values: Vec<(usize, Vec<f64>)> =
                            nodes.iter().map(|&u| (u, theta[&u].clone())).collect();
                        cross_msgs += values.len() as u64;
                        tx.send((it, values)).expect("peer worker died");
                    }
                    // 2. Collect this iteration's payload from each
                    //    in-neighbor worker, buffering any that arrive early.
                    let mut got = 0usize;
                    future.retain(|(pit, values)| {
                        if *pit == it {
                            for (u, t) in values {
                                remote.insert(*u, t.clone());
                            }
                            got += 1;
                            false
                        } else {
                            true
                        }
                    });
                    while got < expect_from {
                        let (pit, values) = my_rx.recv().expect("peer worker died");
                        if pit == it {
                            for (u, t) in values {
                                remote.insert(u, t);
                            }
                            got += 1;
                        } else {
                            future.push((pit, values));
                        }
                    }
                    // 3. Per-node local gradients, fanned out over this
                    //    worker's slice of the thread budget (the oracles
                    //    are independent across nodes), then sequential
                    //    mixing with the same arithmetic as before.
                    let grads: Vec<Vec<f64>> =
                        crate::par::par_map(&my_nodes, inner_threads, |&u| {
                            problem.locals[u].gradient(&theta[&u])
                        });
                    let mut next: std::collections::HashMap<usize, Vec<f64>> =
                        std::collections::HashMap::with_capacity(my_nodes.len());
                    for (ui, &u) in my_nodes.iter().enumerate() {
                        let mut mixed = vec![0.0; p];
                        for &(j, wij) in &weights[u] {
                            let tj = if j == u {
                                &theta[&u]
                            } else if let Some(t) = theta.get(&j) {
                                t
                            } else {
                                remote.get(&j).expect("missing remote neighbor value")
                            };
                            for r in 0..p {
                                mixed[r] += wij * tj[r];
                            }
                        }
                        let grad = &grads[ui];
                        for r in 0..p {
                            mixed[r] -= alpha * grad[r];
                        }
                        next.insert(u, mixed);
                    }
                    theta = next;
                    // 4. Report owned states to the leader (metrics only),
                    //    tagged with the iteration they belong to.
                    let snapshot: Vec<(usize, Vec<f64>)> =
                        my_nodes.iter().map(|&u| (u, theta[&u].clone())).collect();
                    leader.send((it, snapshot, cross_msgs)).expect("leader died");
                }
                // Final state.
                let mut ft = final_thetas.lock().unwrap();
                for (&u, t) in &theta {
                    ft[u * p..(u + 1) * p].copy_from_slice(t);
                }
            });
        }
        drop(leader_tx);

        // Leader: per iteration, gather the k snapshots *tagged with that
        // iteration* and compute metrics (see `gather_by_iteration` —
        // snapshots from workers that have raced ahead are buffered for
        // their own iteration instead of being blended into the current
        // one).
        let mut stacked = vec![0.0; n * p];
        super::gather_by_iteration(&leader_rx, k, iters, |m: &LeaderMsg| m.0, |it, got| {
            let mut cross_total = 0u64;
            for (_, snapshot, cross) in got {
                cross_total += cross;
                for (u, t) in snapshot {
                    stacked[u * p..(u + 1) * p].copy_from_slice(&t);
                }
            }
            records.lock().unwrap().push(WorkerIter {
                iter: it + 1,
                objective: problem.objective(&stacked),
                consensus_error: problem.consensus_error(&stacked),
                cross_messages: cross_total,
            });
        });
    });

    (records.into_inner().unwrap(), final_thetas.into_inner().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::gradient::{DistGradient, GradSchedule};
    use crate::algorithms::ConsensusAlgorithm;
    use crate::coordinator::partition::Partition;
    use crate::graph::generate;
    use crate::net::CommGraph;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn partitioned_matches_bulk_synchronous_exactly() {
        let mut rng = Pcg64::new(501);
        let g = generate::random_connected(12, 26, &mut rng);
        let prob = datasets::synthetic_regression(12, 4, 240, 0.2, 0.05, &mut rng);
        let alpha = 1e-4;
        let iters = 15;

        // Bulk-synchronous reference.
        let mut reference = DistGradient::new(&prob, &g, GradSchedule::Constant(alpha));
        let mut comm = CommGraph::new(&g);
        for _ in 0..iters {
            reference.step(&prob, &mut comm);
        }

        for part in [
            Partition::contiguous(12, 3),
            Partition::round_robin(12, 4),
            Partition::bfs_blocks(&g, 2),
        ] {
            let (records, thetas) = run_partitioned_gradient(&prob, &g, &part, alpha, iters);
            assert_eq!(records.len(), iters);
            for (a, b) in thetas.iter().zip(reference.thetas()) {
                assert!((a - b).abs() < 1e-12, "partitioned deviates: {a} vs {b}");
            }
        }
    }

    #[test]
    fn cross_messages_depend_on_cut() {
        let mut rng = Pcg64::new(502);
        let g = generate::grid(4, 6);
        let prob = datasets::synthetic_regression(24, 3, 240, 0.2, 0.05, &mut rng);
        let bfs = Partition::bfs_blocks(&g, 3);
        let rr = Partition::round_robin(24, 3);
        let (rec_bfs, _) = run_partitioned_gradient(&prob, &g, &bfs, 1e-4, 3);
        let (rec_rr, _) = run_partitioned_gradient(&prob, &g, &rr, 1e-4, 3);
        assert!(
            rec_bfs.last().unwrap().cross_messages <= rec_rr.last().unwrap().cross_messages,
            "locality partition should cut MPI traffic"
        );
    }

    #[test]
    fn single_worker_degenerates_to_sequential() {
        let mut rng = Pcg64::new(503);
        let g = generate::cycle(8);
        let prob = datasets::synthetic_regression(8, 3, 80, 0.2, 0.05, &mut rng);
        let part = Partition::contiguous(8, 1);
        let (records, _) = run_partitioned_gradient(&prob, &g, &part, 1e-4, 5);
        assert_eq!(records.last().unwrap().cross_messages, 0);
    }

    /// Regression for the leader metrics race: worker 0 owns an isolated
    /// component with a trivial local problem, so it has no peers to wait
    /// for and blasts all its iteration snapshots at the leader
    /// immediately, while worker 1 grinds through real per-node work. A
    /// leader that pops k snapshots per iteration *by count* blends worker
    /// 0's iteration t+1 (even t+14) state into iteration t's metrics;
    /// keyed on the iteration tag, every per-iteration objective must
    /// match the bulk-synchronous reference exactly.
    #[test]
    fn fast_worker_cannot_skew_leader_metrics() {
        let mut rng = Pcg64::new(504);
        // Component A: the single node 0 (isolated). Component B: a dense
        // clique over nodes 1..=8 with heavy local objectives.
        let n = 9;
        let mut edges = Vec::new();
        for u in 1..n {
            for v in (u + 1)..n {
                edges.push((u, v));
            }
        }
        let g = Graph::from_edges(n, edges);
        let prob = datasets::synthetic_regression(n, 6, 1800, 0.2, 0.05, &mut rng);
        let alpha = 1e-4;
        let iters = 15;

        // Bulk-synchronous per-iteration reference.
        let mut reference = DistGradient::new(&prob, &g, GradSchedule::Constant(alpha));
        let mut comm = CommGraph::new(&g);
        let mut ref_objectives = Vec::with_capacity(iters);
        for _ in 0..iters {
            reference.step(&prob, &mut comm);
            ref_objectives.push(prob.objective(reference.thetas()));
        }

        // Worker 0 = {node 0} (free to race), worker 1 = the clique.
        let assignment: Vec<usize> = (0..n).map(|u| usize::from(u != 0)).collect();
        let part = Partition { assignment, k: 2 };
        let (records, _) = run_partitioned_gradient(&prob, &g, &part, alpha, iters);
        assert_eq!(records.len(), iters);
        for (rec, expect) in records.iter().zip(&ref_objectives) {
            let scale = expect.abs().max(1.0);
            assert!(
                (rec.objective - expect).abs() <= 1e-12 * scale,
                "iter {}: leader blended a racing snapshot ({} vs {expect})",
                rec.iter,
                rec.objective
            );
        }
    }
}
