//! Node→worker partitioning (the paper assigns its 100 graph nodes evenly
//! to 8 Matlab pool workers; communication between co-located nodes is
//! free, cross-worker edges ride MatlabMPI).

use crate::graph::Graph;

/// A mapping of graph nodes onto `k` workers.
#[derive(Debug, Clone)]
pub struct Partition {
    /// `assignment[node] = worker id` in `0..k`.
    pub assignment: Vec<usize>,
    pub k: usize,
}

impl Partition {
    /// Contiguous blocks (node order).
    pub fn contiguous(n: usize, k: usize) -> Partition {
        assert!(k >= 1);
        let base = n / k;
        let extra = n % k;
        let mut assignment = Vec::with_capacity(n);
        for w in 0..k {
            let cnt = base + usize::from(w < extra);
            assignment.extend(std::iter::repeat(w).take(cnt));
        }
        Partition { assignment, k }
    }

    /// Round-robin.
    pub fn round_robin(n: usize, k: usize) -> Partition {
        assert!(k >= 1);
        Partition { assignment: (0..n).map(|i| i % k).collect(), k }
    }

    /// Greedy edge-locality partition: BFS order chunked into blocks, which
    /// keeps neighborhoods co-located on typical sparse graphs.
    pub fn bfs_blocks(g: &Graph, k: usize) -> Partition {
        assert!(k >= 1);
        let n = g.n;
        let mut order = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        for s in 0..n {
            if seen[s] {
                continue;
            }
            let mut q = std::collections::VecDeque::from([s]);
            seen[s] = true;
            while let Some(u) = q.pop_front() {
                order.push(u);
                for &v in g.neighbors(u) {
                    if !seen[v] {
                        seen[v] = true;
                        q.push_back(v);
                    }
                }
            }
        }
        let mut assignment = vec![0; n];
        let base = n / k;
        let extra = n % k;
        let mut idx = 0;
        for w in 0..k {
            let cnt = base + usize::from(w < extra);
            for _ in 0..cnt {
                assignment[order[idx]] = w;
                idx += 1;
            }
        }
        Partition { assignment, k }
    }

    /// Nodes owned by worker `w`.
    pub fn nodes_of(&self, w: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == w)
            .map(|(i, _)| i)
            .collect()
    }

    /// Number of graph edges crossing worker boundaries (the MPI traffic).
    pub fn cut_edges(&self, g: &Graph) -> usize {
        g.edges
            .iter()
            .filter(|&&(u, v)| self.assignment[u] != self.assignment[v])
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::util::Pcg64;

    #[test]
    fn contiguous_balanced() {
        let p = Partition::contiguous(10, 3);
        assert_eq!(p.nodes_of(0).len(), 4);
        assert_eq!(p.nodes_of(1).len(), 3);
        assert_eq!(p.nodes_of(2).len(), 3);
        assert_eq!(p.assignment.len(), 10);
    }

    #[test]
    fn round_robin_covers_all() {
        let p = Partition::round_robin(7, 2);
        assert_eq!(p.nodes_of(0), vec![0, 2, 4, 6]);
        assert_eq!(p.nodes_of(1), vec![1, 3, 5]);
    }

    #[test]
    fn bfs_blocks_cut_no_worse_than_random_on_grid() {
        let g = generate::grid(6, 6);
        let bfs = Partition::bfs_blocks(&g, 4);
        let rr = Partition::round_robin(36, 4);
        assert!(
            bfs.cut_edges(&g) <= rr.cut_edges(&g),
            "bfs {} vs rr {}",
            bfs.cut_edges(&g),
            rr.cut_edges(&g)
        );
        let mut rng = Pcg64::new(1);
        let _ = rng.next_u64();
    }

    #[test]
    fn all_partitions_are_total() {
        let g = generate::grid(4, 5);
        for p in [
            Partition::contiguous(20, 3),
            Partition::round_robin(20, 3),
            Partition::bfs_blocks(&g, 3),
        ] {
            let total: usize = (0..3).map(|w| p.nodes_of(w).len()).sum();
            assert_eq!(total, 20);
            assert!(p.assignment.iter().all(|&a| a < 3));
        }
    }
}
