//! # sddnewton — Distributed SDD-Newton for Large-Scale Consensus Optimization
//!
//! Reproduction of Tutunov, Bou Ammar & Jadbabaie, *"A Distributed Newton
//! Method for Large Scale Consensus Optimization"* (2016).
//!
//! Three-layer architecture:
//! - **L3 (this crate)**: the distributed coordinator — graph substrate,
//!   message-passing simulation with communication accounting, the
//!   Spielman–Peng/Tutunov SDDM solver, the SDD-Newton algorithm and all
//!   five baselines (ADMM, distributed gradients, distributed averaging,
//!   Network Newton-K, ADD-Newton), experiment harness.
//! - **L2 (python/compile/model.py)**: per-node local computations (primal
//!   recovery, local Hessian application) written in JAX and AOT-lowered to
//!   HLO text at build time.
//! - **L1 (python/compile/kernels/)**: Pallas kernels for the per-node
//!   compute hot-spot (logistic grad/Hessian assembly, batched quadratic
//!   forms), lowered inside the L2 modules.
//!
//! Python never runs on the request path: the rust binary loads the AOT
//! artifacts via PJRT (`runtime`) and falls back to the native `linalg`
//! implementation when an artifact for the requested shape is absent.

// Index-heavy numerical kernels read closer to the paper's math with
// explicit loops; keep clippy's style lints from fighting that.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]
#![allow(clippy::manual_div_ceil)]
#![allow(clippy::type_complexity)]

pub mod util;
pub mod par;
pub mod linalg;
pub mod graph;
pub mod net;
pub mod sddm;
pub mod problems;
pub mod dcp;
pub mod algorithms;
pub mod runtime;
pub mod coordinator;
pub mod config;
pub mod harness;
pub mod benchkit;
