//! Cholesky factorization and SPD solves.
//!
//! Used for the per-node `p×p` local solves in the native (non-PJRT)
//! compute path, and as the oracle the AOT artifacts are verified against.

use super::matrix::Matrix;

/// Lower-triangular Cholesky factor of an SPD matrix.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Matrix,
}

/// Errors from factorization.
#[derive(Debug, Clone, PartialEq)]
pub enum CholeskyError {
    /// Matrix not positive definite (or badly conditioned) at pivot `i`.
    NotPositiveDefinite(usize, f64),
    /// Matrix not square.
    NotSquare(usize, usize),
}

impl std::fmt::Display for CholeskyError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholeskyError::NotPositiveDefinite(i, v) => {
                write!(f, "matrix not positive definite at pivot {i} (value {v})")
            }
            CholeskyError::NotSquare(r, c) => write!(f, "matrix not square: {r}x{c}"),
        }
    }
}

impl std::error::Error for CholeskyError {}

impl Cholesky {
    /// Factor `a = L Lᵀ`.
    pub fn factor(a: &Matrix) -> Result<Cholesky, CholeskyError> {
        if a.rows != a.cols {
            return Err(CholeskyError::NotSquare(a.rows, a.cols));
        }
        let n = a.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(CholeskyError::NotPositiveDefinite(i, sum));
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Solve `A x = b` using the factorization.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        // Forward: L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= self.l[(i, k)] * y[k];
            }
            y[i] = sum / self.l[(i, i)];
        }
        // Backward: Lᵀ x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in (i + 1)..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
        x
    }

    /// Solve for several right-hand sides (columns of `B`, returned as
    /// a matrix of the same shape).
    pub fn solve_mat(&self, b: &Matrix) -> Matrix {
        let n = self.l.rows;
        assert_eq!(b.rows, n);
        let mut out = Matrix::zeros(n, b.cols);
        let mut col = vec![0.0; n];
        for j in 0..b.cols {
            for i in 0..n {
                col[i] = b[(i, j)];
            }
            let x = self.solve(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// log(det A) = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Access the factor.
    pub fn l(&self) -> &Matrix {
        &self.l
    }
}

/// Convenience: solve SPD system from scratch.
pub fn spd_solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>, CholeskyError> {
    Ok(Cholesky::factor(a)?.solve(b))
}

/// Inverse of an SPD matrix (used only off the hot path).
pub fn spd_inverse(a: &Matrix) -> Result<Matrix, CholeskyError> {
    let ch = Cholesky::factor(a)?;
    Ok(ch.solve_mat(&Matrix::eye(a.rows)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::new(seed);
        let mut b = Matrix::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        // BBᵀ + n·I is SPD.
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn solve_roundtrip() {
        let a = random_spd(12, 1);
        let mut rng = Pcg64::new(2);
        let x_true = rng.normal_vec(12);
        let b = a.matvec(&x_true);
        let x = spd_solve(&a, &b).unwrap();
        for (xs, xt) in x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-9, "{xs} vs {xt}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn rejects_nonsquare() {
        let a = Matrix::zeros(2, 3);
        assert!(matches!(Cholesky::factor(&a), Err(CholeskyError::NotSquare(2, 3))));
    }

    #[test]
    fn inverse_is_inverse() {
        let a = random_spd(8, 3);
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        assert!(prod.max_abs_diff(&Matrix::eye(8)) < 1e-9);
    }

    #[test]
    fn log_det_identity_zero() {
        let ch = Cholesky::factor(&Matrix::eye(5)).unwrap();
        assert!(ch.log_det().abs() < 1e-14);
    }

    #[test]
    fn solve_mat_matches_solve() {
        let a = random_spd(6, 4);
        let ch = Cholesky::factor(&a).unwrap();
        let b = Matrix::from_rows(6, 2, (0..12).map(|i| i as f64).collect());
        let xm = ch.solve_mat(&b);
        for j in 0..2 {
            let col: Vec<f64> = (0..6).map(|i| b[(i, j)]).collect();
            let x = ch.solve(&col);
            for i in 0..6 {
                assert!((xm[(i, j)] - x[i]).abs() < 1e-12);
            }
        }
    }
}
