//! Vector kernels over `&[f64]` slices.
//!
//! Free functions (not a newtype) so algorithm code reads like the paper's
//! math and interoperates with raw buffers handed to PJRT.

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// y += alpha * x.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// x *= alpha.
#[inline]
pub fn scale(x: &mut [f64], alpha: f64) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Elementwise a - b into a new vector.
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Elementwise a + b into a new vector.
pub fn add(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x + y).collect()
}

/// Mean of the entries.
pub fn mean(a: &[f64]) -> f64 {
    if a.is_empty() {
        0.0
    } else {
        a.iter().sum::<f64>() / a.len() as f64
    }
}

/// Subtract the mean from every entry (projection onto 1-perp, the
/// range space of a connected graph Laplacian).
pub fn center(a: &mut [f64]) {
    let m = mean(a);
    for v in a.iter_mut() {
        *v -= m;
    }
}

/// Maximum absolute entry.
pub fn max_abs(a: &[f64]) -> f64 {
    a.iter().fold(0.0, |m, &x| m.max(x.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_norm() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn axpy_scale() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, 2.0], &mut y);
        assert_eq!(y, vec![3.0, 5.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.5]);
    }

    #[test]
    fn center_removes_mean() {
        let mut v = vec![1.0, 2.0, 3.0, 6.0];
        center(&mut v);
        assert!(mean(&v).abs() < 1e-15);
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = vec![1.0, -2.0];
        let b = vec![0.5, 4.0];
        assert_eq!(sub(&add(&a, &b), &b), a);
    }

    #[test]
    fn max_abs_works() {
        assert_eq!(max_abs(&[-3.0, 2.0]), 3.0);
        assert_eq!(max_abs(&[]), 0.0);
    }
}
