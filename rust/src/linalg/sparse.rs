//! CSR sparse matrix — the storage format for graph Laplacians and the
//! SDDM chain levels. Matvec here is the L3 hot path of the SDD solver.

use super::cg::LinOp;
use super::matrix::Matrix;

/// RHS-column block width of [`Csr::row_matvec_multi`] — sized so the
/// accumulator block (8 × f64 = one cache line) stays in registers.
const RHS_BLOCK: usize = 8;

/// Compressed sparse row matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, len = rows + 1.
    pub indptr: Vec<usize>,
    /// Column indices, len = nnz.
    pub indices: Vec<usize>,
    /// Values, len = nnz.
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from COO triplets (duplicates are summed).
    pub fn from_triplets(rows: usize, cols: usize, triplets: &[(usize, usize, f64)]) -> Csr {
        let mut sorted: Vec<(usize, usize, f64)> = triplets.to_vec();
        sorted.sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut last: Option<(usize, usize)> = None;
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            if last == Some((r, c)) {
                if let Some(tail) = values.last_mut() {
                    *tail += v;
                }
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] += 1;
                last = Some((r, c));
            }
        }
        for i in 0..rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// y = A x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A x into a preallocated buffer (hot path — no allocation).
    /// Parallelized over row blocks when the matrix is large enough under
    /// the global [`crate::par`] thread budget; results are bit-for-bit
    /// identical to the serial loop for any thread count.
    #[inline]
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        let threads = crate::par::plan_for(self.nnz());
        self.matvec_into_threads(x, y, threads);
    }

    /// [`Self::matvec_into`] with an explicit thread count (no work
    /// threshold — used by tests and benches to force a parallel split).
    pub fn matvec_into_threads(&self, x: &[f64], y: &mut [f64], threads: usize) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        crate::par::par_chunks_mut(y, 1, threads, |row0, yblock| {
            for (k, yi) in yblock.iter_mut().enumerate() {
                let i = row0 + k;
                let mut acc = 0.0;
                let (s, e) = (self.indptr[i], self.indptr[i + 1]);
                for kk in s..e {
                    acc += self.values[kk] * x[self.indices[kk]];
                }
                *yi = acc;
            }
        });
    }

    /// Multi-RHS matvec: Y = A X where X is row-major `cols × w`.
    /// This is the batched per-dimension solve path (p systems at once).
    /// Parallelized over row blocks (each output row is owned by exactly
    /// one thread), bit-for-bit identical to the serial sweep.
    pub fn matvec_multi_into(&self, x: &[f64], w: usize, y: &mut [f64]) {
        let threads = crate::par::plan_for(self.nnz().saturating_mul(w));
        self.matvec_multi_into_threads(x, w, y, threads);
    }

    /// [`Self::matvec_multi_into`] with an explicit thread count.
    pub fn matvec_multi_into_threads(&self, x: &[f64], w: usize, y: &mut [f64], threads: usize) {
        assert_eq!(x.len(), self.cols * w);
        assert_eq!(y.len(), self.rows * w);
        assert!(w > 0, "payload width must be positive");
        crate::par::par_chunks_mut(y, w, threads, |row0, yblock| {
            for (k, yrow) in yblock.chunks_mut(w).enumerate() {
                self.row_matvec_multi(row0 + k, x, w, yrow);
            }
        });
    }

    /// One output row of the multi-RHS matvec: `yrow = (A X)[r, ·]` where
    /// `X` is row-major `cols × w`. Shared by the full block sweep above
    /// and the partitioned per-owned-row path (`net::partitioned`) so both
    /// execute the identical scalar operations in the identical order —
    /// the bit-for-bit contract between the two transports rests on this.
    ///
    /// Cache-blocked over RHS columns: each block of ≤ [`RHS_BLOCK`]
    /// columns accumulates in a stack array across the whole row, so the
    /// output stays register-resident instead of round-tripping through
    /// `yrow` once per nonzero. Per output element the f64 additions
    /// happen in exactly the same `kk` order as the naive double loop, so
    /// results are bitwise identical.
    #[inline]
    pub fn row_matvec_multi(&self, r: usize, x: &[f64], w: usize, yrow: &mut [f64]) {
        let (s, e) = (self.indptr[r], self.indptr[r + 1]);
        let mut j0 = 0;
        while j0 < w {
            let bw = (w - j0).min(RHS_BLOCK);
            let mut acc = [0.0f64; RHS_BLOCK];
            for kk in s..e {
                let v = self.values[kk];
                let xo = self.indices[kk] * w + j0;
                for (j, a) in acc[..bw].iter_mut().enumerate() {
                    *a += v * x[xo + j];
                }
            }
            yrow[j0..j0 + bw].copy_from_slice(&acc[..bw]);
            j0 += bw;
        }
    }

    /// Dense conversion (tests / small problems only).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[k])] += self.values[k];
            }
        }
        m
    }

    /// Diagonal entries as a vector.
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.rows.min(self.cols)];
        for i in 0..d.len() {
            for k in self.indptr[i]..self.indptr[i + 1] {
                if self.indices[k] == i {
                    d[i] += self.values[k];
                }
            }
        }
        d
    }

    /// Row-scale: returns diag(s) * A.
    pub fn scale_rows(&self, s: &[f64]) -> Csr {
        assert_eq!(s.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            for k in out.indptr[i]..out.indptr[i + 1] {
                out.values[k] *= s[i];
            }
        }
        out
    }

    /// Sparse-sparse product (used to build chain levels A_{i+1} ~ (D⁻¹A)²).
    pub fn matmul(&self, other: &Csr) -> Csr {
        assert_eq!(self.cols, other.rows);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices: Vec<usize> = Vec::new();
        let mut values: Vec<f64> = Vec::new();
        // Gustavson's algorithm with a dense accumulator row.
        let mut acc = vec![0.0f64; other.cols];
        let mut mark = vec![usize::MAX; other.cols];
        let mut touched: Vec<usize> = Vec::new();
        for i in 0..self.rows {
            touched.clear();
            for k in self.indptr[i]..self.indptr[i + 1] {
                let a = self.values[k];
                let kk = self.indices[k];
                for l in other.indptr[kk]..other.indptr[kk + 1] {
                    let j = other.indices[l];
                    if mark[j] != i {
                        mark[j] = i;
                        acc[j] = 0.0;
                        touched.push(j);
                    }
                    acc[j] += a * other.values[l];
                }
            }
            touched.sort_unstable();
            for &j in &touched {
                indices.push(j);
                values.push(acc[j]);
            }
            indptr[i + 1] = indices.len();
        }
        Csr { rows: self.rows, cols: other.cols, indptr, indices, values }
    }

    /// Drop entries with |v| <= tol (sparsification used by the chain).
    pub fn prune(&self, tol: f64) -> Csr {
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                if self.values[k].abs() > tol {
                    indices.push(self.indices[k]);
                    values.push(self.values[k]);
                }
            }
            indptr[i + 1] = indices.len();
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

impl LinOp for Csr {
    fn dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.matvec_into(x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csr {
        // [[2, -1, 0], [-1, 2, -1], [0, -1, 2]]
        Csr::from_triplets(
            3,
            3,
            &[
                (0, 0, 2.0),
                (0, 1, -1.0),
                (1, 0, -1.0),
                (1, 1, 2.0),
                (1, 2, -1.0),
                (2, 1, -1.0),
                (2, 2, 2.0),
            ],
        )
    }

    #[test]
    fn matvec_matches_dense() {
        let a = small();
        let d = a.to_dense();
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(a.matvec(&x), d.matvec(&x));
    }

    #[test]
    fn duplicates_summed() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0), (1, 1, 1.0)]);
        assert_eq!(a.to_dense()[(0, 0)], 3.0);
        assert_eq!(a.nnz(), 2);
    }

    #[test]
    fn diagonal_extraction() {
        let a = small();
        assert_eq!(a.diagonal(), vec![2.0, 2.0, 2.0]);
    }

    #[test]
    fn matmul_matches_dense() {
        let a = small();
        let b = small();
        let c = a.matmul(&b);
        let cd = a.to_dense().matmul(&b.to_dense());
        assert!(c.to_dense().max_abs_diff(&cd) < 1e-12);
    }

    #[test]
    fn multi_rhs_matches_single() {
        let a = small();
        let x = vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]; // 3 rows, w=2
        let mut y = vec![0.0; 6];
        a.matvec_multi_into(&x, 2, &mut y);
        let x0: Vec<f64> = vec![1.0, 2.0, 3.0];
        let x1: Vec<f64> = vec![4.0, 5.0, 6.0];
        let y0 = a.matvec(&x0);
        let y1 = a.matvec(&x1);
        for i in 0..3 {
            assert_eq!(y[i * 2], y0[i]);
            assert_eq!(y[i * 2 + 1], y1[i]);
        }
    }

    #[test]
    fn duplicates_summed_when_scattered() {
        // Duplicate coordinates that are *not* adjacent in the input order
        // must still collapse into one stored entry.
        let a = Csr::from_triplets(
            2,
            3,
            &[(1, 2, 4.0), (0, 0, 1.0), (1, 2, -1.5), (0, 2, 2.0), (1, 2, 0.5)],
        );
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.to_dense()[(1, 2)], 3.0);
        assert_eq!(a.to_dense()[(0, 2)], 2.0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_triplets_row_out_of_bounds_panics() {
        let _ = Csr::from_triplets(2, 2, &[(2, 0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn from_triplets_col_out_of_bounds_panics() {
        let _ = Csr::from_triplets(2, 2, &[(0, 2, 1.0)]);
    }

    #[test]
    fn parallel_matvec_bit_for_bit_small() {
        let a = small();
        let x = vec![0.25, -1.5, 3.0];
        let mut serial = vec![0.0; 3];
        a.matvec_into_threads(&x, &mut serial, 1);
        for t in [2usize, 3, 8] {
            let mut par = vec![0.0; 3];
            a.matvec_into_threads(&x, &mut par, t);
            assert_eq!(serial, par, "threads={t}");
        }
    }

    #[test]
    fn multi_rhs_blocked_matches_per_column_across_block_boundaries() {
        // Widths straddling the RHS_BLOCK boundary (…, 8, 9, …) and a
        // multi-block width must all match the per-column reference
        // bitwise — the cache-blocked kernel may not reorder additions.
        let a = small();
        for w in [1usize, 7, 8, 9, 16, 19] {
            let x: Vec<f64> = (0..3 * w).map(|i| (i as f64) * 0.37 - 1.0).collect();
            let mut y = vec![f64::NAN; 3 * w]; // NaN canary: every slot must be written
            a.matvec_multi_into_threads(&x, w, &mut y, 1);
            for c in 0..w {
                let xc: Vec<f64> = (0..3).map(|r| x[r * w + c]).collect();
                let yc = a.matvec(&xc);
                for r in 0..3 {
                    assert_eq!(y[r * w + c], yc[r], "w={w} col={c} row={r}");
                }
            }
        }
    }

    #[test]
    fn multi_rhs_overwrites_stale_output() {
        // row_matvec_multi must fully overwrite yrow (no read of stale
        // contents) — callers pass reused workspaces.
        let a = small();
        let x = vec![1.0; 6];
        let mut y = vec![123.0; 6];
        a.matvec_multi_into_threads(&x, 2, &mut y, 1);
        let mut fresh = vec![0.0; 6];
        a.matvec_multi_into_threads(&x, 2, &mut fresh, 1);
        assert_eq!(y, fresh);
    }

    #[test]
    fn prune_drops_small() {
        let a = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 1e-15), (1, 1, 2.0)]);
        let p = a.prune(1e-12);
        assert_eq!(p.nnz(), 2);
    }

    #[test]
    fn scale_rows_works() {
        let a = small();
        let s = a.scale_rows(&[1.0, 0.5, 2.0]);
        assert_eq!(s.to_dense()[(1, 1)], 1.0);
        assert_eq!(s.to_dense()[(2, 2)], 4.0);
    }
}
