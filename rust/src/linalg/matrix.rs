//! Dense row-major f64 matrix.

use std::fmt;

/// Dense row-major matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// From row-major data.
    pub fn from_rows(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Diagonal matrix from a vector.
    pub fn diag(d: &[f64]) -> Matrix {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = d[i];
        }
        m
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix-vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::vector::dot(self.row(i), x);
        }
        y
    }

    /// Transposed matrix-vector product (xᵀA)ᵀ = Aᵀx.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let row = self.row(i);
                for j in 0..self.cols {
                    y[j] += xi * row[j];
                }
            }
        }
        y
    }

    /// Matrix product self * other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: row-major friendly.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for j in 0..other.cols {
                    orow[j] += aik * brow[j];
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self += alpha * other.
    pub fn add_scaled(&mut self, alpha: f64, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Rank-1 update: self += alpha * x yᵀ.
    pub fn rank1_update(&mut self, alpha: f64, x: &[f64], y: &[f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        for i in 0..self.rows {
            let axi = alpha * x[i];
            if axi != 0.0 {
                let row = self.row_mut(i);
                for j in 0..y.len() {
                    row[j] += axi * y[j];
                }
            }
        }
    }

    /// Quadratic form xᵀ A y.
    pub fn quad_form(&self, x: &[f64], y: &[f64]) -> f64 {
        super::vector::dot(x, &self.matvec(y))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }

    /// Check symmetry within tolerance.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_matvec() {
        let i3 = Matrix::eye(3);
        assert_eq!(i3.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_rows(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let a = Matrix::from_rows(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = vec![1.0, -1.0];
        assert_eq!(a.matvec_t(&x), a.transpose().matvec(&x));
    }

    #[test]
    fn rank1_and_quadform() {
        let mut a = Matrix::zeros(2, 2);
        a.rank1_update(2.0, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(a.data, vec![6.0, 8.0, 12.0, 16.0]);
        let q = a.quad_form(&[1.0, 0.0], &[0.0, 1.0]);
        assert_eq!(q, 8.0);
    }

    #[test]
    fn symmetry_check() {
        let mut a = Matrix::eye(3);
        assert!(a.is_symmetric(0.0));
        a[(0, 1)] = 1.0;
        assert!(!a.is_symmetric(1e-12));
    }
}
