//! Conjugate-gradient solver over matrix-free operators.
//!
//! Used (a) as the reference solver the SDDM chain solver is validated
//! against, and (b) for singular Laplacian systems via projection onto the
//! mean-zero subspace (`project_kernel = true`).

use super::vector::{axpy, center, dot, norm2};

/// A symmetric positive (semi-)definite linear operator.
pub trait LinOp {
    /// Problem dimension.
    fn dim(&self) -> usize;
    /// y = A x.
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

/// Dense-matrix operator adapter.
impl LinOp for super::matrix::Matrix {
    fn dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let r = self.matvec(x);
        y.copy_from_slice(&r);
    }
}

/// CG solve configuration.
#[derive(Debug, Clone)]
pub struct CgOptions {
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub tol: f64,
    /// Iteration cap.
    pub max_iter: usize,
    /// Project iterates and RHS onto the mean-zero subspace — required for
    /// consensus Laplacians whose kernel is span{1}.
    pub project_kernel: bool,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions { tol: 1e-10, max_iter: 10_000, project_kernel: false }
    }
}

/// CG solve result.
#[derive(Debug, Clone)]
pub struct CgResult {
    /// Approximate solution.
    pub x: Vec<f64>,
    /// Iterations performed.
    pub iters: usize,
    /// Final relative residual.
    pub rel_residual: f64,
    /// Whether the tolerance was met.
    pub converged: bool,
}

/// Solve `A x = b` by conjugate gradients.
pub fn cg_solve(a: &dyn LinOp, b: &[f64], opts: &CgOptions) -> CgResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let mut b = b.to_vec();
    if opts.project_kernel {
        center(&mut b);
    }
    let bnorm = norm2(&b).max(1e-300);

    let mut x = vec![0.0; n];
    let mut r = b.clone();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rs_old = dot(&r, &r);
    let mut iters = 0;

    while iters < opts.max_iter {
        if rs_old.sqrt() / bnorm <= opts.tol {
            break;
        }
        a.apply(&p, &mut ap);
        if opts.project_kernel {
            center(&mut ap);
        }
        let denom = dot(&p, &ap);
        if denom.abs() < 1e-300 {
            break;
        }
        let alpha = rs_old / denom;
        axpy(alpha, &p, &mut x);
        axpy(-alpha, &ap, &mut r);
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
        iters += 1;
    }
    if opts.project_kernel {
        center(&mut x);
    }
    let rel = rs_old.sqrt() / bnorm;
    CgResult { x, iters, rel_residual: rel, converged: rel <= opts.tol }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::Matrix;
    use crate::util::Pcg64;

    #[test]
    fn cg_matches_direct_solve() {
        let mut rng = Pcg64::new(9);
        let n = 20;
        let mut b = Matrix::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        let x_true = rng.normal_vec(n);
        let rhs = a.matvec(&x_true);
        let res = cg_solve(&a, &rhs, &CgOptions::default());
        assert!(res.converged, "rel={}", res.rel_residual);
        for (xs, xt) in res.x.iter().zip(&x_true) {
            assert!((xs - xt).abs() < 1e-7);
        }
    }

    #[test]
    fn cg_singular_laplacian_with_projection() {
        // Path graph Laplacian on 4 nodes: singular, kernel = 1.
        let a = Matrix::from_rows(
            4,
            4,
            vec![
                1.0, -1.0, 0.0, 0.0, //
                -1.0, 2.0, -1.0, 0.0, //
                0.0, -1.0, 2.0, -1.0, //
                0.0, 0.0, -1.0, 1.0,
            ],
        );
        // RHS in range(L): L * [1,2,3,4].
        let rhs = a.matvec(&[1.0, 2.0, 3.0, 4.0]);
        let opts = CgOptions { project_kernel: true, ..Default::default() };
        let res = cg_solve(&a, &rhs, &opts);
        assert!(res.converged);
        // Solution should satisfy L x = rhs and have zero mean.
        let lx = a.matvec(&res.x);
        for (u, v) in lx.iter().zip(&rhs) {
            assert!((u - v).abs() < 1e-8);
        }
        let mean: f64 = res.x.iter().sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
    }

    #[test]
    fn cg_zero_rhs_returns_zero() {
        let a = Matrix::eye(5);
        let res = cg_solve(&a, &[0.0; 5], &CgOptions::default());
        assert!(res.converged);
        assert!(res.x.iter().all(|&v| v == 0.0));
        assert_eq!(res.iters, 0);
    }
}
