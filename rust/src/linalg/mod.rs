//! Dense and sparse linear algebra substrate (f64).
//!
//! Everything the coordinator needs that would normally come from
//! `nalgebra`/`ndarray`: dense matrices, Cholesky factorization,
//! conjugate gradients, CSR sparse matrices and matrix-free operators.
//! This module is also the *native reference* implementation for the
//! per-node local computations whose hot path lives in the AOT JAX/Pallas
//! artifacts (`crate::runtime`).

pub mod vector;
pub mod matrix;
pub mod cholesky;
pub mod cg;
pub mod sparse;
pub mod lanczos;

pub use matrix::Matrix;
pub use sparse::Csr;
pub use vector::{axpy, dot, norm2, scale, sub};
