//! Lanczos tridiagonalization for extremal eigenvalue estimation of
//! symmetric operators — sharper and faster than plain power iteration
//! for the Laplacian spectra (μ₂, μ_n) that parameterize Theorem 1 and
//! the chain depth.

use super::cg::LinOp;
use super::vector::{axpy, center, dot, norm2, scale};
use crate::util::Pcg64;

/// Extremal eigenvalue estimates from a Lanczos run.
#[derive(Debug, Clone, Copy)]
pub struct LanczosResult {
    pub lambda_min: f64,
    pub lambda_max: f64,
    /// Krylov steps actually performed (may stop early on breakdown).
    pub steps: usize,
}

/// Run `k` Lanczos steps on a symmetric operator, optionally restricted to
/// the mean-zero subspace (deflating a known constant kernel), and return
/// the extremal Ritz values.
pub fn lanczos_extremal(
    a: &dyn LinOp,
    k: usize,
    deflate_constants: bool,
    rng: &mut Pcg64,
) -> LanczosResult {
    let n = a.dim();
    let k = k.min(n.saturating_sub(if deflate_constants { 1 } else { 0 })).max(1);

    let mut alphas: Vec<f64> = Vec::with_capacity(k);
    let mut betas: Vec<f64> = Vec::with_capacity(k);

    let mut q_prev = vec![0.0; n];
    let mut q = rng.normal_vec(n);
    if deflate_constants {
        center(&mut q);
    }
    let nq = norm2(&q).max(1e-300);
    scale(&mut q, 1.0 / nq);

    // Keep the basis for full reorthogonalization — n is small (graph
    // sizes ≤ a few hundred), so the O(k·n) extra work is negligible and
    // buys numerical robustness.
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(k);
    let mut w = vec![0.0; n];
    let mut steps = 0;

    for j in 0..k {
        a.apply(&q, &mut w);
        if deflate_constants {
            center(&mut w);
        }
        let alpha = dot(&q, &w);
        axpy(-alpha, &q, &mut w);
        if j > 0 {
            axpy(-betas[j - 1], &q_prev, &mut w);
        }
        // Full reorthogonalization.
        for b in &basis {
            let c = dot(b, &w);
            axpy(-c, b, &mut w);
        }
        alphas.push(alpha);
        basis.push(q.clone());
        steps = j + 1;
        let beta = norm2(&w);
        if beta < 1e-12 {
            break;
        }
        betas.push(beta);
        q_prev = std::mem::replace(&mut q, w.clone());
        scale(&mut q, 1.0 / beta);
    }

    let (lo, hi) = tridiag_extremal(&alphas, &betas[..steps.saturating_sub(1)]);
    LanczosResult { lambda_min: lo, lambda_max: hi, steps }
}

/// Extremal eigenvalues of a symmetric tridiagonal matrix by bisection on
/// the Sturm sequence (LAPACK-free).
pub fn tridiag_extremal(diag: &[f64], off: &[f64]) -> (f64, f64) {
    let k = diag.len();
    assert!(k >= 1);
    assert!(off.len() + 1 >= k, "off-diagonal too short");
    // Gershgorin bounds.
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..k {
        let r = if i > 0 { off[i - 1].abs() } else { 0.0 }
            + if i < k - 1 { off[i].abs() } else { 0.0 };
        lo = lo.min(diag[i] - r);
        hi = hi.max(diag[i] + r);
    }
    // Sturm count: #eigenvalues < x.
    let count_below = |x: f64| -> usize {
        let mut count = 0;
        let mut d = 1.0f64;
        for i in 0..k {
            let off2 = if i > 0 { off[i - 1] * off[i - 1] } else { 0.0 };
            d = diag[i] - x - if i > 0 { off2 / d } else { 0.0 };
            if d == 0.0 {
                d = 1e-300;
            }
            if d < 0.0 {
                count += 1;
            }
        }
        count
    };
    let bisect = |target: usize| -> f64 {
        let (mut a, mut b) = (lo - 1e-9, hi + 1e-9);
        for _ in 0..200 {
            let mid = 0.5 * (a + b);
            if count_below(mid) > target {
                b = mid;
            } else {
                a = mid;
            }
            if b - a < 1e-13 * hi.abs().max(1.0) {
                break;
            }
        }
        0.5 * (a + b)
    };
    (bisect(0), bisect(k - 1))
}

/// Laplacian spectrum estimate (μ₂, μ_n) via deflated Lanczos.
pub fn laplacian_spectrum(
    l: &crate::linalg::Csr,
    steps: usize,
    rng: &mut Pcg64,
) -> (f64, f64) {
    let res = lanczos_extremal(l, steps, true, rng);
    (res.lambda_min.max(0.0), res.lambda_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian_csr};
    use crate::linalg::Matrix;

    #[test]
    fn tridiag_extremal_known() {
        // diag(1, 2, 3) — no coupling.
        let (lo, hi) = tridiag_extremal(&[1.0, 2.0, 3.0], &[0.0, 0.0]);
        assert!((lo - 1.0).abs() < 1e-10);
        assert!((hi - 3.0).abs() < 1e-10);
        // [[2,1],[1,2]] → {1, 3}.
        let (lo, hi) = tridiag_extremal(&[2.0, 2.0], &[1.0]);
        assert!((lo - 1.0).abs() < 1e-10, "lo={lo}");
        assert!((hi - 3.0).abs() < 1e-10, "hi={hi}");
    }

    #[test]
    fn lanczos_matches_dense_spectrum() {
        let mut rng = Pcg64::new(401);
        let n = 14;
        let mut b = Matrix::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += 2.0;
        }
        let res = lanczos_extremal(&a, n, false, &mut rng);
        // Reference via power-iteration bounds.
        let (lo_ref, hi_ref) = crate::problems::sym_eig_bounds(&a, 500);
        assert!((res.lambda_max - hi_ref).abs() < 1e-6 * hi_ref, "{} vs {hi_ref}", res.lambda_max);
        assert!((res.lambda_min - lo_ref).abs() < 1e-4 * hi_ref, "{} vs {lo_ref}", res.lambda_min);
    }

    #[test]
    fn laplacian_spectrum_complete_and_cycle() {
        let mut rng = Pcg64::new(402);
        // K_9: μ₂ = μ_n = 9.
        let l = laplacian_csr(&generate::complete(9));
        let (mu2, mun) = laplacian_spectrum(&l, 9, &mut rng);
        assert!((mu2 - 9.0).abs() < 1e-6, "mu2={mu2}");
        assert!((mun - 9.0).abs() < 1e-6, "mun={mun}");
        // C_12: μ₂ = 2(1 − cos(2π/12)), μ_n = 4.
        let l = laplacian_csr(&generate::cycle(12));
        let (mu2, mun) = laplacian_spectrum(&l, 12, &mut rng);
        let expect = 2.0 * (1.0 - (2.0 * std::f64::consts::PI / 12.0).cos());
        assert!((mu2 - expect).abs() < 1e-6, "mu2={mu2} expect={expect}");
        assert!((mun - 4.0).abs() < 1e-6, "mun={mun}");
    }

    #[test]
    fn lanczos_beats_power_iteration_in_steps() {
        // On a random graph, 30 Lanczos steps pin μ₂ to ~1e-8 where the
        // basic shifted power iteration needs thousands.
        let mut rng = Pcg64::new(403);
        let g = generate::random_connected(60, 150, &mut rng);
        let l = laplacian_csr(&g);
        let (mu2_l, _) = laplacian_spectrum(&l, 40, &mut rng);
        let mu2_p = crate::graph::spectral::mu_2(&l, 1e-12, 200_000, &mut rng).value;
        assert!(
            (mu2_l - mu2_p).abs() < 1e-5 * mu2_p.max(1.0),
            "lanczos {mu2_l} vs power {mu2_p}"
        );
    }
}
