//! Double cart-pole (DCP) simulator — the RL benchmark substrate
//! (Appendix G.2). A cart on a 1-D track carries *two* independent
//! inverted pendulums of different lengths; the controller applies a
//! horizontal force. State is 6-dimensional
//! `s = (x, ẋ, θ₁, θ̇₁, θ₂, θ̇₂)` and the paper's policy-search reduction
//! (H.3) consumes rollouts `τ = [s₁, a₁, …, s_T, a_T]` with per-trajectory
//! rewards `R(τ) ≥ 0`.
//!
//! Dynamics follow the standard multi-pole cart model (Wieland 1991):
//! each pole contributes an effective force/mass term; integration is RK4.

use crate::linalg::Matrix;
use crate::util::Pcg64;

/// Physical parameters of the double cart-pole.
#[derive(Debug, Clone)]
pub struct DcpParams {
    /// Cart mass (kg).
    pub m_cart: f64,
    /// Pole masses (kg).
    pub m_pole: [f64; 2],
    /// Pole half-lengths (m).
    pub l_pole: [f64; 2],
    /// Gravity (m/s²).
    pub g: f64,
    /// Integration step (s).
    pub dt: f64,
    /// Force limit |a| ≤ f_max (N).
    pub f_max: f64,
}

impl Default for DcpParams {
    fn default() -> Self {
        DcpParams {
            m_cart: 1.0,
            m_pole: [0.1, 0.05],
            l_pole: [0.5, 0.25],
            g: 9.81,
            dt: 0.02,
            f_max: 20.0,
        }
    }
}

/// 6-dimensional DCP state.
pub type State = [f64; 6];

/// Equations of motion: returns d/dt of the state under force `f`.
/// Standard multiple-pole cart-pole dynamics (Wieland):
///
/// ẍ = (f + Σᵢ F̃ᵢ) / (M + Σᵢ m̃ᵢ),
/// θ̈ᵢ = −(3 / 4lᵢ)(ẍ cos θᵢ + g sin θᵢ),
/// F̃ᵢ = mᵢ lᵢ θ̇ᵢ² sin θᵢ + (3/4) mᵢ g sin θᵢ cos θᵢ,
/// m̃ᵢ = mᵢ (1 − (3/4) cos² θᵢ).
pub fn derivs(p: &DcpParams, s: &State, f: f64) -> State {
    let (xd, th1, th1d, th2, th2d) = (s[1], s[2], s[3], s[4], s[5]);
    // Wieland measures θ from the upright position with g negative; we keep
    // the parameter positive and substitute −g below.
    let g = -p.g;
    let mut f_eff = 0.0;
    let mut m_eff = 0.0;
    let (s1, c1) = th1.sin_cos();
    let (s2, c2) = th2.sin_cos();
    // Pole 1
    f_eff += p.m_pole[0] * p.l_pole[0] * th1d * th1d * s1
        + 0.75 * p.m_pole[0] * g * s1 * c1;
    m_eff += p.m_pole[0] * (1.0 - 0.75 * c1 * c1);
    // Pole 2
    f_eff += p.m_pole[1] * p.l_pole[1] * th2d * th2d * s2
        + 0.75 * p.m_pole[1] * g * s2 * c2;
    m_eff += p.m_pole[1] * (1.0 - 0.75 * c2 * c2);

    let xdd = (f + f_eff) / (p.m_cart + m_eff);
    let th1dd = -0.75 / p.l_pole[0] * (xdd * c1 + g * s1);
    let th2dd = -0.75 / p.l_pole[1] * (xdd * c2 + g * s2);
    [xd, xdd, th1d, th1dd, th2d, th2dd]
}

/// One RK4 integration step under constant force `f`.
pub fn rk4_step(p: &DcpParams, s: &State, f: f64) -> State {
    let h = p.dt;
    let k1 = derivs(p, s, f);
    let k2 = derivs(p, &advance(s, &k1, h / 2.0), f);
    let k3 = derivs(p, &advance(s, &k2, h / 2.0), f);
    let k4 = derivs(p, &advance(s, &k3, h), f);
    let mut out = *s;
    for i in 0..6 {
        out[i] += h / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
    out
}

fn advance(s: &State, d: &State, h: f64) -> State {
    let mut out = *s;
    for i in 0..6 {
        out[i] += h * d[i];
    }
    out
}

/// A rollout: features per step (p × T matrix of states), actions (T),
/// and scalar reward R(τ).
#[derive(Debug, Clone)]
pub struct Rollout {
    /// Feature columns Φ(s_t) — here Φ = identity, p = 6 (paper: "six
    /// parameters and six state features").
    pub features: Matrix,
    /// Actions a_t.
    pub actions: Vec<f64>,
    /// Trajectory reward R(τ) ≥ 0.
    pub reward: f64,
}

/// Gaussian policy `a = θᵀ s + ε`, ε ~ N(0, σ²).
#[derive(Debug, Clone)]
pub struct GaussianPolicy {
    pub theta: Vec<f64>,
    pub sigma: f64,
}

/// Generate one rollout of length `t_len` from a randomized near-upright
/// start. Reward: `R(τ) = Σ_t exp(−(θ₁² + θ₂² + 0.01 x²))` — positive,
/// bounded, larger for trajectories that keep both poles upright.
pub fn rollout(
    p: &DcpParams,
    policy: &GaussianPolicy,
    t_len: usize,
    rng: &mut Pcg64,
) -> Rollout {
    assert_eq!(policy.theta.len(), 6);
    let mut s: State = [
        rng.uniform(-0.05, 0.05),
        0.0,
        rng.uniform(-0.08, 0.08),
        0.0,
        rng.uniform(-0.08, 0.08),
        0.0,
    ];
    let mut features = Matrix::zeros(6, t_len);
    let mut actions = Vec::with_capacity(t_len);
    let mut reward = 0.0;
    for t in 0..t_len {
        for i in 0..6 {
            features[(i, t)] = s[i];
        }
        let mean: f64 = policy.theta.iter().zip(&s).map(|(w, x)| w * x).sum();
        let a = (mean + policy.sigma * rng.normal()).clamp(-p.f_max, p.f_max);
        actions.push(a);
        reward += (-(s[2] * s[2] + s[4] * s[4] + 0.01 * s[0] * s[0])).exp();
        s = rk4_step(p, &s, a);
        // Early termination on fall / runaway keeps rewards meaningful.
        if s[2].abs() > 0.9 || s[4].abs() > 0.9 || s[0].abs() > 3.0 {
            // Remaining columns stay zero; reward stops accumulating.
            for tt in (t + 1)..t_len {
                for i in 0..6 {
                    features[(i, tt)] = 0.0;
                }
                let _ = tt;
            }
            actions.resize(t_len, 0.0);
            break;
        }
    }
    Rollout { features, actions, reward }
}

/// Generate a batch of rollouts under a fixed behaviour policy — the RL
/// dataset of Appendix H.3.
pub fn generate_rollouts(
    p: &DcpParams,
    policy: &GaussianPolicy,
    count: usize,
    t_len: usize,
    rng: &mut Pcg64,
) -> Vec<Rollout> {
    (0..count).map(|_| rollout(p, policy, t_len, rng)).collect()
}

/// A crude stabilizing behaviour policy (hand-tuned PD gains) so rollouts
/// carry signal rather than immediate falls.
pub fn behaviour_policy(sigma: f64) -> GaussianPolicy {
    GaussianPolicy {
        // PD on both poles + weak cart centering: f = k·s.
        theta: vec![1.0, 2.0, 45.0, 6.0, 35.0, 3.0],
        sigma,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upright_equilibrium_is_stationary() {
        let p = DcpParams::default();
        let s: State = [0.0; 6];
        let d = derivs(&p, &s, 0.0);
        for v in d {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn gravity_topples_poles() {
        let p = DcpParams::default();
        let mut s: State = [0.0, 0.0, 0.05, 0.0, 0.05, 0.0];
        for _ in 0..200 {
            s = rk4_step(&p, &s, 0.0);
        }
        // Uncontrolled poles fall away from upright.
        assert!(s[2].abs() > 0.5, "theta1={}", s[2]);
    }

    #[test]
    fn energy_sane_under_rk4() {
        // No NaNs / explosions over a controlled run.
        let p = DcpParams::default();
        let pol = behaviour_policy(0.0);
        let mut rng = Pcg64::new(51);
        let r = rollout(&p, &pol, 150, &mut rng);
        assert!(r.reward.is_finite());
        assert!(r.actions.iter().all(|a| a.is_finite()));
        assert!(r.features.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn stabilizing_policy_beats_zero_policy() {
        let p = DcpParams::default();
        let mut rng = Pcg64::new(52);
        let good = behaviour_policy(0.5);
        let zero = GaussianPolicy { theta: vec![0.0; 6], sigma: 0.5 };
        let rg: f64 = generate_rollouts(&p, &good, 20, 100, &mut rng)
            .iter()
            .map(|r| r.reward)
            .sum();
        let rz: f64 = generate_rollouts(&p, &zero, 20, 100, &mut rng)
            .iter()
            .map(|r| r.reward)
            .sum();
        assert!(rg > rz, "good={rg} zero={rz}");
    }

    #[test]
    fn rollout_shapes() {
        let p = DcpParams::default();
        let pol = behaviour_policy(0.1);
        let mut rng = Pcg64::new(53);
        let r = rollout(&p, &pol, 42, &mut rng);
        assert_eq!(r.features.rows, 6);
        assert_eq!(r.features.cols, 42);
        assert_eq!(r.actions.len(), 42);
        assert!(r.reward >= 0.0);
    }
}
