//! Graph substrate: processor-network topology, Laplacians, spectra.
//!
//! The paper's experiments place `n` processors on a random connected
//! undirected graph with a given edge budget (e.g. 100 nodes / 250 edges
//! for Fig. 1(a,b), 10 nodes / 20 edges for MNIST). All algorithms only
//! communicate along these edges; the SDDM solver's behaviour is governed
//! by the Laplacian spectrum (μ₂, μ_n) of this graph.

pub mod generate;
pub mod laplacian;
pub mod spectral;

pub use generate::random_connected;
pub use laplacian::laplacian_csr;

/// An undirected graph over nodes `0..n`.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Number of nodes.
    pub n: usize,
    /// Undirected edge list, each `(u, v)` with `u < v`, no duplicates.
    pub edges: Vec<(usize, usize)>,
    /// Adjacency lists.
    pub adj: Vec<Vec<usize>>,
}

impl Graph {
    /// Build from an edge list (validates, sorts adjacency). Runs in
    /// O(m log m): degrees are counted first so each adjacency list is
    /// allocated exactly once — no per-push reallocation churn at the
    /// 10⁷-edge scale of the streaming generators.
    pub fn from_edges(n: usize, edges: Vec<(usize, usize)>) -> Graph {
        let mut norm = edges;
        for e in norm.iter_mut() {
            let (a, b) = *e;
            assert!(a < n && b < n, "edge ({a},{b}) out of range");
            assert_ne!(a, b, "self-loop ({a},{a})");
            *e = (a.min(b), a.max(b));
        }
        norm.sort_unstable();
        norm.dedup();
        let mut deg = vec![0usize; n];
        for &(u, v) in &norm {
            deg[u] += 1;
            deg[v] += 1;
        }
        let mut adj: Vec<Vec<usize>> = deg.iter().map(|&d| Vec::with_capacity(d)).collect();
        for &(u, v) in &norm {
            adj[u].push(v);
            adj[v].push(u);
        }
        for l in adj.iter_mut() {
            l.sort_unstable();
        }
        Graph { n, edges: norm, adj }
    }

    /// Number of undirected edges m = |E|.
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Degree of node i.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        self.adj.iter().map(|l| l.len()).max().unwrap_or(0)
    }

    /// Neighbors of node i.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = stack.pop() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter via BFS from every node (small graphs only).
    pub fn diameter(&self) -> usize {
        let mut diam = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            let mut q = std::collections::VecDeque::new();
            dist[s] = 0;
            q.push_back(s);
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            diam = diam.max(*dist.iter().filter(|&&d| d != usize::MAX).max().unwrap());
        }
        diam
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_normalizes() {
        let g = Graph::from_edges(3, vec![(1, 0), (0, 1), (2, 1)]);
        assert_eq!(g.edges, vec![(0, 1), (1, 2)]);
        assert_eq!(g.degree(1), 2);
        assert!(g.is_connected());
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, vec![(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn path_diameter() {
        let g = Graph::from_edges(4, vec![(0, 1), (1, 2), (2, 3)]);
        assert_eq!(g.diameter(), 3);
    }

    #[test]
    #[should_panic]
    fn self_loop_panics() {
        let _ = Graph::from_edges(2, vec![(0, 0)]);
    }
}
