//! Unweighted graph Laplacian `L = D − A` (Eq. 4 of the paper) in CSR
//! form, plus the standard splitting `L = D₀ − A₀` used by the SDDM
//! solver (Section 2).

use super::Graph;
use crate::linalg::Csr;

/// CSR Laplacian of an undirected graph.
pub fn laplacian_csr(g: &Graph) -> Csr {
    let mut trips: Vec<(usize, usize, f64)> = Vec::with_capacity(g.n + 4 * g.m());
    for i in 0..g.n {
        trips.push((i, i, g.degree(i) as f64));
    }
    for &(u, v) in &g.edges {
        trips.push((u, v, -1.0));
        trips.push((v, u, -1.0));
    }
    Csr::from_triplets(g.n, g.n, &trips)
}

/// Adjacency matrix A₀ (non-negative off-diagonal part of the splitting).
pub fn adjacency_csr(g: &Graph) -> Csr {
    let mut trips: Vec<(usize, usize, f64)> = Vec::with_capacity(4 * g.m());
    for &(u, v) in &g.edges {
        trips.push((u, v, 1.0));
        trips.push((v, u, 1.0));
    }
    Csr::from_triplets(g.n, g.n, &trips)
}

/// Degree vector D₀ (diagonal of the Laplacian).
pub fn degrees(g: &Graph) -> Vec<f64> {
    (0..g.n).map(|i| g.degree(i) as f64).collect()
}

/// Verify a CSR matrix is SDD in the paper's sense: symmetric, non-positive
/// off-diagonals, and diagonally dominant `[M]_ii ≥ −Σ_{j≠i} [M]_ij`.
pub fn is_sdd(m: &Csr, tol: f64) -> bool {
    if m.rows != m.cols {
        return false;
    }
    let dense = m.to_dense();
    if !dense.is_symmetric(tol) {
        return false;
    }
    for i in 0..m.rows {
        let mut off = 0.0;
        for j in 0..m.cols {
            if i != j {
                if dense[(i, j)] > tol {
                    return false; // positive off-diagonal
                }
                off += dense[(i, j)];
            }
        }
        if dense[(i, i)] + off < -tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::util::Pcg64;

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let mut rng = Pcg64::new(3);
        let g = generate::random_connected(20, 40, &mut rng);
        let l = laplacian_csr(&g);
        let ones = vec![1.0; 20];
        let y = l.matvec(&ones);
        assert!(y.iter().all(|v| v.abs() < 1e-12));
    }

    #[test]
    fn laplacian_is_sdd() {
        let mut rng = Pcg64::new(4);
        let g = generate::random_connected(15, 30, &mut rng);
        let l = laplacian_csr(&g);
        assert!(is_sdd(&l, 1e-12));
    }

    #[test]
    fn splitting_consistent() {
        let g = generate::cycle(6);
        let l = laplacian_csr(&g);
        let a = adjacency_csr(&g);
        let d = degrees(&g);
        // L = D - A
        let ld = l.to_dense();
        let ad = a.to_dense();
        for i in 0..6 {
            for j in 0..6 {
                let expect = if i == j { d[i] } else { 0.0 } - ad[(i, j)];
                assert!((ld[(i, j)] - expect).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn non_sdd_rejected() {
        // positive off-diagonal
        let m = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 0.5), (1, 0, 0.5), (1, 1, 1.0)]);
        assert!(!is_sdd(&m, 1e-12));
    }
}
