//! Random graph generators matching the paper's experimental setup
//! ("edges were chosen uniformly at random" over a connected topology).

use super::Graph;
use crate::util::Pcg64;

/// Random connected graph with exactly `n` nodes and `m` edges
/// (`m ≥ n−1`): a uniform random spanning tree (via a random permutation
/// walk) guarantees connectivity; remaining `m − (n−1)` edges are chosen
/// uniformly at random among the non-edges.
pub fn random_connected(n: usize, m: usize, rng: &mut Pcg64) -> Graph {
    assert!(n >= 1);
    let max_edges = n * (n - 1) / 2;
    assert!(m >= n.saturating_sub(1), "need at least n-1 edges for connectivity");
    assert!(m <= max_edges, "m={m} exceeds complete graph {max_edges}");

    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m);
    let mut present = std::collections::HashSet::with_capacity(m * 2);

    // Random spanning tree: random permutation, attach each node to a
    // uniformly random earlier node (random recursive tree).
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    for i in 1..n {
        let j = rng.next_below(i as u64) as usize;
        let (u, v) = (perm[i].min(perm[j]), perm[i].max(perm[j]));
        edges.push((u, v));
        present.insert((u, v));
    }

    // Fill with uniform random non-edges.
    while edges.len() < m {
        let a = rng.next_below(n as u64) as usize;
        let b = rng.next_below(n as u64) as usize;
        if a == b {
            continue;
        }
        let (u, v) = (a.min(b), a.max(b));
        if present.insert((u, v)) {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, edges)
}

/// Cycle graph (ring) — useful as a badly-conditioned test topology
/// (μ₂ = 2(1 − cos 2π/n) is tiny).
pub fn cycle(n: usize) -> Graph {
    assert!(n >= 3);
    let edges = (0..n).map(|i| (i, (i + 1) % n)).collect();
    Graph::from_edges(n, edges)
}

/// Path graph.
pub fn path(n: usize) -> Graph {
    assert!(n >= 2);
    let edges = (0..n - 1).map(|i| (i, i + 1)).collect();
    Graph::from_edges(n, edges)
}

/// Complete graph — the best-conditioned topology (μ₂ = μ_n = n).
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push((i, j));
        }
    }
    Graph::from_edges(n, edges)
}

/// Star graph (hub 0).
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    Graph::from_edges(n, (1..n).map(|i| (0, i)).collect())
}

/// 2-D grid graph with `r*c` nodes.
pub fn grid(r: usize, c: usize) -> Graph {
    let id = |i: usize, j: usize| i * c + j;
    let mut edges = Vec::new();
    for i in 0..r {
        for j in 0..c {
            if i + 1 < r {
                edges.push((id(i, j), id(i + 1, j)));
            }
            if j + 1 < c {
                edges.push((id(i, j), id(i, j + 1)));
            }
        }
    }
    Graph::from_edges(r * c, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_connected_has_exact_counts() {
        let mut rng = Pcg64::new(1);
        for &(n, m) in &[(10usize, 20usize), (100, 250), (50, 49)] {
            let g = random_connected(n, m, &mut rng);
            assert_eq!(g.n, n);
            assert_eq!(g.m(), m);
            assert!(g.is_connected(), "n={n} m={m}");
        }
    }

    #[test]
    fn random_graphs_differ_by_seed() {
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(2);
        let g1 = random_connected(30, 60, &mut r1);
        let g2 = random_connected(30, 60, &mut r2);
        assert_ne!(g1.edges, g2.edges);
    }

    #[test]
    fn named_topologies() {
        assert_eq!(cycle(5).m(), 5);
        assert_eq!(path(5).m(), 4);
        assert_eq!(complete(5).m(), 10);
        assert_eq!(star(5).m(), 4);
        assert_eq!(grid(3, 4).m(), 17);
        assert!(grid(3, 4).is_connected());
    }

    #[test]
    #[should_panic]
    fn too_few_edges_panics() {
        let mut rng = Pcg64::new(1);
        let _ = random_connected(10, 5, &mut rng);
    }
}
