//! Random graph generators matching the paper's experimental setup
//! ("edges were chosen uniformly at random" over a connected topology).

use super::Graph;
use crate::util::Pcg64;

/// Random connected graph with exactly `n` nodes and `m` edges
/// (`m ≥ n−1`): a uniform random spanning tree (via a random permutation
/// walk) guarantees connectivity; remaining `m − (n−1)` edges are chosen
/// uniformly at random among the non-edges.
pub fn random_connected(n: usize, m: usize, rng: &mut Pcg64) -> Graph {
    assert!(n >= 1);
    let max_edges = n * (n - 1) / 2;
    assert!(m >= n.saturating_sub(1), "need at least n-1 edges for connectivity");
    assert!(m <= max_edges, "m={m} exceeds complete graph {max_edges}");

    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(m);
    let mut present = std::collections::HashSet::with_capacity(m * 2);

    // Random spanning tree: random permutation, attach each node to a
    // uniformly random earlier node (random recursive tree).
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    for i in 1..n {
        let j = rng.next_below(i as u64) as usize;
        let (u, v) = (perm[i].min(perm[j]), perm[i].max(perm[j]));
        edges.push((u, v));
        present.insert((u, v));
    }

    // Fill with uniform random non-edges.
    while edges.len() < m {
        let a = rng.next_below(n as u64) as usize;
        let b = rng.next_below(n as u64) as usize;
        if a == b {
            continue;
        }
        let (u, v) = (a.min(b), a.max(b));
        if present.insert((u, v)) {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, edges)
}

/// Cycle graph (ring) — useful as a badly-conditioned test topology
/// (μ₂ = 2(1 − cos 2π/n) is tiny).
pub fn cycle(n: usize) -> Graph {
    assert!(n >= 3);
    let edges = (0..n).map(|i| (i, (i + 1) % n)).collect();
    Graph::from_edges(n, edges)
}

/// Path graph.
pub fn path(n: usize) -> Graph {
    assert!(n >= 2);
    let edges = (0..n - 1).map(|i| (i, i + 1)).collect();
    Graph::from_edges(n, edges)
}

/// Complete graph — the best-conditioned topology (μ₂ = μ_n = n).
pub fn complete(n: usize) -> Graph {
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push((i, j));
        }
    }
    Graph::from_edges(n, edges)
}

/// Star graph (hub 0).
pub fn star(n: usize) -> Graph {
    assert!(n >= 2);
    Graph::from_edges(n, (1..n).map(|i| (0, i)).collect())
}

/// Random regular-ish expander: the union of `cycles` independent random
/// Hamiltonian cycles (each a shuffled permutation walked end-around).
/// Streaming O(m): the edge list is emitted directly — no adjacency
/// matrix, no non-edge sampling — so a 10⁶-node / 10⁷-edge instance
/// builds in seconds. Connected by construction (any single cycle
/// already spans all nodes); expected degree ≈ `2·cycles` with strong
/// spectral expansion, the well-conditioned topology for scale runs.
/// `m()` lands slightly under `cycles·n` because coinciding cycle edges
/// dedup.
pub fn expander(n: usize, cycles: usize, rng: &mut Pcg64) -> Graph {
    assert!(n >= 3, "expander needs n >= 3");
    assert!(cycles >= 1, "need at least one Hamiltonian cycle");
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(cycles * n);
    let mut perm: Vec<usize> = (0..n).collect();
    for _ in 0..cycles {
        rng.shuffle(&mut perm);
        for i in 0..n {
            let u = perm[i];
            let v = perm[(i + 1) % n];
            edges.push((u.min(v), u.max(v)));
        }
    }
    Graph::from_edges(n, edges)
}

/// Power-law (heavy-tailed degree) graph via Barabási–Albert
/// preferential attachment: each new node attaches to `attach` distinct
/// existing nodes sampled proportionally to degree (the classic
/// repeated-endpoints trick — sampling a uniform entry of the running
/// endpoint list *is* degree-proportional sampling). Streaming O(m)
/// time and memory, connected by construction; `m() ≈ attach·n`.
pub fn power_law(n: usize, attach: usize, rng: &mut Pcg64) -> Graph {
    assert!(attach >= 1, "need at least one attachment edge per node");
    assert!(n > attach, "need n > attach seed nodes");
    let seed = attach + 1;
    // Seed: a clique on the first `attach+1` nodes so every early target
    // has nonzero degree.
    let mut edges: Vec<(usize, usize)> =
        Vec::with_capacity(seed * (seed - 1) / 2 + (n - seed) * attach);
    let mut endpoints: Vec<usize> = Vec::with_capacity(2 * edges.capacity());
    for i in 0..seed {
        for j in (i + 1)..seed {
            edges.push((i, j));
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    let mut picked: Vec<usize> = Vec::with_capacity(attach);
    for v in seed..n {
        picked.clear();
        while picked.len() < attach {
            let t = endpoints[rng.next_below(endpoints.len() as u64) as usize];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            edges.push((t.min(v), t.max(v)));
            endpoints.push(t);
            endpoints.push(v);
        }
    }
    Graph::from_edges(n, edges)
}

/// 2-D grid graph with `r*c` nodes.
pub fn grid(r: usize, c: usize) -> Graph {
    let id = |i: usize, j: usize| i * c + j;
    let mut edges = Vec::new();
    for i in 0..r {
        for j in 0..c {
            if i + 1 < r {
                edges.push((id(i, j), id(i + 1, j)));
            }
            if j + 1 < c {
                edges.push((id(i, j), id(i, j + 1)));
            }
        }
    }
    Graph::from_edges(r * c, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_connected_has_exact_counts() {
        let mut rng = Pcg64::new(1);
        for &(n, m) in &[(10usize, 20usize), (100, 250), (50, 49)] {
            let g = random_connected(n, m, &mut rng);
            assert_eq!(g.n, n);
            assert_eq!(g.m(), m);
            assert!(g.is_connected(), "n={n} m={m}");
        }
    }

    #[test]
    fn random_graphs_differ_by_seed() {
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(2);
        let g1 = random_connected(30, 60, &mut r1);
        let g2 = random_connected(30, 60, &mut r2);
        assert_ne!(g1.edges, g2.edges);
    }

    #[test]
    fn named_topologies() {
        assert_eq!(cycle(5).m(), 5);
        assert_eq!(path(5).m(), 4);
        assert_eq!(complete(5).m(), 10);
        assert_eq!(star(5).m(), 4);
        assert_eq!(grid(3, 4).m(), 17);
        assert!(grid(3, 4).is_connected());
    }

    #[test]
    #[should_panic]
    fn too_few_edges_panics() {
        let mut rng = Pcg64::new(1);
        let _ = random_connected(10, 5, &mut rng);
    }

    #[test]
    fn expander_is_connected_with_expected_size() {
        let mut rng = Pcg64::new(7);
        for &(n, c) in &[(10usize, 1usize), (200, 3), (500, 5)] {
            let g = expander(n, c, &mut rng);
            assert_eq!(g.n, n);
            assert!(g.is_connected(), "n={n} cycles={c}");
            // Dedup can only shrink the c·n emitted edges, and a single
            // spanning cycle survives any dedup.
            assert!(g.m() <= c * n, "n={n} c={c} m={}", g.m());
            assert!(g.m() >= n, "n={n} c={c} m={}", g.m());
            // Degrees concentrate near 2c — no heavy tail.
            assert!(g.max_degree() <= 2 * c, "cycle union caps degree at 2c");
        }
    }

    #[test]
    fn power_law_is_connected_with_heavy_tail() {
        let mut rng = Pcg64::new(8);
        let (n, attach) = (400usize, 3usize);
        let g = power_law(n, attach, &mut rng);
        assert_eq!(g.n, n);
        assert!(g.is_connected());
        let expected = (attach + 1) * attach / 2 + (n - attach - 1) * attach;
        assert_eq!(g.m(), expected, "preferential attachment never emits duplicate edges");
        // Heavy tail: the busiest hub dwarfs the minimum (≥ attach) degree.
        assert!(
            g.max_degree() >= 5 * attach,
            "no hub emerged: max degree {}",
            g.max_degree()
        );
    }

    #[test]
    fn streaming_generators_are_seed_deterministic() {
        let (g1, g2) = (expander(50, 2, &mut Pcg64::new(11)), expander(50, 2, &mut Pcg64::new(11)));
        assert_eq!(g1.edges, g2.edges);
        let (p1, p2) =
            (power_law(50, 2, &mut Pcg64::new(12)), power_law(50, 2, &mut Pcg64::new(12)));
        assert_eq!(p1.edges, p2.edges);
    }
}
