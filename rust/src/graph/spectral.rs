//! Spectral estimation for graph Laplacians: μ_n (largest eigenvalue) and
//! μ₂ (algebraic connectivity). These drive the theoretical step size α*
//! and the error mapping of Lemma 3 / Theorem 1.

use crate::linalg::vector::{center, norm2, scale};
use crate::linalg::Csr;
use crate::util::Pcg64;

/// Result of an eigenvalue estimate.
#[derive(Debug, Clone, Copy)]
pub struct EigEstimate {
    pub value: f64,
    pub iters: usize,
}

/// Largest Laplacian eigenvalue μ_n by power iteration.
pub fn mu_max(l: &Csr, tol: f64, max_iter: usize, rng: &mut Pcg64) -> EigEstimate {
    let n = l.rows;
    let mut x = rng.normal_vec(n);
    let nx = norm2(&x);
    scale(&mut x, 1.0 / nx);
    let mut y = vec![0.0; n];
    let mut lambda = 0.0;
    let mut iters = 0;
    for k in 0..max_iter {
        l.matvec_into(&x, &mut y);
        let ny = norm2(&y);
        if ny < 1e-300 {
            break;
        }
        let new_lambda = ny; // Rayleigh-ish via norm growth of unit vector
        for i in 0..n {
            x[i] = y[i] / ny;
        }
        iters = k + 1;
        if (new_lambda - lambda).abs() <= tol * new_lambda.max(1e-300) {
            lambda = new_lambda;
            break;
        }
        lambda = new_lambda;
    }
    EigEstimate { value: lambda, iters }
}

/// Second-smallest Laplacian eigenvalue μ₂ (algebraic connectivity) by
/// power iteration on `μ̂ I − L` restricted to the mean-zero subspace
/// (spectral shift + deflation of the known kernel `1`).
pub fn mu_2(l: &Csr, tol: f64, max_iter: usize, rng: &mut Pcg64) -> EigEstimate {
    let n = l.rows;
    let shift = mu_max(l, 1e-8, 2_000, rng).value * 1.0001 + 1e-9;
    let mut x = rng.normal_vec(n);
    center(&mut x);
    let nx = norm2(&x).max(1e-300);
    scale(&mut x, 1.0 / nx);
    let mut y = vec![0.0; n];
    let mut lambda = 0.0;
    let mut iters = 0;
    for k in 0..max_iter {
        // y = (shift I - L) x
        l.matvec_into(&x, &mut y);
        for i in 0..n {
            y[i] = shift * x[i] - y[i];
        }
        center(&mut y);
        let ny = norm2(&y);
        if ny < 1e-300 {
            break;
        }
        let new_lambda = ny;
        for i in 0..n {
            x[i] = y[i] / ny;
        }
        iters = k + 1;
        if (new_lambda - lambda).abs() <= tol * new_lambda.max(1e-300) {
            break;
        }
        lambda = new_lambda;
    }
    // Rayleigh quotient for a final polish: μ₂ = xᵀ L x (x unit, mean-zero).
    l.matvec_into(&x, &mut y);
    let rq = crate::linalg::vector::dot(&x, &y);
    EigEstimate { value: rq.max(0.0), iters }
}

/// Condition number of the Laplacian restricted to range(L): μ_n / μ₂.
pub fn laplacian_condition(l: &Csr, rng: &mut Pcg64) -> f64 {
    let hi = mu_max(l, 1e-9, 5_000, rng).value;
    let lo = mu_2(l, 1e-9, 20_000, rng).value;
    hi / lo.max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::graph::laplacian::laplacian_csr;

    #[test]
    fn complete_graph_spectrum() {
        // K_n has μ₂ = … = μ_n = n.
        let g = generate::complete(8);
        let l = laplacian_csr(&g);
        let mut rng = Pcg64::new(5);
        let hi = mu_max(&l, 1e-10, 5_000, &mut rng).value;
        let lo = mu_2(&l, 1e-10, 20_000, &mut rng).value;
        assert!((hi - 8.0).abs() < 1e-5, "mu_n={hi}");
        assert!((lo - 8.0).abs() < 1e-5, "mu_2={lo}");
    }

    #[test]
    fn cycle_graph_mu2() {
        // C_n: μ₂ = 2(1 − cos(2π/n)), μ_n = 2(1 − cos(π·⌊n/2⌋·2/n)) ≈ 4 for even n.
        let n = 12;
        let g = generate::cycle(n);
        let l = laplacian_csr(&g);
        let mut rng = Pcg64::new(6);
        let expect2 = 2.0 * (1.0 - (2.0 * std::f64::consts::PI / n as f64).cos());
        let lo = mu_2(&l, 1e-12, 100_000, &mut rng).value;
        assert!((lo - expect2).abs() < 1e-6, "mu2={lo} expect={expect2}");
        let hi = mu_max(&l, 1e-12, 100_000, &mut rng).value;
        assert!((hi - 4.0).abs() < 1e-4, "mu_n={hi}");
    }

    #[test]
    fn star_graph_mu_max() {
        // Star on n nodes: μ_n = n.
        let g = generate::star(10);
        let l = laplacian_csr(&g);
        let mut rng = Pcg64::new(7);
        let hi = mu_max(&l, 1e-10, 10_000, &mut rng).value;
        assert!((hi - 10.0).abs() < 1e-4, "mu_n={hi}");
    }

    #[test]
    fn condition_number_ordering() {
        // Complete graph much better conditioned than a cycle.
        let mut rng = Pcg64::new(8);
        let k = laplacian_condition(&laplacian_csr(&generate::complete(10)), &mut rng);
        let c = laplacian_condition(&laplacian_csr(&generate::cycle(10)), &mut rng);
        assert!(k < 1.01, "complete kappa={k}");
        assert!(c > 5.0, "cycle kappa={c}");
    }
}
