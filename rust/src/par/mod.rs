//! Scoped-thread parallel execution substrate (no external deps).
//!
//! The SDD solver's L3 hot paths — CSR `matvec`/`matvec_multi_into`, the
//! per-level forward/backward sweeps of the chain solver, and the batched
//! per-node local computations — are embarrassingly parallel across rows
//! (respectively nodes). This module provides the minimal primitives to
//! exploit that with `std::thread::scope` (stable since 1.63), keeping the
//! crate dependency-free:
//!
//! - [`par_chunks_mut`] — partition a mutable slice into contiguous,
//!   chunk-aligned blocks and process them on worker threads;
//! - [`par_for`] — partition an index range;
//! - [`par_map`] — map a slice to an owned `Vec` in parallel.
//!
//! All primitives partition work **contiguously and deterministically**:
//! every output element is computed by exactly the same scalar operations
//! in the same order as the serial code, so parallel results are
//! bit-for-bit identical to serial ones (asserted by
//! `tests/prop_parallel.rs`). Reductions (dot products, norms) stay serial
//! throughout the crate for the same reason.
//!
//! The global thread budget is a process-wide knob ([`set_threads`] /
//! [`threads`]) threaded through `config::ExperimentConfig` (as a
//! [`Parallelism`] field), the CLI (`--threads`) and `benchkit`
//! (`--threads` bench flag); `SDDN_THREADS` overrides the default of
//! `std::thread::available_parallelism`.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimum scalar work (≈ fused multiply-adds) a thread must receive
/// before spawning pays for itself; below this everything runs inline.
/// Spawning a scoped OS thread costs tens of microseconds, so the bar is
/// set around ~100 µs of arithmetic (≈ 1e5 FMAs) per extra thread —
/// mid-sized kernels stay serial rather than paying spawn/join per call.
pub const MIN_WORK_PER_THREAD: usize = 1 << 17;

/// Global thread budget; 0 = auto (env/`available_parallelism`).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Degree-of-parallelism knob carried by configs and benches.
/// The default (`threads: 0`) means auto-detect.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Parallelism {
    /// Worker-thread budget; 0 = auto-detect.
    pub threads: usize,
}

impl Parallelism {
    /// Auto-detect (`SDDN_THREADS` env var, else available parallelism).
    pub fn auto() -> Parallelism {
        Parallelism { threads: 0 }
    }

    /// Strictly serial execution.
    pub fn serial() -> Parallelism {
        Parallelism { threads: 1 }
    }

    /// Resolve to a concrete thread count (≥ 1).
    pub fn resolved(&self) -> usize {
        if self.threads == 0 {
            default_threads()
        } else {
            self.threads
        }
    }
}

/// Cached auto-detected default (0 = not yet resolved). `plan_for` sits
/// on hot paths, so the env/`available_parallelism` probe runs once.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

fn default_threads() -> usize {
    let cached = DEFAULT_THREADS.load(Ordering::Relaxed);
    if cached != 0 {
        return cached;
    }
    let resolved = match std::env::var("SDDN_THREADS").ok().and_then(|v| v.parse::<usize>().ok())
    {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    };
    DEFAULT_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Set the process-wide thread budget (0 = auto).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Current process-wide thread budget, resolved (≥ 1).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t == 0 {
        default_threads()
    } else {
        t
    }
}

/// Threads to use for a task of `work` scalar operations under the
/// current global budget: never more than the budget, never so many that
/// a thread gets less than [`MIN_WORK_PER_THREAD`].
pub fn plan_for(work: usize) -> usize {
    plan(threads(), work)
}

/// [`plan_for`] with an explicit budget.
pub fn plan(budget: usize, work: usize) -> usize {
    let cap = (work + MIN_WORK_PER_THREAD - 1) / MIN_WORK_PER_THREAD;
    budget.min(cap).max(1)
}

/// Split `data` into up to `threads` contiguous blocks whose boundaries
/// are multiples of `chunk`, and run `f(first_chunk_index, block)` on each
/// block concurrently (the last block runs on the calling thread).
/// `data.len()` must be a multiple of `chunk`.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0, "chunk must be positive");
    debug_assert_eq!(data.len() % chunk, 0, "data not chunk-aligned");
    let n_chunks = data.len() / chunk;
    let t = threads.min(n_chunks).max(1);
    if t <= 1 {
        f(0, data);
        return;
    }
    let per = (n_chunks + t - 1) / t;
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = data;
        let mut start = 0usize;
        while !rest.is_empty() {
            let take = (per * chunk).min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let s = start;
            if rest.is_empty() {
                f(s, head);
            } else {
                scope.spawn(move || f(s, head));
            }
            start += take / chunk;
        }
    });
}

/// Partition `0..n` into up to `threads` contiguous ranges and run `f` on
/// each concurrently (the last range runs on the calling thread).
pub fn par_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let t = threads.min(n).max(1);
    if t <= 1 {
        f(0..n);
        return;
    }
    let per = (n + t - 1) / t;
    std::thread::scope(|scope| {
        let f = &f;
        let mut start = 0usize;
        while start < n {
            let end = (start + per).min(n);
            if end == n {
                f(start..end);
            } else {
                scope.spawn(move || f(start..end));
            }
            start = end;
        }
    });
}

/// Parallel map over a slice, preserving order.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let mut out: Vec<Option<U>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    par_chunks_mut(&mut out, 1, threads, |start, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(&items[start + k]));
        }
    });
    out.into_iter().map(|o| o.expect("par_map slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_every_index_once() {
        for threads in [1usize, 2, 3, 4, 7] {
            for n_chunks in [0usize, 1, 2, 5, 64, 1000] {
                let chunk = 3;
                let mut data = vec![usize::MAX; n_chunks * chunk];
                par_chunks_mut(&mut data, chunk, threads, |start, block| {
                    for (k, v) in block.iter_mut().enumerate() {
                        *v = start * chunk + k;
                    }
                });
                for (i, v) in data.iter().enumerate() {
                    assert_eq!(*v, i, "threads={threads} n_chunks={n_chunks}");
                }
            }
        }
    }

    #[test]
    fn chunk_boundaries_are_aligned() {
        let chunk = 4;
        let mut data = vec![0usize; 10 * chunk];
        par_chunks_mut(&mut data, chunk, 3, |start, block| {
            assert_eq!(block.len() % chunk, 0);
            let _ = start;
        });
    }

    #[test]
    fn par_for_covers_range() {
        use std::sync::Mutex;
        for threads in [1usize, 2, 5] {
            let seen = Mutex::new(vec![0u32; 103]);
            par_for(103, threads, |range| {
                let mut s = seen.lock().unwrap();
                for i in range {
                    s[i] += 1;
                }
            });
            assert!(seen.into_inner().unwrap().iter().all(|&c| c == 1));
        }
    }

    #[test]
    fn par_map_matches_serial() {
        let items: Vec<usize> = (0..57).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [1usize, 2, 4, 8] {
            let par = par_map(&items, threads, |&x| x * x + 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn plan_respects_budget_and_minimum_work() {
        assert_eq!(plan(8, 0), 1);
        assert_eq!(plan(8, 100), 1);
        assert_eq!(plan(8, MIN_WORK_PER_THREAD), 1);
        assert_eq!(plan(8, 2 * MIN_WORK_PER_THREAD), 2);
        assert_eq!(plan(8, 100 * MIN_WORK_PER_THREAD), 8);
        assert_eq!(plan(1, 100 * MIN_WORK_PER_THREAD), 1);
    }

    #[test]
    fn parallelism_knob_resolves() {
        assert_eq!(Parallelism::serial().resolved(), 1);
        assert!(Parallelism::auto().resolved() >= 1);
        assert_eq!(Parallelism { threads: 3 }.resolved(), 3);
        assert_eq!(Parallelism::default(), Parallelism::auto());
    }

    #[test]
    fn global_budget_roundtrip() {
        // Other tests may run concurrently, but only this one writes a
        // non-auto value transiently; results elsewhere are thread-count
        // independent (bit-for-bit identical), so this is safe.
        let before = super::THREADS.load(Ordering::Relaxed);
        set_threads(5);
        assert_eq!(threads(), 5);
        set_threads(before);
        assert!(threads() >= 1);
    }
}
