//! Distributed averaging (Olshevsky [13]; Appendix H.1.2):
//! accelerated-consensus gradient scheme
//!
//! `ω_i(t+1) = θ_i(t) + ½ Σ_{j∈N(i)} (θ_j(t) − θ_i(t))/max(d_i,d_j) − β g_i(t)`
//! `z_i(t+1) = ω_i(t) − β g_i(t)`
//! `θ_i(t+1) = ω_i(t+1) + (1 − 2/(9n+1)) (ω_i(t+1) − z_i(t+1))`
//!
//! with `g_i(t) = ∇f_i(ω_i(t))`. The diffusion term is one application of
//! a degree-weighted Laplacian-style operator through
//! [`Exchange::exchange_apply`] (one round, `2m` messages), so the step
//! runs shard-local on either transport.

use super::ConsensusAlgorithm;
use crate::linalg::Csr;
use crate::net::{Exchange, StaleState};
use crate::problems::ConsensusProblem;

/// Distributed-averaging state (one shard's view).
pub struct DistAveraging {
    /// Gradient step β.
    pub beta: f64,
    /// Stacked θ iterate, local_n × p.
    theta: Vec<f64>,
    /// Stacked ω iterate, local_n × p.
    omega: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Diffusion operator: offdiag `1/max(d_i,d_j)`, diagonal closing
    /// each row to zero — `(D x)_i = Σ_j (x_j − x_i)/max(d_i,d_j)`.
    diffusion: Csr,
    m_edges: usize,
    p: usize,
    momentum: f64,
    /// Reusable diffusion-output scratch (no per-step allocation).
    diff: Vec<f64>,
    /// Bounded-staleness state for the diffusion exchange (`None` = BSP).
    stale: Option<StaleState>,
}

impl DistAveraging {
    /// Initialize at θ(1) = ω(1) = z(1) = 0, owning every node.
    pub fn new(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        beta: f64,
    ) -> DistAveraging {
        Self::new_sharded(problem, g, beta, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        beta: f64,
        owned: Vec<usize>,
    ) -> DistAveraging {
        let n = problem.n();
        let p = problem.p;
        let mut trips = Vec::new();
        for i in 0..n {
            let mut diag = 0.0;
            for &j in g.neighbors(i) {
                let wij = 1.0 / g.degree(i).max(g.degree(j)) as f64;
                trips.push((i, j, wij));
                diag -= wij;
            }
            trips.push((i, i, diag));
        }
        DistAveraging {
            beta,
            theta: vec![0.0; owned.len() * p],
            omega: vec![0.0; owned.len() * p],
            diff: vec![0.0; owned.len() * p],
            owned,
            diffusion: Csr::from_triplets(n, n, &trips),
            m_edges: g.m(),
            p,
            momentum: 1.0 - 2.0 / (9.0 * n as f64 + 1.0),
            stale: None,
        }
    }

    /// Run the diffusion exchange under a bounded-staleness policy:
    /// boundary data may be up to `tau` rounds old
    /// ([`Exchange::exchange_apply_stale`]). `tau = 0` keeps the exact
    /// BSP path — bit-for-bit, zero overhead.
    pub fn with_staleness(mut self, tau: u64) -> Self {
        self.stale = if tau > 0 { Some(StaleState::new(tau)) } else { None };
        self
    }
}

impl ConsensusAlgorithm for DistAveraging {
    fn name(&self) -> String {
        "Distributed Averaging".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        // Diffusion term on θ (one neighbor-exchange round) into the
        // reusable scratch buffer.
        let mut diff = std::mem::take(&mut self.diff);
        diff.clear();
        diff.resize(ln * p, 0.0);
        let msgs = 2 * self.m_edges as u64;
        if let Some(st) = self.stale.as_mut() {
            // Bounded staleness: stale rounds reconstruct the diffusion
            // from cached off-diagonal halos, charged to the savings
            // ledger.
            exch.exchange_apply_stale(&self.diffusion, st, msgs, &self.theta, p, &mut diff);
        } else {
            // sddn-lint: graph-support diffusion operator sparsity is exactly the comm graph
            exch.exchange_apply(&self.diffusion, msgs, &self.theta, p, &mut diff);
        }
        for (li, &u) in self.owned.iter().enumerate() {
            // Gradient at the current ω.
            let grad = problem.locals[u].gradient(&self.omega[li * p..(li + 1) * p]);
            for r in 0..p {
                let idx = li * p + r;
                let omega_next = self.theta[idx] + 0.5 * diff[idx] - self.beta * grad[r];
                let z_next = self.omega[idx] - self.beta * grad[r];
                // θ(t+1) = ω(t+1) + momentum (ω(t+1) − z(t+1)).
                self.theta[idx] = omega_next + self.momentum * (omega_next - z_next);
                self.omega[idx] = omega_next;
            }
        }
        self.diff = diff;
    }

    fn thetas(&self) -> &[f64] {
        &self.omega
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn averaging_descends() {
        let mut rng = Pcg64::new(131);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let mut alg = DistAveraging::new(&prob, &g, 0.005);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 300, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        assert!(
            objs.last().unwrap() < &(objs[1] * 0.9),
            "no decrease: start {} end {}",
            objs[1],
            objs.last().unwrap()
        );
    }

    #[test]
    fn momentum_depends_on_n() {
        let mut rng = Pcg64::new(132);
        let prob5 = datasets::synthetic_regression(5, 3, 50, 0.1, 0.05, &mut rng);
        let prob50 = datasets::synthetic_regression(50, 3, 500, 0.1, 0.05, &mut rng);
        let a5 = DistAveraging::new(&prob5, &generate::cycle(5), 0.01);
        let a50 = DistAveraging::new(&prob50, &generate::cycle(50), 0.01);
        assert!(a50.momentum > a5.momentum);
        assert!(a5.momentum < 1.0 && a50.momentum < 1.0);
    }

    #[test]
    fn single_round_per_iteration() {
        let mut rng = Pcg64::new(133);
        let g = generate::cycle(6);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut alg = DistAveraging::new(&prob, &g, 0.01);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().rounds, 1);
        assert_eq!(comm.stats().messages, 2 * g.m() as u64);
    }
}
