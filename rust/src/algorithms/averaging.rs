//! Distributed averaging (Olshevsky [13]; Appendix H.1.2):
//! accelerated-consensus gradient scheme
//!
//! `ω_i(t+1) = θ_i(t) + ½ Σ_{j∈N(i)} (θ_j(t) − θ_i(t))/max(d_i,d_j) − β g_i(t)`
//! `z_i(t+1) = ω_i(t) − β g_i(t)`
//! `θ_i(t+1) = ω_i(t+1) + (1 − 2/(9n+1)) (ω_i(t+1) − z_i(t+1))`
//!
//! with `g_i(t) = ∇f_i(ω_i(t))`.

use super::ConsensusAlgorithm;
use crate::net::CommGraph;
use crate::problems::ConsensusProblem;

/// Distributed-averaging state.
pub struct DistAveraging {
    /// Gradient step β.
    pub beta: f64,
    theta: Vec<f64>,
    omega: Vec<f64>,
    p: usize,
    momentum: f64,
}

impl DistAveraging {
    /// Initialize at θ(1) = ω(1) = z(1) = 0.
    pub fn new(problem: &ConsensusProblem, beta: f64) -> DistAveraging {
        let n = problem.n();
        let p = problem.p;
        DistAveraging {
            beta,
            theta: vec![0.0; n * p],
            omega: vec![0.0; n * p],
            p,
            momentum: 1.0 - 2.0 / (9.0 * n as f64 + 1.0),
        }
    }
}

impl ConsensusAlgorithm for DistAveraging {
    fn name(&self) -> String {
        "Distributed Averaging".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, comm: &mut CommGraph) {
        let p = self.p;
        let n = problem.n();
        let g = comm.graph();
        let degree: Vec<f64> = (0..n).map(|i| g.degree(i) as f64).collect();
        let gathered = comm.gather_neighbors(&self.theta, p);

        let mut omega_next = vec![0.0; n * p];
        let mut z_next = vec![0.0; n * p];
        for i in 0..n {
            // Gradient at the current ω.
            let grad = problem.locals[i].gradient(&self.omega[i * p..(i + 1) * p]);
            // Diffusion term on θ.
            let mut diff = vec![0.0; p];
            for (j, payload) in &gathered[i] {
                let denom = degree[i].max(degree[*j]);
                for r in 0..p {
                    diff[r] += (payload[r] - self.theta[i * p + r]) / denom;
                }
            }
            for r in 0..p {
                let idx = i * p + r;
                omega_next[idx] = self.theta[idx] + 0.5 * diff[r] - self.beta * grad[r];
                z_next[idx] = self.omega[idx] - self.beta * grad[r];
            }
        }
        // θ(t+1) = ω(t+1) + momentum (ω(t+1) − z(t+1)).
        for idx in 0..n * p {
            self.theta[idx] =
                omega_next[idx] + self.momentum * (omega_next[idx] - z_next[idx]);
        }
        self.omega = omega_next;
    }

    fn thetas(&self) -> &[f64] {
        &self.omega
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn averaging_descends() {
        let mut rng = Pcg64::new(131);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let mut alg = DistAveraging::new(&prob, 0.005);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 300, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        assert!(
            objs.last().unwrap() < &(objs[1] * 0.9),
            "no decrease: start {} end {}",
            objs[1],
            objs.last().unwrap()
        );
    }

    #[test]
    fn momentum_depends_on_n() {
        let mut rng = Pcg64::new(132);
        let prob5 = datasets::synthetic_regression(5, 3, 50, 0.1, 0.05, &mut rng);
        let prob50 = datasets::synthetic_regression(50, 3, 500, 0.1, 0.05, &mut rng);
        let a5 = DistAveraging::new(&prob5, 0.01);
        let a50 = DistAveraging::new(&prob50, 0.01);
        assert!(a50.momentum > a5.momentum);
        assert!(a5.momentum < 1.0 && a50.momentum < 1.0);
    }

    #[test]
    fn single_round_per_iteration() {
        let mut rng = Pcg64::new(133);
        let g = generate::cycle(6);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut alg = DistAveraging::new(&prob, 0.01);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().rounds, 1);
    }
}
