//! Distributed (sub)gradient method (Nedić & Ozdaglar [1]):
//! `θ_i ← Σ_j w_ij θ_j − α_k ∇f_i(θ_i)` with Metropolis weights.
//!
//! The mixing step is one application of the Metropolis weight matrix
//! (diagonal + neighborhoods) through [`Exchange::exchange_apply`] — one
//! neighbor-exchange round of `2m` messages — so the identical step runs
//! shard-local on the partitioned transport.

use super::{metropolis_csr, ConsensusAlgorithm};
use crate::linalg::Csr;
use crate::net::{Exchange, StaleState};
use crate::problems::ConsensusProblem;

/// Step-size schedule.
#[derive(Debug, Clone, Copy)]
pub enum GradSchedule {
    /// Constant α.
    Constant(f64),
    /// Diminishing α₀/√(k+1) (the rate-optimal subgradient schedule).
    Diminishing(f64),
}

/// Distributed gradient descent state (one shard's view).
pub struct DistGradient {
    pub schedule: GradSchedule,
    /// Stacked iterate, local_n × p (row r holds θ(owned[r])).
    thetas: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Global Metropolis mixing matrix W.
    mixing: Csr,
    m_edges: usize,
    k: usize,
    p: usize,
    /// Spare buffer swapped with `thetas` each step (no per-step allocation).
    spare: Vec<f64>,
    /// Bounded-staleness state for the mixing exchange (`None` = BSP).
    stale: Option<StaleState>,
}

impl DistGradient {
    /// Initialize at θ = 0 with Metropolis mixing weights, owning every
    /// node.
    pub fn new(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        schedule: GradSchedule,
    ) -> DistGradient {
        Self::new_sharded(problem, g, schedule, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        schedule: GradSchedule,
        owned: Vec<usize>,
    ) -> DistGradient {
        DistGradient {
            schedule,
            thetas: vec![0.0; owned.len() * problem.p],
            owned,
            mixing: metropolis_csr(g),
            m_edges: g.m(),
            k: 0,
            p: problem.p,
            spare: Vec::new(),
            stale: None,
        }
    }

    /// Run the mixing exchange under a bounded-staleness policy: boundary
    /// data may be up to `tau` rounds old
    /// ([`Exchange::exchange_apply_stale`]). `tau = 0` keeps the exact
    /// BSP path — bit-for-bit, zero overhead.
    pub fn with_staleness(mut self, tau: u64) -> Self {
        self.stale = if tau > 0 { Some(StaleState::new(tau)) } else { None };
        self
    }

    fn alpha(&self) -> f64 {
        match self.schedule {
            GradSchedule::Constant(a) => a,
            GradSchedule::Diminishing(a0) => a0 / ((self.k + 1) as f64).sqrt(),
        }
    }
}

impl ConsensusAlgorithm for DistGradient {
    fn name(&self) -> String {
        "Distributed Gradients".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        let alpha = self.alpha();
        // Mix: θ ← W θ (one neighbor-exchange round of 2m messages). The
        // output lands in the spare buffer, which then swaps with θ — the
        // steady state allocates nothing.
        let mut mixed = std::mem::take(&mut self.spare);
        mixed.clear();
        mixed.resize(ln * p, 0.0);
        let msgs = 2 * self.m_edges as u64;
        if let Some(st) = self.stale.as_mut() {
            // Bounded staleness: stale rounds reconstruct the mix from
            // cached off-diagonal halos, charged to the savings ledger.
            exch.exchange_apply_stale(&self.mixing, st, msgs, &self.thetas, p, &mut mixed);
        } else {
            // sddn-lint: graph-support Metropolis mixing sparsity is exactly the comm graph plus diagonal
            exch.exchange_apply(&self.mixing, msgs, &self.thetas, p, &mut mixed);
        }
        // Gradient step at the *current* iterate — purely local.
        for (li, &u) in self.owned.iter().enumerate() {
            let grad = problem.locals[u].gradient(&self.thetas[li * p..(li + 1) * p]);
            for r in 0..p {
                mixed[li * p + r] -= alpha * grad[r];
            }
        }
        self.spare = std::mem::replace(&mut self.thetas, mixed);
        self.k += 1;
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn gradient_descends_slowly() {
        let mut rng = Pcg64::new(121);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = DistGradient::new(&prob, &g, GradSchedule::Constant(0.01));
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 400, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        // Decreases overall…
        assert!(objs.last().unwrap() < &objs[1]);
        // …but after 400 iterations the iterates are still visibly spread
        // (first-order consensus rate) and the stacked objective has not
        // settled onto the optimum.
        assert!(trace.final_consensus_error() > 1e-6);
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap > 1e-8, "unexpectedly exact: gap={gap}");
    }

    #[test]
    fn diminishing_schedule_shrinks() {
        let mut rng = Pcg64::new(122);
        let g = generate::complete(4);
        let prob = datasets::synthetic_regression(4, 3, 60, 0.1, 0.05, &mut rng);
        let mut alg = DistGradient::new(&prob, &g, GradSchedule::Diminishing(0.05));
        assert!((alg.alpha() - 0.05).abs() < 1e-15);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert!(alg.alpha() < 0.05);
    }

    #[test]
    fn one_message_round_per_iteration() {
        let mut rng = Pcg64::new(123);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut alg = DistGradient::new(&prob, &g, GradSchedule::Constant(0.01));
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().rounds, 1);
        assert_eq!(comm.stats().messages, 2 * g.m() as u64);
    }
}
