//! Distributed ADMM (Wei & Ozdaglar [2]; Appendix H.1.1 / H.2.1).
//!
//! Edge-based consensus with Gauss–Seidel primal sweeps: node `i` updates
//!
//! `θ_i ← argmin_θ f_i(θ) + (β/2) Σ_{j∈P(i)} ‖θ_j^{k+1} − θ − λ_ji/β‖²
//!                        + (β/2) Σ_{j∈S(i)} ‖θ − θ_j^{k} − λ_ij/β‖²`
//!
//! with predecessors `P(i) = {j ∈ N(i) : j < i}` and successors
//! `S(i) = {j ∈ N(i) : j > i}`, followed by the dual update
//! `λ_ji ← λ_ji − β(θ_j − θ_i)` per directed edge.
//!
//! The inner argmin is solved exactly for quadratic locals (H.1.1's closed
//! form is one Newton step) and by damped Newton for logistic locals.

use super::ConsensusAlgorithm;
use crate::net::CommGraph;
use crate::problems::ConsensusProblem;

/// ADMM state.
pub struct Admm {
    /// Penalty parameter β.
    pub beta: f64,
    /// Inner-Newton iterations for the primal argmin (1 suffices for
    /// quadratics; logistic needs a handful).
    pub inner_iters: usize,
    /// Stacked per-node primal iterate (n×p).
    thetas: Vec<f64>,
    /// Per-undirected-edge dual λ_{uv} (u < v, u the predecessor), each R^p.
    duals: Vec<Vec<f64>>,
    p: usize,
}

impl Admm {
    /// Initialize at θ = 0, λ = 0.
    pub fn new(problem: &ConsensusProblem, g: &crate::graph::Graph, beta: f64) -> Admm {
        let p = problem.p;
        Admm {
            beta,
            inner_iters: 8,
            thetas: vec![0.0; problem.n() * p],
            duals: vec![vec![0.0; p]; g.m()],
            p,
        }
    }
}

impl ConsensusAlgorithm for Admm {
    fn name(&self) -> String {
        "Distributed ADMM".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, comm: &mut CommGraph) {
        let p = self.p;
        let n = problem.n();
        let beta = self.beta;
        let g = comm.graph();
        let edges: Vec<(usize, usize)> = g.edges.clone();
        // Edge index lookup.
        let mut edge_of = std::collections::HashMap::new();
        for (e, &(u, v)) in edges.iter().enumerate() {
            edge_of.insert((u, v), e);
        }
        let degree: Vec<usize> = (0..n).map(|i| g.degree(i)).collect();
        let neighbors: Vec<Vec<usize>> = (0..n).map(|i| g.neighbors(i).to_vec()).collect();

        // One synchronous exchange of current θ (the Gauss–Seidel sweep
        // reuses in-iteration updates for predecessors, which in a real
        // deployment ride the same per-edge messages).
        {
            let x = self.thetas.clone();
            let _ = comm.gather_neighbors(&x, p);
        }

        // Gauss–Seidel sweep in node order.
        for i in 0..n {
            // Accumulate the linear offset:
            // s = Σ_{j∈S(i)} [θ_j^k + λ_ij/β] + Σ_{j∈P(i)} [θ_j^{k+1} − λ_ji/β].
            let mut s = vec![0.0; p];
            for &j in &neighbors[i] {
                if j > i {
                    let e = edge_of[&(i, j)];
                    for r in 0..p {
                        s[r] += self.thetas[j * p + r] + self.duals[e][r] / beta;
                    }
                } else {
                    let e = edge_of[&(j, i)];
                    for r in 0..p {
                        s[r] += self.thetas[j * p + r] - self.duals[e][r] / beta;
                    }
                }
            }
            // Damped Newton on ξ_i(θ) = f_i(θ) + (β d(i)/2)‖θ‖² − β sᵀθ + const.
            let local = &problem.locals[i];
            let mut theta = self.thetas[i * p..(i + 1) * p].to_vec();
            for _ in 0..self.inner_iters {
                let mut grad = local.gradient(&theta);
                for r in 0..p {
                    grad[r] += beta * degree[i] as f64 * theta[r] - beta * s[r];
                }
                let gn = crate::linalg::vector::norm2(&grad);
                if gn < 1e-12 {
                    break;
                }
                let step = local.solve_shifted(&theta, &grad, beta * degree[i] as f64);
                for r in 0..p {
                    theta[r] -= step[r];
                }
            }
            self.thetas[i * p..(i + 1) * p].copy_from_slice(&theta);
        }

        // Dual updates λ_{uv} ← λ_{uv} − β(θ_u − θ_v); needs the freshly
        // updated neighbor values: one more exchange round.
        {
            let x = self.thetas.clone();
            let _ = comm.gather_neighbors(&x, p);
        }
        for (e, &(u, v)) in edges.iter().enumerate() {
            for r in 0..p {
                self.duals[e][r] -= beta * (self.thetas[u * p + r] - self.thetas[v * p + r]);
            }
        }
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn admm_converges_on_quadratic() {
        let mut rng = Pcg64::new(111);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 300, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-4, "gap={gap}");
        assert!(trace.final_consensus_error() < 1e-2);
    }

    #[test]
    fn admm_converges_on_logistic() {
        let mut rng = Pcg64::new(112);
        let g = generate::random_connected(6, 12, &mut rng);
        let prob = datasets::mnist_like(
            6,
            6,
            180,
            0,
            crate::problems::logistic::Reg::L2,
            0.05,
            &mut rng,
        );
        let (_, f_star) = prob.centralized_optimum(80, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 250, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-3, "gap={gap}");
    }

    #[test]
    fn objective_monotone_ish_late() {
        // ADMM oscillates early but should settle; check last quarter is
        // within a tight band.
        let mut rng = Pcg64::new(113);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 90, 0.1, 0.05, &mut rng);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 200, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        let tail = &objs[150..];
        let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
            - tail.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-3 * objs[0].abs().max(1.0), "spread={spread}");
    }
}
