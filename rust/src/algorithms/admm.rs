//! Distributed ADMM (Wei & Ozdaglar [2]; Appendix H.1.1 / H.2.1).
//!
//! Edge-based consensus with Gauss–Seidel primal sweeps: node `i` updates
//!
//! `θ_i ← argmin_θ f_i(θ) + (β/2) Σ_{j∈P(i)} ‖θ_j^{k+1} − θ − λ_ji/β‖²
//!                        + (β/2) Σ_{j∈S(i)} ‖θ − θ_j^{k} − λ_ij/β‖²`
//!
//! with predecessors `P(i)` (neighbors that update earlier in the sweep)
//! and successors `S(i)` (neighbors that update later), followed by the
//! dual update `λ_ji ← λ_ji − β(θ_j − θ_i)` per directed edge. The inner
//! argmin is solved by damped Newton (one step is exact for quadratics).
//!
//! # Sharded sweep schedule
//!
//! The sweep order is a dependency: `θ_i` needs the *fresh* values of its
//! predecessors. A literal node-id sweep serializes the whole graph, so
//! instead the sweep runs as a wavefront over the stages of a greedy
//! proper coloring ([`sweep_stages`]): each stage is an independent set,
//! all its nodes update concurrently from fresh lower-stage + stale
//! higher-stage neighbor values, and one boundary round per stage ships
//! the freshly updated values. The schedule depends only on the graph —
//! never on the node→worker partition — which is what keeps the iterates
//! bit-for-bit identical across transports and partitionings (the
//! documented fallback to per-stage boundary rounds; a pipelined
//! node-order wavefront over contiguous shards would tie the trajectory
//! to the partitioning).
//!
//! # Aggregated duals
//!
//! The primal update only reads its incident duals through
//! `s_i = Σ_j θ_j^{mixed} + μ_i/β` with
//! `μ_i = Σ_{j∈S(i)} λ_ij − Σ_{j∈P(i)} λ_ji`, and the per-edge dual
//! update aggregates to `μ_i ← μ_i − β (L θ^{k+1})_i` — *independent* of
//! the edge orientation. Keeping only `μ` makes the whole dual state
//! node-local: the sweep needs one adjacency application per stage and
//! the dual update one Laplacian application, all through
//! [`Exchange::exchange_apply`].
//!
//! # Message accounting
//!
//! Stage 0 refreshes the full halo (`2m` directed messages); stage `s>0`
//! only ships the values stage `s−1` just updated (their degree sum); the
//! dual round ships the last stage's updates. The per-iteration total is
//! `2m + Σ_u deg(u) = 4m` — identical to the classic two-round
//! gather formulation. The wire matches the model: every round goes
//! through [`Exchange::exchange_apply_fresh_rows`] with the round's
//! fresh-row ship mask, so a plan-driven transport ships only that
//! round's active boundary rows instead of re-shipping the whole halo
//! each stage (the over-shipping the `prop_wire` suite
//! regression-tests). The *compute* mask restricts the row kernel to the
//! stage's independent set — the only rows the stage consumes — so one
//! iteration costs one full sweep of row evaluations plus the dual
//! round (`2n`, tallied in [`Admm::row_evals`]) rather than `stages`
//! full matvecs.
//!
//! # Pipelined wavefront
//!
//! The drained schedule ships stage `s−1`'s updates at round `s` —
//! stage `s+1` cannot start until stage `s` has drained globally. The
//! pipelined variant ([`Admm::new_sharded_pipelined`]) instead ships
//! each node's update at its *earliest consumer's* round
//! ([`pipelined_ship_schedule`]): stage `s+1` starts once its own
//! predecessors' boundary rows arrive. Iterates stay bit-for-bit
//! identical and the per-iteration total stays `4m` over `stages + 1`
//! rounds; what changes is *when* each row crosses the wire, which is
//! what lets a transport overlap stage compute with later stages'
//! traffic.

use super::ConsensusAlgorithm;
use crate::graph::Graph;
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::problems::ConsensusProblem;

/// Greedy proper coloring in node-id order — the Gauss–Seidel sweep
/// schedule. Adjacent nodes always land in different stages, so each
/// stage is an independent set and every edge has exactly one
/// *predecessor* endpoint (the lower stage), which updates strictly
/// earlier in the sweep. Depends only on the graph topology, never on
/// the node→worker partition.
pub fn sweep_stages(g: &Graph) -> Vec<usize> {
    let mut stage = vec![usize::MAX; g.n];
    for u in 0..g.n {
        // At most deg(u) neighbors are already colored, so a free stage
        // always exists within 0..=deg(u).
        let mut used = vec![false; g.degree(u) + 1];
        for &v in g.neighbors(u) {
            if stage[v] != usize::MAX && stage[v] < used.len() {
                used[stage[v]] = true;
            }
        }
        // sddn-lint: allow(panic) reason=at most deg(u) stages are taken, so a free stage exists within 0..=deg(u) by pigeonhole
        stage[u] = used.iter().position(|&b| !b).unwrap();
    }
    stage
}

/// The predecessor endpoint of edge `(u, v)` under a sweep schedule: the
/// endpoint that updates first (strictly lower stage — a proper coloring
/// guarantees the stages differ).
pub fn edge_predecessor(stages: &[usize], u: usize, v: usize) -> usize {
    assert_ne!(stages[u], stages[v], "({u},{v}) is not properly colored");
    if stages[u] < stages[v] {
        u
    } else {
        v
    }
}

/// Directed-message schedule of one ADMM iteration: per sweep stage the
/// charged message count (stage 0 ships the full halo, stage `s>0` ships
/// stage `s−1`'s fresh values), plus the dual round (the last stage's
/// fresh values). Sums to `4m` per iteration.
pub fn stage_message_schedule(g: &Graph, stages: &[usize]) -> (Vec<u64>, u64) {
    let n_stages = stages.iter().max().map(|&s| s + 1).unwrap_or(0);
    let degsum_of = |s: usize| -> u64 {
        (0..g.n).filter(|&u| stages[u] == s).map(|u| g.degree(u) as u64).sum()
    };
    let mut per_stage = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        per_stage.push(if s == 0 { 2 * g.m() as u64 } else { degsum_of(s - 1) });
    }
    (per_stage, degsum_of(n_stages - 1))
}

/// Pipelined ship-at-earliest-consumer schedule: instead of draining
/// stage `s−1` globally before stage `s` starts, a node's update ships
/// exactly at the round of its *earliest consumer* — the minimum stage
/// among its strictly-higher-stage neighbors (`ec(u)`), or the dual
/// round when no later stage reads it. Returns, per sweep round
/// `s ∈ 0..stages`, the fresh-row ship mask and its charged message
/// count, plus the dual round's mask and charge.
///
/// Why this preserves bit-identity with the drained schedule: a stage-`s`
/// reader's lower-stage neighbor `v` has `ec(v) ≤ s` (the reader itself
/// is a higher-stage neighbor of `v`), so `v`'s fresh value arrived at or
/// before round `s`; higher-stage neighbors last shipped θ^k at round 0 —
/// exactly the mirror state the drained wavefront computes from. At the
/// dual round every neighbor's final value has shipped (at its `ec`, or
/// in the dual mask itself). Conservation: every node ships θ^k at round
/// 0 and its update exactly once after its stage, so the per-iteration
/// total stays `2m + Σ_u deg(u) = 4m` over the same `stages + 1` rounds.
pub fn pipelined_ship_schedule(
    g: &Graph,
    stages: &[usize],
) -> (Vec<Vec<bool>>, Vec<u64>, Vec<bool>, u64) {
    let n_stages = stages.iter().max().map(|&s| s + 1).unwrap_or(0);
    // Earliest consumer stage per node (usize::MAX = only the dual reads
    // this node's update from a later round).
    let ec: Vec<usize> = (0..g.n)
        .map(|u| {
            g.neighbors(u)
                .iter()
                .filter(|&&v| stages[v] > stages[u])
                .map(|&v| stages[v])
                .min()
                .unwrap_or(usize::MAX)
        })
        .collect();
    let mut masks: Vec<Vec<bool>> = Vec::with_capacity(n_stages);
    let mut msgs: Vec<u64> = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        let mask: Vec<bool> = if s == 0 {
            vec![true; g.n]
        } else {
            (0..g.n).map(|u| ec[u] == s).collect()
        };
        let charge = if s == 0 {
            2 * g.m() as u64
        } else {
            (0..g.n).filter(|&u| mask[u]).map(|u| g.degree(u) as u64).sum()
        };
        masks.push(mask);
        msgs.push(charge);
    }
    let dual_mask: Vec<bool> = (0..g.n).map(|u| ec[u] == usize::MAX).collect();
    let dual_msgs = (0..g.n).filter(|&u| dual_mask[u]).map(|u| g.degree(u) as u64).sum();
    (masks, msgs, dual_mask, dual_msgs)
}

/// ADMM state (one shard's view).
pub struct Admm {
    /// Penalty parameter β.
    pub beta: f64,
    /// Inner-Newton iterations for the primal argmin (1 suffices for
    /// quadratics; logistic needs a handful).
    pub inner_iters: usize,
    /// Stacked primal iterate, local_n × p.
    thetas: Vec<f64>,
    /// Aggregated incident duals μ_i, local_n × p.
    mu: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Sweep stage of every global node.
    stage_of: Vec<usize>,
    /// Number of sweep stages.
    stages: usize,
    /// Per-stage compute masks: `stage_masks[s][u]` ⇔ `stage_of[u] == s` —
    /// the independent set stage `s` actually updates (and therefore the
    /// only rows whose neighbor sums it needs).
    stage_masks: Vec<Vec<bool>>,
    /// All-rows mask for the stage-0 full halo refresh and the dual round.
    full_mask: Vec<bool>,
    /// Fresh-row ship mask per sweep round: drained schedule ships stage
    /// `s−1`'s updates at round `s`; the pipelined schedule ships each
    /// node at its earliest consumer's round ([`pipelined_ship_schedule`]).
    ship_masks: Vec<Vec<bool>>,
    /// Fresh-row ship mask for the dual round.
    dual_ship: Vec<bool>,
    /// Rows evaluated per sweep stage (popcount of the compute mask).
    stage_counts: Vec<u64>,
    /// Directed messages charged per sweep stage.
    stage_msgs: Vec<u64>,
    /// Directed messages charged for the dual round.
    dual_msgs: u64,
    /// Whether the ship schedule is the pipelined wavefront.
    pub pipelined: bool,
    /// Modeled system-wide row evaluations so far: the compute-mask
    /// popcounts each exchange round charged. One iteration costs
    /// `2n` — one full sweep (the stages partition the nodes) plus the
    /// dual round — independent of the stage count; the pre-fix kernel
    /// evaluated every owned row every stage, `(stages+1)·n`.
    pub row_evals: u64,
    /// Global adjacency (neighbor sums of the sweep).
    adjacency: Csr,
    /// Global Laplacian (the aggregated dual update).
    laplacian: Csr,
    /// Global degrees d_i (the β d_i proximal shift).
    degree: Vec<f64>,
    p: usize,
}

impl Admm {
    /// Initialize at θ = 0, μ = 0, owning every node.
    pub fn new(problem: &ConsensusProblem, g: &Graph, beta: f64) -> Admm {
        Self::new_sharded(problem, g, beta, (0..problem.n()).collect())
    }

    /// Like [`Admm::new`] but with the pipelined ship schedule.
    pub fn new_pipelined(problem: &ConsensusProblem, g: &Graph, beta: f64) -> Admm {
        Self::new_sharded_pipelined(problem, g, beta, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending),
    /// using the drained per-stage ship schedule.
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &Graph,
        beta: f64,
        owned: Vec<usize>,
    ) -> Admm {
        Self::build(problem, g, beta, owned, false)
    }

    /// Shard-local instance using the pipelined ship-at-earliest-consumer
    /// schedule ([`pipelined_ship_schedule`]): bit-identical iterates and
    /// the same `4m` per-iteration message total, but stage `s+1`'s
    /// boundary rows ship as soon as their own predecessors update rather
    /// than after stage `s` drains globally.
    pub fn new_sharded_pipelined(
        problem: &ConsensusProblem,
        g: &Graph,
        beta: f64,
        owned: Vec<usize>,
    ) -> Admm {
        Self::build(problem, g, beta, owned, true)
    }

    fn build(
        problem: &ConsensusProblem,
        g: &Graph,
        beta: f64,
        owned: Vec<usize>,
        pipelined: bool,
    ) -> Admm {
        let p = problem.p;
        let stage_of = sweep_stages(g);
        let stages = stage_of.iter().max().map(|&s| s + 1).unwrap_or(0);
        let stage_masks: Vec<Vec<bool>> = (0..stages)
            .map(|s| (0..g.n).map(|u| stage_of[u] == s).collect())
            .collect();
        let stage_counts: Vec<u64> = stage_masks
            .iter()
            .map(|m| m.iter().filter(|&&b| b).count() as u64)
            .collect();
        let (ship_masks, stage_msgs, dual_ship, dual_msgs) = if pipelined {
            pipelined_ship_schedule(g, &stage_of)
        } else {
            let (msgs, dual) = stage_message_schedule(g, &stage_of);
            let mut ships = vec![vec![true; g.n]];
            ships.extend(stage_masks[..stages - 1].iter().cloned());
            (ships, msgs, stage_masks[stages - 1].clone(), dual)
        };
        Admm {
            beta,
            inner_iters: 8,
            thetas: vec![0.0; owned.len() * p],
            mu: vec![0.0; owned.len() * p],
            owned,
            stage_of,
            stages,
            stage_masks,
            full_mask: vec![true; g.n],
            ship_masks,
            dual_ship,
            stage_counts,
            stage_msgs,
            dual_msgs,
            pipelined,
            row_evals: 0,
            adjacency: crate::graph::laplacian::adjacency_csr(g),
            laplacian: crate::graph::laplacian_csr(g),
            degree: crate::graph::laplacian::degrees(g),
            p,
        }
    }
}

impl ConsensusAlgorithm for Admm {
    fn name(&self) -> String {
        "Distributed ADMM".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        let beta = self.beta;

        // Gauss–Seidel sweep as a stage wavefront: each stage refreshes
        // the neighbor sums its independent set consumes (fresh
        // lower-stage + stale higher-stage values) and updates that set.
        // The compute mask restricts the row kernel to exactly the
        // stage's consumers, so one iteration costs one full sweep of row
        // evaluations (the stages partition the nodes) plus the dual
        // round — not `stages` full matvecs; the shared per-row kernel
        // keeps masked rows bit-identical to the full sweep on every
        // transport. Rows outside the mask are left unspecified and never
        // read.
        let mut work = self.thetas.clone();
        let mut nbr = vec![0.0; ln * p];
        for s in 0..self.stages {
            // Drained schedule: stage 0 refreshes the full halo (`work` =
            // θ^k everywhere), stage s>0 ships the rows stage s−1 just
            // updated. Pipelined schedule: round s ships the rows whose
            // earliest consumer is stage s. Either way a plan-driven
            // transport puts exactly the modeled per-round charge on the
            // wire.
            let fresh = &self.ship_masks[s];
            // Adjacency sparsity is exactly the comm graph.
            exch.exchange_apply_fresh_rows(
                &self.adjacency,
                fresh,
                &self.stage_masks[s],
                self.stage_msgs[s],
                &work,
                p,
                &mut nbr,
            );
            self.row_evals += self.stage_counts[s];
            for (li, &u) in self.owned.iter().enumerate() {
                if self.stage_of[u] != s {
                    continue;
                }
                // s_i = Σ_{j∈N(i)} θ_j^{mixed} + μ_i/β.
                let mut si = vec![0.0; p];
                for r in 0..p {
                    si[r] = nbr[li * p + r] + self.mu[li * p + r] / beta;
                }
                // Damped Newton on
                // ξ_i(θ) = f_i(θ) + (β d_i/2)‖θ‖² − β s_iᵀθ + const.
                let local = &problem.locals[u];
                let mut theta = work[li * p..(li + 1) * p].to_vec();
                for _ in 0..self.inner_iters {
                    let mut grad = local.gradient(&theta);
                    for r in 0..p {
                        grad[r] += beta * self.degree[u] * theta[r] - beta * si[r];
                    }
                    if crate::linalg::vector::norm2(&grad) < 1e-12 {
                        break;
                    }
                    let step = local.solve_shifted(&theta, &grad, beta * self.degree[u]);
                    for r in 0..p {
                        theta[r] -= step[r];
                    }
                }
                work[li * p..(li + 1) * p].copy_from_slice(&theta);
            }
        }

        // Aggregated dual update μ ← μ − β (L θ^{k+1}): one more boundary
        // round shipping the not-yet-shipped fresh values (drained: the
        // final stage; pipelined: every node with no later-stage
        // consumer). The dual consumes every owned row, so compute is the
        // full mask. Laplacian sparsity is the comm graph plus diagonal.
        let mut lap = vec![0.0; ln * p];
        exch.exchange_apply_fresh_rows(
            &self.laplacian,
            &self.dual_ship,
            &self.full_mask,
            self.dual_msgs,
            &work,
            p,
            &mut lap,
        );
        self.row_evals += self.full_mask.len() as u64;
        for i in 0..ln * p {
            self.mu[i] -= beta * lap[i];
        }
        self.thetas = work;
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn admm_converges_on_quadratic() {
        let mut rng = Pcg64::new(111);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 300, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-4, "gap={gap}");
        assert!(trace.final_consensus_error() < 1e-2);
    }

    #[test]
    fn admm_converges_on_logistic() {
        let mut rng = Pcg64::new(112);
        let g = generate::random_connected(6, 12, &mut rng);
        let prob = datasets::mnist_like(
            6,
            6,
            180,
            0,
            crate::problems::logistic::Reg::L2,
            0.05,
            &mut rng,
        );
        let (_, f_star) = prob.centralized_optimum(80, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 250, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-3, "gap={gap}");
    }

    #[test]
    fn objective_monotone_ish_late() {
        // ADMM oscillates early but should settle; check last quarter is
        // within a tight band.
        let mut rng = Pcg64::new(113);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 90, 0.1, 0.05, &mut rng);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 200, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        let tail = &objs[150..];
        let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
            - tail.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-3 * objs[0].abs().max(1.0), "spread={spread}");
    }

    /// The sweep schedule is a proper coloring: adjacent nodes never
    /// share a stage, so every edge has exactly one predecessor — the
    /// invariant the Gauss–Seidel dependency rests on.
    #[test]
    fn sweep_stages_are_a_proper_coloring() {
        let mut rng = Pcg64::new(114);
        for g in [
            generate::star(8),
            generate::path(9),
            generate::grid(3, 4),
            generate::random_connected(14, 30, &mut rng),
        ] {
            let stages = sweep_stages(&g);
            let max_deg = (0..g.n).map(|u| g.degree(u)).max().unwrap();
            for &(u, v) in &g.edges {
                assert_ne!(stages[u], stages[v], "edge ({u},{v}) shares stage");
                // Exactly one predecessor, and it updates strictly earlier.
                let pred = edge_predecessor(&stages, u, v);
                let succ = if pred == u { v } else { u };
                assert!(stages[pred] < stages[succ]);
                assert_eq!(pred, edge_predecessor(&stages, v, u), "direction not symmetric");
            }
            // Greedy bound: at most Δ+1 stages.
            assert!(*stages.iter().max().unwrap() <= max_deg);
        }
    }

    /// Bipartite orderings collapse to two stages: on a path the stages
    /// alternate, and node-id order makes even ids the predecessors.
    #[test]
    fn path_sweep_alternates_stages() {
        let g = generate::path(7);
        let stages = sweep_stages(&g);
        for u in 0..7 {
            assert_eq!(stages[u], u % 2);
        }
        for &(u, v) in &g.edges {
            let pred = edge_predecessor(&stages, u, v);
            assert_eq!(pred % 2, 0, "predecessors on a path are the even ids");
        }
    }

    /// The per-stage message schedule must total the classic two-round
    /// cost: 2m (full refresh) + 2m (every node ships its update once).
    #[test]
    fn stage_messages_total_4m() {
        let mut rng = Pcg64::new(115);
        for g in [
            generate::star(9),
            generate::grid(4, 5),
            generate::random_connected(12, 26, &mut rng),
        ] {
            let stages = sweep_stages(&g);
            let (per_stage, dual) = stage_message_schedule(&g, &stages);
            assert_eq!(per_stage[0], 2 * g.m() as u64);
            let total: u64 = per_stage.iter().sum::<u64>() + dual;
            assert_eq!(total, 4 * g.m() as u64, "schedule total drifted");
        }
    }

    /// One ADMM iteration charges stages+1 rounds and exactly 4m directed
    /// messages on the bulk transport.
    #[test]
    fn admm_iteration_charges_4m_messages() {
        let mut rng = Pcg64::new(116);
        let g = generate::random_connected(8, 14, &mut rng);
        let prob = datasets::synthetic_regression(8, 3, 80, 0.1, 0.05, &mut rng);
        let stages = sweep_stages(&g);
        let n_stages = stages.iter().max().unwrap() + 1;
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().messages, 4 * g.m() as u64);
        assert_eq!(comm.stats().rounds, n_stages as u64 + 1);
    }

    /// The per-stage over-compute is fixed: each sweep stage evaluates
    /// only its own independent set, so one iteration costs `n` row
    /// evaluations for the whole sweep (the stages partition the nodes)
    /// plus `n` for the dual round — not `(stages+1)·n` as the old
    /// full-matvec-per-stage kernel charged.
    #[test]
    fn row_evals_charge_one_sweep_plus_dual_per_iteration() {
        let mut rng = Pcg64::new(118);
        let g = generate::random_connected(9, 18, &mut rng);
        let prob = datasets::synthetic_regression(9, 3, 90, 0.1, 0.05, &mut rng);
        let n_stages = sweep_stages(&g).iter().max().unwrap() + 1;
        assert!(n_stages >= 2, "need a multi-stage sweep for the regression to bite");
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let iters = 3u64;
        for _ in 0..iters {
            alg.step(&prob, &mut comm);
        }
        let n = g.n as u64;
        assert_eq!(alg.row_evals, iters * 2 * n);
        // The pre-fix cost: every stage evaluated every owned row.
        assert!(alg.row_evals < iters * (n_stages as u64 + 1) * n);
    }

    /// Forwards everything to an inner [`CommGraph`] but keeps the
    /// *default* `exchange_apply_fresh_rows` (which computes the full-row
    /// superset) — the reference the masked kernel must match bit for
    /// bit.
    struct FullComputeRef<'g>(crate::net::CommGraph<'g>);

    impl Exchange for FullComputeRef<'_> {
        fn n(&self) -> usize {
            self.0.n()
        }
        fn owned(&self) -> &[usize] {
            Exchange::owned(&self.0)
        }
        fn exchange_apply(
            &mut self,
            a: &Csr,
            directed_messages: u64,
            x: &[f64],
            w: usize,
            out: &mut [f64],
        ) {
            self.0.exchange_apply(a, directed_messages, x, w, out);
        }
        fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
            self.0.laplacian_apply_into(x, w, out);
        }
        fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
            self.0.allreduce_sum(locals, w)
        }
        fn stats(&self) -> &crate::net::CommStats {
            self.0.stats()
        }
        fn stats_mut(&mut self) -> &mut crate::net::CommStats {
            self.0.stats_mut()
        }
    }

    /// Masked per-stage compute must be invisible in the iterates: the
    /// rows a stage consumes come out of the same per-row kernel whether
    /// or not the transport skips the masked-out rows.
    #[test]
    fn masked_stage_compute_matches_full_compute_bitwise() {
        let mut rng = Pcg64::new(117);
        let g = generate::random_connected(10, 20, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 120, 0.1, 0.05, &mut rng);
        let mut masked = Admm::new(&prob, &g, 1.0);
        let mut full = Admm::new(&prob, &g, 1.0);
        let mut comm_m = crate::net::CommGraph::new(&g);
        let mut comm_f = FullComputeRef(crate::net::CommGraph::new(&g));
        for it in 0..25 {
            masked.step(&prob, &mut comm_m);
            full.step(&prob, &mut comm_f);
            assert_eq!(masked.thetas(), full.thetas(), "iterates diverged at iteration {it}");
        }
        // The compute mask changes which kernels run, never the ledger.
        assert_eq!(comm_m.stats(), comm_f.0.stats());
    }

    /// The pipelined ship schedule reorders *when* rows cross the wire,
    /// never what any stage reads: iterates and modeled totals are
    /// bit-identical to the drained schedule.
    #[test]
    fn pipelined_wavefront_matches_drained_bitwise() {
        let mut rng = Pcg64::new(119);
        let g = generate::random_connected(11, 24, &mut rng);
        let prob = datasets::synthetic_regression(11, 3, 110, 0.1, 0.05, &mut rng);
        let n_stages = sweep_stages(&g).iter().max().unwrap() + 1;
        let mut drained = Admm::new(&prob, &g, 1.0);
        let mut pipelined = Admm::new_pipelined(&prob, &g, 1.0);
        assert!(pipelined.pipelined && !drained.pipelined);
        let mut comm_d = crate::net::CommGraph::new(&g);
        let mut comm_p = crate::net::CommGraph::new(&g);
        let iters = 20u64;
        for it in 0..iters {
            drained.step(&prob, &mut comm_d);
            pipelined.step(&prob, &mut comm_p);
            assert_eq!(
                drained.thetas(),
                pipelined.thetas(),
                "iterates diverged at iteration {it}"
            );
        }
        // Same modeled totals: 4m messages over stages+1 rounds per
        // iteration, and the same row-evaluation count.
        assert_eq!(comm_p.stats().messages, iters * 4 * g.m() as u64);
        assert_eq!(comm_p.stats().rounds, iters * (n_stages as u64 + 1));
        assert_eq!(comm_d.stats().messages, comm_p.stats().messages);
        assert_eq!(comm_d.stats().rounds, comm_p.stats().rounds);
        assert_eq!(drained.row_evals, pipelined.row_evals);
    }

    /// The pipelined schedule is conservative and fresh: round 0 ships
    /// the full halo, every node ships its update exactly once afterwards
    /// (never before its own stage has run), every reader's lower-stage
    /// neighbor has shipped by the reader's round, and the charges total
    /// 4m.
    #[test]
    fn pipelined_schedule_ships_each_update_exactly_once() {
        let mut rng = Pcg64::new(120);
        for g in [
            generate::star(8),
            generate::path(9),
            generate::grid(3, 4),
            generate::random_connected(13, 28, &mut rng),
        ] {
            let stages = sweep_stages(&g);
            let (masks, msgs, dual_mask, dual_msgs) = pipelined_ship_schedule(&g, &stages);
            assert!(masks[0].iter().all(|&b| b), "round 0 must refresh the full halo");
            assert_eq!(msgs[0], 2 * g.m() as u64);
            for u in 0..g.n {
                let ships =
                    masks[1..].iter().filter(|m| m[u]).count() + dual_mask[u] as usize;
                assert_eq!(ships, 1, "node {u} must ship its update exactly once");
                for (s, m) in masks.iter().enumerate().skip(1) {
                    if m[u] {
                        assert!(
                            stages[u] < s,
                            "node {u} shipped at round {s} before updating at stage {}",
                            stages[u]
                        );
                    }
                }
                // Freshness: every lower-stage neighbor of u has shipped
                // by u's own round — the invariant bit-identity rests on.
                for &v in g.neighbors(u) {
                    if stages[v] < stages[u] {
                        let shipped_at = (1..masks.len()).find(|&s| masks[s][v]);
                        assert!(
                            shipped_at.is_some_and(|s| s <= stages[u]),
                            "neighbor {v} of {u} not fresh by stage {}",
                            stages[u]
                        );
                    }
                }
            }
            let total: u64 = msgs.iter().sum::<u64>() + dual_msgs;
            assert_eq!(total, 4 * g.m() as u64, "pipelined schedule total drifted");
        }
    }
}
