//! Distributed ADMM (Wei & Ozdaglar [2]; Appendix H.1.1 / H.2.1).
//!
//! Edge-based consensus with Gauss–Seidel primal sweeps: node `i` updates
//!
//! `θ_i ← argmin_θ f_i(θ) + (β/2) Σ_{j∈P(i)} ‖θ_j^{k+1} − θ − λ_ji/β‖²
//!                        + (β/2) Σ_{j∈S(i)} ‖θ − θ_j^{k} − λ_ij/β‖²`
//!
//! with predecessors `P(i)` (neighbors that update earlier in the sweep)
//! and successors `S(i)` (neighbors that update later), followed by the
//! dual update `λ_ji ← λ_ji − β(θ_j − θ_i)` per directed edge. The inner
//! argmin is solved by damped Newton (one step is exact for quadratics).
//!
//! # Sharded sweep schedule
//!
//! The sweep order is a dependency: `θ_i` needs the *fresh* values of its
//! predecessors. A literal node-id sweep serializes the whole graph, so
//! instead the sweep runs as a wavefront over the stages of a greedy
//! proper coloring ([`sweep_stages`]): each stage is an independent set,
//! all its nodes update concurrently from fresh lower-stage + stale
//! higher-stage neighbor values, and one boundary round per stage ships
//! the freshly updated values. The schedule depends only on the graph —
//! never on the node→worker partition — which is what keeps the iterates
//! bit-for-bit identical across transports and partitionings (the
//! documented fallback to per-stage boundary rounds; a pipelined
//! node-order wavefront over contiguous shards would tie the trajectory
//! to the partitioning).
//!
//! # Aggregated duals
//!
//! The primal update only reads its incident duals through
//! `s_i = Σ_j θ_j^{mixed} + μ_i/β` with
//! `μ_i = Σ_{j∈S(i)} λ_ij − Σ_{j∈P(i)} λ_ji`, and the per-edge dual
//! update aggregates to `μ_i ← μ_i − β (L θ^{k+1})_i` — *independent* of
//! the edge orientation. Keeping only `μ` makes the whole dual state
//! node-local: the sweep needs one adjacency application per stage and
//! the dual update one Laplacian application, all through
//! [`Exchange::exchange_apply`].
//!
//! # Message accounting
//!
//! Stage 0 refreshes the full halo (`2m` directed messages); stage `s>0`
//! only ships the values stage `s−1` just updated (their degree sum); the
//! dual round ships the last stage's updates. The per-iteration total is
//! `2m + Σ_u deg(u) = 4m` — identical to the classic two-round
//! gather formulation. The wire matches the model: every round goes
//! through [`Exchange::exchange_apply_fresh`] with the stage's fresh-row
//! mask, so a plan-driven transport ships only that stage's active
//! boundary rows instead of re-shipping the whole halo each stage (the
//! over-shipping the `prop_wire` suite regression-tests).

use super::ConsensusAlgorithm;
use crate::graph::Graph;
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::problems::ConsensusProblem;

/// Greedy proper coloring in node-id order — the Gauss–Seidel sweep
/// schedule. Adjacent nodes always land in different stages, so each
/// stage is an independent set and every edge has exactly one
/// *predecessor* endpoint (the lower stage), which updates strictly
/// earlier in the sweep. Depends only on the graph topology, never on
/// the node→worker partition.
pub fn sweep_stages(g: &Graph) -> Vec<usize> {
    let mut stage = vec![usize::MAX; g.n];
    for u in 0..g.n {
        // At most deg(u) neighbors are already colored, so a free stage
        // always exists within 0..=deg(u).
        let mut used = vec![false; g.degree(u) + 1];
        for &v in g.neighbors(u) {
            if stage[v] != usize::MAX && stage[v] < used.len() {
                used[stage[v]] = true;
            }
        }
        // sddn-lint: allow(panic) reason=at most deg(u) stages are taken, so a free stage exists within 0..=deg(u) by pigeonhole
        stage[u] = used.iter().position(|&b| !b).unwrap();
    }
    stage
}

/// The predecessor endpoint of edge `(u, v)` under a sweep schedule: the
/// endpoint that updates first (strictly lower stage — a proper coloring
/// guarantees the stages differ).
pub fn edge_predecessor(stages: &[usize], u: usize, v: usize) -> usize {
    assert_ne!(stages[u], stages[v], "({u},{v}) is not properly colored");
    if stages[u] < stages[v] {
        u
    } else {
        v
    }
}

/// Directed-message schedule of one ADMM iteration: per sweep stage the
/// charged message count (stage 0 ships the full halo, stage `s>0` ships
/// stage `s−1`'s fresh values), plus the dual round (the last stage's
/// fresh values). Sums to `4m` per iteration.
pub fn stage_message_schedule(g: &Graph, stages: &[usize]) -> (Vec<u64>, u64) {
    let n_stages = stages.iter().max().map(|&s| s + 1).unwrap_or(0);
    let degsum_of = |s: usize| -> u64 {
        (0..g.n).filter(|&u| stages[u] == s).map(|u| g.degree(u) as u64).sum()
    };
    let mut per_stage = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        per_stage.push(if s == 0 { 2 * g.m() as u64 } else { degsum_of(s - 1) });
    }
    (per_stage, degsum_of(n_stages - 1))
}

/// ADMM state (one shard's view).
pub struct Admm {
    /// Penalty parameter β.
    pub beta: f64,
    /// Inner-Newton iterations for the primal argmin (1 suffices for
    /// quadratics; logistic needs a handful).
    pub inner_iters: usize,
    /// Stacked primal iterate, local_n × p.
    thetas: Vec<f64>,
    /// Aggregated incident duals μ_i, local_n × p.
    mu: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Sweep stage of every global node.
    stage_of: Vec<usize>,
    /// Number of sweep stages.
    stages: usize,
    /// Fresh-row masks: `stage_masks[s][u]` ⇔ `stage_of[u] == s` — what a
    /// plan-driven transport ships after stage `s` updates.
    stage_masks: Vec<Vec<bool>>,
    /// All-rows mask for the stage-0 full halo refresh.
    full_mask: Vec<bool>,
    /// Directed messages charged per sweep stage.
    stage_msgs: Vec<u64>,
    /// Directed messages charged for the dual round.
    dual_msgs: u64,
    /// Global adjacency (neighbor sums of the sweep).
    adjacency: Csr,
    /// Global Laplacian (the aggregated dual update).
    laplacian: Csr,
    /// Global degrees d_i (the β d_i proximal shift).
    degree: Vec<f64>,
    p: usize,
}

impl Admm {
    /// Initialize at θ = 0, μ = 0, owning every node.
    pub fn new(problem: &ConsensusProblem, g: &Graph, beta: f64) -> Admm {
        Self::new_sharded(problem, g, beta, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &Graph,
        beta: f64,
        owned: Vec<usize>,
    ) -> Admm {
        let p = problem.p;
        let stage_of = sweep_stages(g);
        let stages = stage_of.iter().max().map(|&s| s + 1).unwrap_or(0);
        let (stage_msgs, dual_msgs) = stage_message_schedule(g, &stage_of);
        let stage_masks: Vec<Vec<bool>> = (0..stages)
            .map(|s| (0..g.n).map(|u| stage_of[u] == s).collect())
            .collect();
        Admm {
            beta,
            inner_iters: 8,
            thetas: vec![0.0; owned.len() * p],
            mu: vec![0.0; owned.len() * p],
            owned,
            stage_of,
            stages,
            stage_masks,
            full_mask: vec![true; g.n],
            stage_msgs,
            dual_msgs,
            adjacency: crate::graph::laplacian::adjacency_csr(g),
            laplacian: crate::graph::laplacian_csr(g),
            degree: crate::graph::laplacian::degrees(g),
            p,
        }
    }
}

impl ConsensusAlgorithm for Admm {
    fn name(&self) -> String {
        "Distributed ADMM".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        let beta = self.beta;

        // Gauss–Seidel sweep as a stage wavefront: each stage refreshes
        // the neighbor sums (fresh lower-stage + stale higher-stage
        // values) and updates its independent set. Known trade-off: the
        // exchange primitive computes every owned row each stage though
        // only the stage's independent set consumes the result — S full
        // matvecs per iteration instead of one. Sparse graphs color in
        // few stages so the redundancy is small, and sharing the full-row
        // kernel with the bulk transport is what keeps the two paths
        // bit-for-bit identical; a row-subset exchange variant is the
        // obvious follow-up if ADMM compute ever dominates.
        let mut work = self.thetas.clone();
        for s in 0..self.stages {
            let mut nbr = vec![0.0; ln * p];
            // Stage 0 refreshes the full halo (`work` = θ^k everywhere);
            // stage s>0 only ships the rows stage s−1 just updated — on a
            // plan-driven transport exactly the stage's active boundary
            // crosses the wire, matching the modeled per-stage charge.
            let fresh = if s == 0 { &self.full_mask } else { &self.stage_masks[s - 1] };
            // sddn-lint: graph-support adjacency sparsity is exactly the comm graph
            exch.exchange_apply_fresh(
                &self.adjacency,
                fresh,
                self.stage_msgs[s],
                &work,
                p,
                &mut nbr,
            );
            for (li, &u) in self.owned.iter().enumerate() {
                if self.stage_of[u] != s {
                    continue;
                }
                // s_i = Σ_{j∈N(i)} θ_j^{mixed} + μ_i/β.
                let mut si = vec![0.0; p];
                for r in 0..p {
                    si[r] = nbr[li * p + r] + self.mu[li * p + r] / beta;
                }
                // Damped Newton on
                // ξ_i(θ) = f_i(θ) + (β d_i/2)‖θ‖² − β s_iᵀθ + const.
                let local = &problem.locals[u];
                let mut theta = work[li * p..(li + 1) * p].to_vec();
                for _ in 0..self.inner_iters {
                    let mut grad = local.gradient(&theta);
                    for r in 0..p {
                        grad[r] += beta * self.degree[u] * theta[r] - beta * si[r];
                    }
                    if crate::linalg::vector::norm2(&grad) < 1e-12 {
                        break;
                    }
                    let step = local.solve_shifted(&theta, &grad, beta * self.degree[u]);
                    for r in 0..p {
                        theta[r] -= step[r];
                    }
                }
                work[li * p..(li + 1) * p].copy_from_slice(&theta);
            }
        }

        // Aggregated dual update μ ← μ − β (L θ^{k+1}): one more boundary
        // round shipping the final stage's fresh values.
        let mut lap = vec![0.0; ln * p];
        let last = &self.stage_masks[self.stages - 1];
        // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph plus diagonal
        exch.exchange_apply_fresh(&self.laplacian, last, self.dual_msgs, &work, p, &mut lap);
        for i in 0..ln * p {
            self.mu[i] -= beta * lap[i];
        }
        self.thetas = work;
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn admm_converges_on_quadratic() {
        let mut rng = Pcg64::new(111);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 300, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-4, "gap={gap}");
        assert!(trace.final_consensus_error() < 1e-2);
    }

    #[test]
    fn admm_converges_on_logistic() {
        let mut rng = Pcg64::new(112);
        let g = generate::random_connected(6, 12, &mut rng);
        let prob = datasets::mnist_like(
            6,
            6,
            180,
            0,
            crate::problems::logistic::Reg::L2,
            0.05,
            &mut rng,
        );
        let (_, f_star) = prob.centralized_optimum(80, 1e-10);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 250, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-3, "gap={gap}");
    }

    #[test]
    fn objective_monotone_ish_late() {
        // ADMM oscillates early but should settle; check last quarter is
        // within a tight band.
        let mut rng = Pcg64::new(113);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 90, 0.1, 0.05, &mut rng);
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 200, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        let tail = &objs[150..];
        let spread = tail.iter().cloned().fold(f64::MIN, f64::max)
            - tail.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread < 1e-3 * objs[0].abs().max(1.0), "spread={spread}");
    }

    /// The sweep schedule is a proper coloring: adjacent nodes never
    /// share a stage, so every edge has exactly one predecessor — the
    /// invariant the Gauss–Seidel dependency rests on.
    #[test]
    fn sweep_stages_are_a_proper_coloring() {
        let mut rng = Pcg64::new(114);
        for g in [
            generate::star(8),
            generate::path(9),
            generate::grid(3, 4),
            generate::random_connected(14, 30, &mut rng),
        ] {
            let stages = sweep_stages(&g);
            let max_deg = (0..g.n).map(|u| g.degree(u)).max().unwrap();
            for &(u, v) in &g.edges {
                assert_ne!(stages[u], stages[v], "edge ({u},{v}) shares stage");
                // Exactly one predecessor, and it updates strictly earlier.
                let pred = edge_predecessor(&stages, u, v);
                let succ = if pred == u { v } else { u };
                assert!(stages[pred] < stages[succ]);
                assert_eq!(pred, edge_predecessor(&stages, v, u), "direction not symmetric");
            }
            // Greedy bound: at most Δ+1 stages.
            assert!(*stages.iter().max().unwrap() <= max_deg);
        }
    }

    /// Bipartite orderings collapse to two stages: on a path the stages
    /// alternate, and node-id order makes even ids the predecessors.
    #[test]
    fn path_sweep_alternates_stages() {
        let g = generate::path(7);
        let stages = sweep_stages(&g);
        for u in 0..7 {
            assert_eq!(stages[u], u % 2);
        }
        for &(u, v) in &g.edges {
            let pred = edge_predecessor(&stages, u, v);
            assert_eq!(pred % 2, 0, "predecessors on a path are the even ids");
        }
    }

    /// The per-stage message schedule must total the classic two-round
    /// cost: 2m (full refresh) + 2m (every node ships its update once).
    #[test]
    fn stage_messages_total_4m() {
        let mut rng = Pcg64::new(115);
        for g in [
            generate::star(9),
            generate::grid(4, 5),
            generate::random_connected(12, 26, &mut rng),
        ] {
            let stages = sweep_stages(&g);
            let (per_stage, dual) = stage_message_schedule(&g, &stages);
            assert_eq!(per_stage[0], 2 * g.m() as u64);
            let total: u64 = per_stage.iter().sum::<u64>() + dual;
            assert_eq!(total, 4 * g.m() as u64, "schedule total drifted");
        }
    }

    /// One ADMM iteration charges stages+1 rounds and exactly 4m directed
    /// messages on the bulk transport.
    #[test]
    fn admm_iteration_charges_4m_messages() {
        let mut rng = Pcg64::new(116);
        let g = generate::random_connected(8, 14, &mut rng);
        let prob = datasets::synthetic_regression(8, 3, 80, 0.1, 0.05, &mut rng);
        let stages = sweep_stages(&g);
        let n_stages = stages.iter().max().unwrap() + 1;
        let mut alg = Admm::new(&prob, &g, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().messages, 4 * g.m() as u64);
        assert_eq!(comm.stats().rounds, n_stages as u64 + 1);
    }
}
