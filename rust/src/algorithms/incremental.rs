//! Incremental SDD-Newton — the extension sketched in the paper's
//! conclusions ("Our next step is to develop incremental versions of this
//! algorithm").
//!
//! Per outer iteration only a fraction ρ of nodes refresh their primal
//! recovery `y_i = φ_i((LΛ)_i)` (the per-node Newton solve that dominates
//! local computation for logistic problems); the rest reuse their cached
//! `y_i`. The dual gradient `M y` then mixes fresh and stale blocks — an
//! inexactness that Theorem 1's ε-analysis absorbs as long as staleness
//! stays bounded: nodes are refreshed round-robin so every node is at
//! most ⌈1/ρ⌉ iterations stale.

use super::solvers::LaplacianSolver;
use super::ConsensusAlgorithm;
use crate::net::{CommGraph, Exchange};
use crate::problems::ConsensusProblem;
use crate::runtime::LocalBackend;

/// Incremental SDD-Newton state.
pub struct IncrementalSddNewton<'a> {
    backend: &'a dyn LocalBackend,
    solver: &'a dyn LaplacianSolver,
    /// Step size α.
    pub alpha: f64,
    /// Fraction of nodes refreshed per iteration (ρ ∈ (0, 1]).
    pub refresh_fraction: f64,
    lambda: Vec<f64>,
    y: Vec<f64>,
    /// Round-robin refresh cursor.
    cursor: usize,
    /// Count of per-node primal recoveries actually performed.
    pub recover_count: u64,
    p: usize,
}

impl<'a> IncrementalSddNewton<'a> {
    /// Initialize at λ = 0 with a full refresh.
    pub fn new(
        problem: &ConsensusProblem,
        backend: &'a dyn LocalBackend,
        solver: &'a dyn LaplacianSolver,
        alpha: f64,
        refresh_fraction: f64,
    ) -> IncrementalSddNewton<'a> {
        assert!(refresh_fraction > 0.0 && refresh_fraction <= 1.0);
        let (n, p) = (problem.n(), problem.p);
        let mut y = vec![0.0; n * p];
        backend.primal_recover_all(problem, &vec![0.0; n * p], &mut y);
        IncrementalSddNewton {
            backend,
            solver,
            alpha,
            refresh_fraction,
            lambda: vec![0.0; n * p],
            y,
            cursor: 0,
            recover_count: n as u64,
            p,
        }
    }

    /// Refresh the primal iterate on the next round-robin block of nodes.
    fn partial_refresh(&mut self, problem: &ConsensusProblem, v: &[f64]) {
        let n = problem.n();
        let p = self.p;
        let k = ((n as f64 * self.refresh_fraction).ceil() as usize).clamp(1, n);
        // Recover the whole batch once, copy only the refreshed block.
        // (The batched artifact computes all nodes anyway; a deployment
        // with per-node workers would invoke only the k selected solvers —
        // we count those k in `recover_count`.)
        let mut fresh = vec![0.0; n * p];
        self.backend.primal_recover_all(problem, v, &mut fresh);
        for j in 0..k {
            let i = (self.cursor + j) % n;
            self.y[i * p..(i + 1) * p].copy_from_slice(&fresh[i * p..(i + 1) * p]);
        }
        self.cursor = (self.cursor + k) % n;
        self.recover_count += k as u64;
    }
}

impl ConsensusAlgorithm for IncrementalSddNewton<'_> {
    fn name(&self) -> String {
        format!("Incremental SDD-Newton (ρ={})", self.refresh_fraction)
    }

    fn step(&mut self, problem: &ConsensusProblem, comm: &mut CommGraph) {
        let p = self.p;
        let n = problem.n();

        // (1) partial primal refresh.
        let v = comm.laplacian_apply(&self.lambda, p);
        self.partial_refresh(problem, &v);

        // (2) dual gradient with the mixed fresh/stale primal.
        let g = comm.laplacian_apply(&self.y, p);

        // (3–5) same splitting as the full method, with the closed-form
        // first solve (centering) to keep the incremental variant lean.
        let mut z = self.y.clone();
        comm.center(&mut z, p);
        let mut b = vec![0.0; n * p];
        self.backend.hess_apply_all(problem, &self.y, &z, &mut b);
        // Kernel-consistency correction.
        let hsum = self.backend.hess_sum(problem, &self.y);
        let mut bsum = vec![0.0; p];
        for i in 0..n {
            for r in 0..p {
                bsum[r] += b[i * p + r];
            }
        }
        comm.stats_mut().record_allreduce(n, p * p + p);
        if let Ok(c) = crate::linalg::cholesky::spd_solve(&hsum, &bsum) {
            let tiled: Vec<f64> = (0..n).flat_map(|_| c.iter().map(|v| -v)).collect();
            let mut bc = vec![0.0; n * p];
            self.backend.hess_apply_all(problem, &self.y, &tiled, &mut bc);
            for i in 0..n * p {
                b[i] += bc[i];
            }
        }
        let d = self.solver.solve(&b, p, comm).x;

        // (6) dual ascent.
        for i in 0..n * p {
            self.lambda[i] += self.alpha * d[i];
        }
        let _ = g;
    }

    fn thetas(&self) -> &[f64] {
        &self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::solvers::sddm_for_graph;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg64;

    #[test]
    fn incremental_converges_with_partial_refresh() {
        let mut rng = Pcg64::new(601);
        let g = generate::random_connected(12, 28, &mut rng);
        let prob = datasets::synthetic_regression(12, 4, 240, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let solver = sddm_for_graph(&g, 1e-3, &mut rng);
        let backend = NativeBackend;
        let mut alg =
            IncrementalSddNewton::new(&prob, &backend, &solver, 0.8, 0.34);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 60, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
        // Stale blocks bound the attainable accuracy (ε-neighborhood of
        // Theorem 1 with ε set by the staleness); partial refresh must
        // still reach a tight neighborhood.
        assert!(gap < 1e-3, "gap={gap}");
        assert!(
            trace.final_consensus_error() < 1e-2 * trace.records[0].consensus_error.max(1.0)
        );
    }

    #[test]
    fn full_refresh_matches_regular_behaviour() {
        let mut rng = Pcg64::new(602);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let solver = sddm_for_graph(&g, 1e-5, &mut rng);
        let backend = NativeBackend;
        let mut alg = IncrementalSddNewton::new(&prob, &backend, &solver, 1.0, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 10, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
        assert!(gap < 1e-9, "gap={gap}");
    }

    #[test]
    fn smaller_fraction_slows_but_does_not_break() {
        let mut rng = Pcg64::new(603);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let backend = NativeBackend;
        let gap_at = |rho: f64, iters: usize| {
            let mut rng2 = Pcg64::new(604);
            let solver = sddm_for_graph(&g, 1e-4, &mut rng2);
            // Staleness acts like a delayed direction: damp the step by ρ
            // (the usual remedy for asynchronous/delayed updates).
            let alpha = 0.8 * rho.sqrt();
            let mut alg = IncrementalSddNewton::new(&prob, &backend, &solver, alpha, rho);
            let mut comm = crate::net::CommGraph::new(&g);
            let trace = run(
                &mut alg,
                &prob,
                &mut comm,
                &RunOptions { max_iters: iters, ..Default::default() },
            );
            (trace.final_objective() - f_star).abs() / f_star.abs()
        };
        let fast = gap_at(1.0, 8);
        let slow = gap_at(0.25, 8);
        assert!(fast < slow, "full refresh should lead at equal iterations");
        let g80 = gap_at(0.25, 80);
        assert!(
            g80 < 1e-2,
            "partial refresh must still reach a tight neighborhood: gap={g80}"
        );
    }
}
