//! Incremental SDD-Newton — the extension sketched in the paper's
//! conclusions ("Our next step is to develop incremental versions of this
//! algorithm").
//!
//! Per outer iteration only a fraction ρ of nodes refresh their primal
//! recovery `y_i = φ_i((LΛ)_i)` (the per-node Newton solve that dominates
//! local computation for logistic problems); the rest reuse their cached
//! `y_i`. The dual gradient `M y` then mixes fresh and stale blocks — an
//! inexactness that Theorem 1's ε-analysis absorbs as long as staleness
//! stays bounded: the refresh window walks the *global* node ids
//! round-robin, so every node is at most ⌈1/ρ⌉ iterations stale and the
//! schedule is identical on every shard. The step itself runs against
//! the [`Exchange`] trait (centering first solve, a real p²+p all-reduce
//! for the kernel correction), bit-for-bit across transports.

use super::solvers::LaplacianSolver;
use super::ConsensusAlgorithm;
use crate::net::Exchange;
use crate::problems::ConsensusProblem;
use crate::runtime::LocalBackend;

/// Incremental SDD-Newton state (one shard's view).
pub struct IncrementalSddNewton<'a> {
    backend: &'a dyn LocalBackend,
    solver: &'a dyn LaplacianSolver,
    /// Step size α.
    pub alpha: f64,
    /// Fraction of nodes refreshed per iteration (ρ ∈ (0, 1]).
    pub refresh_fraction: f64,
    /// Dual iterate, stacked local_n × p.
    lambda: Vec<f64>,
    /// Cached primal iterate, stacked local_n × p.
    y: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Global node count.
    n: usize,
    /// Round-robin refresh cursor over *global* node ids (identical on
    /// every shard).
    cursor: usize,
    /// Count of per-node primal recoveries this shard actually performed.
    pub recover_count: u64,
    p: usize,
}

impl<'a> IncrementalSddNewton<'a> {
    /// Initialize at λ = 0 with a full refresh, owning every node.
    pub fn new(
        problem: &ConsensusProblem,
        backend: &'a dyn LocalBackend,
        solver: &'a dyn LaplacianSolver,
        alpha: f64,
        refresh_fraction: f64,
    ) -> IncrementalSddNewton<'a> {
        let owned = (0..problem.n()).collect();
        Self::new_sharded(problem, backend, solver, alpha, refresh_fraction, owned)
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        backend: &'a dyn LocalBackend,
        solver: &'a dyn LaplacianSolver,
        alpha: f64,
        refresh_fraction: f64,
        owned: Vec<usize>,
    ) -> IncrementalSddNewton<'a> {
        assert!(refresh_fraction > 0.0 && refresh_fraction <= 1.0);
        let (n, p) = (problem.n(), problem.p);
        let ln = owned.len();
        let v0 = vec![0.0; ln * p];
        let mut y = vec![0.0; ln * p];
        backend.primal_recover_nodes(problem, &owned, &v0, &mut y);
        IncrementalSddNewton {
            backend,
            solver,
            alpha,
            refresh_fraction,
            lambda: vec![0.0; ln * p],
            y,
            owned,
            n,
            cursor: 0,
            recover_count: ln as u64,
            p,
        }
    }

    /// Refresh the primal iterate on the owned slice of the next global
    /// round-robin window `[cursor, cursor + k) mod n`.
    fn partial_refresh(&mut self, problem: &ConsensusProblem, v: &[f64]) {
        let n = self.n;
        let p = self.p;
        let k = ((n as f64 * self.refresh_fraction).ceil() as usize).clamp(1, n);
        let cursor = self.cursor;
        let in_window = |u: usize| (u + n - cursor) % n < k;
        let mut nodes = Vec::new();
        let mut locs = Vec::new();
        for (li, &u) in self.owned.iter().enumerate() {
            if in_window(u) {
                nodes.push(u);
                locs.push(li);
            }
        }
        let mut vs = vec![0.0; nodes.len() * p];
        for (t, &li) in locs.iter().enumerate() {
            vs[t * p..(t + 1) * p].copy_from_slice(&v[li * p..(li + 1) * p]);
        }
        let mut fresh = vec![0.0; nodes.len() * p];
        self.backend.primal_recover_nodes(problem, &nodes, &vs, &mut fresh);
        for (t, &li) in locs.iter().enumerate() {
            self.y[li * p..(li + 1) * p].copy_from_slice(&fresh[t * p..(t + 1) * p]);
        }
        self.cursor = (self.cursor + k) % n;
        self.recover_count += nodes.len() as u64;
    }
}

impl ConsensusAlgorithm for IncrementalSddNewton<'_> {
    fn name(&self) -> String {
        format!("Incremental SDD-Newton (ρ={})", self.refresh_fraction)
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();

        // (1) partial primal refresh at the current λ.
        let v = exch.laplacian_apply(&self.lambda, p);
        self.partial_refresh(problem, &v);

        // (2–3) closed-form first solve (centering) on the mixed
        // fresh/stale primal — one all-reduce.
        let mut z = self.y.clone();
        exch.center(&mut z, p);

        // (4) b_i = ∇²f_i(y_i) z_i — local.
        let mut b = vec![0.0; ln * p];
        self.backend.hess_apply_nodes(problem, &self.owned, &self.y, &z, &mut b);

        // (4b) kernel-consistency correction: solve `(Σ_i ∇²f_i) c = −Σ_i b_i`
        // — the sums are one p²+p all-reduce — and shift `b ← b + ∇²f (1 ⊗ c)`.
        let wk = p * p + p;
        let mut hblocks = vec![0.0; ln * p * p];
        self.backend.hess_nodes(problem, &self.owned, &self.y, &mut hblocks);
        let mut locals = vec![0.0; ln * wk];
        for li in 0..ln {
            locals[li * wk..li * wk + p * p]
                .copy_from_slice(&hblocks[li * p * p..(li + 1) * p * p]);
            locals[li * wk + p * p..(li + 1) * wk].copy_from_slice(&b[li * p..(li + 1) * p]);
        }
        let tot = exch.allreduce_sum(&locals, wk);
        let hsum = crate::linalg::Matrix::from_rows(p, p, tot[..p * p].to_vec());
        let bsum = &tot[p * p..];
        if let Ok(c) = crate::linalg::cholesky::spd_solve(&hsum, bsum) {
            let tiled: Vec<f64> = (0..ln).flat_map(|_| c.iter().map(|v| -v)).collect();
            let mut bc = vec![0.0; ln * p];
            self.backend.hess_apply_nodes(problem, &self.owned, &self.y, &tiled, &mut bc);
            for i in 0..ln * p {
                b[i] += bc[i];
            }
        }

        // (5) M d = b.
        let d = self.solver.solve(&b, p, exch).x;

        // (6) dual ascent.
        for i in 0..ln * p {
            self.lambda[i] += self.alpha * d[i];
        }
    }

    fn thetas(&self) -> &[f64] {
        &self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::solvers::sddm_for_graph;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg64;

    #[test]
    fn incremental_converges_with_partial_refresh() {
        let mut rng = Pcg64::new(601);
        let g = generate::random_connected(12, 28, &mut rng);
        let prob = datasets::synthetic_regression(12, 4, 240, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let solver = sddm_for_graph(&g, 1e-3, &mut rng);
        let backend = NativeBackend;
        let mut alg =
            IncrementalSddNewton::new(&prob, &backend, &solver, 0.8, 0.34);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 60, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
        // Stale blocks bound the attainable accuracy (ε-neighborhood of
        // Theorem 1 with ε set by the staleness); partial refresh must
        // still reach a tight neighborhood.
        assert!(gap < 1e-3, "gap={gap}");
        assert!(
            trace.final_consensus_error() < 1e-2 * trace.records[0].consensus_error.max(1.0)
        );
    }

    #[test]
    fn full_refresh_matches_regular_behaviour() {
        let mut rng = Pcg64::new(602);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let solver = sddm_for_graph(&g, 1e-5, &mut rng);
        let backend = NativeBackend;
        let mut alg = IncrementalSddNewton::new(&prob, &backend, &solver, 1.0, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 10, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs();
        assert!(gap < 1e-9, "gap={gap}");
    }

    #[test]
    fn smaller_fraction_slows_but_does_not_break() {
        let mut rng = Pcg64::new(603);
        let g = generate::random_connected(10, 22, &mut rng);
        let prob = datasets::synthetic_regression(10, 3, 150, 0.2, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-11);
        let backend = NativeBackend;
        let gap_at = |rho: f64, iters: usize| {
            let mut rng2 = Pcg64::new(604);
            let solver = sddm_for_graph(&g, 1e-4, &mut rng2);
            // Staleness acts like a delayed direction: damp the step by ρ
            // (the usual remedy for asynchronous/delayed updates).
            let alpha = 0.8 * rho.sqrt();
            let mut alg = IncrementalSddNewton::new(&prob, &backend, &solver, alpha, rho);
            let mut comm = crate::net::CommGraph::new(&g);
            let trace = run(
                &mut alg,
                &prob,
                &mut comm,
                &RunOptions { max_iters: iters, ..Default::default() },
            );
            (trace.final_objective() - f_star).abs() / f_star.abs()
        };
        let fast = gap_at(1.0, 8);
        let slow = gap_at(0.25, 8);
        assert!(fast < slow, "full refresh should lead at equal iterations");
        let g80 = gap_at(0.25, 80);
        assert!(
            g80 < 1e-2,
            "partial refresh must still reach a tight neighborhood: gap={g80}"
        );
    }

    /// The refresh window is keyed to global ids: ⌈ρn⌉ recoveries per
    /// iteration regardless of how the work is counted up.
    #[test]
    fn refresh_window_walks_all_nodes_round_robin() {
        let mut rng = Pcg64::new(605);
        let g = generate::random_connected(9, 18, &mut rng);
        let prob = datasets::synthetic_regression(9, 3, 90, 0.2, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-3, &mut rng);
        let backend = NativeBackend;
        let mut alg = IncrementalSddNewton::new(&prob, &backend, &solver, 0.5, 0.34);
        let per_iter = (9.0f64 * 0.34).ceil() as u64;
        let base = alg.recover_count;
        let mut comm = crate::net::CommGraph::new(&g);
        for it in 1..=3 {
            alg.step(&prob, &mut comm);
            assert_eq!(alg.recover_count, base + it * per_iter);
        }
    }
}
