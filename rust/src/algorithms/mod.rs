//! The six consensus algorithms evaluated in the paper.
//!
//! | Module | Paper name | Kind |
//! |---|---|---|
//! | [`sdd_newton`] | Distributed SDD-Newton (the contribution) | dual 2nd-order |
//! | [`sdd_newton`] w/ [`solvers::NeumannSolver`] | Distributed Newton ADD [8] | dual 2nd-order |
//! | [`admm`] | Distributed ADMM [2] | dual decomposition |
//! | [`gradient`] | Distributed (sub)gradients [1] | primal 1st-order |
//! | [`averaging`] | Distributed averaging [13] | primal 1st-order |
//! | [`network_newton`] | Network Newton-K [9,10] | penalty 2nd-order |
//! | [`incremental`] | Incremental SDD-Newton (conclusions) | dual 2nd-order |
//! | [`local_steps`] | Local-step Newton (ADAPD-style) | primal-dual, comm-avoiding |
//!
//! Every algorithm implements [`ConsensusAlgorithm::step`] against the
//! [`crate::net::Exchange`] trait with **shard-local** buffers, so the
//! identical step code runs on the bulk-synchronous
//! [`crate::net::CommGraph`] (one instance owning every node) and on the
//! partitioned worker runtime
//! ([`crate::coordinator::run_partitioned_baseline`], one sharded
//! instance per worker thread) — bit-for-bit, including the modeled
//! message ledger (`tests/prop_parallel.rs`). Neighbor access goes
//! through graph-support CSR operators (`exchange_apply`), never through
//! per-neighbor gathers, which keeps the implementations honestly
//! distributed and the message counts exact. ADMM's Gauss–Seidel sweep is
//! scheduled over greedy-coloring stages (see [`admm::sweep_stages`]) so
//! its sequential dependency survives sharding.

pub mod solvers;
pub mod sdd_newton;
pub mod incremental;
pub mod admm;
pub mod gradient;
pub mod averaging;
pub mod network_newton;
pub mod local_steps;

use crate::linalg::Csr;
use crate::net::{CommGraph, CommStats, Exchange};
use crate::problems::ConsensusProblem;

/// One row of a convergence trace.
#[derive(Debug, Clone)]
pub struct IterRecord {
    /// Outer iteration index (0 = initial point).
    pub iter: usize,
    /// Global objective Σ f_i(θ_i) at the stacked iterate.
    pub objective: f64,
    /// Consensus error √(Σ‖θ_i − θ̄‖²).
    pub consensus_error: f64,
    /// Cumulative communication at the *end* of this iteration.
    pub comm: CommStats,
    /// Wall-clock seconds since the run started.
    pub elapsed: f64,
}

/// A full run trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub algorithm: String,
    pub records: Vec<IterRecord>,
    /// Final stacked per-node iterate (n×p) — lets callers evaluate the
    /// consensus solution (e.g. `objective_at(mean)` scoring, policy
    /// evaluation) without re-running.
    pub final_thetas: Vec<f64>,
}

impl Trace {
    /// Final objective.
    pub fn final_objective(&self) -> f64 {
        self.records.last().map(|r| r.objective).unwrap_or(f64::NAN)
    }

    /// Final consensus error.
    pub fn final_consensus_error(&self) -> f64 {
        self.records.last().map(|r| r.consensus_error).unwrap_or(f64::NAN)
    }

    /// Convergence test at a record: |objective gap| within `tol`
    /// (relative to f*) AND consensus error reduced below `tol` relative
    /// to its starting magnitude. A non-consensus iterate can undershoot
    /// the consensus optimum (Σ f_i(θ_i) < F(θ*)), so the objective test
    /// alone would be meaningless. The consensus threshold is genuinely
    /// relative — `tol · ce0` — so a near-consensus start (small `ce0`)
    /// still has to *reduce* its error by the requested factor; the tiny
    /// floor only guards an exactly-consensus start against a zero
    /// threshold.
    fn converged_at(&self, r: &IterRecord, f_star: f64, tol: f64) -> bool {
        let scale = f_star.abs().max(1.0);
        let ce0 = self.records[0].consensus_error.max(1e-12);
        (r.objective - f_star).abs() / scale <= tol && r.consensus_error <= tol * ce0
    }

    /// First iteration that satisfies [`Self::converged_at`], if any.
    pub fn iters_to_gap(&self, f_star: f64, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| self.converged_at(r, f_star, tol))
            .map(|r| r.iter)
    }

    /// Messages used up to the first converged iteration.
    pub fn messages_to_gap(&self, f_star: f64, tol: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| self.converged_at(r, f_star, tol))
            .map(|r| r.comm.messages)
    }
}

/// The common interface: one outer iteration at a time, exposing the
/// stacked per-node primal iterate for metric collection.
///
/// An instance owns the same node set as the [`Exchange`] handle it is
/// stepped against: every node on the bulk-synchronous transport, one
/// worker's shard on the partitioned runtime. All buffers (including
/// [`Self::thetas`]) are stacked `local_n × p` in `owned()` order.
pub trait ConsensusAlgorithm {
    /// Display name (matches the paper's legend).
    fn name(&self) -> String;
    /// Perform one outer iteration against any transport.
    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange);
    /// Current stacked per-node iterate (row-major local_n×p).
    fn thetas(&self) -> &[f64];
}

impl<T: ConsensusAlgorithm + ?Sized> ConsensusAlgorithm for Box<T> {
    fn name(&self) -> String {
        (**self).name()
    }
    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        (**self).step(problem, exch)
    }
    fn thetas(&self) -> &[f64] {
        (**self).thetas()
    }
}

/// Stop conditions for [`run`].
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Stop when the relative objective gap to `f_star` drops below this
    /// (requires `f_star`).
    pub gap_tol: Option<f64>,
    /// Optimal value for gap-based stopping / reporting.
    pub f_star: Option<f64>,
    /// Stop when cumulative messages exceed this budget.
    pub message_budget: Option<u64>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { max_iters: 100, gap_tol: None, f_star: None, message_budget: None }
    }
}

/// Drive an algorithm, collecting a trace (record 0 is the initial point).
pub fn run(
    alg: &mut dyn ConsensusAlgorithm,
    problem: &ConsensusProblem,
    comm: &mut CommGraph,
    opts: &RunOptions,
) -> Trace {
    let timer = crate::util::Timer::start();
    let mut records = Vec::with_capacity(opts.max_iters + 1);
    let snapshot = |alg: &dyn ConsensusAlgorithm, comm: &CommGraph, it: usize, t: f64| IterRecord {
        iter: it,
        objective: problem.objective(alg.thetas()),
        consensus_error: problem.consensus_error(alg.thetas()),
        comm: *comm.stats(),
        elapsed: t,
    };
    records.push(snapshot(alg, comm, 0, timer.secs()));
    for it in 1..=opts.max_iters {
        alg.step(problem, &mut *comm);
        let rec = snapshot(alg, comm, it, timer.secs());
        let done_gap = match (opts.gap_tol, opts.f_star) {
            (Some(tol), Some(fs)) => (rec.objective - fs) / fs.abs().max(1.0) <= tol,
            _ => false,
        };
        let done_budget = opts
            .message_budget
            .map(|b| rec.comm.messages >= b)
            .unwrap_or(false);
        records.push(rec);
        if done_gap || done_budget {
            break;
        }
    }
    Trace { algorithm: alg.name(), records, final_thetas: alg.thetas().to_vec() }
}

/// Metropolis–Hastings doubly-stochastic weights over a graph:
/// `w_ij = 1/(1+max(d_i,d_j))` for edges, `w_ii = 1 − Σ_j w_ij`.
/// Shared by the first-order baselines and Network Newton.
pub fn metropolis_weights(g: &crate::graph::Graph) -> Vec<Vec<(usize, f64)>> {
    let n = g.n;
    let mut w: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for i in 0..n {
        let mut self_w = 1.0;
        for &j in g.neighbors(i) {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w[i].push((j, wij));
            self_w -= wij;
        }
        w[i].push((i, self_w));
    }
    w
}

/// [`metropolis_weights`] as a global `n × n` CSR (diagonal +
/// neighborhoods) — the operator form the Exchange-generic baselines
/// apply through [`Exchange::exchange_apply`]. Support stays within the
/// graph halos, so it rides either transport.
pub fn metropolis_csr(g: &crate::graph::Graph) -> Csr {
    let w = metropolis_weights(g);
    let mut trips = Vec::new();
    for (i, row) in w.iter().enumerate() {
        for &(j, v) in row {
            trips.push((i, j, v));
        }
    }
    Csr::from_triplets(g.n, g.n, &trips)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn metropolis_rows_stochastic_and_symmetric() {
        let mut rng = crate::util::Pcg64::new(81);
        let g = generate::random_connected(12, 25, &mut rng);
        let w = metropolis_weights(&g);
        for i in 0..12 {
            let s: f64 = w[i].iter().map(|(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-12);
            for &(j, v) in &w[i] {
                assert!(v > 0.0);
                if j != i {
                    let back = w[j].iter().find(|(k, _)| *k == i).unwrap().1;
                    assert!((back - v).abs() < 1e-12);
                }
            }
        }
    }

    /// The three Metropolis invariants the cross-transport parity tests
    /// cannot localize when they fail, on the structured topologies where
    /// degree asymmetry is extreme (star), minimal (chain) and mixed
    /// (grid): rows sum to 1, z_ij = z_ji, and the self-weight closes the
    /// row exactly (z_ii = 1 − Σ_{j≠i} z_ij).
    #[test]
    fn metropolis_invariants_on_star_chain_grid() {
        for g in [generate::star(9), generate::path(10), generate::grid(3, 4)] {
            let w = metropolis_weights(&g);
            for i in 0..g.n {
                let row_sum: f64 = w[i].iter().map(|(_, v)| v).sum();
                assert!((row_sum - 1.0).abs() < 1e-12, "row {i} sums to {row_sum}");
                let mut off_sum = 0.0;
                let mut self_w = f64::NAN;
                for &(j, v) in &w[i] {
                    if j == i {
                        self_w = v;
                        continue;
                    }
                    off_sum += v;
                    // Symmetry z_ij = z_ji.
                    let back = w[j].iter().find(|(k, _)| *k == i).unwrap().1;
                    assert_eq!(back, v, "asymmetric weight on edge ({i},{j})");
                    // Metropolis value: 1/(1 + max degree).
                    let expect = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                    assert_eq!(v, expect, "edge ({i},{j})");
                }
                assert!(
                    (self_w - (1.0 - off_sum)).abs() < 1e-15,
                    "self-weight of node {i} does not close the row"
                );
                assert!(self_w > 0.0, "non-positive self-weight at node {i}");
            }
        }
    }

    /// The CSR form must carry exactly the weight-list entries (diagonal
    /// included) — it is what the Exchange-generic baselines apply.
    #[test]
    fn metropolis_csr_matches_weight_lists() {
        let mut rng = crate::util::Pcg64::new(82);
        let g = generate::random_connected(10, 20, &mut rng);
        let w = metropolis_weights(&g);
        let csr = metropolis_csr(&g);
        assert_eq!(csr.rows, g.n);
        assert_eq!(csr.nnz(), g.n + 2 * g.m());
        for i in 0..g.n {
            for kk in csr.indptr[i]..csr.indptr[i + 1] {
                let j = csr.indices[kk];
                let v = w[i].iter().find(|(jj, _)| *jj == j).unwrap().1;
                assert_eq!(csr.values[kk], v, "entry ({i},{j})");
            }
        }
    }

    /// Regression: a near-consensus start must still be required to
    /// *reduce* its consensus error by the factor `tol`. The old
    /// threshold `tol · max(ce0, 1)` degenerated to the absolute `tol`
    /// whenever ce0 < 1, declaring convergence without any reduction.
    #[test]
    fn converged_at_is_relative_for_near_consensus_starts() {
        let rec = |it: usize, ce: f64| IterRecord {
            iter: it,
            objective: 1.0,
            consensus_error: ce,
            comm: CommStats::default(),
            elapsed: 0.0,
        };
        let trace = Trace {
            algorithm: "synthetic".to_string(),
            records: vec![rec(0, 1e-6), rec(1, 1e-7), rec(2, 5e-9)],
            final_thetas: Vec::new(),
        };
        // Objective gap is zero throughout; only the consensus test
        // decides. tol·ce0 = 1e-8: iter 1 (1e-7) has NOT reduced the
        // error 100×, iter 2 (5e-9) has.
        assert_eq!(trace.iters_to_gap(1.0, 1e-2), Some(2));
        // A start already at machine-zero consensus converges immediately
        // thanks to the 1e-12 floor.
        let flat = Trace {
            algorithm: "flat".to_string(),
            records: vec![rec(0, 0.0), rec(1, 0.0)],
            final_thetas: Vec::new(),
        };
        assert_eq!(flat.iters_to_gap(1.0, 1e-2), Some(0));
    }
}
