//! The six consensus algorithms evaluated in the paper.
//!
//! | Module | Paper name | Kind |
//! |---|---|---|
//! | [`sdd_newton`] | Distributed SDD-Newton (the contribution) | dual 2nd-order |
//! | [`sdd_newton`] w/ [`solvers::NeumannSolver`] | Distributed Newton ADD [8] | dual 2nd-order |
//! | [`admm`] | Distributed ADMM [2] | dual decomposition |
//! | [`gradient`] | Distributed (sub)gradients [1] | primal 1st-order |
//! | [`averaging`] | Distributed averaging [13] | primal 1st-order |
//! | [`network_newton`] | Network Newton-K [9,10] | penalty 2nd-order |
//!
//! All algorithms interact with other nodes *only* through the
//! [`crate::net::Exchange`] transports, so reported message counts are
//! exact. SDD-Newton additionally runs sharded on the partitioned worker
//! runtime (`coordinator::run_partitioned_newton`).

pub mod solvers;
pub mod sdd_newton;
pub mod incremental;
pub mod admm;
pub mod gradient;
pub mod averaging;
pub mod network_newton;

use crate::net::{CommGraph, CommStats};
use crate::problems::ConsensusProblem;

/// One row of a convergence trace.
#[derive(Debug, Clone)]
pub struct IterRecord {
    /// Outer iteration index (0 = initial point).
    pub iter: usize,
    /// Global objective Σ f_i(θ_i) at the stacked iterate.
    pub objective: f64,
    /// Consensus error √(Σ‖θ_i − θ̄‖²).
    pub consensus_error: f64,
    /// Cumulative communication at the *end* of this iteration.
    pub comm: CommStats,
    /// Wall-clock seconds since the run started.
    pub elapsed: f64,
}

/// A full run trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub algorithm: String,
    pub records: Vec<IterRecord>,
    /// Final stacked per-node iterate (n×p) — lets callers evaluate the
    /// consensus solution (e.g. `objective_at(mean)` scoring, policy
    /// evaluation) without re-running.
    pub final_thetas: Vec<f64>,
}

impl Trace {
    /// Final objective.
    pub fn final_objective(&self) -> f64 {
        self.records.last().map(|r| r.objective).unwrap_or(f64::NAN)
    }

    /// Final consensus error.
    pub fn final_consensus_error(&self) -> f64 {
        self.records.last().map(|r| r.consensus_error).unwrap_or(f64::NAN)
    }

    /// Convergence test at a record: |objective gap| within `tol`
    /// (relative to f*) AND consensus error reduced below `tol` relative
    /// to its starting magnitude. A non-consensus iterate can undershoot
    /// the consensus optimum (Σ f_i(θ_i) < F(θ*)), so the objective test
    /// alone would be meaningless.
    fn converged_at(&self, r: &IterRecord, f_star: f64, tol: f64) -> bool {
        let scale = f_star.abs().max(1.0);
        let ce0 = self.records[0].consensus_error.max(1e-12);
        (r.objective - f_star).abs() / scale <= tol && r.consensus_error <= tol * ce0.max(1.0)
    }

    /// First iteration that satisfies [`Self::converged_at`], if any.
    pub fn iters_to_gap(&self, f_star: f64, tol: f64) -> Option<usize> {
        self.records
            .iter()
            .find(|r| self.converged_at(r, f_star, tol))
            .map(|r| r.iter)
    }

    /// Messages used up to the first converged iteration.
    pub fn messages_to_gap(&self, f_star: f64, tol: f64) -> Option<u64> {
        self.records
            .iter()
            .find(|r| self.converged_at(r, f_star, tol))
            .map(|r| r.comm.messages)
    }
}

/// The common interface: one outer iteration at a time, exposing the
/// stacked per-node primal iterate for metric collection.
pub trait ConsensusAlgorithm {
    /// Display name (matches the paper's legend).
    fn name(&self) -> String;
    /// Perform one outer iteration.
    fn step(&mut self, problem: &ConsensusProblem, comm: &mut CommGraph);
    /// Current stacked per-node iterate (row-major n×p).
    fn thetas(&self) -> &[f64];
}

/// Stop conditions for [`run`].
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Maximum outer iterations.
    pub max_iters: usize,
    /// Stop when the relative objective gap to `f_star` drops below this
    /// (requires `f_star`).
    pub gap_tol: Option<f64>,
    /// Optimal value for gap-based stopping / reporting.
    pub f_star: Option<f64>,
    /// Stop when cumulative messages exceed this budget.
    pub message_budget: Option<u64>,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions { max_iters: 100, gap_tol: None, f_star: None, message_budget: None }
    }
}

/// Drive an algorithm, collecting a trace (record 0 is the initial point).
pub fn run(
    alg: &mut dyn ConsensusAlgorithm,
    problem: &ConsensusProblem,
    comm: &mut CommGraph,
    opts: &RunOptions,
) -> Trace {
    let timer = crate::util::Timer::start();
    let mut records = Vec::with_capacity(opts.max_iters + 1);
    let snapshot = |alg: &dyn ConsensusAlgorithm, comm: &CommGraph, it: usize, t: f64| IterRecord {
        iter: it,
        objective: problem.objective(alg.thetas()),
        consensus_error: problem.consensus_error(alg.thetas()),
        comm: *comm.stats(),
        elapsed: t,
    };
    records.push(snapshot(alg, comm, 0, timer.secs()));
    for it in 1..=opts.max_iters {
        alg.step(problem, comm);
        let rec = snapshot(alg, comm, it, timer.secs());
        let done_gap = match (opts.gap_tol, opts.f_star) {
            (Some(tol), Some(fs)) => (rec.objective - fs) / fs.abs().max(1.0) <= tol,
            _ => false,
        };
        let done_budget = opts
            .message_budget
            .map(|b| rec.comm.messages >= b)
            .unwrap_or(false);
        records.push(rec);
        if done_gap || done_budget {
            break;
        }
    }
    Trace { algorithm: alg.name(), records, final_thetas: alg.thetas().to_vec() }
}

/// Metropolis–Hastings doubly-stochastic weights over a graph:
/// `w_ij = 1/(1+max(d_i,d_j))` for edges, `w_ii = 1 − Σ_j w_ij`.
/// Shared by the first-order baselines and Network Newton.
pub fn metropolis_weights(g: &crate::graph::Graph) -> Vec<Vec<(usize, f64)>> {
    let n = g.n;
    let mut w: Vec<Vec<(usize, f64)>> = vec![Vec::new(); n];
    for i in 0..n {
        let mut self_w = 1.0;
        for &j in g.neighbors(i) {
            let wij = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
            w[i].push((j, wij));
            self_w -= wij;
        }
        w[i].push((i, self_w));
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn metropolis_rows_stochastic_and_symmetric() {
        let mut rng = crate::util::Pcg64::new(81);
        let g = generate::random_connected(12, 25, &mut rng);
        let w = metropolis_weights(&g);
        for i in 0..12 {
            let s: f64 = w[i].iter().map(|(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-12);
            for &(j, v) in &w[i] {
                assert!(v > 0.0);
                if j != i {
                    let back = w[j].iter().find(|(k, _)| *k == i).unwrap().1;
                    assert!((back - v).abs() < 1e-12);
                }
            }
        }
    }
}
