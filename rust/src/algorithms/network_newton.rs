//! Network Newton-K (Mokhtari, Ling & Ribeiro [9,10]).
//!
//! Penalty reformulation: minimize
//! `Φ(y) = ½ yᵀ(I − Z)y + α Σ_i f_i(y_i)` with `Z` the Metropolis weight
//! matrix (lifted blockwise to R^{np}). Gradient
//! `g_i = (1 − z_ii) y_i − Σ_{j∈N(i)} z_ij y_j + α ∇f_i(y_i)`; Hessian
//! `H = I − Z + α G` is split `H = D − B` with
//! `D_i = α ∇²f_i + 2(1 − z_ii) I` and `B_ij = z_ij I (i≠j)`,
//! `B_ii = (1 − z_ii) I`, and the NN-K direction truncates the Neumann
//! series `d^{(k+1)} = D⁻¹(B d^{(k)} − g)`, `d^{(0)} = −D⁻¹ g`.
//!
//! Both `I − Z` and `B` are graph-support CSR operators applied through
//! [`Exchange::exchange_apply`] — one round for the gradient plus one per
//! hop — so the step runs shard-local on either transport. Note the fixed
//! penalty biases the limit away from the exact consensus optimum —
//! visible in Fig. 1 where NN-1/2 stall above the others.

use super::{metropolis_weights, ConsensusAlgorithm};
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::problems::ConsensusProblem;

/// Network Newton state (one shard's view).
pub struct NetworkNewton {
    /// Taylor truncation K (1 or 2 in the paper's experiments).
    pub k_hops: usize,
    /// Penalty weight α.
    pub alpha: f64,
    /// Step size ε.
    pub epsilon: f64,
    /// Stacked iterate, local_n × p.
    thetas: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Self-weights z_ii, indexed by global node.
    self_weight: Vec<f64>,
    /// Penalty-gradient operator `I − Z`.
    grad_op: Csr,
    /// Splitting operator `B` (diag `1 − z_ii`, offdiag `z_ij`).
    hop_op: Csr,
    m_edges: usize,
    p: usize,
}

impl NetworkNewton {
    /// Initialize at θ = 0, owning every node.
    pub fn new(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        k_hops: usize,
        alpha: f64,
        epsilon: f64,
    ) -> NetworkNewton {
        Self::new_sharded(problem, g, k_hops, alpha, epsilon, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        k_hops: usize,
        alpha: f64,
        epsilon: f64,
        owned: Vec<usize>,
    ) -> NetworkNewton {
        let n = problem.n();
        let weights = metropolis_weights(g);
        let mut self_weight = vec![0.0; n];
        let mut grad_trips = Vec::new();
        let mut hop_trips = Vec::new();
        for (i, row) in weights.iter().enumerate() {
            for &(j, z) in row {
                if j == i {
                    self_weight[i] = z;
                    grad_trips.push((i, i, 1.0 - z));
                    hop_trips.push((i, i, 1.0 - z));
                } else {
                    grad_trips.push((i, j, -z));
                    hop_trips.push((i, j, z));
                }
            }
        }
        NetworkNewton {
            k_hops,
            alpha,
            epsilon,
            thetas: vec![0.0; owned.len() * problem.p],
            owned,
            self_weight,
            grad_op: Csr::from_triplets(n, n, &grad_trips),
            hop_op: Csr::from_triplets(n, n, &hop_trips),
            m_edges: g.m(),
            p: problem.p,
        }
    }

    /// Block solve with `D_u = α ∇²f_u + 2(1 − z_uu) I`, expressed through
    /// the structured `solve_shifted`: `(αH + cI)x = r ⇔ (H + (c/α)I)x = r/α`.
    fn d_solve(
        &self,
        problem: &ConsensusProblem,
        u: usize,
        theta_row: &[f64],
        rhs: &[f64],
    ) -> Vec<f64> {
        let c = 2.0 * (1.0 - self.self_weight[u]);
        let scaled: Vec<f64> = rhs.iter().map(|v| v / self.alpha).collect();
        problem.locals[u].solve_shifted(theta_row, &scaled, c / self.alpha)
    }
}

impl ConsensusAlgorithm for NetworkNewton {
    fn name(&self) -> String {
        format!("Network Newton-{}", self.k_hops)
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();

        // Penalty gradient g = (I − Z) y + α ∇f (one exchange round).
        let mut g = vec![0.0; ln * p];
        // sddn-lint: graph-support penalty-gradient operator sparsity is exactly the comm graph plus diagonal
        exch.exchange_apply(&self.grad_op, 2 * self.m_edges as u64, &self.thetas, p, &mut g);
        for (li, &u) in self.owned.iter().enumerate() {
            let grad_f = problem.locals[u].gradient(&self.thetas[li * p..(li + 1) * p]);
            for r in 0..p {
                g[li * p + r] += self.alpha * grad_f[r];
            }
        }

        // d⁰ = −D⁻¹ g; d^{k+1} = D⁻¹(B d^k − g). Each hop: 1 exchange round.
        let mut d = vec![0.0; ln * p];
        for (li, &u) in self.owned.iter().enumerate() {
            let row = li * p..(li + 1) * p;
            let sol = self.d_solve(problem, u, &self.thetas[row.clone()], &g[row]);
            for r in 0..p {
                d[li * p + r] = -sol[r];
            }
        }
        for _ in 0..self.k_hops {
            let mut bd = vec![0.0; ln * p];
            // sddn-lint: graph-support hop operator sparsity is exactly the comm graph plus diagonal
            exch.exchange_apply(&self.hop_op, 2 * self.m_edges as u64, &d, p, &mut bd);
            let mut next = vec![0.0; ln * p];
            for (li, &u) in self.owned.iter().enumerate() {
                let mut rhs = bd[li * p..(li + 1) * p].to_vec();
                for r in 0..p {
                    rhs[r] -= g[li * p + r];
                }
                let sol = self.d_solve(problem, u, &self.thetas[li * p..(li + 1) * p], &rhs);
                next[li * p..(li + 1) * p].copy_from_slice(&sol);
            }
            d = next;
        }

        for idx in 0..ln * p {
            self.thetas[idx] += self.epsilon * d[idx];
        }
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn nn_descends_but_biased() {
        let mut rng = Pcg64::new(141);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = NetworkNewton::new(&prob, &g, 2, 0.1, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 200, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        assert!(objs.last().unwrap() < &objs[1], "no descent");
        // Penalty bias: it should NOT match the exact optimum to high
        // precision with a fixed α.
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap > 1e-8, "unexpectedly exact for a penalty method: {gap}");
    }

    #[test]
    fn nn2_uses_more_rounds_than_nn1() {
        let mut rng = Pcg64::new(142);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut comm1 = crate::net::CommGraph::new(&g);
        let mut nn1 = NetworkNewton::new(&prob, &g, 1, 0.1, 1.0);
        nn1.step(&prob, &mut comm1);
        let mut comm2 = crate::net::CommGraph::new(&g);
        let mut nn2 = NetworkNewton::new(&prob, &g, 2, 0.1, 1.0);
        nn2.step(&prob, &mut comm2);
        assert!(comm2.stats().rounds > comm1.stats().rounds);
        assert_eq!(comm1.stats().rounds, 2); // gradient + 1 hop
        assert_eq!(comm2.stats().rounds, 3); // gradient + 2 hops
    }
}
