//! Network Newton-K (Mokhtari, Ling & Ribeiro [9,10]).
//!
//! Penalty reformulation: minimize
//! `Φ(y) = ½ yᵀ(I − Z)y + α Σ_i f_i(y_i)` with `Z` the Metropolis weight
//! matrix (lifted blockwise to R^{np}). Gradient
//! `g_i = (1 − z_ii) y_i − Σ_{j∈N(i)} z_ij y_j + α ∇f_i(y_i)`; Hessian
//! `H = I − Z + α G` is split `H = D − B` with
//! `D_i = α ∇²f_i + 2(1 − z_ii) I` and `B_ij = z_ij I (i≠j)`,
//! `B_ii = (1 − z_ii) I`, and the NN-K direction truncates the Neumann
//! series `d^{(k+1)} = D⁻¹(B d^{(k)} − g)`, `d^{(0)} = −D⁻¹ g`.
//! Each hop costs one exchange round. Note the fixed penalty biases the
//! limit away from the exact consensus optimum — visible in Fig. 1 where
//! NN-1/2 stall above the others.

use super::{metropolis_weights, ConsensusAlgorithm};
use crate::net::CommGraph;
use crate::problems::ConsensusProblem;

/// Network Newton state.
pub struct NetworkNewton {
    /// Taylor truncation K (1 or 2 in the paper's experiments).
    pub k_hops: usize,
    /// Penalty weight α.
    pub alpha: f64,
    /// Step size ε.
    pub epsilon: f64,
    thetas: Vec<f64>,
    weights: Vec<Vec<(usize, f64)>>,
    p: usize,
}

impl NetworkNewton {
    /// Initialize at θ = 0.
    pub fn new(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        k_hops: usize,
        alpha: f64,
        epsilon: f64,
    ) -> NetworkNewton {
        NetworkNewton {
            k_hops,
            alpha,
            epsilon,
            thetas: vec![0.0; problem.n() * problem.p],
            weights: metropolis_weights(g),
            p: problem.p,
        }
    }

    fn self_weight(&self, i: usize) -> f64 {
        self.weights[i].iter().find(|(j, _)| *j == i).unwrap().1
    }
}

impl ConsensusAlgorithm for NetworkNewton {
    fn name(&self) -> String {
        format!("Network Newton-{}", self.k_hops)
    }

    fn step(&mut self, problem: &ConsensusProblem, comm: &mut CommGraph) {
        let p = self.p;
        let n = problem.n();

        // Penalty gradient (one exchange round on y).
        let gathered = comm.gather_neighbors(&self.thetas, p);
        let mut g = vec![0.0; n * p];
        for i in 0..n {
            let zii = self.self_weight(i);
            let grad_f = problem.locals[i].gradient(&self.thetas[i * p..(i + 1) * p]);
            for r in 0..p {
                g[i * p + r] = (1.0 - zii) * self.thetas[i * p + r] + self.alpha * grad_f[r];
            }
            for (j, payload) in &gathered[i] {
                let zij = self.weights[i].iter().find(|(jj, _)| jj == j).unwrap().1;
                for r in 0..p {
                    g[i * p + r] -= zij * payload[r];
                }
            }
        }

        // Block solves with D_i = α ∇²f_i + 2(1 − z_ii) I, expressed through
        // the structured `solve_shifted`: (αH + cI)x = r ⇔ (H + (c/α)I)x = r/α.
        let d_solve = |i: usize, thetas: &[f64], rhs: &[f64]| -> Vec<f64> {
            let zii = self.self_weight(i);
            let c = 2.0 * (1.0 - zii);
            let scaled: Vec<f64> = rhs.iter().map(|v| v / self.alpha).collect();
            problem.locals[i].solve_shifted(
                &thetas[i * p..(i + 1) * p],
                &scaled,
                c / self.alpha,
            )
        };

        // d⁰ = −D⁻¹ g; d^{k+1} = D⁻¹(B d^k − g). Each hop: 1 exchange round.
        let mut d = vec![0.0; n * p];
        for i in 0..n {
            let sol = d_solve(i, &self.thetas, &g[i * p..(i + 1) * p]);
            for r in 0..p {
                d[i * p + r] = -sol[r];
            }
        }
        for _ in 0..self.k_hops {
            let gathered_d = comm.gather_neighbors(&d, p);
            let mut next = vec![0.0; n * p];
            for i in 0..n {
                let zii = self.self_weight(i);
                // (B d)_i = (1 − z_ii) d_i + Σ_j z_ij d_j.
                let mut bd = vec![0.0; p];
                for r in 0..p {
                    bd[r] = (1.0 - zii) * d[i * p + r];
                }
                for (j, payload) in &gathered_d[i] {
                    let zij = self.weights[i].iter().find(|(jj, _)| jj == j).unwrap().1;
                    for r in 0..p {
                        bd[r] += zij * payload[r];
                    }
                }
                for r in 0..p {
                    bd[r] -= g[i * p + r];
                }
                let sol = d_solve(i, &self.thetas, &bd);
                next[i * p..(i + 1) * p].copy_from_slice(&sol);
            }
            d = next;
        }

        for idx in 0..n * p {
            self.thetas[idx] += self.epsilon * d[idx];
        }
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn nn_descends_but_biased() {
        let mut rng = Pcg64::new(141);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = NetworkNewton::new(&prob, &g, 2, 0.1, 1.0);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 200, ..Default::default() },
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        assert!(objs.last().unwrap() < &objs[1], "no descent");
        // Penalty bias: it should NOT match the exact optimum to high
        // precision with a fixed α.
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap > 1e-8, "unexpectedly exact for a penalty method: {gap}");
    }

    #[test]
    fn nn2_uses_more_rounds_than_nn1() {
        let mut rng = Pcg64::new(142);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut comm1 = crate::net::CommGraph::new(&g);
        let mut nn1 = NetworkNewton::new(&prob, &g, 1, 0.1, 1.0);
        nn1.step(&prob, &mut comm1);
        let mut comm2 = crate::net::CommGraph::new(&g);
        let mut nn2 = NetworkNewton::new(&prob, &g, 2, 0.1, 1.0);
        nn2.step(&prob, &mut comm2);
        assert!(comm2.stats().rounds > comm1.stats().rounds);
        assert_eq!(comm1.stats().rounds, 2); // gradient + 1 hop
        assert_eq!(comm2.stats().rounds, 3); // gradient + 2 hops
    }
}
