//! Communication-avoiding local-step Newton (ADAPD-style [11]).
//!
//! A decoupled primal–dual proximal scheme that trades local compute for
//! boundary rounds, after the ADAPD family (Accelerated Primal-Dual with
//! local steps; knob names `eta` / local max-iterations follow that
//! exemplar). One outer iteration:
//!
//! 1. **Local solves** (no communication): `local_steps` damped-Newton
//!    iterations on the proximal model
//!    `ξ_i(θ) = f_i(θ) + y_iᵀθ + (1/2η)‖θ − z_i‖²`,
//!    warm-started at θ_i^k. Each extra inner solve is a boundary round a
//!    step-synchronous method would have shipped; the ledger records the
//!    `local_steps − 1` skipped rounds as savings
//!    ([`crate::net::CommStats::record_skipped_exchange`]) so
//!    iterations-vs-communication plots can price the trade.
//! 2. **Mixing**: `comm_rounds` Metropolis exchanges `z ← W z` seeded
//!    from the fresh primal (`z^{k+1} = W^c θ^{k+1}`), each a real
//!    neighbor round of `2m` directed messages.
//! 3. **Dual ascent** (local): `y_i ← y_i + (θ_i − z_i)/η`.
//!
//! Fixed points are consensus optima: W is doubly stochastic so
//! `Σ_i y_i ≡ 0` is invariant from the zero start, a fixed point forces
//! `θ = z` = consensus (mixing is exact on consensus states) and the
//! inner stationarity `∇f_i(θ̄) + y_i + (θ̄ − z_i)/η = 0` then sums to
//! `Σ_i ∇f_i(θ̄) = 0`.
//!
//! With `local_steps = 1` and `comm_rounds = 1` the method spends exactly
//! one boundary round per outer iteration — the same wire profile as the
//! first-order baselines — and the savings counters stay zero.

use super::{metropolis_csr, ConsensusAlgorithm};
use crate::linalg::Csr;
use crate::net::Exchange;
use crate::problems::ConsensusProblem;

/// Local-step Newton state (one shard's view).
pub struct LocalNewton {
    /// Proximal step size η (the inner model's curvature is shifted by
    /// 1/η; smaller η contracts the dual faster, larger η the mean).
    pub eta: f64,
    /// Inner damped-Newton iterations per outer iteration (ADAPD's local
    /// max-iterations knob). Each beyond the first is a skipped boundary
    /// round, recorded in the ledger's savings counters.
    pub local_steps: usize,
    /// Metropolis mixing rounds per outer iteration (`z = W^c θ`).
    pub comm_rounds: usize,
    /// Stacked primal iterate θ, local_n × p.
    thetas: Vec<f64>,
    /// Stacked consensus variable z, local_n × p.
    z: Vec<f64>,
    /// Stacked dual y, local_n × p.
    y: Vec<f64>,
    /// Global ids of the owned nodes, ascending.
    owned: Vec<usize>,
    /// Global Metropolis mixing matrix W.
    mixing: Csr,
    m_edges: usize,
    p: usize,
    /// Spare buffer ping-ponged with `z` during mixing (no steady-state
    /// allocation beyond the first iteration).
    spare: Vec<f64>,
}

impl LocalNewton {
    /// Initialize at θ = z = y = 0, owning every node.
    pub fn new(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        eta: f64,
        local_steps: usize,
        comm_rounds: usize,
    ) -> LocalNewton {
        Self::new_sharded(problem, g, eta, local_steps, comm_rounds, (0..problem.n()).collect())
    }

    /// Shard-local instance owning the given global nodes (ascending).
    pub fn new_sharded(
        problem: &ConsensusProblem,
        g: &crate::graph::Graph,
        eta: f64,
        local_steps: usize,
        comm_rounds: usize,
        owned: Vec<usize>,
    ) -> LocalNewton {
        assert!(eta > 0.0, "proximal step size must be positive");
        assert!(local_steps >= 1, "need at least one local solve per outer iteration");
        assert!(comm_rounds >= 1, "need at least one mixing round per outer iteration");
        let ln = owned.len();
        let p = problem.p;
        LocalNewton {
            eta,
            local_steps,
            comm_rounds,
            thetas: vec![0.0; ln * p],
            z: vec![0.0; ln * p],
            y: vec![0.0; ln * p],
            owned,
            mixing: metropolis_csr(g),
            m_edges: g.m(),
            p,
            spare: Vec::new(),
        }
    }
}

impl ConsensusAlgorithm for LocalNewton {
    fn name(&self) -> String {
        "Local-Step Newton".to_string()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        let eta = self.eta;
        let round_msgs = 2 * self.m_edges as u64;

        // 1. Local solves — `local_steps` damped-Newton iterations on the
        // proximal model, no communication.
        for (li, &u) in self.owned.iter().enumerate() {
            let local = &problem.locals[u];
            let mut theta = self.thetas[li * p..(li + 1) * p].to_vec();
            for _ in 0..self.local_steps {
                let mut grad = local.gradient(&theta);
                for r in 0..p {
                    grad[r] += self.y[li * p + r] + (theta[r] - self.z[li * p + r]) / eta;
                }
                if crate::linalg::vector::norm2(&grad) < 1e-12 {
                    break;
                }
                let step = local.solve_shifted(&theta, &grad, 1.0 / eta);
                for r in 0..p {
                    theta[r] -= step[r];
                }
            }
            self.thetas[li * p..(li + 1) * p].copy_from_slice(&theta);
        }
        // Every inner solve beyond the first is a boundary round a
        // step-synchronous method would have shipped — charge the savings
        // ledger so the avoided traffic is priced, never the wire.
        for _ in 1..self.local_steps {
            exch.stats_mut().record_skipped_exchange(round_msgs, p);
        }

        // 2. Mixing: z ← W^c θ, each round a real neighbor exchange.
        // sddn-lint: graph-support Metropolis mixing sparsity is exactly the comm graph plus diagonal
        exch.exchange_apply(&self.mixing, round_msgs, &self.thetas, p, &mut self.z);
        for _ in 1..self.comm_rounds {
            let mut next = std::mem::take(&mut self.spare);
            next.clear();
            next.resize(ln * p, 0.0);
            // sddn-lint: graph-support Metropolis mixing sparsity is exactly the comm graph plus diagonal
            exch.exchange_apply(&self.mixing, round_msgs, &self.z, p, &mut next);
            self.spare = std::mem::replace(&mut self.z, next);
        }

        // 3. Dual ascent — local.
        for i in 0..ln * p {
            self.y[i] += (self.thetas[i] - self.z[i]) / eta;
        }
    }

    fn thetas(&self) -> &[f64] {
        &self.thetas
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn local_newton_converges_on_quadratic() {
        let mut rng = Pcg64::new(131);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 4, 160, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let mut alg = LocalNewton::new(&prob, &g, 0.5, 4, 2);
        let mut comm = crate::net::CommGraph::new(&g);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 400, ..Default::default() },
        );
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-2, "gap={gap}");
        let ce0 = trace.records[0].consensus_error.max(1e-12);
        assert!(
            trace.final_consensus_error() < 0.1 * ce0 || trace.final_consensus_error() < 1e-6,
            "consensus error did not shrink: {} vs start {ce0}",
            trace.final_consensus_error()
        );
        let objs: Vec<f64> = trace.records.iter().map(|r| r.objective).collect();
        assert!(objs.last().unwrap() < &objs[1], "objective did not decrease");
    }

    /// The wire/savings split: one outer iteration puts exactly
    /// `comm_rounds` real rounds on the wire and records
    /// `local_steps − 1` skipped rounds of `2m` messages as savings.
    #[test]
    fn ledger_splits_real_rounds_from_modeled_savings() {
        let mut rng = Pcg64::new(132);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let p = prob.p as u64;
        let mut alg = LocalNewton::new(&prob, &g, 0.5, 3, 2);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        let m2 = 2 * g.m() as u64;
        assert_eq!(comm.stats().rounds, 2);
        assert_eq!(comm.stats().messages, 2 * m2);
        assert_eq!(comm.stats().skipped_rounds, 2);
        assert_eq!(comm.stats().saved_messages, 2 * m2);
        assert_eq!(comm.stats().saved_floats, 2 * m2 * p);
    }

    /// `local_steps = 1, comm_rounds = 1` has the first-order baselines'
    /// wire profile: one round of 2m per outer iteration, zero savings.
    #[test]
    fn single_step_single_round_matches_baseline_profile() {
        let mut rng = Pcg64::new(133);
        let g = generate::random_connected(6, 10, &mut rng);
        let prob = datasets::synthetic_regression(6, 3, 60, 0.1, 0.05, &mut rng);
        let mut alg = LocalNewton::new(&prob, &g, 0.5, 1, 1);
        let mut comm = crate::net::CommGraph::new(&g);
        alg.step(&prob, &mut comm);
        assert_eq!(comm.stats().rounds, 1);
        assert_eq!(comm.stats().messages, 2 * g.m() as u64);
        assert_eq!(comm.stats().skipped_rounds, 0);
        assert_eq!(comm.stats().saved_messages, 0);
    }

    /// Equal-local-work framing: with a fixed total inner-solve budget,
    /// raising `local_steps` divides the outer iterations and therefore
    /// the real rounds — the communication-avoiding claim, priced by the
    /// ledger.
    #[test]
    fn fixed_local_budget_cuts_real_rounds_as_local_steps_grow() {
        let mut rng = Pcg64::new(134);
        let g = generate::random_connected(8, 16, &mut rng);
        let prob = datasets::synthetic_regression(8, 3, 80, 0.1, 0.05, &mut rng);
        let budget = 8usize;
        let mut prev_floats = u64::MAX;
        for local_steps in [1usize, 2, 4] {
            let outer = budget / local_steps;
            let mut alg = LocalNewton::new(&prob, &g, 0.5, local_steps, 1);
            let mut comm = crate::net::CommGraph::new(&g);
            for _ in 0..outer {
                alg.step(&prob, &mut comm);
            }
            assert!(
                comm.stats().floats < prev_floats,
                "cross floats must strictly shrink as local steps grow"
            );
            prev_floats = comm.stats().floats;
        }
    }
}
