//! Distributed SDD-Newton (Section 4) — the paper's contribution.
//!
//! Dual ascent `λ^{k+1} = λ^k + α d̃^k` where `d̃` is the ε-approximate
//! Newton direction obtained by splitting the dual Newton system (Eq. 7)
//! into Laplacian solves (Eq. 8/9):
//!
//! 1. primal recovery `y = y(λ)` (Eq. 6) — [`LocalBackend`];
//! 2. dual gradient `g = M y` — one exchange round;
//! 3. solve `M z = g` — inner [`LaplacianSolver`] (p batched systems);
//! 4. `b_i = ∇²f_i(y_i) z_i` — [`LocalBackend`], purely local;
//! 5. solve `M d = b` — inner solver again;
//! 6. `λ ← λ + α d̃`.
//!
//! Plugging [`crate::algorithms::solvers::NeumannSolver`] in as the inner
//! solver yields the paper's "Distributed Newton ADD" baseline; the SDDM
//! solver yields SDD-Newton proper; the preprocessed
//! [`crate::sddm::SquaredSddmSolver`] trades denser messages for far
//! fewer rounds and — via the overlay halo plans its levels register —
//! runs on the partitioned transport too, so no inner solver is
//! bulk-only anymore.
//!
//! The whole step runs against the [`Exchange`] trait (the
//! [`ConsensusAlgorithm::step`] contract every algorithm now shares): on
//! the bulk-synchronous [`crate::net::CommGraph`] one instance owns every
//! node; on the partitioned worker runtime
//! (`coordinator::run_partitioned_newton`) each worker drives its own
//! sharded instance over a channel transport — bit-for-bit identically.

use super::solvers::LaplacianSolver;
use super::ConsensusAlgorithm;
use crate::linalg::Csr;
use crate::net::{Exchange, StaleState};
use crate::problems::ConsensusProblem;
use crate::runtime::LocalBackend;
use crate::util::BufferPool;

/// Step-size policy.
#[derive(Debug, Clone, Copy)]
pub enum StepSize {
    /// Fixed α (the paper grid-searches {0.01, …, 0.9, 1}).
    Fixed(f64),
    /// Theorem 1's conservative α* = (γ/Γ)²(μ₂/μ_n)⁴(1−ε)/(1+ε)².
    Theory { gamma: f64, big_gamma: f64, mu2: f64, mun: f64, eps: f64 },
}

impl StepSize {
    /// Resolve to a numeric step.
    pub fn value(&self) -> f64 {
        match *self {
            StepSize::Fixed(a) => a,
            StepSize::Theory { gamma, big_gamma, mu2, mun, eps } => {
                let r1 = (gamma / big_gamma).powi(2);
                let r2 = (mu2 / mun).powi(4);
                r1 * r2 * (1.0 - eps) / (1.0 + eps).powi(2)
            }
        }
    }
}

/// How to handle the first system `M z = M y` of Eq. 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FirstSolve {
    /// Run the inner solver (paper-faithful).
    Solver,
    /// Use the closed form: the mean-zero solution of `M z = M y` is the
    /// per-dimension centering of `y` (one all-reduce). An optimization
    /// the paper's accounting does not exploit — kept as an ablation.
    Centering,
}

/// The SDD-Newton algorithm state (one shard's view: all nodes on the
/// bulk-synchronous driver, one worker's nodes on the partitioned
/// runtime).
pub struct SddNewton<'a> {
    backend: &'a dyn LocalBackend,
    solver: &'a dyn LaplacianSolver,
    step: StepSize,
    first_solve: FirstSolve,
    kernel_correction: bool,
    /// Global ids of the nodes this instance owns (ascending).
    owned: Vec<usize>,
    /// Whether the shard covers every node — enables the backend's
    /// whole-problem batched entry points (PJRT artifacts are fixed-shape).
    full: bool,
    /// Dual iterate, stacked local_n×p (row r holds λ(owned[r])).
    lambda: Vec<f64>,
    /// Current primal iterate y(λ), stacked local_n×p.
    y: Vec<f64>,
    p: usize,
    label: String,
    /// Reusable scratch for the step hot loop — after warm-up an outer
    /// iteration allocates nothing beyond transport-level bookkeeping.
    pool: BufferPool,
    /// Bounded-staleness state for the outer dual-gradient read `g = M y`
    /// (`None` = BSP). Carries the Laplacian operator because the
    /// staleness path routes through [`Exchange::exchange_apply_stale`]
    /// rather than the transport's built-in `laplacian_apply_into`.
    stale: Option<(Csr, StaleState)>,
}

impl<'a> SddNewton<'a> {
    /// Initialize at λ = 0 (so `y₀` is each node's local optimum),
    /// owning every node.
    pub fn new(
        problem: &ConsensusProblem,
        backend: &'a dyn LocalBackend,
        solver: &'a dyn LaplacianSolver,
        step: StepSize,
    ) -> SddNewton<'a> {
        Self::new_sharded(problem, backend, solver, step, (0..problem.n()).collect())
    }

    /// Initialize a shard-local instance owning the given global nodes
    /// (ascending) — one per worker on the partitioned runtime.
    pub fn new_sharded(
        problem: &ConsensusProblem,
        backend: &'a dyn LocalBackend,
        solver: &'a dyn LaplacianSolver,
        step: StepSize,
        owned: Vec<usize>,
    ) -> SddNewton<'a> {
        let p = problem.p;
        let full = owned.len() == problem.n();
        let ln = owned.len();
        let lambda = vec![0.0; ln * p];
        let mut alg = SddNewton {
            backend,
            solver,
            step,
            first_solve: FirstSolve::Solver,
            kernel_correction: true,
            owned,
            full,
            lambda,
            y: vec![0.0; ln * p],
            p,
            label: String::new(),
            pool: BufferPool::new(),
            stale: None,
        };
        alg.label = match solver.name() {
            "neumann" => "Distributed ADD-Newton".to_string(),
            "exact-cg" => "Distributed Newton (exact)".to_string(),
            "sddm-squared" => "Distributed SDD-Newton (preprocessed)".to_string(),
            _ => "Distributed SDD-Newton".to_string(),
        };
        let v0 = vec![0.0; ln * p];
        let mut y0 = std::mem::take(&mut alg.y);
        alg.recover(problem, &v0, &mut y0);
        alg.y = y0;
        alg
    }

    /// Switch the Eq.-8 first-system strategy (ablation).
    pub fn with_first_solve(mut self, fs: FirstSolve) -> Self {
        self.first_solve = fs;
        self
    }

    /// Toggle the kernel-consistency correction (ablation; default on).
    pub fn with_kernel_correction(mut self, on: bool) -> Self {
        self.kernel_correction = on;
        self
    }

    /// Run the outer dual-gradient read `g = M y` under a bounded-
    /// staleness policy: the boundary rows of `y` may be up to `tau`
    /// rounds old ([`Exchange::exchange_apply_stale`]). `lap` must be the
    /// graph Laplacian ([`crate::graph::laplacian_csr`]) — the same
    /// operator `laplacian_apply_into` applies, so `tau = 0` is
    /// bit-for-bit the BSP path with the identical ledger charge (one
    /// round of `2m` messages). Primal recovery and the inner solver
    /// always read fresh state: the dual gradient is the one outer halo
    /// read where bounded staleness degrades gracefully (it only delays
    /// the ascent direction), and the one the staleness sweep prices.
    pub fn with_staleness(mut self, lap: Csr, tau: u64) -> Self {
        self.stale = if tau > 0 { Some((lap, StaleState::new(tau))) } else { None };
        self
    }

    /// Current dual iterate (stacked local_n×p).
    pub fn lambda(&self) -> &[f64] {
        &self.lambda
    }

    /// Global ids of the owned nodes.
    pub fn owned(&self) -> &[usize] {
        &self.owned
    }

    /// Primal recovery over the owned nodes. On a full shard this is the
    /// backend's whole-problem batched entry point (so PJRT artifacts keep
    /// working); on a partial shard the node-list variant — both compute
    /// the identical per-node oracles.
    fn recover(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]) {
        if self.full {
            self.backend.primal_recover_all(problem, v, out);
        } else {
            self.backend.primal_recover_nodes(problem, &self.owned, v, out);
        }
    }

    /// Hessian application over the owned nodes (same dispatch).
    fn hess_apply(&self, problem: &ConsensusProblem, thetas: &[f64], z: &[f64], out: &mut [f64]) {
        if self.full {
            self.backend.hess_apply_all(problem, thetas, z, out);
        } else {
            self.backend.hess_apply_nodes(problem, &self.owned, thetas, z, out);
        }
    }

    /// One SDD-Newton outer iteration against any transport — the body
    /// of [`ConsensusAlgorithm::step`].
    // sddn-lint: hot-path
    fn step_impl(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        let p = self.p;
        let ln = self.owned.len();
        debug_assert_eq!(exch.local_n(), ln);

        // (1) primal recovery at current λ: v = (I_p ⊗ L) λ.
        let mut v = self.pool.take(ln * p);
        exch.laplacian_apply_into(&self.lambda, p, &mut v);
        let mut y = std::mem::take(&mut self.y);
        self.recover(problem, &v, &mut y);
        self.y = y;
        self.pool.put(v);

        // (2) dual gradient g = M y — the one outer halo read the
        // bounded-staleness policy may serve from cache.
        let mut g = self.pool.take(ln * p);
        if let Some((lap, st)) = self.stale.as_mut() {
            let dm = (lap.nnz() - lap.rows) as u64;
            exch.exchange_apply_stale(lap, st, dm, &self.y, p, &mut g);
        } else {
            exch.laplacian_apply_into(&self.y, p, &mut g);
        }

        // (3) M z = g.
        let solver = self.solver;
        let z = match self.first_solve {
            FirstSolve::Solver => solver.solve_ws(&g, p, exch, &mut self.pool).x,
            FirstSolve::Centering => {
                let mut z = self.pool.take_copy(&self.y);
                exch.center(&mut z, p);
                z
            }
        };
        self.pool.put(g);

        // (4) b_i = ∇²f_i(y_i) z_i — local.
        let mut b = self.pool.take(ln * p);
        self.hess_apply(problem, &self.y, &z, &mut b);
        self.pool.put(z);

        // (4b) Kernel-consistency correction. `M z = g` pins `z` only up to
        // a per-dimension constant `1 ⊗ c`; the second system `M d = ∇²f z`
        // is consistent only for the choice with `Σ_i ∇²f_i z_i = 0`.
        // Solve `(Σ_i ∇²f_i) c = −Σ_i b_i` — the sums are one p²+p
        // all-reduce — and shift `b ← b + ∇²f (1 ⊗ c)`.
        if self.kernel_correction {
            let wk = p * p + p;
            let mut hblocks = self.pool.take(ln * p * p);
            self.backend.hess_nodes(problem, &self.owned, &self.y, &mut hblocks);
            let mut locals = self.pool.take(ln * wk);
            for li in 0..ln {
                locals[li * wk..li * wk + p * p]
                    .copy_from_slice(&hblocks[li * p * p..(li + 1) * p * p]);
                locals[li * wk + p * p..(li + 1) * wk]
                    .copy_from_slice(&b[li * p..(li + 1) * p]);
            }
            self.pool.put(hblocks);
            let tot = exch.allreduce_sum(&locals, wk);
            self.pool.put(locals);
            let hsum = crate::linalg::Matrix::from_rows(p, p, tot[..p * p].to_vec());
            let bsum = &tot[p * p..];
            if let Ok(c) = crate::linalg::cholesky::spd_solve(&hsum, bsum) {
                let mut tiled = self.pool.take(ln * p);
                for li in 0..ln {
                    for (j, cj) in c.iter().enumerate() {
                        tiled[li * p + j] = -cj;
                    }
                }
                let mut bc = self.pool.take(ln * p);
                self.hess_apply(problem, &self.y, &tiled, &mut bc);
                self.pool.put(tiled);
                for i in 0..ln * p {
                    b[i] += bc[i];
                }
                self.pool.put(bc);
            }
        }

        // (5) M d = b.
        let d = solver.solve_ws(&b, p, exch, &mut self.pool).x;
        self.pool.put(b);

        // (6) dual ascent λ ← λ + α d.
        let alpha = self.step.value();
        for i in 0..ln * p {
            self.lambda[i] += alpha * d[i];
        }
        self.pool.put(d);

        // Refresh the primal iterate for metric collection.
        let mut v2 = self.pool.take(ln * p);
        exch.laplacian_apply_into(&self.lambda, p, &mut v2);
        let mut y = std::mem::take(&mut self.y);
        self.recover(problem, &v2, &mut y);
        self.y = y;
        self.pool.put(v2);
    }
}

impl ConsensusAlgorithm for SddNewton<'_> {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn step(&mut self, problem: &ConsensusProblem, exch: &mut dyn Exchange) {
        self.step_impl(problem, exch);
    }

    fn thetas(&self) -> &[f64] {
        &self.y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::solvers::{sddm_for_graph, ExactCgSolver, NeumannSolver};
    use crate::algorithms::{run, RunOptions};
    use crate::graph::generate;
    use crate::problems::datasets;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg64;

    #[test]
    fn converges_on_quadratic_consensus() {
        let mut rng = Pcg64::new(101);
        let g = generate::random_connected(12, 30, &mut rng);
        let prob = datasets::synthetic_regression(12, 5, 240, 0.1, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-4, &mut rng);
        let backend = NativeBackend;
        let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0));
        let mut comm = crate::net::CommGraph::new(&g);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let trace = run(
            &mut alg,
            &prob,
            &mut comm,
            &RunOptions { max_iters: 40, ..Default::default() },
        );
        let gap0 = trace.records[0].objective - f_star;
        let gap_end = trace.final_objective() - f_star;
        assert!(gap_end.abs() < 1e-3 * gap0.abs().max(1.0), "gap0={gap0} gap_end={gap_end}");
        assert!(trace.final_consensus_error() < 1e-2 * trace.records[0].consensus_error);
    }

    #[test]
    fn centering_first_solve_matches_solver() {
        let mut rng = Pcg64::new(102);
        let g = generate::random_connected(10, 25, &mut rng);
        let prob = datasets::synthetic_regression(10, 4, 200, 0.1, 0.05, &mut rng);
        let solver = sddm_for_graph(&g, 1e-8, &mut rng);
        let backend = NativeBackend;
        let run_with = |fs: FirstSolve| {
            let mut alg = SddNewton::new(&prob, &backend, &solver, StepSize::Fixed(1.0))
                .with_first_solve(fs);
            let mut comm = crate::net::CommGraph::new(&g);
            let trace = run(
                &mut alg,
                &prob,
                &mut comm,
                &RunOptions { max_iters: 10, ..Default::default() },
            );
            (trace.final_objective(), comm.stats().messages)
        };
        let (f_solver, m_solver) = run_with(FirstSolve::Solver);
        let (f_center, m_center) = run_with(FirstSolve::Centering);
        assert!((f_solver - f_center).abs() < 1e-6 * f_solver.abs().max(1.0));
        assert!(m_center < m_solver, "centering should save messages");
    }

    #[test]
    fn add_newton_slower_than_sdd_newton() {
        let mut rng = Pcg64::new(103);
        let g = generate::random_connected(14, 35, &mut rng);
        let prob = datasets::synthetic_regression(14, 4, 280, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-10);
        let backend = NativeBackend;

        let sddm = sddm_for_graph(&g, 1e-3, &mut rng);
        let mut sdd = SddNewton::new(&prob, &backend, &sddm, StepSize::Fixed(1.0));
        let mut c1 = crate::net::CommGraph::new(&g);
        let t_sdd = run(&mut sdd, &prob, &mut c1, &RunOptions { max_iters: 6, ..Default::default() });

        let neumann = NeumannSolver::from_graph(&g, 2);
        let mut add = SddNewton::new(&prob, &backend, &neumann, StepSize::Fixed(1.0));
        let mut c2 = crate::net::CommGraph::new(&g);
        let t_add = run(&mut add, &prob, &mut c2, &RunOptions { max_iters: 6, ..Default::default() });

        let gap = |f: f64| (f - f_star).abs();
        assert!(
            gap(t_sdd.final_objective()) < gap(t_add.final_objective()),
            "sdd gap {} vs add gap {}",
            gap(t_sdd.final_objective()),
            gap(t_add.final_objective())
        );
    }

    #[test]
    fn exact_cg_direction_converges_quadratically_fast() {
        let mut rng = Pcg64::new(104);
        let g = generate::random_connected(10, 25, &mut rng);
        let prob = datasets::synthetic_regression(10, 4, 150, 0.1, 0.05, &mut rng);
        let (_, f_star) = prob.centralized_optimum(60, 1e-12);
        let backend = NativeBackend;
        let cg = ExactCgSolver::from_graph(&g, 1e-12);
        let mut alg = SddNewton::new(&prob, &backend, &cg, StepSize::Fixed(1.0));
        let mut comm = crate::net::CommGraph::new(&g);
        let trace =
            run(&mut alg, &prob, &mut comm, &RunOptions { max_iters: 3, ..Default::default() });
        // Quadratic dual + exact Newton direction ⇒ essentially one step.
        let gap = (trace.final_objective() - f_star).abs() / f_star.abs().max(1.0);
        assert!(gap < 1e-8, "gap={gap}");
    }

    #[test]
    fn theory_step_size_is_conservative_but_decreasing() {
        let s = StepSize::Theory { gamma: 1.0, big_gamma: 2.0, mu2: 0.5, mun: 5.0, eps: 0.1 };
        let a = s.value();
        assert!(a > 0.0 && a < 0.01, "alpha*={a}");
        assert!(StepSize::Fixed(1.0).value() == 1.0);
    }
}
