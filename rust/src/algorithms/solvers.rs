//! Inner Laplacian-system solvers used by the dual Newton methods.
//!
//! The SDD-Newton contribution plugs the Peng–Spielman SDDM solver into
//! the inner solves of Eq. 8/9; the "Distributed Newton ADD" baseline [8]
//! replaces it with an N-term Taylor/Neumann expansion of the Laplacian
//! pseudo-inverse; CG (with kernel projection) provides an exact-direction
//! oracle for ablations. All three run against the [`Exchange`] trait, so
//! the same solver code executes on the bulk-synchronous simulation and on
//! the partitioned worker runtime.

use crate::linalg::Csr;
use crate::net::Exchange;
use crate::sddm::{SddmSolver, SolveOutcome, SquaredSddmSolver};
use crate::util::BufferPool;

/// A distributed solver for Laplacian systems `L x_r = b_r`, batched over
/// `w` right-hand sides (stacked shard-local `local_n × w` row-major).
pub trait LaplacianSolver: Send + Sync {
    /// Solve, recording communication into the exchange's ledger.
    fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome;
    /// Solve with caller-provided scratch buffers. Solvers whose inner
    /// loops can reuse pooled scratch override this; the default ignores
    /// the pool. Identical numerical results either way.
    // sddn-lint: hot-path
    fn solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> SolveOutcome {
        let _ = pool;
        self.solve(b, w, exch)
    }
    /// Display name for traces.
    fn name(&self) -> &'static str;
}

impl LaplacianSolver for SddmSolver {
    fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome {
        SddmSolver::solve(self, b, w, exch)
    }
    // sddn-lint: hot-path
    fn solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> SolveOutcome {
        SddmSolver::solve_ws(self, b, w, exch, pool)
    }
    fn name(&self) -> &'static str {
        "sddm"
    }
}

/// The preprocessed (explicit-squaring) SDDM solver: one
/// extended-neighborhood round per level application. Its level supports
/// exceed the graph edges, so on the partitioned transport it rides the
/// *overlay halo plans* the levels register — the same solver code runs
/// on either transport.
impl LaplacianSolver for SquaredSddmSolver {
    fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome {
        self.chain.solve(b, w, self.opts.eps, self.opts.max_richardson, exch)
    }
    // sddn-lint: hot-path
    fn solve_ws(
        &self,
        b: &[f64],
        w: usize,
        exch: &mut dyn Exchange,
        pool: &mut BufferPool,
    ) -> SolveOutcome {
        self.chain.solve_ws(b, w, self.opts.eps, self.opts.max_richardson, exch, pool)
    }
    fn name(&self) -> &'static str {
        "sddm-squared"
    }
}

/// ADD-style truncated Neumann solver: with the splitting `L = D − A`,
/// `L⁺ b ≈ Σ_{k=0}^{N} (D⁻¹A)^k D⁻¹ b` on the mean-zero subspace. Each
/// term is one neighbor-exchange round. The error is *fixed* by N — it
/// cannot be driven to arbitrary ε, which is exactly the accuracy gap the
/// paper exploits (Section 6's comparison to distributed Newton ADD).
pub struct NeumannSolver {
    /// Number of expansion terms beyond the diagonal (N).
    pub terms: usize,
    /// Degree vector D (Laplacian diagonal), indexed by global node.
    pub degrees: Vec<f64>,
    /// Adjacency CSR (A).
    pub adjacency: Csr,
    /// Undirected edge count (for message accounting).
    pub m_edges: usize,
}

impl NeumannSolver {
    /// Build from a graph.
    pub fn from_graph(g: &crate::graph::Graph, terms: usize) -> NeumannSolver {
        NeumannSolver {
            terms,
            degrees: crate::graph::laplacian::degrees(g),
            adjacency: crate::graph::laplacian::adjacency_csr(g),
            m_edges: g.m(),
        }
    }
}

impl LaplacianSolver for NeumannSolver {
    fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome {
        let ln = exch.local_n();
        assert_eq!(b.len(), ln * w);
        let owned = exch.owned().to_vec();
        // term_0 = D^{-1} b;  x = Σ_k term_k;  term_{k+1} = D^{-1} A term_k.
        let mut term = vec![0.0; ln * w];
        for (r, &u) in owned.iter().enumerate() {
            for j in 0..w {
                term[r * w + j] = b[r * w + j] / self.degrees[u];
            }
        }
        let mut x = term.clone();
        let mut tmp = vec![0.0; ln * w];
        for _ in 0..self.terms {
            // sddn-lint: graph-support adjacency sparsity is exactly the comm graph
            exch.exchange_apply(&self.adjacency, 2 * self.m_edges as u64, &term, w, &mut tmp);
            for (r, &u) in owned.iter().enumerate() {
                for j in 0..w {
                    term[r * w + j] = tmp[r * w + j] / self.degrees[u];
                }
            }
            for i in 0..ln * w {
                x[i] += term[i];
            }
        }
        exch.center(&mut x, w);
        // Residual for reporting (not used for control — N is fixed).
        SolveOutcome { x, sweeps: self.terms, rel_residual: f64::NAN, converged: true }
    }
    fn name(&self) -> &'static str {
        "neumann"
    }
}

/// Exact-direction oracle: projected CG to machine precision, batched over
/// the `w` right-hand sides in **lockstep** — every column advances each
/// round (converged columns freeze), so the round count is the *maximum*
/// per-column iteration count, which is what a distributed deployment
/// pays. Per iteration: one exchange round of width `w` plus the
/// projection/inner-product all-reduces.
pub struct ExactCgSolver {
    pub laplacian: Csr,
    pub m_edges: usize,
    pub tol: f64,
}

impl ExactCgSolver {
    /// Build from a graph.
    pub fn from_graph(g: &crate::graph::Graph, tol: f64) -> ExactCgSolver {
        ExactCgSolver {
            laplacian: crate::graph::laplacian::laplacian_csr(g),
            m_edges: g.m(),
            tol,
        }
    }
}

/// Per-column global inner products `Σ_i a[i,·] ⊙ b[i,·]` — one
/// all-reduce of width `w`.
fn col_dots(exch: &mut dyn Exchange, a: &[f64], b: &[f64], w: usize) -> Vec<f64> {
    let locals: Vec<f64> = a.iter().zip(b).map(|(x, y)| x * y).collect();
    exch.allreduce_sum(&locals, w)
}

impl LaplacianSolver for ExactCgSolver {
    fn solve(&self, b: &[f64], w: usize, exch: &mut dyn Exchange) -> SolveOutcome {
        let n = exch.n();
        let ln = exch.local_n();
        assert_eq!(b.len(), ln * w);
        let len = ln * w;

        // Kernel projection of the RHS (consensus Laplacian: kernel = 1).
        let mut b0 = b.to_vec();
        exch.center(&mut b0, w);
        let bnorms: Vec<f64> = col_dots(exch, &b0, &b0, w)
            .into_iter()
            .map(|v| v.sqrt().max(1e-300))
            .collect();

        let mut x = vec![0.0; len];
        let mut r = b0.clone();
        let mut p = r.clone();
        let mut ap = vec![0.0; len];
        let mut rs = col_dots(exch, &r, &r, w);
        let mut active: Vec<bool> =
            (0..w).map(|j| rs[j].sqrt() / bnorms[j] > self.tol).collect();
        let max_iter = 20 * n;
        let mut iters = 0usize;

        while iters < max_iter && active.iter().any(|&a| a) {
            // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph plus diagonal
            exch.exchange_apply(&self.laplacian, 2 * self.m_edges as u64, &p, w, &mut ap);
            exch.center(&mut ap, w);
            let pap = col_dots(exch, &p, &ap, w);
            // Columns whose curvature vanished freeze (matches the serial
            // CG's denominator guard); the rest take their own step.
            let mut alpha = vec![0.0; w];
            let mut stepping = vec![false; w];
            for j in 0..w {
                if !active[j] {
                    continue;
                }
                if pap[j].abs() < 1e-300 {
                    active[j] = false;
                } else {
                    alpha[j] = rs[j] / pap[j];
                    stepping[j] = true;
                }
            }
            for row in 0..ln {
                for j in 0..w {
                    if stepping[j] {
                        let idx = row * w + j;
                        x[idx] += alpha[j] * p[idx];
                        r[idx] -= alpha[j] * ap[idx];
                    }
                }
            }
            let rs_new = col_dots(exch, &r, &r, w);
            let mut beta = vec![0.0; w];
            for j in 0..w {
                if stepping[j] {
                    beta[j] = rs_new[j] / rs[j];
                }
            }
            for row in 0..ln {
                for j in 0..w {
                    if stepping[j] {
                        let idx = row * w + j;
                        p[idx] = r[idx] + beta[j] * p[idx];
                    }
                }
            }
            for j in 0..w {
                if stepping[j] {
                    rs[j] = rs_new[j];
                    if rs[j].sqrt() / bnorms[j] <= self.tol {
                        active[j] = false;
                    }
                }
            }
            iters += 1;
        }
        exch.center(&mut x, w);
        let worst = (0..w)
            .map(|j| rs[j].sqrt() / bnorms[j])
            .fold(0.0f64, f64::max);
        SolveOutcome { x, sweeps: iters, rel_residual: worst, converged: worst <= self.tol }
    }
    fn name(&self) -> &'static str {
        "exact-cg"
    }
}

/// Convenience: build the default SDDM solver for a graph at accuracy ε.
pub fn sddm_for_graph(
    g: &crate::graph::Graph,
    eps: f64,
    rng: &mut crate::util::Pcg64,
) -> SddmSolver {
    let l = crate::graph::laplacian_csr(g);
    let chain = crate::sddm::Chain::build(&l, &crate::sddm::ChainOptions::default(), rng)
        // sddn-lint: allow(panic) reason=a graph Laplacian is SDD by construction, so chain building cannot fail here
        .expect("Laplacian is SDD by construction");
    SddmSolver::new(chain, crate::sddm::SolverOptions { eps, max_richardson: 300 })
}

/// Convenience: build the preprocessed (explicitly squared) SDDM solver
/// for a graph at accuracy ε. `prune_tol` drops tiny entries after each
/// squaring (0 = exact levels).
pub fn squared_sddm_for_graph(
    g: &crate::graph::Graph,
    eps: f64,
    prune_tol: f64,
    rng: &mut crate::util::Pcg64,
) -> SquaredSddmSolver {
    let l = crate::graph::laplacian_csr(g);
    let chain = crate::sddm::SquaredChain::build(
        &l,
        &crate::sddm::ChainOptions::default(),
        prune_tol,
        rng,
    )
    // sddn-lint: allow(panic) reason=a graph Laplacian is SDD by construction, so chain building cannot fail here
    .expect("Laplacian is SDD by construction");
    SquaredSddmSolver::new(chain, crate::sddm::SolverOptions { eps, max_richardson: 300 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::net::CommGraph;
    use crate::util::Pcg64;

    #[test]
    fn neumann_reduces_residual_but_saturates() {
        let mut rng = Pcg64::new(91);
        let g = generate::random_connected(20, 50, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(20);
        let b = l.matvec(&z);
        let mut prev = f64::INFINITY;
        for terms in [0usize, 2, 6] {
            let s = NeumannSolver::from_graph(&g, terms);
            let mut comm = CommGraph::new(&g);
            let out = s.solve(&b, 1, &mut comm);
            let mut r = l.matvec(&out.x);
            for i in 0..20 {
                r[i] = b[i] - r[i];
            }
            crate::linalg::vector::center(&mut r);
            let rel = crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b);
            assert!(rel <= prev + 1e-12, "terms={terms} rel={rel} prev={prev}");
            prev = rel;
        }
        // Even with 6 terms the expansion hasn't solved the system exactly.
        assert!(prev > 1e-6, "Neumann should not be exact: {prev}");
    }

    #[test]
    fn exact_cg_solver_is_exact() {
        let mut rng = Pcg64::new(92);
        let g = generate::random_connected(15, 35, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(15);
        let b = l.matvec(&z);
        let s = ExactCgSolver::from_graph(&g, 1e-12);
        let mut comm = CommGraph::new(&g);
        let out = s.solve(&b, 1, &mut comm);
        let lx = l.matvec(&out.x);
        for i in 0..15 {
            assert!((lx[i] - b[i]).abs() < 1e-8);
        }
        assert!(comm.stats().messages > 0);
    }

    /// Regression for the ragged multi-RHS accounting: batched CG runs
    /// the columns in lockstep until the *slowest* converges, so the
    /// charged rounds are the per-column maximum — not the truncating
    /// integer mean the old model used (which undercounted whenever the
    /// columns were ragged).
    #[test]
    fn exact_cg_charges_ragged_batches_at_the_max_column() {
        let mut rng = Pcg64::new(94);
        let g = generate::random_connected(25, 55, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(25);
        let hard = l.matvec(&z); // needs many CG iterations
        let easy = vec![0.0; 25]; // converges in zero iterations
        let s = ExactCgSolver::from_graph(&g, 1e-10);

        let mut c_hard = CommGraph::new(&g);
        let solo_hard = s.solve(&hard, 1, &mut c_hard);
        let mut c_easy = CommGraph::new(&g);
        let solo_easy = s.solve(&easy, 1, &mut c_easy);
        assert!(solo_hard.sweeps > 2, "hard column should iterate");
        assert_eq!(solo_easy.sweeps, 0, "zero RHS converges immediately");

        let mut b = vec![0.0; 25 * 2];
        for i in 0..25 {
            b[i * 2] = hard[i];
            b[i * 2 + 1] = easy[i];
        }
        let mut c_batch = CommGraph::new(&g);
        let batched = s.solve(&b, 2, &mut c_batch);
        // Max, not mean: the old `total_iters / w` model would have
        // charged roughly half these rounds.
        assert_eq!(batched.sweeps, solo_hard.sweeps);
        assert!(batched.sweeps > (solo_hard.sweeps + solo_easy.sweeps) / 2);
        // Every lockstep iteration moves one full-width edge round.
        let edge_msgs = 2 * g.m() as u64 * batched.sweeps as u64;
        assert!(c_batch.stats().messages >= edge_msgs, "rounds must cover the max column");
        // The frozen easy column must not perturb the hard column.
        for i in 0..25 {
            assert!((batched.x[i * 2] - solo_hard.x[i]).abs() < 1e-12);
            assert_eq!(batched.x[i * 2 + 1], 0.0);
        }
    }

    #[test]
    fn sddm_hits_eps_where_add_style_neumann_cannot() {
        // The property the paper exploits (Section 6): ADD's truncation
        // fixes the direction error (N = 2 hops), while the SDDM solver
        // reaches any requested ε.
        let mut rng = Pcg64::new(93);
        let g = generate::random_connected(30, 80, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let rel = |x: &Vec<f64>| {
            let mut r = l.matvec(x);
            for i in 0..30 {
                r[i] = b[i] - r[i];
            }
            crate::linalg::vector::center(&mut r);
            crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b)
        };
        let sddm = sddm_for_graph(&g, 1e-6, &mut rng);
        let mut c1 = CommGraph::new(&g);
        let o1 = LaplacianSolver::solve(&sddm, &b, 1, &mut c1);
        assert!(rel(&o1.x) <= 1e-6, "sddm rel={}", rel(&o1.x));
        // ADD-style truncation (N = 2 as in [8]'s experiments).
        let nm = NeumannSolver::from_graph(&g, 2);
        let mut c2 = CommGraph::new(&g);
        let o2 = nm.solve(&b, 1, &mut c2);
        assert!(rel(&o2.x) > 1e-2, "neumann unexpectedly accurate: {}", rel(&o2.x));
    }
}
