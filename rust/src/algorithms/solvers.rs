//! Inner Laplacian-system solvers used by the dual Newton methods.
//!
//! The SDD-Newton contribution plugs the Peng–Spielman SDDM solver into
//! the inner solves of Eq. 8/9; the "Distributed Newton ADD" baseline [8]
//! replaces it with an N-term Taylor/Neumann expansion of the Laplacian
//! pseudo-inverse; CG (with kernel projection) provides an exact-direction
//! oracle for ablations.

use crate::linalg::cg::{cg_solve, CgOptions};
use crate::linalg::Csr;
use crate::net::{CommGraph, CommStats};
use crate::sddm::{SddmSolver, SolveOutcome};

/// A distributed solver for Laplacian systems `L x_r = b_r`, batched over
/// `w` right-hand sides (stacked row-major `n × w`).
pub trait LaplacianSolver: Send + Sync {
    /// Solve, recording communication into `stats`.
    fn solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> SolveOutcome;
    /// Display name for traces.
    fn name(&self) -> &'static str;
}

impl LaplacianSolver for SddmSolver {
    fn solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> SolveOutcome {
        SddmSolver::solve(self, b, w, stats)
    }
    fn name(&self) -> &'static str {
        "sddm"
    }
}

/// ADD-style truncated Neumann solver: with the splitting `L = D − A`,
/// `L⁺ b ≈ Σ_{k=0}^{N} (D⁻¹A)^k D⁻¹ b` on the mean-zero subspace. Each
/// term is one neighbor-exchange round. The error is *fixed* by N — it
/// cannot be driven to arbitrary ε, which is exactly the accuracy gap the
/// paper exploits (Section 6's comparison to distributed Newton ADD).
pub struct NeumannSolver {
    /// Number of expansion terms beyond the diagonal (N).
    pub terms: usize,
    /// Degree vector D (Laplacian diagonal).
    pub degrees: Vec<f64>,
    /// Adjacency CSR (A).
    pub adjacency: Csr,
    /// Undirected edge count (for message accounting).
    pub m_edges: usize,
}

impl NeumannSolver {
    /// Build from a graph.
    pub fn from_graph(g: &crate::graph::Graph, terms: usize) -> NeumannSolver {
        NeumannSolver {
            terms,
            degrees: crate::graph::laplacian::degrees(g),
            adjacency: crate::graph::laplacian::adjacency_csr(g),
            m_edges: g.m(),
        }
    }

    fn center(&self, v: &mut [f64], w: usize, stats: &mut CommStats) {
        let n = self.degrees.len();
        for j in 0..w {
            let mut s = 0.0;
            for i in 0..n {
                s += v[i * w + j];
            }
            let mean = s / n as f64;
            for i in 0..n {
                v[i * w + j] -= mean;
            }
        }
        stats.record_allreduce(n, w);
    }
}

impl LaplacianSolver for NeumannSolver {
    fn solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> SolveOutcome {
        let n = self.degrees.len();
        assert_eq!(b.len(), n * w);
        // term_0 = D^{-1} b;  x = Σ_k term_k;  term_{k+1} = D^{-1} A term_k.
        let mut term = vec![0.0; n * w];
        for i in 0..n {
            for j in 0..w {
                term[i * w + j] = b[i * w + j] / self.degrees[i];
            }
        }
        let mut x = term.clone();
        let mut tmp = vec![0.0; n * w];
        for _ in 0..self.terms {
            self.adjacency.matvec_multi_into(&term, w, &mut tmp);
            stats.record_edge_round(self.m_edges, w);
            for i in 0..n {
                for j in 0..w {
                    term[i * w + j] = tmp[i * w + j] / self.degrees[i];
                }
            }
            for i in 0..n * w {
                x[i] += term[i];
            }
        }
        self.center(&mut x, w, stats);
        // Residual for reporting (not used for control — N is fixed).
        SolveOutcome { x, sweeps: self.terms, rel_residual: f64::NAN, converged: true }
    }
    fn name(&self) -> &'static str {
        "neumann"
    }
}

/// Exact-direction oracle: projected CG to machine precision. The
/// communication model charges one exchange round per CG matvec and one
/// all-reduce per inner product pair, matching a distributed CG.
pub struct ExactCgSolver {
    pub laplacian: Csr,
    pub m_edges: usize,
    pub tol: f64,
}

impl ExactCgSolver {
    /// Build from a graph.
    pub fn from_graph(g: &crate::graph::Graph, tol: f64) -> ExactCgSolver {
        ExactCgSolver {
            laplacian: crate::graph::laplacian::laplacian_csr(g),
            m_edges: g.m(),
            tol,
        }
    }
}

impl LaplacianSolver for ExactCgSolver {
    fn solve(&self, b: &[f64], w: usize, stats: &mut CommStats) -> SolveOutcome {
        let n = self.laplacian.rows;
        let mut x = vec![0.0; n * w];
        let mut worst = 0.0f64;
        let mut total_iters = 0;
        for j in 0..w {
            let col: Vec<f64> = (0..n).map(|i| b[i * w + j]).collect();
            let res = cg_solve(
                &self.laplacian,
                &col,
                &CgOptions { tol: self.tol, max_iter: 20 * n, project_kernel: true },
            );
            for i in 0..n {
                x[i * w + j] = res.x[i];
            }
            worst = worst.max(res.rel_residual);
            total_iters += res.iters;
        }
        // Comm model: each CG iteration = 1 matvec round + 2 dot all-reduces,
        // shared across the w batched systems (they iterate in lockstep in a
        // distributed implementation; we charge the max column count).
        let per_col = total_iters / w.max(1);
        for _ in 0..per_col {
            stats.record_edge_round(self.m_edges, w);
            stats.record_allreduce(n, 2);
        }
        SolveOutcome { x, sweeps: per_col, rel_residual: worst, converged: worst <= self.tol }
    }
    fn name(&self) -> &'static str {
        "exact-cg"
    }
}

/// Convenience: build the default SDDM solver for a graph at accuracy ε.
pub fn sddm_for_graph(
    g: &crate::graph::Graph,
    eps: f64,
    rng: &mut crate::util::Pcg64,
) -> SddmSolver {
    let l = crate::graph::laplacian_csr(g);
    let chain = crate::sddm::Chain::build(&l, &crate::sddm::ChainOptions::default(), rng)
        .expect("Laplacian is SDD by construction");
    SddmSolver::new(chain, crate::sddm::SolverOptions { eps, max_richardson: 300 })
}

/// Helper shared by dual methods: the dual gradient norm ‖M y‖ computed
/// distributedly (used for step-size diagnostics).
pub fn dual_grad_norm(comm: &mut CommGraph, y: &[f64], p: usize) -> f64 {
    let g = comm.laplacian_apply(y, p);
    comm.norm2_sq(&g, p).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::util::Pcg64;

    #[test]
    fn neumann_reduces_residual_but_saturates() {
        let mut rng = Pcg64::new(91);
        let g = generate::random_connected(20, 50, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(20);
        let b = l.matvec(&z);
        let mut prev = f64::INFINITY;
        for terms in [0usize, 2, 6] {
            let s = NeumannSolver::from_graph(&g, terms);
            let mut stats = CommStats::default();
            let out = s.solve(&b, 1, &mut stats);
            let mut r = l.matvec(&out.x);
            for i in 0..20 {
                r[i] = b[i] - r[i];
            }
            crate::linalg::vector::center(&mut r);
            let rel = crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b);
            assert!(rel <= prev + 1e-12, "terms={terms} rel={rel} prev={prev}");
            prev = rel;
        }
        // Even with 6 terms the expansion hasn't solved the system exactly.
        assert!(prev > 1e-6, "Neumann should not be exact: {prev}");
    }

    #[test]
    fn exact_cg_solver_is_exact() {
        let mut rng = Pcg64::new(92);
        let g = generate::random_connected(15, 35, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(15);
        let b = l.matvec(&z);
        let s = ExactCgSolver::from_graph(&g, 1e-12);
        let mut stats = CommStats::default();
        let out = s.solve(&b, 1, &mut stats);
        let lx = l.matvec(&out.x);
        for i in 0..15 {
            assert!((lx[i] - b[i]).abs() < 1e-8);
        }
        assert!(stats.messages > 0);
    }

    #[test]
    fn sddm_hits_eps_where_add_style_neumann_cannot() {
        // The property the paper exploits (Section 6): ADD's truncation
        // fixes the direction error (N = 2 hops), while the SDDM solver
        // reaches any requested ε.
        let mut rng = Pcg64::new(93);
        let g = generate::random_connected(30, 80, &mut rng);
        let l = crate::graph::laplacian_csr(&g);
        let z = rng.normal_vec(30);
        let b = l.matvec(&z);
        let rel = |x: &Vec<f64>| {
            let mut r = l.matvec(x);
            for i in 0..30 {
                r[i] = b[i] - r[i];
            }
            crate::linalg::vector::center(&mut r);
            crate::linalg::vector::norm2(&r) / crate::linalg::vector::norm2(&b)
        };
        let sddm = sddm_for_graph(&g, 1e-6, &mut rng);
        let mut s1 = CommStats::default();
        let o1 = LaplacianSolver::solve(&sddm, &b, 1, &mut s1);
        assert!(rel(&o1.x) <= 1e-6, "sddm rel={}", rel(&o1.x));
        // ADD-style truncation (N = 2 as in [8]'s experiments).
        let nm = NeumannSolver::from_graph(&g, 2);
        let mut s2 = CommStats::default();
        let o2 = nm.solve(&b, 1, &mut s2);
        assert!(rel(&o2.x) > 1e-2, "neumann unexpectedly accurate: {}", rel(&o2.x));
    }
}
