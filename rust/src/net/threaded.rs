//! Threaded message-passing runtime: an MPI stand-in on std::thread +
//! mpsc channels. Each graph node becomes a worker thread that can only
//! `send`/`recv` along graph edges plus participate in all-reduces routed
//! through the leader. The `end_to_end` example runs distributed
//! averaging-style programs on this runtime to demonstrate the node
//! programs are honestly local.

use crate::graph::Graph;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// A message between nodes: (source, payload).
type Msg = (usize, Vec<f64>);

/// Per-node communication handle passed to the node program.
pub struct NodeCtx {
    /// This node's id.
    pub id: usize,
    /// Neighbor ids (sorted).
    pub neighbors: Vec<usize>,
    senders: Vec<(usize, Sender<Msg>)>,
    inbox: Receiver<Msg>,
    /// Per-sender reorder buffer: a fast neighbor may already have sent
    /// its next-round message; it must not be consumed as someone else's
    /// current-round message.
    pending: std::cell::RefCell<std::collections::HashMap<usize, std::collections::VecDeque<Vec<f64>>>>,
    to_leader: Sender<(usize, Vec<f64>)>,
    from_leader: Receiver<Vec<f64>>,
}

impl NodeCtx {
    /// Send a payload to a neighbor (panics if not adjacent).
    pub fn send(&self, to: usize, payload: Vec<f64>) {
        let s = self
            .senders
            .iter()
            .find(|(id, _)| *id == to)
            // sddn-lint: allow(panic) reason=sending to a non-neighbor is a node-program bug; the documented contract is to panic
            .unwrap_or_else(|| panic!("node {} is not adjacent to {}", self.id, to));
        // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
        s.1.send((self.id, payload)).expect("peer hung up");
    }

    /// Broadcast the same payload to all neighbors.
    pub fn send_all(&self, payload: &[f64]) {
        for (_, s) in &self.senders {
            // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
            s.send((self.id, payload.to_vec())).expect("peer hung up");
        }
    }

    /// Receive one message from a specific neighbor, buffering messages
    /// from other (possibly faster) senders for later rounds.
    pub fn recv_from(&self, from: usize) -> Vec<f64> {
        {
            let mut pend = self.pending.borrow_mut();
            if let Some(q) = pend.get_mut(&from) {
                if let Some(m) = q.pop_front() {
                    return m;
                }
            }
        }
        loop {
            // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
            let (src, payload) = self.inbox.recv().expect("peer hung up");
            if src == from {
                return payload;
            }
            self.pending
                .borrow_mut()
                .entry(src)
                .or_default()
                .push_back(payload);
        }
    }

    /// Receive one message from *any* neighbor. Messages parked in the
    /// reorder buffer are older than anything still in the inbox (they
    /// were pulled off the channel while waiting for someone else), so
    /// the buffer MUST be drained before blocking on the inbox —
    /// otherwise a fast neighbor's early sends would starve behind its
    /// own later traffic. Buffered messages drain in neighbor order.
    pub fn recv(&self) -> (usize, Vec<f64>) {
        {
            let mut pend = self.pending.borrow_mut();
            for &j in &self.neighbors {
                if let Some(q) = pend.get_mut(&j) {
                    if let Some(m) = q.pop_front() {
                        return (j, m);
                    }
                }
            }
        }
        // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
        self.inbox.recv().expect("peer hung up")
    }

    /// Receive exactly one message from each neighbor (in neighbor order),
    /// returning (neighbor, payload) pairs. This is the synchronous-round
    /// receive used by diffusion-style algorithms.
    pub fn recv_round(&self) -> Vec<(usize, Vec<f64>)> {
        self.neighbors
            .iter()
            .map(|&j| (j, self.recv_from(j)))
            .collect()
    }

    /// All-reduce (sum) a local vector through the leader; every node gets
    /// the global sum back.
    pub fn allreduce_sum(&self, local: Vec<f64>) -> Vec<f64> {
        // sddn-lint: allow(panic) reason=leader disconnect mid-reduce is unrecoverable; dying loudly beats deadlocking the run
        self.to_leader.send((self.id, local)).expect("leader hung up");
        // sddn-lint: allow(panic) reason=leader disconnect mid-reduce is unrecoverable; dying loudly beats deadlocking the run
        self.from_leader.recv().expect("leader hung up")
    }
}

/// Outcome of a threaded run: per-node results in node order.
pub struct RunOutput<T> {
    /// Whatever each node program returned, indexed by node id.
    pub per_node: Vec<T>,
}

/// Spawn one thread per node, run `program` on each, and drive leader-side
/// all-reduce aggregation until all nodes finish. The node program gets its
/// `NodeCtx` and must perform the *same number* of all-reduce calls on
/// every node (standard BSP contract).
pub fn run_threaded<T, F>(g: &Graph, program: F) -> RunOutput<T>
where
    T: Send + 'static,
    F: Fn(NodeCtx) -> T + Send + Sync + Clone + 'static,
{
    let n = g.n;
    // Edge channels.
    let mut senders_for: Vec<Vec<(usize, Sender<Msg>)>> = (0..n).map(|_| Vec::new()).collect();
    let mut inbox_rx: Vec<Receiver<Msg>> = Vec::with_capacity(n);
    let mut inbox_tx: Vec<Sender<Msg>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Msg>();
        inbox_tx.push(tx);
        inbox_rx.push(rx);
    }
    for i in 0..n {
        for &j in g.neighbors(i) {
            senders_for[i].push((j, inbox_tx[j].clone()));
        }
    }
    // Leader channels.
    let (to_leader_tx, to_leader_rx) = channel::<(usize, Vec<f64>)>();
    let mut from_leader_tx: Vec<Sender<Vec<f64>>> = Vec::with_capacity(n);
    let mut from_leader_rx: Vec<Receiver<Vec<f64>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel::<Vec<f64>>();
        from_leader_tx.push(tx);
        from_leader_rx.push(rx);
    }

    let mut handles = Vec::with_capacity(n);
    for (i, (inbox, from_leader)) in inbox_rx.into_iter().zip(from_leader_rx).enumerate() {
        let ctx = NodeCtx {
            id: i,
            neighbors: g.neighbors(i).to_vec(),
            senders: std::mem::take(&mut senders_for[i]),
            inbox,
            pending: std::cell::RefCell::new(std::collections::HashMap::new()),
            to_leader: to_leader_tx.clone(),
            from_leader,
        };
        let prog = program.clone();
        handles.push(thread::spawn(move || prog(ctx)));
    }
    drop(to_leader_tx);

    // Leader loop: collect n contributions per all-reduce, broadcast sums.
    // Terminates when all node senders are dropped (threads finished).
    loop {
        let mut contributions: Vec<(usize, Vec<f64>)> = Vec::with_capacity(n);
        match to_leader_rx.recv() {
            Ok(first) => contributions.push(first),
            Err(_) => break, // all nodes done
        }
        for _ in 1..n {
            // sddn-lint: allow(panic) reason=a node dying mid-reduce is unrecoverable; dying loudly beats deadlocking the run
            contributions.push(to_leader_rx.recv().expect("node died mid-allreduce"));
        }
        let w = contributions[0].1.len();
        let mut total = vec![0.0; w];
        for (_, v) in &contributions {
            assert_eq!(v.len(), w, "ragged all-reduce");
            for j in 0..w {
                total[j] += v[j];
            }
        }
        for tx in &from_leader_tx {
            let _ = tx.send(total.clone());
        }
    }

    // sddn-lint: allow(panic) reason=propagating a node panic to the caller is the only sane join policy
    let per_node = handles.into_iter().map(|h| h.join().expect("node panicked")).collect();
    RunOutput { per_node }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;

    #[test]
    fn allreduce_sums_ids() {
        let g = generate::cycle(5);
        let out = run_threaded(&g, |ctx: NodeCtx| {
            let s = ctx.allreduce_sum(vec![ctx.id as f64]);
            s[0]
        });
        for v in out.per_node {
            assert_eq!(v, 10.0); // 0+1+2+3+4
        }
    }

    #[test]
    fn neighbor_exchange_round() {
        let g = generate::path(4);
        let out = run_threaded(&g, |ctx: NodeCtx| {
            ctx.send_all(&[ctx.id as f64]);
            let got = ctx.recv_round();
            got.iter().map(|(_, p)| p[0]).sum::<f64>()
        });
        // Path 0-1-2-3: neighbor sums are [1, 2, 4, 2].
        assert_eq!(out.per_node, vec![1.0, 2.0, 4.0, 2.0]);
    }

    #[test]
    fn fast_neighbor_rounds_stay_ordered() {
        // Node 1 races two rounds ahead; the slow endpoints must receive
        // its round-1 payload before its round-2 payload (FIFO through
        // the reorder buffer), never swapped or dropped.
        let g = generate::path(3);
        let out = run_threaded(&g, |ctx: NodeCtx| {
            if ctx.id == 1 {
                // Deliberately fast: fire both rounds back-to-back.
                for round in [1.0, 2.0] {
                    ctx.send(0, vec![round]);
                    ctx.send(2, vec![round]);
                }
                0.0
            } else {
                // Deliberately slow: both messages are already queued.
                std::thread::sleep(std::time::Duration::from_millis(30));
                let a = ctx.recv_from(1);
                let b = ctx.recv_from(1);
                assert_eq!(a, vec![1.0], "node {} got rounds out of order", ctx.id);
                assert_eq!(b, vec![2.0], "node {} got rounds out of order", ctx.id);
                a[0] * 10.0 + b[0]
            }
        });
        assert_eq!(out.per_node, vec![12.0, 0.0, 12.0]);
    }

    #[test]
    fn recv_drains_pending_before_blocking_on_inbox() {
        // Star: node 0 talks to 1 and 2. Node 1 sends immediately; node 2
        // sends late. Node 0 first blocks on recv_from(2), which parks 1's
        // early message in the reorder buffer. The subsequent recv() must
        // return that buffered message — if recv skipped the buffer and
        // blocked on the inbox it would instead pick up 1's *second*
        // message ([99.0]) and the assertion below would fail.
        let g = generate::star(3);
        let out = run_threaded(&g, |ctx: NodeCtx| match ctx.id {
            0 => {
                let from2 = ctx.recv_from(2);
                assert_eq!(from2, vec![20.0]);
                let (src, payload) = ctx.recv();
                assert_eq!((src, payload), (1, vec![10.0]), "pending buffer not drained");
                let tail = ctx.recv_from(1);
                assert_eq!(tail, vec![99.0]);
                1.0
            }
            1 => {
                ctx.send(0, vec![10.0]);
                std::thread::sleep(std::time::Duration::from_millis(60));
                ctx.send(0, vec![99.0]);
                0.0
            }
            _ => {
                std::thread::sleep(std::time::Duration::from_millis(20));
                ctx.send(0, vec![20.0]);
                0.0
            }
        });
        assert_eq!(out.per_node, vec![1.0, 0.0, 0.0]);
    }

    #[test]
    fn diffusion_converges_to_mean() {
        // x_{t+1}(i) = x_t(i) + 0.3 * sum_{j∈N(i)} (x_t(j) − x_t(i))
        let g = generate::complete(4);
        let out = run_threaded(&g, |ctx: NodeCtx| {
            let mut x = ctx.id as f64; // initial values 0,1,2,3 → mean 1.5
            for _ in 0..60 {
                ctx.send_all(&[x]);
                let got = ctx.recv_round();
                let s: f64 = got.iter().map(|(_, p)| p[0] - x).sum();
                x += 0.2 * s;
            }
            x
        });
        for v in out.per_node {
            assert!((v - 1.5).abs() < 1e-6, "v={v}");
        }
    }
}
