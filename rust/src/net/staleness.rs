//! Bounded-staleness halo policy.
//!
//! The strict BSP contract refreshes every halo every round. A
//! [`StalenessPolicy`] with `tau > 0` lets an exchange call site reuse
//! boundary data up to `tau` rounds old: out of every `tau + 1`
//! consecutive calls, one *refresh* round actually crosses the wire and
//! the following `tau` *stale* rounds are reconstructed locally from the
//! cached off-diagonal contribution plus the (always fresh) diagonal
//! self-term. `tau = 0` is bit-for-bit the BSP path.
//!
//! The reconstruction is exact in the following sense: for an operator
//! `a` and owned row `u`,
//!
//! ```text
//! (a · x̂)[u] = a[u,u] · x[u]  +  Σ_{v≠u} a[u,v] · x̂[v]
//! ```
//!
//! The second term is what the refresh round cached (`offdiag`); a stale
//! round recombines it with the *current* local `x[u]`. The output of a
//! stale round is therefore a pure function of (last refresh output,
//! current local iterate) — both of which are already bit-identical
//! across transports — so bounded staleness preserves cross-transport
//! bit-equality for every `tau`, on every transport, with zero
//! per-transport code.
//!
//! Ledger accounting: refresh rounds charge the normal
//! [`crate::net::CommStats::record_exchange`]; stale rounds charge only
//! [`crate::net::CommStats::record_skipped_exchange`] — the modeled
//! savings — so wire-truth assertions over `messages`/`floats`/`bytes`
//! hold unchanged.

use crate::linalg::Csr;

/// How stale consumed boundary data may be, in rounds.
///
/// `tau = 0` means strict BSP (every round refreshes); `tau = 2` means
/// one wire round out of every three.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StalenessPolicy {
    /// Maximum halo age in rounds.
    pub tau: u64,
}

impl StalenessPolicy {
    /// Strict BSP: never consume stale data.
    pub fn bsp() -> Self {
        StalenessPolicy { tau: 0 }
    }

    /// Fresh per-call-site state for this policy.
    pub fn state(&self) -> StaleState {
        StaleState::new(self.tau)
    }
}

/// Per-call-site staleness state: one `StaleState` per (operator,
/// vector) stream of exchange calls. Created via [`StalenessPolicy`] or
/// [`StaleState::new`]; consumed by
/// [`crate::net::Exchange::exchange_apply_stale`].
#[derive(Debug, Clone)]
pub struct StaleState {
    /// Maximum halo age in rounds (0 = strict BSP).
    pub tau: u64,
    /// Calls issued so far; `age % (tau + 1) == 0` refreshes.
    age: u64,
    /// Whether `owned`/`diag` have been captured yet.
    primed: bool,
    /// Global ids of the handle's owned rows, captured on first refresh.
    owned: Vec<usize>,
    /// Operator diagonal `a[u,u]` per owned row.
    diag: Vec<f64>,
    /// Cached off-diagonal contribution per owned row × width, from the
    /// last refresh round.
    offdiag: Vec<f64>,
}

impl StaleState {
    /// Fresh state for a maximum halo age of `tau` rounds.
    pub fn new(tau: u64) -> Self {
        StaleState { tau, age: 0, primed: false, owned: Vec::new(), diag: Vec::new(), offdiag: Vec::new() }
    }

    /// True when the next call will cross the wire (the first call
    /// always does).
    pub fn next_is_refresh(&self) -> bool {
        self.tau == 0 || self.age % (self.tau + 1) == 0
    }

    /// Capture the owned-row set and operator diagonal (idempotent).
    pub(crate) fn prime(&mut self, a: &Csr, owned: &[usize]) {
        if self.primed {
            return;
        }
        self.owned.extend_from_slice(owned);
        self.diag.reserve(owned.len());
        for &u in owned {
            let mut d = 0.0;
            for k in a.indptr[u]..a.indptr[u + 1] {
                if a.indices[k] == u {
                    d += a.values[k];
                }
            }
            self.diag.push(d);
        }
        self.primed = true;
    }

    /// After a refresh round wrote `out = (a·x̂)[owned]`, cache the
    /// off-diagonal part `out − diag ⊙ x` for the stale rounds to come.
    pub(crate) fn cache_refresh(&mut self, x: &[f64], w: usize, out: &[f64]) {
        self.offdiag.clear();
        self.offdiag.extend_from_slice(out);
        for (li, &d) in self.diag.iter().enumerate() {
            for j in 0..w {
                self.offdiag[li * w + j] -= d * x[li * w + j];
            }
        }
        self.age += 1;
    }

    /// Reconstruct a stale round locally: cached off-diagonal plus the
    /// fresh diagonal self-term.
    pub(crate) fn apply_stale(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.offdiag.len());
        for (li, &d) in self.diag.iter().enumerate() {
            for j in 0..w {
                out[li * w + j] = self.offdiag[li * w + j] + d * x[li * w + j];
            }
        }
        self.age += 1;
    }
}
