//! Partitioned channel transport: the [`Exchange`](super::Exchange)
//! implementation that runs node shards on worker OS threads.
//!
//! This is the deployment shape of the paper (100 graph nodes divided
//! over 8 Matlab pool workers, boundary values on MatlabMPI): a
//! [`crate::coordinator::Partition`] assigns every graph node to one of
//! `k` workers; intra-worker edges are local memory, cross-worker edges
//! ride mpsc channels. Four pieces:
//!
//! - [`ShardPlan`] — the static graph-halo plan per worker: which owned
//!   (boundary) nodes neighbor which peer, plus the node→worker owner
//!   map every exchange plan is derived from.
//! - [`ExchangePlan`] — a *per-operator* sparse exchange plan derived
//!   from the operator's actual CSR support: per peer, exactly the owned
//!   rows that peer's rows read. Graph-support operators get their plan
//!   automatically on first use; operators whose support exceeds the
//!   graph neighborhoods (squared-chain overlays, future preconditioners)
//!   must be opted in through [`Exchange::register_plan`], which builds
//!   an *overlay halo plan* from the same derivation. Sender and receiver
//!   derive identical plans from the same global CSR + owner map, so
//!   payloads need no per-node framing — only a round tag.
//! - [`ShardExchange`] — the per-worker handle. `exchange_apply` ships
//!   exactly the plan's boundary rows (tagged with the round number and
//!   reorder-buffered on receive, so a fast peer cannot smuggle round
//!   `t+1` payloads into round `t`);
//!   [`Exchange::exchange_apply_fresh`] further restricts a round to the
//!   freshly-updated source rows, which is how ADMM's sweep stages ship
//!   only each stage's active boundary. The mirror of needed global
//!   columns feeds [`crate::linalg::Csr::row_matvec_multi`] — the *same*
//!   row kernel the bulk transport uses, which is what makes the two
//!   transports bit-for-bit identical.
//! - [`run_reducer`] — the tree all-reduce stand-in: contributions are
//!   keyed by a sequence number (never popped by count, so a fast worker's
//!   reduce `s+1` cannot blend into `s`), assembled into a dense global
//!   stack and summed in **global node order** — the identical float
//!   additions the bulk transport performs.
//!
//! Modeled [`CommStats`] are tallied identically on every worker (each
//! worker observes the same system-wide rounds); real channel traffic is
//! tracked separately in [`ShardExchange::cross_messages`] /
//! [`ShardExchange::cross_floats`]. Because shipping is plan-driven, the
//! real traffic is *predictable from the plans*: [`plan_cross_rows`] is
//! the wire model the `prop_wire` suite, the `partitioned_baselines`
//! bench and the `sddnewton partitioned` CLI check the channels against.

use super::{CommStats, Exchange};
use crate::coordinator::partition::Partition;
use crate::graph::Graph;
use crate::linalg::Csr;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::mpsc::{Receiver, Sender};

/// One boundary payload on the wire:
/// `(sender worker, exchange round, values in the sender's plan order)`.
pub type WireMsg = (usize, u64, Vec<f64>);

/// One all-reduce contribution:
/// `(worker, reduce sequence number, owned locals in shard order)`.
pub type ReduceMsg = (usize, u64, Vec<f64>);

/// Static communication plan for one worker's shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// This worker's id in `0..k`.
    pub worker: usize,
    /// Owned global node ids, ascending — the shard-local row order.
    pub owned: Vec<usize>,
    /// `local_of[global] = local row`, `usize::MAX` when not owned.
    pub local_of: Vec<usize>,
    /// `owner[global] = worker id` — the map every per-operator
    /// [`ExchangePlan`] is derived from.
    pub owner: Vec<usize>,
    /// Nodes whose values are available after a *graph-halo* exchange
    /// (owned ∪ halo). Unregistered operators must stay within this set.
    pub covered: Vec<bool>,
    /// Per peer (ascending): owned boundary nodes neighboring that peer,
    /// ascending — the graph-halo send set (what an operator with full
    /// edge support ships).
    pub send: Vec<(usize, Vec<usize>)>,
    /// Per peer (ascending): that peer's nodes neighboring this shard,
    /// ascending — mirrors the peer's `send` entry for this worker.
    pub recv: Vec<(usize, Vec<usize>)>,
}

/// A sparse exchange plan derived from one operator's CSR support: for
/// worker `me`, exactly which owned rows each peer's rows read (`send`)
/// and which remote rows this worker's rows read (`recv`). Operators with
/// support beyond the graph edges (squared-chain overlays) get *overlay
/// halo plans* through the identical derivation — the support, not the
/// graph, decides what crosses the wire.
#[derive(Debug, Clone)]
pub struct ExchangePlan {
    /// Diagnostic name (e.g. `"graph-support"`, `"squared-chain level"`).
    pub name: String,
    /// Per peer (ascending): owned rows shipped to that peer each round,
    /// ascending.
    pub send: Vec<(usize, Vec<usize>)>,
    /// Per peer (ascending): remote rows received from that peer each
    /// round, ascending — mirrors the peer's `send` entry for this worker.
    pub recv: Vec<(usize, Vec<usize>)>,
    /// Nodes whose values are available after one round under this plan
    /// (owned ∪ this operator's halo) — covers the operator's support by
    /// construction.
    pub covered: Vec<bool>,
}

fn dedup_sorted(m: BTreeMap<usize, Vec<usize>>) -> Vec<(usize, Vec<usize>)> {
    m.into_iter()
        .map(|(peer, mut nodes)| {
            nodes.sort_unstable();
            nodes.dedup();
            (peer, nodes)
        })
        .collect()
}

/// Build the graph-halo plans for every worker of a partition. The halo
/// depends only on the graph topology; per-operator [`ExchangePlan`]s are
/// derived on demand from each operator's support.
pub fn build_shard_plans(g: &Graph, part: &Partition) -> Vec<ShardPlan> {
    let n = g.n;
    assert_eq!(part.assignment.len(), n, "partition does not cover the graph");
    let mut plans = Vec::with_capacity(part.k);
    for w in 0..part.k {
        let owned = part.nodes_of(w);
        let mut local_of = vec![usize::MAX; n];
        let mut covered = vec![false; n];
        for (li, &u) in owned.iter().enumerate() {
            local_of[u] = li;
            covered[u] = true;
        }
        let mut send: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let mut recv: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &u in &owned {
            for &v in g.neighbors(u) {
                let pv = part.assignment[v];
                if pv != w {
                    send.entry(pv).or_default().push(u);
                    recv.entry(pv).or_default().push(v);
                    covered[v] = true;
                }
            }
        }
        plans.push(ShardPlan {
            worker: w,
            owned,
            local_of,
            owner: part.assignment.clone(),
            covered,
            send: dedup_sorted(send),
            recv: dedup_sorted(recv),
        });
    }
    plans
}

/// Derive worker `me`'s sparse [`ExchangePlan`] for operator `a` from its
/// CSR support: row `v` of `a` reading column `u` with `owner[u] ≠
/// owner[v]` puts `u` on the `owner[u] → owner[v]` wire. Every worker
/// derives from the same global CSR and owner map, so the k plans are
/// mutually consistent (`send[me→q]` on `me` equals `recv[q←me]` on `q`).
pub fn derive_exchange_plan(name: &str, a: &Csr, owner: &[usize], me: usize) -> ExchangePlan {
    assert_eq!(a.rows, owner.len(), "operator/partition size mismatch");
    assert_eq!(a.cols, owner.len(), "operator must be square over the nodes");
    let n = owner.len();
    let mut covered: Vec<bool> = owner.iter().map(|&o| o == me).collect();
    let mut send: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    let mut recv: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for v in 0..n {
        let pv = owner[v];
        for kk in a.indptr[v]..a.indptr[v + 1] {
            let u = a.indices[kk];
            let pu = owner[u];
            if pu == pv {
                continue;
            }
            if pv == me {
                recv.entry(pu).or_default().push(u);
                covered[u] = true;
            } else if pu == me {
                send.entry(pv).or_default().push(u);
            }
        }
    }
    ExchangePlan {
        name: name.to_string(),
        send: dedup_sorted(send),
        recv: dedup_sorted(recv),
        covered,
    }
}

/// Wire model of one plan-driven exchange round: the system-wide number
/// of cross-worker row payloads operator `a` puts on the channels, i.e.
/// distinct `(row u, destination worker)` pairs with a reader of `u` on a
/// worker other than `owner[u]`. `fresh` restricts the count to masked
/// source rows — the [`Exchange::exchange_apply_fresh`] rounds of a
/// wavefront schedule. This is what the wire-truth suite compares
/// [`ShardExchange::cross_messages`] against.
pub fn plan_cross_rows(a: &Csr, owner: &[usize], fresh: Option<&[bool]>) -> u64 {
    assert_eq!(a.rows, owner.len(), "operator/partition size mismatch");
    let mut pairs: BTreeSet<(usize, usize)> = BTreeSet::new();
    for v in 0..a.rows {
        let pv = owner[v];
        for kk in a.indptr[v]..a.indptr[v + 1] {
            let u = a.indices[kk];
            if owner[u] == pv {
                continue;
            }
            if fresh.is_some_and(|m| !m[u]) {
                continue;
            }
            pairs.insert((u, pv));
        }
    }
    pairs.len() as u64
}

/// Cache key identifying an operator across rounds: the addresses of all
/// three CSR buffers plus nnz and shape. The operators of a run (chain
/// walk matrix, Laplacian, adjacency, overlay levels) are long-lived —
/// the transport requires them to outlive the run, see
/// [`Exchange::register_plan`] — so deriving each plan once keeps the
/// O(nnz) scan off the per-round hot path; keying every buffer address
/// makes an allocator-reuse collision (freed operator, new one at the
/// same address with identical nnz/shape) require three simultaneous
/// coincidences instead of one.
pub(crate) type OpKey = (usize, usize, usize, usize, usize);

pub(crate) fn op_key(a: &Csr) -> OpKey {
    (
        a.indices.as_ptr() as usize,
        a.indptr.as_ptr() as usize,
        a.values.as_ptr() as usize,
        a.nnz(),
        a.rows,
    )
}

/// Receive the `round`-tagged payload from `peer`, parking any other
/// (possibly future-round) payloads in the reorder buffer.
///
/// `high_water` bounds how far ahead of the awaited round a parked
/// payload may be: under a bounded-staleness policy with halo age τ a
/// correct peer can legitimately run at most τ+1 exchange rounds ahead,
/// so a payload tagged beyond `round + high_water` is a protocol
/// violation (or a runaway peer that would otherwise grow the buffer
/// without bound) and dies loudly — never a silent drop, which would
/// corrupt a later round. `None` keeps the legacy unbounded buffer
/// (sparse masked schedules can park arbitrarily many rounds a worker
/// never consumes).
fn recv_round(
    pending: &mut HashMap<(usize, u64), Vec<f64>>,
    inbox: &Receiver<WireMsg>,
    peer: usize,
    round: u64,
    high_water: Option<u64>,
) -> Vec<f64> {
    if let Some(d) = pending.remove(&(peer, round)) {
        return d;
    }
    loop {
        // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
        let (src, r, data) = inbox.recv().expect("peer worker died");
        if src == peer && r == round {
            return data;
        }
        if let Some(bound) = high_water {
            assert!(
                r <= round + bound,
                "reorder buffer high-water exceeded: worker {src} is at round {r}, \
                 {} ahead of awaited round {round} (bound {bound}); a bounded-staleness \
                 policy with halo age tau admits at most tau+1 rounds of skew",
                r - round
            );
        }
        let prev = pending.insert((src, r), data);
        assert!(prev.is_none(), "duplicate payload from worker {src} round {r}");
    }
}

/// Per-worker [`Exchange`] handle over mpsc channels.
pub struct ShardExchange<'a> {
    n: usize,
    k: usize,
    m_edges: usize,
    /// Graph Laplacian shared by all workers (for `laplacian_apply`).
    lap: &'a Csr,
    plan: ShardPlan,
    /// Senders toward every worker, indexed by worker id (overlay plans
    /// may reach workers that are not graph-halo neighbors; the self
    /// entry is never used).
    peer_txs: Vec<Sender<WireMsg>>,
    inbox: Receiver<WireMsg>,
    /// Reorder buffer for early payloads, keyed `(sender, round)`.
    pending: HashMap<(usize, u64), Vec<f64>>,
    /// Mirror of the global stack holding fresh values for covered nodes.
    mirror: Vec<f64>,
    round: u64,
    red_seq: u64,
    to_reducer: Sender<ReduceMsg>,
    from_reducer: Receiver<Vec<f64>>,
    /// Per-operator exchange plans, derived once from each operator's
    /// support (lazily for graph-support operators, eagerly through
    /// [`Exchange::register_plan`] for overlays).
    op_plans: HashMap<OpKey, ExchangePlan>,
    /// Arena of boundary-payload buffers: consumed inbound payloads are
    /// parked here and reused for outbound sends, so steady-state rounds
    /// allocate nothing. Capped at [`PAYLOAD_POOL_CAP`].
    payload_pool: Vec<Vec<f64>>,
    /// Persistent scratch for the fresh-masked receive row list.
    fresh_scratch: Vec<usize>,
    /// Reorder-buffer high-water mark in rounds; `None` = unbounded
    /// (legacy). See [`ShardExchange::set_reorder_high_water`].
    reorder_high_water: Option<u64>,
    stats: CommStats,
    cross: u64,
    cross_floats: u64,
}

/// Cap on parked payload buffers per worker (excess buffers are dropped).
const PAYLOAD_POOL_CAP: usize = 64;

impl<'a> ShardExchange<'a> {
    /// Wire up a worker handle. `peer_txs` holds one sender per worker,
    /// indexed by worker id (including an unused entry for this worker).
    pub fn new(
        g: &Graph,
        lap: &'a Csr,
        k: usize,
        plan: ShardPlan,
        peer_txs: Vec<Sender<WireMsg>>,
        inbox: Receiver<WireMsg>,
        to_reducer: Sender<ReduceMsg>,
        from_reducer: Receiver<Vec<f64>>,
    ) -> ShardExchange<'a> {
        assert_eq!(peer_txs.len(), k, "need one sender per worker");
        assert_eq!(lap.rows, g.n);
        ShardExchange {
            n: g.n,
            k,
            m_edges: g.m(),
            lap,
            plan,
            peer_txs,
            inbox,
            pending: HashMap::new(),
            mirror: Vec::new(),
            round: 0,
            red_seq: 0,
            to_reducer,
            from_reducer,
            op_plans: HashMap::new(),
            payload_pool: Vec::new(),
            fresh_scratch: Vec::new(),
            reorder_high_water: None,
            stats: CommStats::default(),
            cross: 0,
            cross_floats: 0,
        }
    }

    /// Take a cleared payload buffer from the arena (or allocate one).
    fn take_payload(&mut self) -> Vec<f64> {
        let mut buf = self.payload_pool.pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Park a consumed payload buffer for reuse.
    fn park_payload(&mut self, buf: Vec<f64>) {
        if self.payload_pool.len() < PAYLOAD_POOL_CAP && buf.capacity() > 0 {
            self.payload_pool.push(buf);
        }
    }

    /// Real cross-worker channel traffic so far: one count per boundary
    /// row payload plus 2 per all-reduce (up + down through the leader).
    /// This is the deployment's MPI traffic, distinct from the modeled
    /// per-node [`CommStats`] — and, with plan-driven shipping, exactly
    /// predicted by [`plan_cross_rows`].
    pub fn cross_messages(&self) -> u64 {
        self.cross
    }

    /// Real floats moved over the channels so far (row payloads × width,
    /// plus all-reduce up/down payloads). ×8 for bytes on the wire.
    pub fn cross_floats(&self) -> u64 {
        self.cross_floats
    }

    /// Bound the reorder buffer: a payload parked more than `rounds`
    /// exchange rounds ahead of the awaited round dies loudly instead of
    /// growing the buffer without bound. Under a bounded-staleness
    /// policy with halo age τ the correct setting is τ+1 — a well-behaved
    /// peer can never legitimately exceed that skew. Opt-in because
    /// sparse masked schedules (wavefronts where a worker's receive set
    /// is empty for many rounds) legitimately park far-future payloads.
    pub fn set_reorder_high_water(&mut self, rounds: u64) {
        self.reorder_high_water = Some(rounds);
    }

    /// This worker's shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The exchange plan the transport derived (or had registered) for an
    /// operator, if any — lets tests and benches inspect what ships.
    pub fn plan_for(&self, a: &Csr) -> Option<&ExchangePlan> {
        self.op_plans.get(&op_key(a))
    }

    /// Ensure an exchange plan exists for `a`. Unregistered operators
    /// must stay within the graph halo; wider support requires an
    /// explicit [`Exchange::register_plan`] opt-in.
    fn ensure_plan(&mut self, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        for &u in &self.plan.owned {
            for kk in a.indptr[u]..a.indptr[u + 1] {
                assert!(
                    self.plan.covered[a.indices[kk]],
                    "operator support escapes the halo at row {u}: the partitioned \
                     transport only ships graph-support operators unless an overlay \
                     plan is registered (Exchange::register_plan)"
                );
            }
        }
        let plan = derive_exchange_plan("graph-support", a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    /// One plan-driven exchange round; `fresh` (when given) restricts the
    /// shipped rows to the freshly-updated source set — both endpoints
    /// intersect the same plan with the same global mask, so the wire
    /// stays framed by the round tag alone. `compute` (when given)
    /// restricts the step-3 row kernels to the masked owned rows; rows
    /// outside it are left unspecified (the caller promised not to read
    /// them) — what ships is unchanged, only local arithmetic is skipped.
    // sddn-lint: hot-path
    fn exchange_round(
        &mut self,
        a: &Csr,
        fresh: Option<&[bool]>,
        compute: Option<&[bool]>,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        let ln = self.plan.owned.len();
        assert_eq!(a.rows, self.n, "operator shape mismatch");
        assert_eq!(x.len(), ln * w, "payload shape mismatch");
        assert_eq!(out.len(), ln * w);
        if let Some(m) = fresh {
            assert_eq!(m.len(), self.n, "fresh mask must cover every global node");
        }
        if let Some(c) = compute {
            assert_eq!(c.len(), self.n, "compute mask must cover every global node");
        }
        self.ensure_plan(a);
        self.round += 1;
        let round = self.round;
        let mirror_reset = self.mirror.len() != self.n * w;
        if mirror_reset {
            // sddn-lint: allow(alloc) reason=one-time mirror growth on first round at a new width, reused afterwards
            self.mirror = vec![0.0; self.n * w];
        }
        let key = op_key(a);
        let xplan = &self.op_plans[&key];
        let live = |u: usize| fresh.is_none_or(|m| m[u]);

        // A fresh round relies on the mirror retaining each non-fresh halo
        // row's last-shipped value; right after a (re)allocation those
        // slots are unseeded zeros, so every halo row this operator reads
        // must be in the mask — silent drift would be far worse than this
        // panic (issue one full exchange at the new width first).
        if mirror_reset && fresh.is_some() {
            for (_, rows) in &xplan.recv {
                for &u in rows {
                    assert!(
                        live(u),
                        "fresh exchange after a mirror reset would read unseeded halo \
                         row {u}: issue a full exchange at this width first"
                    );
                }
            }
        }

        // 1. Ship the plan's (fresh) owned rows to each peer, tagged with
        //    the round. Outbound buffers come from the payload arena —
        //    every consumed inbound payload is parked there in step 2, so
        //    steady-state rounds recycle instead of allocating.
        for (peer, rows) in &xplan.send {
            let mut buf = self.payload_pool.pop().unwrap_or_default();
            buf.clear();
            buf.reserve(rows.len() * w);
            let mut shipped = 0u64;
            for &u in rows {
                if !live(u) {
                    continue;
                }
                let li = self.plan.local_of[u];
                buf.extend_from_slice(&x[li * w..(li + 1) * w]);
                shipped += 1;
            }
            if shipped == 0 {
                if self.payload_pool.len() < PAYLOAD_POOL_CAP {
                    self.payload_pool.push(buf);
                }
                continue;
            }
            self.peer_txs[*peer]
                .send((self.plan.worker, round, buf))
                // sddn-lint: allow(panic) reason=peer disconnect mid-round is unrecoverable; dying loudly beats deadlocking the run
                .unwrap_or_else(|_| panic!("peer worker {peer} died"));
            self.cross += shipped;
            self.cross_floats += shipped * w as u64;
        }

        // 2. Refresh the mirror: owned rows from `x`, (fresh) halo rows
        //    from the peers (reorder-buffered by round). The dominant
        //    full-round case borrows the plan rows directly; only masked
        //    rounds fill the persistent filtered-row scratch.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            self.mirror[u * w..(u + 1) * w].copy_from_slice(&x[li * w..(li + 1) * w]);
        }
        for (peer, rows) in &xplan.recv {
            let expect: &[usize] = match fresh {
                None => rows,
                Some(_) => {
                    self.fresh_scratch.clear();
                    self.fresh_scratch.extend(rows.iter().copied().filter(|&u| live(u)));
                    &self.fresh_scratch
                }
            };
            if expect.is_empty() {
                continue;
            }
            let data =
                recv_round(&mut self.pending, &self.inbox, *peer, round, self.reorder_high_water);
            assert_eq!(data.len(), expect.len() * w, "halo payload width drifted");
            for (idx, &u) in expect.iter().enumerate() {
                self.mirror[u * w..(u + 1) * w].copy_from_slice(&data[idx * w..(idx + 1) * w]);
            }
            if self.payload_pool.len() < PAYLOAD_POOL_CAP && data.capacity() > 0 {
                self.payload_pool.push(data);
            }
        }

        // 3. Owned rows via the shared CSR row kernel (bit-for-bit equal
        //    to the bulk transport's block sweep). A compute mask skips
        //    rows the caller will not read — wavefront schedules pay for
        //    one independent set per stage instead of the full shard.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            if compute.is_none_or(|c| c[u]) {
                a.row_matvec_multi(u, &self.mirror, w, &mut out[li * w..(li + 1) * w]);
            }
        }
        self.stats.record_exchange(directed_messages, w);
    }
}

impl Exchange for ShardExchange<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn owned(&self) -> &[usize] {
        &self.plan.owned
    }

    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        self.exchange_round(a, None, None, directed_messages, x, w, out);
    }

    fn exchange_apply_fresh(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        self.exchange_round(a, Some(fresh), None, directed_messages, x, w, out);
    }

    fn exchange_apply_fresh_rows(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        compute: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        self.exchange_round(a, Some(fresh), Some(compute), directed_messages, x, w, out);
    }

    fn register_plan(&mut self, name: &str, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        let plan = derive_exchange_plan(name, a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        let lap = self.lap;
        // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph plus diagonal
        self.exchange_apply(lap, 2 * self.m_edges as u64, x, w, out);
    }

    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        assert_eq!(locals.len(), self.plan.owned.len() * w);
        self.red_seq += 1;
        let mut up = self.take_payload();
        up.extend_from_slice(locals);
        // sddn-lint: allow(panic) reason=reducer disconnect mid-reduce is unrecoverable; dying loudly beats deadlocking the run
        self.to_reducer.send((self.plan.worker, self.red_seq, up)).expect("reducer died");
        // sddn-lint: allow(panic) reason=reducer disconnect mid-reduce is unrecoverable; dying loudly beats deadlocking the run
        let down = self.from_reducer.recv().expect("reducer died");
        assert_eq!(down.len(), w, "all-reduce width drifted across workers");
        if self.k > 1 {
            self.cross += 2;
            self.cross_floats += (locals.len() + w) as u64;
        }
        self.stats.record_allreduce(self.n, w);
        // The reducer answers in a recycled contribution buffer (large
        // capacity); park it and hand the caller a right-sized copy so the
        // arena keeps its buffers across up/down cycles.
        let total = down.clone();
        self.park_payload(down);
        total
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

/// Leader-side all-reduce loop. Contributions are keyed by their sequence
/// number — a fast worker already at reduce `s+1` cannot be blended into
/// reduce `s` — and the dense global stack is summed in node order, so the
/// totals match the bulk transport bit for bit. Runs until every worker
/// sender is dropped.
///
/// Hot-loop hygiene: the dense assembly buffer persists across reduces
/// (every slot is overwritten — the shards partition the node set), and
/// each worker's answer rides back in that worker's own recycled
/// contribution buffer, so the workers' payload arenas keep their
/// buffers across up/down cycles and a steady-state reduce allocates
/// nothing beyond the `w`-float total.
pub fn run_reducer(
    n: usize,
    owned_of: &[Vec<usize>],
    rx: Receiver<ReduceMsg>,
    txs: &[Sender<Vec<f64>>],
) {
    let k = owned_of.len();
    assert_eq!(txs.len(), k);
    let mut open: BTreeMap<u64, (usize, Vec<Option<Vec<f64>>>)> = BTreeMap::new();
    let mut dense: Vec<f64> = Vec::new();
    while let Ok((wid, seq, vals)) = rx.recv() {
        let slot = open.entry(seq).or_insert_with(|| (0, vec![None; k]));
        assert!(slot.1[wid].is_none(), "duplicate all-reduce contribution from worker {wid}");
        slot.1[wid] = Some(vals);
        slot.0 += 1;
        if slot.0 < k {
            continue;
        }
        // sddn-lint: allow(panic) reason=slot seq was just completed above, so the entry is present by construction
        let (_, parts) = open.remove(&seq).unwrap();
        let w = parts
            .iter()
            .zip(owned_of)
            .find_map(|(part, owned)| {
                // sddn-lint: allow(panic) reason=a completed slot holds all k contributions by construction
                (!owned.is_empty()).then(|| part.as_ref().unwrap().len() / owned.len())
            })
            .unwrap_or(0);
        // Fully overwritten below (the shards partition 0..n), so a plain
        // resize suffices — no per-reduce allocation or re-zeroing.
        dense.resize(n * w, 0.0);
        for (part, owned) in parts.iter().zip(owned_of) {
            // sddn-lint: allow(panic) reason=a completed slot holds all k contributions by construction
            let vals = part.as_ref().unwrap();
            for (li, &u) in owned.iter().enumerate() {
                dense[u * w..(u + 1) * w].copy_from_slice(&vals[li * w..(li + 1) * w]);
            }
        }
        // Global node order — identical float additions to the bulk sweep.
        let mut total = vec![0.0; w];
        for i in 0..n {
            for j in 0..w {
                total[j] += dense[i * w + j];
            }
        }
        // Answer each worker in its own recycled contribution buffer.
        for (tx, part) in txs.iter().zip(parts) {
            // sddn-lint: allow(panic) reason=a completed slot holds all k contributions by construction
            let mut back = part.unwrap();
            back.clear();
            back.extend_from_slice(&total);
            let _ = tx.send(back);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian_csr};
    use crate::util::Pcg64;
    use std::sync::mpsc::channel;
    use std::sync::Mutex;

    #[test]
    fn plans_are_symmetric_and_cover_halos() {
        let mut rng = Pcg64::new(41);
        let g = generate::random_connected(14, 30, &mut rng);
        let part = Partition::round_robin(14, 3);
        let plans = build_shard_plans(&g, &part);
        for p in &plans {
            // Every owned node is covered; every neighbor of an owned node
            // is covered.
            for &u in &p.owned {
                assert!(p.covered[u]);
                for &v in g.neighbors(u) {
                    assert!(p.covered[v], "worker {} misses halo node {v}", p.worker);
                }
            }
            // send[w→q] must equal recv[q←w] on q's side.
            for (peer, nodes) in &p.send {
                let q = &plans[*peer];
                let back = q
                    .recv
                    .iter()
                    .find(|(from, _)| *from == p.worker)
                    .map(|(_, ns)| ns.clone())
                    .unwrap_or_default();
                assert_eq!(&back, nodes, "asymmetric plan {} → {}", p.worker, peer);
            }
        }
    }

    /// For a full-edge-support operator (the Laplacian) the derived
    /// exchange plan must coincide with the static graph-halo plan — the
    /// fallback and the derivation agree wherever both apply.
    #[test]
    fn laplacian_exchange_plan_matches_graph_halo() {
        let mut rng = Pcg64::new(43);
        let g = generate::random_connected(13, 28, &mut rng);
        let lap = laplacian_csr(&g);
        for part in [Partition::contiguous(13, 3), Partition::round_robin(13, 4)] {
            let plans = build_shard_plans(&g, &part);
            for p in &plans {
                let xp = derive_exchange_plan("lap", &lap, &p.owner, p.worker);
                assert_eq!(xp.send, p.send, "worker {} send drifted", p.worker);
                assert_eq!(xp.recv, p.recv, "worker {} recv drifted", p.worker);
            }
            // The wire model counts exactly the halo boundary rows.
            let b: u64 = plans
                .iter()
                .map(|p| p.send.iter().map(|(_, ns)| ns.len() as u64).sum::<u64>())
                .sum();
            assert_eq!(plan_cross_rows(&lap, &part.assignment, None), b);
        }
    }

    /// Derived plans are mutually consistent across workers for *any*
    /// square operator, including overlays whose support leaves the graph
    /// neighborhoods.
    #[test]
    fn derived_plans_are_symmetric_for_overlays() {
        let mut rng = Pcg64::new(44);
        let g = generate::random_connected(12, 22, &mut rng);
        let lap = laplacian_csr(&g);
        // A 2-hop overlay: support of L² exceeds the edge set.
        let two_hop = lap.matmul(&lap);
        let part = Partition::contiguous(12, 4);
        let plans: Vec<ExchangePlan> = (0..4)
            .map(|w| derive_exchange_plan("two-hop", &two_hop, &part.assignment, w))
            .collect();
        for (w, p) in plans.iter().enumerate() {
            for (peer, nodes) in &p.send {
                let back = plans[*peer]
                    .recv
                    .iter()
                    .find(|(from, _)| *from == w)
                    .map(|(_, ns)| ns.clone())
                    .unwrap_or_default();
                assert_eq!(&back, nodes, "asymmetric overlay plan {w} → {peer}");
            }
            // The plan's halo covers the operator's support on owned rows.
            for v in 0..12 {
                if part.assignment[v] != w {
                    continue;
                }
                for kk in two_hop.indptr[v]..two_hop.indptr[v + 1] {
                    assert!(p.covered[two_hop.indices[kk]], "worker {w} misses support of row {v}");
                }
            }
        }
    }

    /// Two workers exchanging over channels must reproduce the bulk
    /// transport bit for bit — both the Laplacian round and the
    /// all-reduce, including the modeled counters — and the channel
    /// traffic must equal the plan model.
    #[test]
    fn shard_exchange_matches_bulk_bit_for_bit() {
        let mut rng = Pcg64::new(42);
        let g = generate::random_connected(11, 24, &mut rng);
        let lap = laplacian_csr(&g);
        let w = 3;
        let x = rng.normal_vec(11 * w);

        let mut comm = crate::net::CommGraph::new(&g);
        let bulk_y = comm.laplacian_apply(&x, w);
        let bulk_total = comm.allreduce_sum(&x, w);
        let bulk_stats = *comm.stats();

        for part in [Partition::contiguous(11, 2), Partition::round_robin(11, 3)] {
            let k = part.k;
            let plans = build_shard_plans(&g, &part);
            let owned_of: Vec<Vec<usize>> = plans.iter().map(|p| p.owned.clone()).collect();

            let mut wire_tx = Vec::new();
            let mut wire_rx = Vec::new();
            for _ in 0..k {
                let (tx, rx) = channel::<WireMsg>();
                wire_tx.push(tx);
                wire_rx.push(Some(rx));
            }
            let (red_tx, red_rx) = channel::<ReduceMsg>();
            let mut red_out_tx = Vec::new();
            let mut red_out_rx = Vec::new();
            for _ in 0..k {
                let (tx, rx) = channel::<Vec<f64>>();
                red_out_tx.push(tx);
                red_out_rx.push(Some(rx));
            }

            let n = g.n;
            let wire_model = plan_cross_rows(&lap, &part.assignment, None) + 2 * k as u64;
            let results = Mutex::new(vec![(Vec::new(), Vec::new(), CommStats::default(), 0u64); k]);
            std::thread::scope(|scope| {
                {
                    let owned_of = owned_of.clone();
                    let txs = red_out_tx.clone();
                    scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
                }
                for (wid, plan) in plans.into_iter().enumerate() {
                    let peer_txs: Vec<_> = wire_tx.clone();
                    let inbox = wire_rx[wid].take().unwrap();
                    let from_red = red_out_rx[wid].take().unwrap();
                    let red = red_tx.clone();
                    let xl: Vec<f64> = plan
                        .owned
                        .iter()
                        .flat_map(|&u| x[u * w..(u + 1) * w].to_vec())
                        .collect();
                    let (g, lap, results) = (&g, &lap, &results);
                    scope.spawn(move || {
                        let mut ex =
                            ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                        let y = ex.laplacian_apply(&xl, w);
                        let total = ex.allreduce_sum(&xl, w);
                        results.lock().unwrap()[wid] =
                            (y, total, *ex.stats(), ex.cross_messages());
                    });
                }
                drop(red_tx);
                drop(red_out_tx);
            });

            let results = results.into_inner().unwrap();
            let mut cross_total = 0u64;
            for (wid, (y, total, stats, cross)) in results.iter().enumerate() {
                assert_eq!(total, &bulk_total, "worker {wid} all-reduce drifted");
                assert_eq!(stats, &bulk_stats, "worker {wid} modeled stats drifted");
                cross_total += cross;
                for (li, &u) in owned_of[wid].iter().enumerate() {
                    assert_eq!(
                        &y[li * w..(li + 1) * w],
                        &bulk_y[u * w..(u + 1) * w],
                        "worker {wid} row {u} drifted"
                    );
                }
            }
            assert_eq!(cross_total, wire_model, "k={k}: channel traffic escaped the plan model");
        }
    }
}
