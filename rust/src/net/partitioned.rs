//! Partitioned channel transport: the [`Exchange`](super::Exchange)
//! implementation that runs node shards on worker OS threads.
//!
//! This is the deployment shape of the paper (100 graph nodes divided
//! over 8 Matlab pool workers, boundary values on MatlabMPI): a
//! [`crate::coordinator::Partition`] assigns every graph node to one of
//! `k` workers; intra-worker edges are local memory, cross-worker edges
//! ride mpsc channels. Three pieces:
//!
//! - [`ShardPlan`] — the static halo plan per worker: which owned
//!   (boundary) nodes must be shipped to which peer each exchange round,
//!   and which remote nodes will arrive from whom. Sender and receiver
//!   derive the plan from the same graph, so payloads need no per-node
//!   framing — only a round tag.
//! - [`ShardExchange`] — the per-worker handle. `exchange_apply` ships
//!   boundary rows (tagged with the round number and reorder-buffered on
//!   receive, so a fast peer cannot smuggle round `t+1` payloads into
//!   round `t`), assembles a mirror of the needed global columns, and
//!   computes each owned row with [`crate::linalg::Csr::row_matvec_multi`]
//!   — the *same* row kernel the bulk transport uses, which is what makes
//!   the two transports bit-for-bit identical.
//! - [`run_reducer`] — the tree all-reduce stand-in: contributions are
//!   keyed by a sequence number (never popped by count, so a fast worker's
//!   reduce `s+1` cannot blend into `s`), assembled into a dense global
//!   stack and summed in **global node order** — the identical float
//!   additions the bulk transport performs.
//!
//! Modeled [`CommStats`] are tallied identically on every worker (each
//! worker observes the same system-wide rounds); real channel traffic is
//! tracked separately in [`ShardExchange::cross_messages`], which is what
//! the partitioned benches report as MPI traffic.

use super::{CommStats, Exchange};
use crate::coordinator::partition::Partition;
use crate::graph::Graph;
use crate::linalg::Csr;
use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, Sender};

/// One boundary payload on the wire:
/// `(sender worker, exchange round, values in the sender's plan order)`.
pub type WireMsg = (usize, u64, Vec<f64>);

/// One all-reduce contribution:
/// `(worker, reduce sequence number, owned locals in shard order)`.
pub type ReduceMsg = (usize, u64, Vec<f64>);

/// Static communication plan for one worker's shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// This worker's id in `0..k`.
    pub worker: usize,
    /// Owned global node ids, ascending — the shard-local row order.
    pub owned: Vec<usize>,
    /// `local_of[global] = local row`, `usize::MAX` when not owned.
    pub local_of: Vec<usize>,
    /// Nodes whose values are available after a halo exchange
    /// (owned ∪ halo).
    pub covered: Vec<bool>,
    /// Per peer (ascending): owned boundary nodes shipped to that peer
    /// each round, ascending.
    pub send: Vec<(usize, Vec<usize>)>,
    /// Per peer (ascending): that peer's nodes received each round,
    /// ascending — mirrors the peer's `send` entry for this worker.
    pub recv: Vec<(usize, Vec<usize>)>,
}

/// Build the halo plans for every worker of a partition. The plan depends
/// only on the graph topology: any operator whose support stays within
/// the graph neighborhoods (walk matrices, adjacency, Laplacian) can ride
/// the same plan.
pub fn build_shard_plans(g: &Graph, part: &Partition) -> Vec<ShardPlan> {
    let n = g.n;
    assert_eq!(part.assignment.len(), n, "partition does not cover the graph");
    let mut plans = Vec::with_capacity(part.k);
    for w in 0..part.k {
        let owned = part.nodes_of(w);
        let mut local_of = vec![usize::MAX; n];
        let mut covered = vec![false; n];
        for (li, &u) in owned.iter().enumerate() {
            local_of[u] = li;
            covered[u] = true;
        }
        let mut send: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let mut recv: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for &u in &owned {
            for &v in g.neighbors(u) {
                let pv = part.assignment[v];
                if pv != w {
                    send.entry(pv).or_default().push(u);
                    recv.entry(pv).or_default().push(v);
                    covered[v] = true;
                }
            }
        }
        let dedup_sorted = |m: BTreeMap<usize, Vec<usize>>| -> Vec<(usize, Vec<usize>)> {
            m.into_iter()
                .map(|(peer, mut nodes)| {
                    nodes.sort_unstable();
                    nodes.dedup();
                    (peer, nodes)
                })
                .collect()
        };
        plans.push(ShardPlan {
            worker: w,
            owned,
            local_of,
            covered,
            send: dedup_sorted(send),
            recv: dedup_sorted(recv),
        });
    }
    plans
}

/// Per-worker [`Exchange`] handle over mpsc channels.
pub struct ShardExchange<'a> {
    n: usize,
    k: usize,
    m_edges: usize,
    /// Graph Laplacian shared by all workers (for `laplacian_apply`).
    lap: &'a Csr,
    plan: ShardPlan,
    /// Senders toward each peer, aligned with `plan.send`.
    peer_txs: Vec<Sender<WireMsg>>,
    inbox: Receiver<WireMsg>,
    /// Reorder buffer for early payloads, keyed `(sender, round)`.
    pending: HashMap<(usize, u64), Vec<f64>>,
    /// Mirror of the global stack holding fresh values for covered nodes.
    mirror: Vec<f64>,
    round: u64,
    red_seq: u64,
    to_reducer: Sender<ReduceMsg>,
    from_reducer: Receiver<Vec<f64>>,
    /// Operators whose support has been checked against the halo, keyed
    /// `(indices ptr, nnz, rows)`. The operators of a run (chain walk
    /// matrix, Laplacian, adjacency) are long-lived, so validating once
    /// keeps the O(local nnz) scan off the per-round hot path.
    validated: Vec<(usize, usize, usize)>,
    stats: CommStats,
    cross: u64,
}

impl<'a> ShardExchange<'a> {
    /// Wire up a worker handle. `peer_txs` must be aligned with
    /// `plan.send` (one sender per peer, same order).
    pub fn new(
        g: &Graph,
        lap: &'a Csr,
        k: usize,
        plan: ShardPlan,
        peer_txs: Vec<Sender<WireMsg>>,
        inbox: Receiver<WireMsg>,
        to_reducer: Sender<ReduceMsg>,
        from_reducer: Receiver<Vec<f64>>,
    ) -> ShardExchange<'a> {
        assert_eq!(peer_txs.len(), plan.send.len());
        assert_eq!(lap.rows, g.n);
        ShardExchange {
            n: g.n,
            k,
            m_edges: g.m(),
            lap,
            plan,
            peer_txs,
            inbox,
            pending: HashMap::new(),
            mirror: Vec::new(),
            round: 0,
            red_seq: 0,
            to_reducer,
            from_reducer,
            validated: Vec::new(),
            stats: CommStats::default(),
            cross: 0,
        }
    }

    /// Real cross-worker channel traffic so far: one count per boundary
    /// node payload plus 2 per all-reduce (up + down through the leader).
    /// This is the deployment's MPI traffic, distinct from the modeled
    /// per-node [`CommStats`].
    pub fn cross_messages(&self) -> u64 {
        self.cross
    }

    /// This worker's shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Receive the `round`-tagged payload from `peer`, parking any other
    /// (possibly future-round) payloads in the reorder buffer.
    fn recv_round_from(&mut self, peer: usize, round: u64) -> Vec<f64> {
        if let Some(d) = self.pending.remove(&(peer, round)) {
            return d;
        }
        loop {
            let (src, r, data) = self.inbox.recv().expect("peer worker died");
            if src == peer && r == round {
                return data;
            }
            let prev = self.pending.insert((src, r), data);
            assert!(prev.is_none(), "duplicate payload from worker {src} round {r}");
        }
    }
}

impl Exchange for ShardExchange<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn owned(&self) -> &[usize] {
        &self.plan.owned
    }

    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        let ln = self.plan.owned.len();
        assert_eq!(a.rows, self.n, "operator shape mismatch");
        assert_eq!(x.len(), ln * w, "payload shape mismatch");
        assert_eq!(out.len(), ln * w);
        self.round += 1;
        let round = self.round;

        // 1. Ship owned boundary rows to each peer, tagged with the round.
        for ((peer, nodes), tx) in self.plan.send.iter().zip(&self.peer_txs) {
            let mut buf = Vec::with_capacity(nodes.len() * w);
            for &u in nodes {
                let li = self.plan.local_of[u];
                buf.extend_from_slice(&x[li * w..(li + 1) * w]);
            }
            tx.send((self.plan.worker, round, buf))
                .unwrap_or_else(|_| panic!("peer worker {peer} died"));
            self.cross += nodes.len() as u64;
        }

        // 2. Refresh the mirror: owned rows from `x`, halo rows from the
        //    peers (reorder-buffered by round).
        if self.mirror.len() != self.n * w {
            self.mirror = vec![0.0; self.n * w];
        }
        for (li, &u) in self.plan.owned.iter().enumerate() {
            self.mirror[u * w..(u + 1) * w].copy_from_slice(&x[li * w..(li + 1) * w]);
        }
        let recv_plan = std::mem::take(&mut self.plan.recv);
        for (peer, nodes) in &recv_plan {
            let data = self.recv_round_from(*peer, round);
            assert_eq!(data.len(), nodes.len() * w, "halo payload width drifted");
            for (idx, &u) in nodes.iter().enumerate() {
                self.mirror[u * w..(u + 1) * w].copy_from_slice(&data[idx * w..(idx + 1) * w]);
            }
        }
        self.plan.recv = recv_plan;

        // 3. The operator must not read outside the halo — a support that
        //    escapes the graph neighborhoods (e.g. a squared-chain overlay)
        //    needs a co-located transport. Checked once per operator, not
        //    per round (the scan is comparable to the matvec itself).
        let op_key = (a.indices.as_ptr() as usize, a.nnz(), a.rows);
        if !self.validated.contains(&op_key) {
            for &u in &self.plan.owned {
                for kk in a.indptr[u]..a.indptr[u + 1] {
                    assert!(
                        self.plan.covered[a.indices[kk]],
                        "operator support escapes the halo at row {u}: the partitioned \
                         transport only ships graph-support operators"
                    );
                }
            }
            self.validated.push(op_key);
        }

        // 4. Owned rows via the shared CSR row kernel (bit-for-bit equal
        //    to the bulk transport's block sweep).
        for (li, &u) in self.plan.owned.iter().enumerate() {
            a.row_matvec_multi(u, &self.mirror, w, &mut out[li * w..(li + 1) * w]);
        }
        self.stats.record_exchange(directed_messages, w);
    }

    fn laplacian_apply(&mut self, x: &[f64], w: usize) -> Vec<f64> {
        let lap = self.lap;
        let mut y = vec![0.0; x.len()];
        self.exchange_apply(lap, 2 * self.m_edges as u64, x, w, &mut y);
        y
    }

    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        assert_eq!(locals.len(), self.plan.owned.len() * w);
        self.red_seq += 1;
        self.to_reducer
            .send((self.plan.worker, self.red_seq, locals.to_vec()))
            .expect("reducer died");
        let total = self.from_reducer.recv().expect("reducer died");
        assert_eq!(total.len(), w, "all-reduce width drifted across workers");
        if self.k > 1 {
            self.cross += 2;
        }
        self.stats.record_allreduce(self.n, w);
        total
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

/// Leader-side all-reduce loop. Contributions are keyed by their sequence
/// number — a fast worker already at reduce `s+1` cannot be blended into
/// reduce `s` — and the dense global stack is summed in node order, so the
/// totals match the bulk transport bit for bit. Runs until every worker
/// sender is dropped.
pub fn run_reducer(
    n: usize,
    owned_of: &[Vec<usize>],
    rx: Receiver<ReduceMsg>,
    txs: &[Sender<Vec<f64>>],
) {
    let k = owned_of.len();
    assert_eq!(txs.len(), k);
    let mut open: BTreeMap<u64, (usize, Vec<Option<Vec<f64>>>)> = BTreeMap::new();
    while let Ok((wid, seq, vals)) = rx.recv() {
        let slot = open.entry(seq).or_insert_with(|| (0, vec![None; k]));
        assert!(slot.1[wid].is_none(), "duplicate all-reduce contribution from worker {wid}");
        slot.1[wid] = Some(vals);
        slot.0 += 1;
        if slot.0 < k {
            continue;
        }
        let (_, parts) = open.remove(&seq).unwrap();
        let w = parts
            .iter()
            .zip(owned_of)
            .find_map(|(part, owned)| {
                (!owned.is_empty()).then(|| part.as_ref().unwrap().len() / owned.len())
            })
            .unwrap_or(0);
        let mut dense = vec![0.0; n * w];
        for (part, owned) in parts.iter().zip(owned_of) {
            let vals = part.as_ref().unwrap();
            for (li, &u) in owned.iter().enumerate() {
                dense[u * w..(u + 1) * w].copy_from_slice(&vals[li * w..(li + 1) * w]);
            }
        }
        // Global node order — identical float additions to the bulk sweep.
        let mut total = vec![0.0; w];
        for i in 0..n {
            for j in 0..w {
                total[j] += dense[i * w + j];
            }
        }
        for tx in txs {
            let _ = tx.send(total.clone());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generate, laplacian_csr};
    use crate::util::Pcg64;
    use std::sync::mpsc::channel;
    use std::sync::Mutex;

    #[test]
    fn plans_are_symmetric_and_cover_halos() {
        let mut rng = Pcg64::new(41);
        let g = generate::random_connected(14, 30, &mut rng);
        let part = Partition::round_robin(14, 3);
        let plans = build_shard_plans(&g, &part);
        for p in &plans {
            // Every owned node is covered; every neighbor of an owned node
            // is covered.
            for &u in &p.owned {
                assert!(p.covered[u]);
                for &v in g.neighbors(u) {
                    assert!(p.covered[v], "worker {} misses halo node {v}", p.worker);
                }
            }
            // send[w→q] must equal recv[q←w] on q's side.
            for (peer, nodes) in &p.send {
                let q = &plans[*peer];
                let back = q
                    .recv
                    .iter()
                    .find(|(from, _)| *from == p.worker)
                    .map(|(_, ns)| ns.clone())
                    .unwrap_or_default();
                assert_eq!(&back, nodes, "asymmetric plan {} → {}", p.worker, peer);
            }
        }
    }

    /// Two workers exchanging over channels must reproduce the bulk
    /// transport bit for bit — both the Laplacian round and the
    /// all-reduce, including the modeled counters.
    #[test]
    fn shard_exchange_matches_bulk_bit_for_bit() {
        let mut rng = Pcg64::new(42);
        let g = generate::random_connected(11, 24, &mut rng);
        let lap = laplacian_csr(&g);
        let w = 3;
        let x = rng.normal_vec(11 * w);

        let mut comm = crate::net::CommGraph::new(&g);
        let bulk_y = comm.laplacian_apply(&x, w);
        let bulk_total = comm.allreduce_sum(&x, w);
        let bulk_stats = *comm.stats();

        for part in [Partition::contiguous(11, 2), Partition::round_robin(11, 3)] {
            let k = part.k;
            let plans = build_shard_plans(&g, &part);
            let owned_of: Vec<Vec<usize>> = plans.iter().map(|p| p.owned.clone()).collect();

            let mut wire_tx = Vec::new();
            let mut wire_rx = Vec::new();
            for _ in 0..k {
                let (tx, rx) = channel::<WireMsg>();
                wire_tx.push(tx);
                wire_rx.push(Some(rx));
            }
            let (red_tx, red_rx) = channel::<ReduceMsg>();
            let mut red_out_tx = Vec::new();
            let mut red_out_rx = Vec::new();
            for _ in 0..k {
                let (tx, rx) = channel::<Vec<f64>>();
                red_out_tx.push(tx);
                red_out_rx.push(Some(rx));
            }

            let n = g.n;
            let results = Mutex::new(vec![(Vec::new(), Vec::new(), CommStats::default()); k]);
            std::thread::scope(|scope| {
                {
                    let owned_of = owned_of.clone();
                    let txs = red_out_tx.clone();
                    scope.spawn(move || run_reducer(n, &owned_of, red_rx, &txs));
                }
                for (wid, plan) in plans.into_iter().enumerate() {
                    let peer_txs: Vec<_> =
                        plan.send.iter().map(|(peer, _)| wire_tx[*peer].clone()).collect();
                    let inbox = wire_rx[wid].take().unwrap();
                    let from_red = red_out_rx[wid].take().unwrap();
                    let red = red_tx.clone();
                    let xl: Vec<f64> = plan
                        .owned
                        .iter()
                        .flat_map(|&u| x[u * w..(u + 1) * w].to_vec())
                        .collect();
                    let (g, lap, results) = (&g, &lap, &results);
                    scope.spawn(move || {
                        let mut ex =
                            ShardExchange::new(g, lap, k, plan, peer_txs, inbox, red, from_red);
                        let y = ex.laplacian_apply(&xl, w);
                        let total = ex.allreduce_sum(&xl, w);
                        results.lock().unwrap()[wid] = (y, total, *ex.stats());
                    });
                }
                drop(red_tx);
                drop(red_out_tx);
            });

            let results = results.into_inner().unwrap();
            for (wid, (y, total, stats)) in results.iter().enumerate() {
                assert_eq!(total, &bulk_total, "worker {wid} all-reduce drifted");
                assert_eq!(stats, &bulk_stats, "worker {wid} modeled stats drifted");
                for (li, &u) in owned_of[wid].iter().enumerate() {
                    assert_eq!(
                        &y[li * w..(li + 1) * w],
                        &bulk_y[u * w..(u + 1) * w],
                        "worker {wid} row {u} drifted"
                    );
                }
            }
        }
    }
}
