//! Deterministic schedule explorer for the partitioned transport.
//!
//! The sharded runtime's ordering defenses — the round-tagged reorder
//! buffer in [`ShardExchange`] and the sequence-keyed reducer in
//! [`run_reducer`] — are exercised by the regular test suite only on the
//! schedules the OS happens to produce. [`ModelExchange`] closes that gap:
//! it runs the k worker step-functions once on real threads to *record*
//! every channel's traffic, then *replays* each receiver single-threaded
//! under adversarially permuted delivery orders and asserts the iterates
//! (and every outbound byte) are bit-for-bit identical.
//!
//! # Why per-receiver permutation covers all global schedules
//!
//! Every receiver in the runtime — a worker's [`ShardExchange`] plus the
//! algorithm step-function driving it, and the reducer loop — is a
//! deterministic function of its *per-channel input streams*. A global
//! thread schedule can influence a receiver only by changing how its
//! per-sender FIFO streams interleave at its single inbox (mpsc preserves
//! per-sender order; cross-sender order is the scheduler's choice). So if
//! (a) every receiver produces bit-identical outputs and *outbound
//! streams* under every merge of its recorded input streams, and (b) the
//! outbound streams equal the recorded ones, then by induction no global
//! schedule can produce a different result. The explorer verifies exactly
//! (a) and (b): exhaustively when the merge count is small (all delivery
//! permutations at k ≤ 3 over a bounded round window), by seeded
//! uniformly-random merges above.
//!
//! The reducer needs no extra pairing argument: a worker sends reduce
//! contribution `s+1` only after receiving answer `s`, so under any
//! per-worker-FIFO merge slot `s` completes before `s+1` and the answers
//! ride back to each worker in sequence order.

use super::partitioned::{build_shard_plans, run_reducer, ReduceMsg, ShardExchange, WireMsg};
use crate::coordinator::partition::Partition;
use crate::graph::laplacian::laplacian_csr;
use crate::graph::Graph;
use crate::linalg::Csr;
use crate::util::Pcg64;
use std::collections::BTreeMap;
use std::sync::mpsc::channel;

/// Both wire payloads and reduce contributions share this shape:
/// `(source id, round/sequence tag, values)`.
type Envelope = (usize, u64, Vec<f64>);

/// Bounds for one exploration run.
#[derive(Debug, Clone)]
pub struct ExploreOptions {
    /// Receivers whose merge count is at most this are explored
    /// exhaustively (every delivery permutation of their input streams).
    pub exhaustive_limit: u128,
    /// Seeded uniformly-random merges per receiver above the limit.
    pub random_schedules: usize,
    /// Base seed for the random sweeps (each receiver gets its own
    /// deterministic stream derived from this).
    pub seed: u64,
}

impl Default for ExploreOptions {
    fn default() -> Self {
        ExploreOptions { exhaustive_limit: 20_000, random_schedules: 48, seed: 0x5DD_C0DE }
    }
}

/// What one exploration verified.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    /// Worker count `k`.
    pub workers: usize,
    /// Total replays performed across all receivers (including the
    /// canonical arrival-order replays).
    pub schedules_checked: u64,
    /// True when *every* receiver was explored exhaustively — the
    /// bit-identity claim then holds for all delivery schedules, not just
    /// the sampled ones.
    pub exhaustive: bool,
    /// Boundary payloads recorded on the worker wires.
    pub wire_messages: usize,
    /// All-reduce contributions recorded at the reducer.
    pub reduce_messages: usize,
}

/// A divergence found by the explorer. Any variant is a real ordering bug
/// (or a non-deterministic step-function, which the BSP contract forbids).
#[derive(Debug, Clone)]
pub enum ScheduleError {
    /// A worker's returned iterate differed from the recorded run.
    Iterate {
        /// Worker whose output diverged.
        worker: usize,
        /// Which replay schedule exposed it.
        schedule: String,
    },
    /// A worker's outbound boundary stream differed from the recorded run.
    Wire {
        /// Sending worker (the one being replayed).
        sender: usize,
        /// Destination worker of the diverging stream.
        receiver: usize,
        /// Which replay schedule exposed it.
        schedule: String,
    },
    /// A worker's outbound reduce contributions differed.
    Reduce {
        /// Worker whose contributions diverged.
        worker: usize,
        /// Which replay schedule exposed it.
        schedule: String,
    },
    /// The reducer's answer stream to a worker differed.
    Answer {
        /// Worker whose answer stream diverged.
        worker: usize,
        /// Which replay schedule exposed it.
        schedule: String,
    },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Iterate { worker, schedule } => {
                write!(f, "worker {worker} iterate diverged under {schedule}")
            }
            ScheduleError::Wire { sender, receiver, schedule } => {
                write!(f, "wire stream {sender} → {receiver} diverged under {schedule}")
            }
            ScheduleError::Reduce { worker, schedule } => {
                write!(f, "reduce contributions of worker {worker} diverged under {schedule}")
            }
            ScheduleError::Answer { worker, schedule } => {
                write!(f, "reducer answers to worker {worker} diverged under {schedule}")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Everything one recorded run put on the channels.
struct Recording {
    /// Per destination worker: boundary payloads in arrival order at that
    /// worker's inbox.
    wire: Vec<Vec<WireMsg>>,
    /// Reduce contributions in arrival order at the reducer.
    reduce: Vec<ReduceMsg>,
    /// Per worker: the reducer's answers in FIFO order.
    answers: Vec<Vec<Vec<f64>>>,
    /// Per worker: the step-function's returned iterate.
    outputs: Vec<Vec<f64>>,
}

/// Single-threaded, seeded schedule explorer over the real
/// [`ShardExchange`] + [`run_reducer`] code paths (nothing is mocked: the
/// replays construct genuine handles over preloaded mpsc channels).
pub struct ModelExchange<'g> {
    g: &'g Graph,
    lap: Csr,
    plans: Vec<super::partitioned::ShardPlan>,
    owned_of: Vec<Vec<usize>>,
    k: usize,
}

/// Bit-exact slice comparison (NaN-safe, signed-zero-strict).
fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bit-exact envelope-stream comparison (source, tag, payload bits).
fn streams_equal(got: &[Envelope], expect: &[&Envelope]) -> bool {
    got.len() == expect.len()
        && got
            .iter()
            .zip(expect)
            .all(|(g, e)| g.0 == e.0 && g.1 == e.1 && bits_equal(&g.2, &e.2))
}

/// Group an arrival-ordered log by source id, preserving each source's
/// FIFO order. Sources come out in ascending id order.
fn group_by_source(log: &[Envelope]) -> Vec<Vec<Envelope>> {
    let mut by_src: BTreeMap<usize, Vec<Envelope>> = BTreeMap::new();
    for msg in log {
        by_src.entry(msg.0).or_default().push(msg.clone());
    }
    by_src.into_values().collect()
}

/// Number of distinct merges of streams with these lengths — the
/// multinomial `(Σl)! / Πl!`, saturating at `u128::MAX`.
fn count_merges(lens: &[usize]) -> u128 {
    let mut total: u128 = 0;
    let mut count: u128 = 1;
    for &l in lens {
        for i in 1..=l as u128 {
            total += 1;
            count = count.saturating_mul(total) / i;
        }
    }
    count
}

/// Visit every merge of streams with the given lengths. `picks` receives
/// the stream index chosen at each step.
fn for_each_merge(
    remaining: &mut [usize],
    picks: &mut Vec<usize>,
    visit: &mut dyn FnMut(&[usize]) -> Result<(), ScheduleError>,
) -> Result<(), ScheduleError> {
    if remaining.iter().all(|&r| r == 0) {
        return visit(picks);
    }
    for s in 0..remaining.len() {
        if remaining[s] > 0 {
            remaining[s] -= 1;
            picks.push(s);
            for_each_merge(remaining, picks, visit)?;
            picks.pop();
            remaining[s] += 1;
        }
    }
    Ok(())
}

/// One exactly-uniform random merge: picking the next stream with
/// probability proportional to its remaining length gives every merge
/// probability `Πl! / (Σl)!`.
fn random_merge(lens: &[usize], rng: &mut Pcg64) -> Vec<usize> {
    let mut rem = lens.to_vec();
    let mut total: usize = rem.iter().sum();
    let mut picks = Vec::with_capacity(total);
    while total > 0 {
        let mut t = rng.next_below(total as u64) as usize;
        for (s, r) in rem.iter_mut().enumerate() {
            if t < *r {
                picks.push(s);
                *r -= 1;
                total -= 1;
                break;
            }
            t -= *r;
        }
    }
    picks
}

/// Materialize the merge described by `picks` from the per-source streams.
fn build_merged(streams: &[Vec<Envelope>], picks: &[usize]) -> Vec<Envelope> {
    let mut idx = vec![0usize; streams.len()];
    let mut merged = Vec::with_capacity(picks.len());
    for &s in picks {
        merged.push(streams[s][idx[s]].clone());
        idx[s] += 1;
    }
    merged
}

impl<'g> ModelExchange<'g> {
    /// Set up the explorer for a graph and partition (the same
    /// [`build_shard_plans`] wiring the production runtime uses).
    pub fn new(g: &'g Graph, part: &Partition) -> ModelExchange<'g> {
        let plans = build_shard_plans(g, part);
        let owned_of = plans.iter().map(|p| p.owned.clone()).collect();
        ModelExchange { g, lap: laplacian_csr(g), plans, owned_of, k: part.k }
    }

    /// Worker count.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Record one concurrent run, then replay every receiver under
    /// permuted delivery orders, asserting bit-identical iterates and
    /// outbound streams throughout.
    ///
    /// The step-function must be deterministic given `(worker, handle)`
    /// and must follow the BSP contract (same collective sequence on
    /// every worker) — construct all algorithm state inside the closure.
    pub fn explore<F>(
        &self,
        program: F,
        opts: &ExploreOptions,
    ) -> Result<ExploreReport, ScheduleError>
    where
        F: Fn(usize, &mut ShardExchange<'_>) -> Vec<f64> + Sync,
    {
        let rec = self.record(&program);
        let mut checked = 0u64;
        let mut exhaustive = true;

        for i in 0..self.k {
            // Canonical arrival-order replay first: validates the replay
            // machinery and catches non-deterministic step-functions with
            // the clearest possible signal.
            self.replay_worker(i, &rec.wire[i], &rec, &program, "the recorded arrival order")?;
            checked += 1;
            let streams = group_by_source(&rec.wire[i]);
            let (c, ex) = explore_receiver(&streams, opts, i as u64, &mut |merged, label| {
                self.replay_worker(i, merged, &rec, &program, label)
            })?;
            checked += c;
            exhaustive &= ex;
        }

        self.replay_reducer(&rec.reduce, &rec, "the recorded arrival order")?;
        checked += 1;
        let streams = group_by_source(&rec.reduce);
        let (c, ex) = explore_receiver(&streams, opts, self.k as u64, &mut |merged, label| {
            self.replay_reducer(merged, &rec, label)
        })?;
        checked += c;
        exhaustive &= ex;

        Ok(ExploreReport {
            workers: self.k,
            schedules_checked: checked,
            exhaustive,
            wire_messages: rec.wire.iter().map(Vec::len).sum(),
            reduce_messages: rec.reduce.len(),
        })
    }

    /// Run the program once on real threads with a logging tap spliced
    /// into every channel. The taps forward messages unchanged (mpsc
    /// preserves per-sender order through them), so the recorded run is a
    /// genuine concurrent execution.
    fn record<F>(&self, program: &F) -> Recording
    where
        F: Fn(usize, &mut ShardExchange<'_>) -> Vec<f64> + Sync,
    {
        let k = self.k;
        let n = self.g.n;
        let mut tap_tx = Vec::with_capacity(k);
        let mut tap_rx = Vec::with_capacity(k);
        let mut inbox_tx = Vec::with_capacity(k);
        let mut inbox_rx = Vec::with_capacity(k);
        for _ in 0..k {
            let (t, r) = channel::<WireMsg>();
            tap_tx.push(t);
            tap_rx.push(r);
            let (t, r) = channel::<WireMsg>();
            inbox_tx.push(t);
            inbox_rx.push(r);
        }
        let (rtap_tx, rtap_rx) = channel::<ReduceMsg>();
        let (red_tx, red_rx) = channel::<ReduceMsg>();
        let mut anstap_tx = Vec::with_capacity(k);
        let mut anstap_rx = Vec::with_capacity(k);
        let mut ans_tx = Vec::with_capacity(k);
        let mut ans_rx = Vec::with_capacity(k);
        for _ in 0..k {
            let (t, r) = channel::<Vec<f64>>();
            anstap_tx.push(t);
            anstap_rx.push(r);
            let (t, r) = channel::<Vec<f64>>();
            ans_tx.push(t);
            ans_rx.push(r);
        }

        std::thread::scope(|scope| {
            let mut wire_handles = Vec::with_capacity(k);
            for (rx, fwd) in tap_rx.into_iter().zip(inbox_tx) {
                wire_handles.push(scope.spawn(move || {
                    let mut log: Vec<WireMsg> = Vec::new();
                    while let Ok(msg) = rx.recv() {
                        log.push((msg.0, msg.1, msg.2.clone()));
                        let _ = fwd.send(msg);
                    }
                    log
                }));
            }
            let red_handle = scope.spawn(move || {
                let mut log: Vec<ReduceMsg> = Vec::new();
                while let Ok(msg) = rtap_rx.recv() {
                    log.push((msg.0, msg.1, msg.2.clone()));
                    let _ = red_tx.send(msg);
                }
                log
            });
            let mut ans_handles = Vec::with_capacity(k);
            for (rx, fwd) in anstap_rx.into_iter().zip(ans_tx) {
                ans_handles.push(scope.spawn(move || {
                    let mut log: Vec<Vec<f64>> = Vec::new();
                    while let Ok(ans) = rx.recv() {
                        log.push(ans.clone());
                        let _ = fwd.send(ans);
                    }
                    log
                }));
            }
            // The reducer owns the answer-tap senders: when it returns
            // (all reduce senders dropped), the answer taps drain out.
            let owned_of = &self.owned_of;
            scope.spawn(move || run_reducer(n, owned_of, red_rx, &anstap_tx));

            let mut worker_handles = Vec::with_capacity(k);
            for (i, (inbox, from_red)) in inbox_rx.into_iter().zip(ans_rx).enumerate() {
                let peer_txs = tap_tx.clone();
                let to_red = rtap_tx.clone();
                let plan = self.plans[i].clone();
                let (g, lap) = (self.g, &self.lap);
                worker_handles.push(scope.spawn(move || {
                    let mut ex =
                        ShardExchange::new(g, lap, k, plan, peer_txs, inbox, to_red, from_red);
                    program(i, &mut ex)
                }));
            }
            drop(tap_tx);
            drop(rtap_tx);

            let outputs = worker_handles
                .into_iter()
                // sddn-lint: allow(panic) reason=a panicking step-function must surface to the caller, not hang the scope
                .map(|h| h.join().expect("worker panicked while recording"))
                .collect();
            let wire = wire_handles
                .into_iter()
                // sddn-lint: allow(panic) reason=tap threads only log and forward; a panic there is a harness bug
                .map(|h| h.join().expect("wire tap panicked"))
                .collect();
            // sddn-lint: allow(panic) reason=tap threads only log and forward; a panic there is a harness bug
            let reduce = red_handle.join().expect("reduce tap panicked");
            let answers = ans_handles
                .into_iter()
                // sddn-lint: allow(panic) reason=tap threads only log and forward; a panic there is a harness bug
                .map(|h| h.join().expect("answer tap panicked"))
                .collect();
            Recording { wire, reduce, answers, outputs }
        })
    }

    /// Replay worker `i` single-threaded with its inbox preloaded in
    /// `merged` order, then compare the iterate and every outbound stream
    /// against the recording bit for bit.
    fn replay_worker<F>(
        &self,
        i: usize,
        merged: &[WireMsg],
        rec: &Recording,
        program: &F,
        label: &str,
    ) -> Result<(), ScheduleError>
    where
        F: Fn(usize, &mut ShardExchange<'_>) -> Vec<f64> + Sync,
    {
        let (inbox_tx, inbox_rx) = channel::<WireMsg>();
        for msg in merged {
            let _ = inbox_tx.send(msg.clone());
        }
        drop(inbox_tx);
        let (ans_tx, ans_rx) = channel::<Vec<f64>>();
        for ans in &rec.answers[i] {
            let _ = ans_tx.send(ans.clone());
        }
        drop(ans_tx);
        let mut sink_tx = Vec::with_capacity(self.k);
        let mut sink_rx = Vec::with_capacity(self.k);
        for _ in 0..self.k {
            let (t, r) = channel::<WireMsg>();
            sink_tx.push(t);
            sink_rx.push(r);
        }
        let (rsink_tx, rsink_rx) = channel::<ReduceMsg>();

        let mut ex = ShardExchange::new(
            self.g,
            &self.lap,
            self.k,
            self.plans[i].clone(),
            sink_tx,
            inbox_rx,
            rsink_tx,
            ans_rx,
        );
        let out = program(i, &mut ex);
        drop(ex);

        if !bits_equal(&out, &rec.outputs[i]) {
            return Err(ScheduleError::Iterate { worker: i, schedule: label.to_string() });
        }
        for (j, rx) in sink_rx.iter().enumerate() {
            let sent: Vec<WireMsg> = rx.try_iter().collect();
            let expect: Vec<&WireMsg> = rec.wire[j].iter().filter(|m| m.0 == i).collect();
            if !streams_equal(&sent, &expect) {
                return Err(ScheduleError::Wire {
                    sender: i,
                    receiver: j,
                    schedule: label.to_string(),
                });
            }
        }
        let contrib: Vec<ReduceMsg> = rsink_rx.try_iter().collect();
        let expect: Vec<&ReduceMsg> = rec.reduce.iter().filter(|m| m.0 == i).collect();
        if !streams_equal(&contrib, &expect) {
            return Err(ScheduleError::Reduce { worker: i, schedule: label.to_string() });
        }
        Ok(())
    }

    /// Replay the reducer with its contribution stream preloaded in
    /// `merged` order and compare every answer stream bit for bit.
    fn replay_reducer(
        &self,
        merged: &[ReduceMsg],
        rec: &Recording,
        label: &str,
    ) -> Result<(), ScheduleError> {
        let (tx, rx) = channel::<ReduceMsg>();
        for msg in merged {
            let _ = tx.send(msg.clone());
        }
        drop(tx);
        let mut ans_tx = Vec::with_capacity(self.k);
        let mut ans_rx = Vec::with_capacity(self.k);
        for _ in 0..self.k {
            let (t, r) = channel::<Vec<f64>>();
            ans_tx.push(t);
            ans_rx.push(r);
        }
        run_reducer(self.g.n, &self.owned_of, rx, &ans_tx);
        drop(ans_tx);
        for (i, rx) in ans_rx.iter().enumerate() {
            let got: Vec<Vec<f64>> = rx.try_iter().collect();
            let expect = &rec.answers[i];
            let same = got.len() == expect.len()
                && got.iter().zip(expect).all(|(a, b)| bits_equal(a, b));
            if !same {
                return Err(ScheduleError::Answer { worker: i, schedule: label.to_string() });
            }
        }
        Ok(())
    }
}

/// Explore one receiver's merge space: exhaustively when the multinomial
/// merge count fits the limit, by seeded uniform sweeps otherwise.
/// Returns (replays performed, explored exhaustively).
fn explore_receiver(
    streams: &[Vec<Envelope>],
    opts: &ExploreOptions,
    receiver_stream: u64,
    replay: &mut dyn FnMut(&[Envelope], &str) -> Result<(), ScheduleError>,
) -> Result<(u64, bool), ScheduleError> {
    let lens: Vec<usize> = streams.iter().map(Vec::len).collect();
    if lens.iter().all(|&l| l == 0) {
        return Ok((0, true));
    }
    let total = count_merges(&lens);
    let mut checked = 0u64;
    if total <= opts.exhaustive_limit {
        let mut remaining = lens.clone();
        let mut picks = Vec::new();
        for_each_merge(&mut remaining, &mut picks, &mut |picks| {
            checked += 1;
            let merged = build_merged(streams, picks);
            replay(&merged, &format!("exhaustive schedule #{checked} of {total}"))
        })?;
        Ok((checked, true))
    } else {
        let mut rng = Pcg64::with_stream(opts.seed, receiver_stream);
        for s in 0..opts.random_schedules {
            let picks = random_merge(&lens, &mut rng);
            let merged = build_merged(streams, &picks);
            let label =
                format!("seeded schedule #{s} (seed {}, stream {receiver_stream})", opts.seed);
            replay(&merged, &label)?;
            checked += 1;
        }
        Ok((checked, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::net::Exchange;

    fn small_setup() -> (Graph, Partition) {
        let mut rng = Pcg64::new(77);
        let g = generate::random_connected(9, 16, &mut rng);
        (g, Partition::contiguous(9, 3))
    }

    /// Three Laplacian rounds + an all-reduce per round: exercises both
    /// the reorder buffer and the sequence-keyed reducer.
    fn round_program(i: usize, ex: &mut ShardExchange<'_>) -> Vec<f64> {
        let _ = i;
        let w = 2;
        let n = ex.n();
        let x_global = Pcg64::new(5).normal_vec(n * w);
        let owned = ex.owned().to_vec();
        let mut x: Vec<f64> = owned
            .iter()
            .flat_map(|&u| x_global[u * w..(u + 1) * w].to_vec())
            .collect();
        let mut y = vec![0.0; x.len()];
        for _ in 0..3 {
            ex.laplacian_apply_into(&x, w, &mut y);
            let total = ex.allreduce_sum(&y, w);
            for (idx, v) in x.iter_mut().enumerate() {
                *v = y[idx] + total[idx % w] / n as f64;
            }
        }
        x
    }

    #[test]
    fn explorer_verifies_round_program_exhaustively() {
        let (g, part) = small_setup();
        let model = ModelExchange::new(&g, &part);
        let report = model.explore(round_program, &ExploreOptions::default()).unwrap();
        assert!(report.exhaustive, "k=3 small run must be exhaustively explored");
        assert!(report.schedules_checked > 4, "checked {}", report.schedules_checked);
        assert!(report.wire_messages > 0);
        assert_eq!(report.reduce_messages, 9, "3 workers × 3 reduces");
    }

    /// Tampering with a recorded payload must surface as a divergence —
    /// the explorer is only trustworthy if it can actually fail.
    #[test]
    fn tampered_recording_is_caught() {
        let (g, part) = small_setup();
        let model = ModelExchange::new(&g, &part);
        let mut rec = model.record(&round_program);
        // Flip one bit of the first recorded boundary payload.
        let (dst, val) = rec
            .wire
            .iter()
            .enumerate()
            .find_map(|(d, log)| (!log.is_empty()).then_some((d, 0)))
            .unwrap();
        rec.wire[dst][val].2[0] += 1.0;
        let wire = rec.wire[dst].clone();
        let err = model.replay_worker(dst, &wire, &rec, &round_program, "tampered");
        assert!(err.is_err(), "tampered payload must not replay cleanly");
    }

    #[test]
    fn merge_counting_matches_enumeration() {
        assert_eq!(count_merges(&[3, 3]), 20);
        assert_eq!(count_merges(&[2, 2, 2]), 90);
        assert_eq!(count_merges(&[0, 4]), 1);
        let mut seen = 0u64;
        let mut remaining = vec![2, 2, 2];
        let mut picks = Vec::new();
        for_each_merge(&mut remaining, &mut picks, &mut |p| {
            assert_eq!(p.len(), 6);
            seen += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, 90);
    }

    #[test]
    fn random_merges_are_valid_permutations() {
        let lens = vec![3, 1, 4];
        let mut rng = Pcg64::new(9);
        for _ in 0..50 {
            let picks = random_merge(&lens, &mut rng);
            assert_eq!(picks.len(), 8);
            for (s, &l) in lens.iter().enumerate() {
                assert_eq!(picks.iter().filter(|&&p| p == s).count(), l);
            }
        }
    }
}
