//! Message-passing substrate.
//!
//! The paper runs on MatlabMPI over a Matlab parallel pool; the quantity
//! it reports (Fig. 2(c)) is *local communication exchange* — messages
//! between neighboring processors. We reproduce that with a synchronous,
//! round-based model:
//!
//! - [`CommGraph`] is the only window algorithms get onto other nodes'
//!   state: neighbor exchange and tree all-reduce primitives, each of
//!   which increments exact message/float counters. Algorithm code
//!   physically cannot read non-neighbor state except through these
//!   primitives, which keeps the implementations honestly distributed
//!   while running fast on one core.
//! - [`threaded`] runs the same node programs on real OS threads with
//!   channels (an MPI stand-in), used by the `end_to_end` example to
//!   demonstrate true parallel execution.

pub mod stats;
pub mod threaded;

use crate::graph::Graph;
pub use stats::CommStats;

/// Synchronous neighbor-communication view of a graph with accounting.
pub struct CommGraph<'g> {
    g: &'g Graph,
    stats: CommStats,
}

impl<'g> CommGraph<'g> {
    /// Wrap a graph.
    pub fn new(g: &'g Graph) -> Self {
        CommGraph { g, stats: CommStats::default() }
    }

    /// The underlying topology.
    pub fn graph(&self) -> &Graph {
        self.g
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.g.n
    }

    /// Communication counters so far.
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// Mutable counters — lets sub-solvers (SDDM, Neumann, CG) record their
    /// exchanges into the same ledger.
    pub fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }

    /// Reset counters (e.g. between experiment phases).
    pub fn reset_stats(&mut self) {
        self.stats = CommStats::default();
    }

    /// One synchronous exchange round: every node sends its `w`-float
    /// payload to every neighbor. Returns, for each node, the *sum* of its
    /// neighbors' payloads (the primitive underlying Laplacian products,
    /// Jacobi sweeps and diffusion averaging).
    ///
    /// `x` is row-major `n × w`. Cost: `2m` messages of `w` floats.
    pub fn neighbor_sum(&mut self, x: &[f64], w: usize) -> Vec<f64> {
        let n = self.g.n;
        assert_eq!(x.len(), n * w, "payload shape mismatch");
        let mut out = vec![0.0; n * w];
        for &(u, v) in &self.g.edges {
            for j in 0..w {
                out[u * w + j] += x[v * w + j];
                out[v * w + j] += x[u * w + j];
            }
        }
        self.stats.record_edge_round(self.g.m(), w);
        out
    }

    /// In-place variant of [`neighbor_sum`] writing into `out`.
    pub fn neighbor_sum_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        let n = self.g.n;
        assert_eq!(x.len(), n * w);
        assert_eq!(out.len(), n * w);
        out.fill(0.0);
        for &(u, v) in &self.g.edges {
            for j in 0..w {
                out[u * w + j] += x[v * w + j];
                out[v * w + j] += x[u * w + j];
            }
        }
        self.stats.record_edge_round(self.g.m(), w);
    }

    /// Laplacian application `y = (I_w ⊗ L) x` as one exchange round:
    /// `y_i = d(i)·x_i − Σ_{j∈N(i)} x_j`. Cost: `2m` messages of `w` floats.
    pub fn laplacian_apply(&mut self, x: &[f64], w: usize) -> Vec<f64> {
        let n = self.g.n;
        let mut y = self.neighbor_sum(x, w);
        for i in 0..n {
            let d = self.g.degree(i) as f64;
            for j in 0..w {
                y[i * w + j] = d * x[i * w + j] - y[i * w + j];
            }
        }
        y
    }

    /// Per-neighbor gather: for each node, the list of `(neighbor, payload)`
    /// pairs. Needed by ADMM/averaging updates that weight neighbors
    /// individually. Cost: `2m` messages of `w` floats.
    pub fn gather_neighbors(&mut self, x: &[f64], w: usize) -> Vec<Vec<(usize, Vec<f64>)>> {
        let n = self.g.n;
        assert_eq!(x.len(), n * w);
        let mut out: Vec<Vec<(usize, Vec<f64>)>> = (0..n)
            .map(|i| Vec::with_capacity(self.g.degree(i)))
            .collect();
        for i in 0..n {
            for &j in self.g.neighbors(i) {
                out[i].push((j, x[j * w..(j + 1) * w].to_vec()));
            }
        }
        self.stats.record_edge_round(self.g.m(), w);
        out
    }

    /// Tree all-reduce (sum) of per-node scalars: every node ends with the
    /// global sum. Cost: `2(n−1)` messages of `w` floats (up + down a
    /// spanning tree), 2 rounds.
    pub fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        let n = self.g.n;
        assert_eq!(locals.len(), n * w);
        let mut total = vec![0.0; w];
        for i in 0..n {
            for j in 0..w {
                total[j] += locals[i * w + j];
            }
        }
        self.stats.record_allreduce(n, w);
        total
    }

    /// Distributed mean-centering: subtract the global per-column mean from
    /// each node's `w`-float payload. One all-reduce.
    pub fn center(&mut self, x: &mut [f64], w: usize) {
        let n = self.g.n;
        let total = self.allreduce_sum(x, w);
        for i in 0..n {
            for j in 0..w {
                x[i * w + j] -= total[j] / n as f64;
            }
        }
    }

    /// Distributed squared 2-norm of a stacked per-node vector.
    pub fn norm2_sq(&mut self, x: &[f64], w: usize) -> f64 {
        let n = self.g.n;
        let locals: Vec<f64> = (0..n)
            .map(|i| x[i * w..(i + 1) * w].iter().map(|v| v * v).sum())
            .collect();
        self.allreduce_sum(&locals, 1)[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::graph::laplacian::laplacian_csr;
    use crate::util::Pcg64;

    #[test]
    fn laplacian_apply_matches_csr() {
        let mut rng = Pcg64::new(10);
        let g = generate::random_connected(12, 25, &mut rng);
        let l = laplacian_csr(&g);
        let mut comm = CommGraph::new(&g);
        let x = rng.normal_vec(12);
        let via_comm = comm.laplacian_apply(&x, 1);
        let via_csr = l.matvec(&x);
        for (a, b) in via_comm.iter().zip(&via_csr) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(comm.stats().messages, 2 * g.m() as u64);
        assert_eq!(comm.stats().floats, 2 * g.m() as u64);
    }

    #[test]
    fn laplacian_apply_multiwidth() {
        let mut rng = Pcg64::new(11);
        let g = generate::random_connected(8, 14, &mut rng);
        let l = laplacian_csr(&g);
        let w = 3;
        let x = rng.normal_vec(8 * w);
        let mut comm = CommGraph::new(&g);
        let y = comm.laplacian_apply(&x, w);
        // Compare column-by-column.
        for j in 0..w {
            let col: Vec<f64> = (0..8).map(|i| x[i * w + j]).collect();
            let ycol = l.matvec(&col);
            for i in 0..8 {
                assert!((y[i * w + j] - ycol[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn allreduce_and_center() {
        let g = generate::complete(5);
        let mut comm = CommGraph::new(&g);
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let s = comm.allreduce_sum(&x, 1);
        assert_eq!(s, vec![15.0]);
        comm.center(&mut x, 1);
        assert!(x.iter().sum::<f64>().abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let g = generate::cycle(6);
        let mut comm = CommGraph::new(&g);
        let x1 = vec![0.0; 6];
        let x2 = vec![0.0; 12];
        let _ = comm.neighbor_sum(&x1, 1);
        let _ = comm.neighbor_sum(&x2, 2);
        assert_eq!(comm.stats().messages, 24); // 2 rounds × 2m, m = 6
        assert_eq!(comm.stats().floats, 12 + 24);
        assert_eq!(comm.stats().rounds, 2);
        comm.reset_stats();
        assert_eq!(comm.stats().messages, 0);
    }

    #[test]
    fn gather_matches_topology() {
        let g = generate::path(4);
        let mut comm = CommGraph::new(&g);
        let x = vec![10.0, 20.0, 30.0, 40.0];
        let gathered = comm.gather_neighbors(&x, 1);
        assert_eq!(gathered[0], vec![(1usize, vec![20.0])]);
        assert_eq!(gathered[1], vec![(0, vec![10.0]), (2, vec![30.0])]);
    }

    #[test]
    fn norm2_sq_matches() {
        let g = generate::complete(4);
        let mut comm = CommGraph::new(&g);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let n2 = comm.norm2_sq(&x, 2);
        let direct: f64 = x.iter().map(|v| v * v).sum();
        assert!((n2 - direct).abs() < 1e-12);
    }
}
