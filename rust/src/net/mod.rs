//! Message-passing substrate.
//!
//! The paper runs on MatlabMPI over a Matlab parallel pool; the quantity
//! it reports (Fig. 2(c)) is *local communication exchange* — messages
//! between neighboring processors. Every algorithm in this crate talks to
//! other nodes exclusively through the [`Exchange`] trait, which offers
//! exactly the primitives the paper's runtime has (neighbor exchange,
//! tree all-reduce) and meters each one:
//!
//! - [`CommGraph`] is the bulk-synchronous transport: one process owns
//!   every node and each primitive is a metered in-memory sweep. Algorithm
//!   code physically cannot read non-neighbor state except through the
//!   trait, which keeps the implementations honestly distributed while
//!   running fast on one core. (There is deliberately no per-neighbor
//!   gather primitive: neighbor access is always a graph-support CSR
//!   operator through [`Exchange::exchange_apply`], which is what lets
//!   every algorithm run shard-local unchanged.)
//! - [`partitioned::ShardExchange`] is the partitioned transport: graph
//!   nodes are divided among worker OS threads (as the paper divides 100
//!   nodes over 8 pool workers) and boundary payloads ride mpsc channels,
//!   tagged with round numbers and reorder-buffered. It produces
//!   bit-for-bit the same iterates and the same modeled counters as
//!   [`CommGraph`] (see `tests/prop_parallel.rs`).
//! - [`threaded`] runs one thread per *node* (rather than per worker),
//!   used by the `end_to_end` example to demonstrate fully local node
//!   programs.
//! - [`tcp::TcpExchange`] is the multi-host transport: each worker is a
//!   separate OS *process* and boundary payloads ride length-prefixed
//!   binary frames over TCP sockets (rendezvoused through a rank-0
//!   leader, see [`crate::coordinator::tcp`]). Same plans, same row
//!   kernel, same reduce order — bit-for-bit identical to both in-process
//!   transports, with the wire-truth ledger extended to observed socket
//!   bytes (`payload_bytes == cross_floats × 8`, headers accounted
//!   separately).
//! - [`hybrid::HybridExchange`] is the host-aware hybrid transport: a
//!   hostfile maps ranks to named hosts, co-located ranks exchange
//!   through the in-process channel path (zero serialization) while
//!   cross-host edges ride the checksummed TCP frames — the deployment
//!   shape of a real multi-node cluster, where intra-node and inter-node
//!   links differ by orders of magnitude. The ledger splits accordingly
//!   (`cross_floats` into intra-host vs inter-host, socket bytes counted
//!   only on inter-host edges), and dropped mesh connections reconnect
//!   and replay instead of killing the run.

#![warn(missing_docs)]

pub mod hybrid;
pub mod model;
pub mod partitioned;
pub mod staleness;
pub mod stats;
pub mod tcp;
pub mod threaded;

use crate::graph::laplacian::laplacian_csr;
use crate::graph::Graph;
use crate::linalg::Csr;
pub use staleness::{StaleState, StalenessPolicy};
pub use stats::CommStats;

/// The communication window algorithms get onto the rest of the network.
///
/// An `Exchange` handle *owns* a set of graph nodes (all of them for the
/// bulk-synchronous [`CommGraph`], one shard for
/// [`partitioned::ShardExchange`]). Stacked buffers passed to the trait
/// are **shard-local**: row `r` holds the `w` floats of global node
/// `owned()[r]`. Both transports execute the same scalar operations in
/// the same order, so a program written against this trait produces
/// bit-for-bit identical iterates on either.
///
/// The synchronous (BSP) contract: every handle of a run must issue the
/// same sequence of collective calls. Convergence decisions must be made
/// from globally-reduced values only — every primitive here returns
/// values that are identical on all workers.
pub trait Exchange {
    /// Global node count.
    fn n(&self) -> usize;

    /// Global ids of the nodes this handle owns, ascending. Local stacked
    /// buffers hold rows in this order.
    fn owned(&self) -> &[usize];

    /// Neighbor exchange: write the owned rows of `a · x̂` into `out`,
    /// where `x̂` is the global `n × w` stack assembled from every
    /// handle's local `x`. The operator `a` is a global `n × n` CSR whose
    /// support must stay within the graph neighborhoods (plus diagonal)
    /// unless an overlay plan was registered for it
    /// ([`Self::register_plan`]); the round is charged as
    /// `directed_messages` messages of `w` floats.
    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    );

    /// Neighbor exchange restricted to freshly-updated source rows: the
    /// same contract as [`Self::exchange_apply`], plus the caller's
    /// promise that every global row with `fresh[u] == false` still holds
    /// the value it had the last time it crossed the wire (under *any*
    /// operator — transports keep one mirror per node, not per operator).
    /// A plan-driven transport ships only the fresh boundary rows
    /// (wavefront schedules like ADMM's sweep stages use this to put
    /// exactly the modeled messages on the wire); in-memory transports
    /// always read fresh state, so the default forwards to the full
    /// exchange. The modeled charge is `directed_messages` either way —
    /// `fresh` changes what crosses the wire, never the ledger.
    fn exchange_apply_fresh(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        let _ = fresh;
        // sddn-lint: allow(overlay) reason=default forwards to exchange_apply, which enforces the operator contract itself
        self.exchange_apply(a, directed_messages, x, w, out);
    }

    /// Neighbor exchange restricted to freshly-updated source rows *and*
    /// a subset of owned output rows: same wire contract as
    /// [`Self::exchange_apply_fresh`], plus the caller's promise that it
    /// will only read output rows with `compute[owned()[li]] == true` —
    /// rows outside the compute mask are left unspecified, letting
    /// plan-driven transports skip their row kernels (wavefront
    /// schedules like ADMM's sweep stages consume only one independent
    /// set per stage). The default computes the superset — masked-out
    /// rows are simply ignored by the caller — so computed rows are
    /// bit-identical whether or not a transport overrides this.
    fn exchange_apply_fresh_rows(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        compute: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        let _ = compute;
        // sddn-lint: allow(overlay) reason=default forwards to exchange_apply_fresh, which enforces the operator contract itself
        self.exchange_apply_fresh(a, fresh, directed_messages, x, w, out);
    }

    /// Neighbor exchange under a bounded-staleness policy: `st` carries
    /// the per-call-site [`StaleState`]. With `st.tau == 0` this is a
    /// plain [`Self::exchange_apply`] (bit-for-bit, zero overhead). With
    /// `tau > 0`, one call out of every `tau + 1` is a *refresh* (a real
    /// exchange, charged normally) and the rest are *stale* rounds
    /// reconstructed locally from the cached off-diagonal contribution
    /// plus the fresh diagonal self-term — no wire activity, charged to
    /// the ledger's savings counters
    /// ([`CommStats::record_skipped_exchange`]). See [`staleness`] for
    /// the exactness argument; stale outputs are a pure function of the
    /// last refresh output and the current local iterate, so
    /// cross-transport bit-equality holds for every `tau`.
    fn exchange_apply_stale(
        &mut self,
        a: &Csr,
        st: &mut StaleState,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if st.next_is_refresh() {
            st.prime(a, self.owned());
            // sddn-lint: allow(overlay) reason=staleness wrapper forwards to exchange_apply, which enforces the operator contract itself
            self.exchange_apply(a, directed_messages, x, w, out);
            if st.tau > 0 {
                st.cache_refresh(x, w, out);
            }
        } else {
            st.apply_stale(x, w, out);
            self.stats_mut().record_skipped_exchange(directed_messages, w);
        }
    }

    /// Register a named exchange plan for operator `a`: a plan-driven
    /// transport derives, from `a`'s actual CSR support, exactly which
    /// owned rows each peer reads — enabling *overlay* operators whose
    /// support exceeds the graph neighborhoods (e.g. preprocessed
    /// squared-chain levels) to ride the partitioned transport.
    /// Transports with co-located state need no plan; the default is a
    /// no-op, so the same algorithm code runs everywhere. Registering the
    /// same operator twice is idempotent.
    ///
    /// Contract: an operator passed to `register_plan`/`exchange_apply`
    /// must stay alive and unmodified for the rest of the run — plan
    /// caches key on the operator's buffer identity, the pattern every
    /// algorithm here follows (operators are built once at construction).
    fn register_plan(&mut self, _name: &str, _a: &Csr) {}

    /// Laplacian application `y = (I_w ⊗ L) x` over the transport's graph
    /// into a caller-provided buffer — one neighbor-exchange round of
    /// `2m` messages. This is the hot-path form: iteration loops keep a
    /// reusable workspace instead of allocating a fresh `Vec` per round.
    fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]);

    /// Allocating convenience wrapper around
    /// [`Self::laplacian_apply_into`].
    fn laplacian_apply(&mut self, x: &[f64], w: usize) -> Vec<f64> {
        let mut y = vec![0.0; x.len()];
        self.laplacian_apply_into(x, w, &mut y);
        y
    }

    /// Tree all-reduce (sum): per-column global sums of the `local_n × w`
    /// locals. Every handle returns the same `w` floats; the reduction is
    /// performed in global node order so the result is independent of the
    /// partitioning. Cost: `2(n−1)` messages of `w` floats, 2 rounds.
    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64>;

    /// Communication counters so far (the modeled system-wide cost; on the
    /// partitioned transport every worker tallies the identical ledger).
    fn stats(&self) -> &CommStats;

    /// Mutable counters — lets sub-solvers record custom exchanges into
    /// the same ledger.
    fn stats_mut(&mut self) -> &mut CommStats;

    /// Number of owned nodes.
    fn local_n(&self) -> usize {
        self.owned().len()
    }

    /// Distributed mean-centering: subtract the global per-column mean
    /// from each owned row. One all-reduce.
    fn center(&mut self, x: &mut [f64], w: usize) {
        let total = self.allreduce_sum(x, w);
        let n = self.n() as f64;
        for row in x.chunks_mut(w) {
            for (j, v) in row.iter_mut().enumerate() {
                *v -= total[j] / n;
            }
        }
    }

    /// Distributed squared 2-norm of a stacked per-node vector. One
    /// all-reduce of width 1.
    fn norm2_sq(&mut self, x: &[f64], w: usize) -> f64 {
        let locals: Vec<f64> = x
            .chunks(w)
            .map(|row| row.iter().map(|v| v * v).sum())
            .collect();
        self.allreduce_sum(&locals, 1)[0]
    }

    /// Dual gradient norm ‖M y‖₂ at a stacked primal iterate `y` — the
    /// step-size diagnostic shared by the dual Newton methods. Costs one
    /// exchange round plus one all-reduce.
    fn dual_grad_norm(&mut self, y: &[f64], p: usize) -> f64 {
        let g = self.laplacian_apply(y, p);
        self.norm2_sq(&g, p).sqrt()
    }
}

/// Bulk-synchronous transport: a single process owns every node of the
/// graph and each primitive is an accounted in-memory sweep.
pub struct CommGraph<'g> {
    g: &'g Graph,
    stats: CommStats,
    owned: Vec<usize>,
    /// Graph Laplacian, built lazily for `laplacian_apply`.
    lap: Option<Csr>,
}

impl<'g> CommGraph<'g> {
    /// Wrap a graph.
    pub fn new(g: &'g Graph) -> Self {
        CommGraph {
            g,
            stats: CommStats::default(),
            owned: (0..g.n).collect(),
            lap: None,
        }
    }

    /// The underlying topology.
    pub fn graph(&self) -> &Graph {
        self.g
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.g.n
    }

    /// Communication counters so far.
    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    /// Mutable counters — lets sub-solvers (SDDM, Neumann, CG) record their
    /// exchanges into the same ledger.
    pub fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }

    /// Reset counters (e.g. between experiment phases).
    pub fn reset_stats(&mut self) {
        self.stats = CommStats::default();
    }

    /// One synchronous exchange round: every node sends its `w`-float
    /// payload to every neighbor. Returns, for each node, the *sum* of its
    /// neighbors' payloads (the primitive underlying Jacobi sweeps and
    /// diffusion averaging).
    ///
    /// `x` is row-major `n × w`. Cost: `2m` messages of `w` floats.
    pub fn neighbor_sum(&mut self, x: &[f64], w: usize) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        self.neighbor_sum_into(x, w, &mut out);
        out
    }

    /// In-place variant of [`neighbor_sum`](Self::neighbor_sum) writing into `out`.
    pub fn neighbor_sum_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        let n = self.g.n;
        assert_eq!(x.len(), n * w);
        assert_eq!(out.len(), n * w);
        out.fill(0.0);
        for &(u, v) in &self.g.edges {
            for j in 0..w {
                out[u * w + j] += x[v * w + j];
                out[v * w + j] += x[u * w + j];
            }
        }
        self.stats.record_edge_round(self.g.m(), w);
    }
}

impl Exchange for CommGraph<'_> {
    fn n(&self) -> usize {
        self.g.n
    }

    fn owned(&self) -> &[usize] {
        &self.owned
    }

    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        assert_eq!(x.len(), self.g.n * w, "payload shape mismatch");
        a.matvec_multi_into(x, w, out);
        self.stats.record_exchange(directed_messages, w);
    }

    fn exchange_apply_fresh_rows(
        &mut self,
        a: &Csr,
        _fresh: &[bool],
        compute: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        // Bulk state is co-located, so `fresh` is moot; the compute mask
        // skips row kernels exactly like the partitioned transports —
        // computed rows match the full sweep bit for bit.
        assert_eq!(x.len(), self.g.n * w, "payload shape mismatch");
        assert_eq!(compute.len(), self.g.n, "compute mask shape mismatch");
        for u in 0..self.g.n {
            if compute[u] {
                a.row_matvec_multi(u, x, w, &mut out[u * w..(u + 1) * w]);
            }
        }
        self.stats.record_exchange(directed_messages, w);
    }

    fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        assert_eq!(x.len(), self.g.n * w, "payload shape mismatch");
        assert_eq!(out.len(), x.len(), "output shape mismatch");
        let g = self.g;
        let lap = self.lap.get_or_insert_with(|| laplacian_csr(g));
        lap.matvec_multi_into(x, w, out);
        self.stats.record_edge_round(self.g.m(), w);
    }

    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        let n = self.g.n;
        assert_eq!(locals.len(), n * w);
        let mut total = vec![0.0; w];
        for i in 0..n {
            for j in 0..w {
                total[j] += locals[i * w + j];
            }
        }
        self.stats.record_allreduce(n, w);
        total
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate;
    use crate::graph::laplacian::laplacian_csr;
    use crate::util::Pcg64;

    #[test]
    fn laplacian_apply_matches_csr() {
        let mut rng = Pcg64::new(10);
        let g = generate::random_connected(12, 25, &mut rng);
        let l = laplacian_csr(&g);
        let mut comm = CommGraph::new(&g);
        let x = rng.normal_vec(12);
        let via_comm = comm.laplacian_apply(&x, 1);
        let via_csr = l.matvec(&x);
        for (a, b) in via_comm.iter().zip(&via_csr) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(comm.stats().messages, 2 * g.m() as u64);
        assert_eq!(comm.stats().floats, 2 * g.m() as u64);
    }

    #[test]
    fn laplacian_apply_multiwidth() {
        let mut rng = Pcg64::new(11);
        let g = generate::random_connected(8, 14, &mut rng);
        let l = laplacian_csr(&g);
        let w = 3;
        let x = rng.normal_vec(8 * w);
        let mut comm = CommGraph::new(&g);
        let y = comm.laplacian_apply(&x, w);
        // Compare column-by-column.
        for j in 0..w {
            let col: Vec<f64> = (0..8).map(|i| x[i * w + j]).collect();
            let ycol = l.matvec(&col);
            for i in 0..8 {
                assert!((y[i * w + j] - ycol[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn exchange_apply_charges_custom_message_count() {
        let mut rng = Pcg64::new(12);
        let g = generate::random_connected(9, 16, &mut rng);
        let l = laplacian_csr(&g);
        let mut comm = CommGraph::new(&g);
        let x = rng.normal_vec(9);
        let mut y = vec![0.0; 9];
        comm.exchange_apply(&l, 5, &x, 1, &mut y);
        assert_eq!(comm.stats().messages, 5);
        assert_eq!(comm.stats().rounds, 1);
        let direct = l.matvec(&x);
        assert_eq!(y, direct);
    }

    #[test]
    fn allreduce_and_center() {
        let g = generate::complete(5);
        let mut comm = CommGraph::new(&g);
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let s = comm.allreduce_sum(&x, 1);
        assert_eq!(s, vec![15.0]);
        comm.center(&mut x, 1);
        assert!(x.iter().sum::<f64>().abs() < 1e-12);
    }

    #[test]
    fn stats_accumulate() {
        let g = generate::cycle(6);
        let mut comm = CommGraph::new(&g);
        let x1 = vec![0.0; 6];
        let x2 = vec![0.0; 12];
        let _ = comm.neighbor_sum(&x1, 1);
        let _ = comm.neighbor_sum(&x2, 2);
        assert_eq!(comm.stats().messages, 24); // 2 rounds × 2m, m = 6
        assert_eq!(comm.stats().floats, 12 + 24);
        assert_eq!(comm.stats().rounds, 2);
        comm.reset_stats();
        assert_eq!(comm.stats().messages, 0);
    }

    #[test]
    fn norm2_sq_matches() {
        let g = generate::complete(4);
        let mut comm = CommGraph::new(&g);
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let n2 = comm.norm2_sq(&x, 2);
        let direct: f64 = x.iter().map(|v| v * v).sum();
        assert!((n2 - direct).abs() < 1e-12);
    }

    #[test]
    fn bulk_handle_owns_every_node() {
        let g = generate::cycle(7);
        let comm = CommGraph::new(&g);
        assert_eq!(Exchange::n(&comm), 7);
        assert_eq!(comm.local_n(), 7);
        assert_eq!(comm.owned(), &[0, 1, 2, 3, 4, 5, 6]);
    }
}
