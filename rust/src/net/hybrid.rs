//! Host-aware hybrid transport: the fourth [`Exchange`](super::Exchange)
//! implementation, routing every boundary payload by deployment
//! placement. Ranks placed on the *same* host exchange through in-process
//! channels — the zero-serialization
//! [`ShardExchange`](super::partitioned::ShardExchange) path — while
//! cross-host edges ride the checksummed TCP
//! [`frame`](super::tcp::frame)s of the socket transport. Same plans,
//! same row kernel, same reduce order: iterates are bit-for-bit identical
//! to all three existing transports (`tests/hybrid_wire.rs`).
//!
//! # Placement
//!
//! A deployment is described by an MPI-style hostfile: one host per line,
//! optionally `slots=N` for the number of ranks it runs, `#` comments and
//! blank lines ignored, ranks assigned in file order
//! ([`parse_hostfile`]). The leader process broadcasts its own placement
//! with the peer table (`ADDR\tHOST` lines, see
//! [`crate::coordinator::tcp`]); every worker cross-checks that column
//! against its local hostfile and refuses to run on drift — two processes
//! disagreeing about who is co-located would corrupt the byte ledger.
//! By convention the coordinator runs on rank 0's host, so ranks sharing
//! that host classify their all-reduce traffic as intra-host.
//!
//! # Wire-truth split
//!
//! The comm ledger splits by placement: [`HybridExchange::intra_cross`] /
//! [`HybridExchange::intra_floats`] count channel payloads,
//! [`HybridExchange::inter_cross`] / [`HybridExchange::inter_floats`]
//! count socket payloads, and the sums equal the single-transport totals
//! of `ShardExchange`/`TcpExchange` exactly. Socket bytes are counted
//! only on inter-host edges: `payload_bytes == inter_floats × 8` and
//! `header_bytes` is a multiple of
//! [`HEADER_BYTES`](super::tcp::frame::HEADER_BYTES) — asserted the same
//! three ways as the pure TCP transport (unit, property, CLI smoke).
//! All-reduce frames from ranks co-located with the leader ride a
//! loopback socket and are deliberately excluded from the socket byte
//! ledger (they are intra-host traffic).
//!
//! # Reconnect
//!
//! The socket leg is hardened for real clusters. Every cross-host
//! connection retains its last [`REPLAY_ROUNDS`] rounds of outbound
//! frames; when a connection drops mid-run, the *higher* rank of the pair
//! redials (it dialed at bootstrap too — the static dialer rule) with the
//! existing `SDDN_TCP_RETRIES`/`SDDN_TCP_RETRY_MS` knobs while the lower
//! rank re-accepts on its kept-open mesh listener, then **both** sides
//! replay their retained frames. Receivers deduplicate replays against
//! the highest round already consumed per peer, so a frame that survived
//! the crash is dropped on redelivery and iterates stay bit-identical.
//! Replayed bytes are not re-counted (first-transmission accounting keeps
//! the byte invariant). Only when recovery exceeds the iteration deadline
//! (`SDDN_TCP_TIMEOUT_MS`) does the round fail, with the same typed
//! [`TcpError`] the pure TCP transport uses.

use super::partitioned::{derive_exchange_plan, op_key, ExchangePlan, OpKey, ShardPlan};
use super::tcp::frame::{
    bytes_to_f64s, put_f64s, put_u64s, read_frame, write_frame, FrameKind, TcpError, HEADER_BYTES,
};
use super::tcp::{accept_with_deadline, connect_with_retry, WorkerNetConfig, METRIC_COUNTERS};
use super::{CommStats, Exchange};
use crate::linalg::Csr;
use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How many recent exchange rounds of outbound frames every cross-host
/// connection retains for post-reconnect replay. A peer lagging further
/// behind a dropped connection than this cannot be replayed to and the
/// round fails with the typed timeout instead.
pub const REPLAY_ROUNDS: u64 = 4;

/// Cap on parked payload buffers (excess buffers are dropped) — same
/// arena discipline as the in-process transport.
const PAYLOAD_POOL_CAP: usize = 64;

/// A deployment placement: which named host runs each rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `host_of[rank]` = host name, ranks in hostfile order.
    host_of: Vec<String>,
}

impl Placement {
    /// Pool size (total ranks across all hosts).
    pub fn k(&self) -> usize {
        self.host_of.len()
    }

    /// The host name running `rank`.
    pub fn host(&self, rank: usize) -> &str {
        &self.host_of[rank]
    }

    /// Distinct host names in order of first appearance.
    pub fn hosts(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for h in &self.host_of {
            if !out.contains(&h.as_str()) {
                out.push(h);
            }
        }
        out
    }

    /// Ranks placed on `host`, ascending.
    pub fn ranks_on(&self, host: &str) -> Vec<usize> {
        self.host_of
            .iter()
            .enumerate()
            .filter(|(_, h)| h.as_str() == host)
            .map(|(r, _)| r)
            .collect()
    }

    /// Whether two ranks share a host (every rank shares with itself).
    pub fn same_host(&self, a: usize, b: usize) -> bool {
        self.host_of[a] == self.host_of[b]
    }

    /// The host running rank 0 — by convention also the host running the
    /// coordinator, which is how all-reduce traffic is classified.
    pub fn leader_host(&self) -> &str {
        &self.host_of[0]
    }
}

/// Parse an MPI-style hostfile into a [`Placement`].
///
/// One host per line, optionally followed by `slots=N` (default 1) for
/// the number of consecutive ranks the host runs; `#` starts a comment,
/// blank lines are skipped, and repeated host names accumulate further
/// ranks. Ranks are assigned in file order:
///
/// ```text
/// hostA slots=2   # ranks 0,1
/// hostB           # rank 2
/// hostA           # rank 3 — back on hostA
/// ```
pub fn parse_hostfile(text: &str) -> Result<Placement, String> {
    let mut host_of: Vec<String> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or(raw).trim();
        if line.is_empty() {
            continue;
        }
        let mut toks = line.split_whitespace();
        let Some(host) = toks.next() else { continue };
        let mut slots = 1usize;
        for tok in toks {
            if let Some(v) = tok.strip_prefix("slots=") {
                slots = v.parse().map_err(|_| {
                    format!("hostfile line {}: bad slot count {v:?}", lineno + 1)
                })?;
                if slots == 0 {
                    return Err(format!("hostfile line {}: slots=0 assigns no ranks", lineno + 1));
                }
            } else {
                return Err(format!(
                    "hostfile line {}: unknown token {tok:?} (expected `host [slots=N]`)",
                    lineno + 1
                ));
            }
        }
        for _ in 0..slots {
            host_of.push(host.to_string());
        }
    }
    if host_of.is_empty() {
        return Err("hostfile assigns no ranks (every line is blank or a comment)".to_string());
    }
    Ok(Placement { host_of })
}

/// What lands in a rank's hybrid inbox: channel payloads from co-located
/// ranks, decoded socket payloads from cross-host reader threads, and
/// connection lifecycle notices (generation-tagged so a notice from an
/// already-replaced connection is ignored).
pub(crate) enum HybridMsg {
    /// A round-tagged boundary payload from a co-located rank (moved, not
    /// serialized).
    Local {
        /// Sender rank.
        src: usize,
        /// Exchange round.
        round: u64,
        /// Values in the sender's plan order.
        vals: Vec<f64>,
    },
    /// A round-tagged boundary payload decoded off a cross-host socket.
    Remote {
        /// Sender rank.
        src: usize,
        /// Exchange round.
        round: u64,
        /// Values in the sender's plan order.
        vals: Vec<f64>,
    },
    /// A cross-host connection closed (cleanly or after a shutdown).
    Closed {
        /// Peer rank.
        src: usize,
        /// Connection generation the notice belongs to.
        generation: u64,
    },
    /// A cross-host connection failed.
    Failed {
        /// Peer rank.
        src: usize,
        /// Connection generation the notice belongs to.
        generation: u64,
        /// What went wrong.
        err: TcpError,
    },
}

/// The in-process channel endpoints wiring one rank into its host's
/// co-located group. Built by [`local_links`] in the per-host launcher
/// and consumed by [`HybridExchange::connect`]; opaque outside the crate.
pub struct LocalLink {
    pub(crate) rank: usize,
    pub(crate) inbox: Receiver<HybridMsg>,
    pub(crate) inbox_tx: Sender<HybridMsg>,
    /// Senders toward co-located ranks, indexed by rank (`None` for self
    /// and for ranks on other hosts).
    pub(crate) peer_txs: Vec<Option<Sender<HybridMsg>>>,
}

impl LocalLink {
    /// The rank this link belongs to.
    pub fn rank(&self) -> usize {
        self.rank
    }
}

/// Build the channel links for every rank `placement` puts on `host`,
/// in ascending rank order. Each link's inbox also receives the rank's
/// cross-host socket traffic once [`HybridExchange::connect`] wires the
/// mesh readers into it.
pub fn local_links(placement: &Placement, host: &str) -> Vec<LocalLink> {
    let k = placement.k();
    let ranks = placement.ranks_on(host);
    let mut txs: Vec<Sender<HybridMsg>> = Vec::with_capacity(ranks.len());
    let mut rxs: Vec<Receiver<HybridMsg>> = Vec::with_capacity(ranks.len());
    for _ in &ranks {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }
    ranks
        .iter()
        .zip(rxs)
        .enumerate()
        .map(|(i, (&r, rx))| {
            let mut peer_txs: Vec<Option<Sender<HybridMsg>>> = vec![None; k];
            for (j, &q) in ranks.iter().enumerate() {
                if q != r {
                    peer_txs[q] = Some(txs[j].clone());
                }
            }
            LocalLink { rank: r, inbox: rx, inbox_tx: txs[i].clone(), peer_txs }
        })
        .collect()
}

/// Pump one cross-host connection's read end into the hybrid inbox,
/// tagging lifecycle notices with the connection generation so notices
/// from a connection that has since been replaced are ignored.
fn spawn_remote_reader(
    mut reader: BufReader<TcpStream>,
    src: usize,
    generation: u64,
    tx: Sender<HybridMsg>,
) {
    std::thread::spawn(move || {
        let ctx = format!("rank {src}");
        loop {
            match read_frame(&mut reader, &ctx) {
                Ok(f) => {
                    if f.kind != FrameKind::Payload || f.src as usize != src {
                        let _ = tx.send(HybridMsg::Failed {
                            src,
                            generation,
                            err: TcpError::Protocol {
                                msg: format!(
                                    "unexpected {:?} frame from rank {} on the rank-{src} \
                                     data connection",
                                    f.kind, f.src
                                ),
                            },
                        });
                        return;
                    }
                    match bytes_to_f64s(&f.body, &ctx) {
                        Ok(vals) => {
                            if tx
                                .send(HybridMsg::Remote { src, round: f.tag, vals })
                                .is_err()
                            {
                                return; // exchange dropped; shutting down
                            }
                        }
                        Err(err) => {
                            let _ = tx.send(HybridMsg::Failed { src, generation, err });
                            return;
                        }
                    }
                }
                Err(TcpError::PeerClosed { .. }) => {
                    let _ = tx.send(HybridMsg::Closed { src, generation });
                    return;
                }
                Err(err) => {
                    let _ = tx.send(HybridMsg::Failed { src, generation, err });
                    return;
                }
            }
        }
    });
}

/// One cross-host mesh connection.
struct RemotePeer {
    /// Write half (the reader thread holds a clone of the read half).
    stream: TcpStream,
    /// The peer's mesh listener address — what the higher rank redials.
    addr: String,
    /// Bumped on every (re)connection; lifecycle notices carry the
    /// generation they were observed under.
    generation: u64,
    /// Whether the current connection is believed alive.
    up: bool,
    /// Round-tagged outbound frame bodies retained for replay, oldest
    /// first, pruned to the last [`REPLAY_ROUNDS`] rounds.
    replay: VecDeque<(u64, Vec<u8>)>,
}

/// All channel + socket + recovery state of one rank, kept in its own
/// struct so [`HybridExchange::exchange_round`] can drive it while a
/// shared borrow of the exchange-plan cache is alive (disjoint fields).
struct Mesh {
    rank: usize,
    k: usize,
    /// Kept open for the lifetime of the run: reconnecting higher ranks
    /// redial it.
    listener: TcpListener,
    /// Cross-host connections, indexed by rank (`None` for self and
    /// co-located ranks).
    remotes: Vec<Option<RemotePeer>>,
    inbox: Receiver<HybridMsg>,
    /// Self-held sender clone: the inbox can never disconnect, so the
    /// recv timeout is the only liveness guard.
    inbox_tx: Sender<HybridMsg>,
    /// Channel senders toward co-located ranks, indexed by rank.
    local_txs: Vec<Option<Sender<HybridMsg>>>,
    /// `co_located[q]` — rank q shares this host (false for self).
    co_located: Vec<bool>,
    /// Reorder buffer for early payloads, keyed `(sender, round)`.
    pending: HashMap<(usize, u64), Vec<f64>>,
    /// Highest round consumed per peer — the replay deduplication
    /// watermark (only meaningful for cross-host peers).
    consumed: Vec<u64>,
    /// Completed mesh reconnections.
    reconnects: u64,
    timeout: Duration,
    retries: u32,
    backoff: Duration,
}

impl Mesh {
    /// The cross-host connection to `peer`, or a typed error when the
    /// placement never gave us one.
    fn remote_mut(&mut self, peer: usize) -> Result<&mut RemotePeer, TcpError> {
        match self.remotes.get_mut(peer).and_then(|r| r.as_mut()) {
            Some(rp) => Ok(rp),
            None => {
                Err(TcpError::Protocol { msg: format!("no mesh connection to rank {peer}") })
            }
        }
    }

    /// Mark the connection to `src` down — but only if `generation`
    /// matches the current connection, so a stale notice from an
    /// already-replaced connection's reader is ignored. Shuts the socket
    /// down so the far side notices promptly and starts its own recovery.
    fn note_down(&mut self, src: usize, generation: u64) {
        if let Some(rp) = self.remotes.get_mut(src).and_then(|r| r.as_mut()) {
            if rp.up && rp.generation == generation {
                rp.up = false;
                let _ = rp.stream.shutdown(Shutdown::Both);
            }
        }
    }

    /// Move a boundary payload to a co-located rank over its channel.
    fn send_local(&mut self, peer: usize, round: u64, vals: Vec<f64>) -> Result<(), TcpError> {
        match self.local_txs.get(peer).and_then(|t| t.as_ref()) {
            Some(tx) => tx
                .send(HybridMsg::Local { src: self.rank, round, vals })
                .map_err(|_| TcpError::PeerClosed { who: format!("co-located rank {peer}") }),
            None => Err(TcpError::Protocol { msg: format!("rank {peer} is not co-located") }),
        }
    }

    /// Write one round-tagged payload frame to a cross-host peer. The
    /// body is retained in the replay buffer *before* the write, so a
    /// transient failure (broken pipe, peer-side shutdown) recovers by
    /// reconnecting and replaying instead of erroring out.
    fn send_remote(&mut self, peer: usize, round: u64, body: &[u8]) -> Result<(), TcpError> {
        let ctx = format!("rank {peer}");
        {
            let rp = self.remote_mut(peer)?;
            rp.replay.push_back((round, body.to_vec()));
            while rp.replay.front().is_some_and(|(r, _)| r + REPLAY_ROUNDS <= round) {
                rp.replay.pop_front();
            }
        }
        let deadline = Instant::now() + self.timeout;
        if !self.remote_mut(peer)?.up {
            // recover() replays the retained frames, including this one.
            return self.recover(peer, deadline);
        }
        let rank = self.rank as u16;
        let result = {
            let rp = self.remote_mut(peer)?;
            write_frame(&mut rp.stream, FrameKind::Payload, rank, round, body, &ctx)
        };
        match result {
            Ok(()) => Ok(()),
            Err(TcpError::Io { .. }) | Err(TcpError::PeerClosed { .. }) => {
                let generation = self.remote_mut(peer)?.generation;
                self.note_down(peer, generation);
                self.recover(peer, deadline)
            }
            Err(other) => Err(other),
        }
    }

    /// Replay every retained outbound frame to a freshly reconnected
    /// peer. Replayed bytes are *not* added to the byte ledger —
    /// first-transmission accounting keeps `payload_bytes` equal to
    /// `inter_floats × 8`; the receiver deduplicates by consumed round.
    fn replay_to(&mut self, peer: usize) -> Result<(), TcpError> {
        let rank = self.rank as u16;
        let ctx = format!("rank {peer} (replay)");
        let rp = self.remote_mut(peer)?;
        for (round, body) in &rp.replay {
            write_frame(&mut rp.stream, FrameKind::Payload, rank, *round, body, &ctx)?;
        }
        Ok(())
    }

    /// Re-establish the dropped connection to cross-host peer `q` and
    /// replay retained frames. The static dialer rule mirrors bootstrap:
    /// the higher rank of the pair redials the lower rank's kept-open
    /// mesh listener (TCP backlog holds the redial until the lower rank
    /// accepts). While waiting for `q`, a reconnect Hello from a
    /// *different* down higher rank is installed too — two connections
    /// dropping at once must not deadlock the accept loop.
    fn recover(&mut self, q: usize, deadline: Instant) -> Result<(), TcpError> {
        let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };
        if q < self.rank {
            // We dialed q at bootstrap; redial with the same knobs.
            let (addr, generation) = {
                let rp = self.remote_mut(q)?;
                let _ = rp.stream.shutdown(Shutdown::Both);
                rp.up = false;
                rp.generation += 1;
                (rp.addr.clone(), rp.generation)
            };
            let mut s = connect_with_retry(&addr, self.retries, self.backoff)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            let ctx = format!("rank {q}");
            write_frame(&mut s, FrameKind::Hello, self.rank as u16, generation, &[], &ctx)?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            spawn_remote_reader(BufReader::new(read_half), q, generation, self.inbox_tx.clone());
            {
                let rp = self.remote_mut(q)?;
                rp.stream = s;
                rp.up = true;
            }
            self.reconnects += 1;
            return self.replay_to(q);
        }
        // q dialed us at bootstrap; wait for its redial.
        loop {
            let s = accept_with_deadline(&self.listener, deadline)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            s.set_read_timeout(Some(self.timeout)).map_err(|e| io("peer set timeout", e))?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            let mut reader = BufReader::new(read_half);
            let hello = read_frame(&mut reader, "mesh re-accept")?;
            if hello.kind != FrameKind::Hello {
                return Err(TcpError::Protocol {
                    msg: format!("expected a reconnect Hello, got a {:?} frame", hello.kind),
                });
            }
            let src = hello.src as usize;
            let reconnectable = src > self.rank
                && src < self.k
                && self.remotes.get(src).and_then(|r| r.as_ref()).is_some_and(|rp| !rp.up);
            if !reconnectable {
                return Err(TcpError::Protocol {
                    msg: format!("unexpected reconnect Hello from rank {src}"),
                });
            }
            s.set_read_timeout(None).map_err(|e| io("peer clear timeout", e))?;
            let generation = {
                let rp = self.remote_mut(src)?;
                let _ = rp.stream.shutdown(Shutdown::Both);
                rp.generation += 1;
                rp.stream = s;
                rp.up = true;
                rp.generation
            };
            // Keep the handshake BufReader — it may already hold replayed
            // payload bytes that arrived behind the Hello.
            spawn_remote_reader(reader, src, generation, self.inbox_tx.clone());
            self.reconnects += 1;
            self.replay_to(src)?;
            if src == q {
                return Ok(());
            }
        }
    }

    /// Receive the `round`-tagged payload from `peer`, parking other
    /// (possibly future-round) payloads in the reorder buffer. Replayed
    /// duplicates of already-consumed rounds are dropped against the
    /// per-peer watermark; a dropped connection to the awaited peer is
    /// recovered in place. The whole wait is bounded by one timeout
    /// window — past it, the round fails with the typed error.
    fn recv_round(&mut self, peer: usize, round: u64) -> Result<Vec<f64>, TcpError> {
        let deadline = Instant::now() + self.timeout;
        if let Some(d) = self.pending.remove(&(peer, round)) {
            if !self.co_located[peer] && round > self.consumed[peer] {
                self.consumed[peer] = round;
            }
            return Ok(d);
        }
        if !self.co_located[peer]
            && self.remotes.get(peer).and_then(|r| r.as_ref()).is_some_and(|rp| !rp.up)
        {
            self.recover(peer, deadline)?;
        }
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return Err(TcpError::Timeout {
                    who: format!("rank {peer}"),
                    waiting_for: format!("the round-{round} boundary payload"),
                });
            }
            match self.inbox.recv_timeout(left) {
                Ok(HybridMsg::Local { src, round: r, vals }) => {
                    if src == peer && r == round {
                        return Ok(vals);
                    }
                    // Channels cannot legitimately duplicate: a second
                    // copy of the same (sender, round) is a wiring bug.
                    if self.pending.insert((src, r), vals).is_some() {
                        return Err(TcpError::Protocol {
                            msg: format!("duplicate channel payload from rank {src} round {r}"),
                        });
                    }
                }
                Ok(HybridMsg::Remote { src, round: r, vals }) => {
                    if r <= self.consumed[src] {
                        continue; // replayed duplicate of a consumed round
                    }
                    if src == peer && r == round {
                        self.consumed[src] = r;
                        return Ok(vals);
                    }
                    // A replay may duplicate a parked-but-unconsumed
                    // round; keep the first copy (they are bit-identical).
                    self.pending.entry((src, r)).or_insert(vals);
                }
                Ok(HybridMsg::Closed { src, generation }) => {
                    self.note_down(src, generation);
                    if src == peer {
                        self.recover(peer, deadline)?;
                    }
                }
                Ok(HybridMsg::Failed { src, generation, err }) => {
                    if matches!(err, TcpError::Protocol { .. }) {
                        // Protocol violations are bugs, not transients —
                        // reconnecting would mask them.
                        return Err(TcpError::Protocol {
                            msg: format!("data connection to rank {src} failed: {err}"),
                        });
                    }
                    self.note_down(src, generation);
                    if src == peer {
                        self.recover(peer, deadline)?;
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TcpError::Timeout {
                        who: format!("rank {peer}"),
                        waiting_for: format!("the round-{round} boundary payload"),
                    });
                }
                Err(RecvTimeoutError::Disconnected) => {
                    // Unreachable: the mesh holds its own inbox sender.
                    return Err(TcpError::Protocol {
                        msg: "hybrid inbox disconnected".to_string(),
                    });
                }
            }
        }
    }
}

/// Per-rank [`Exchange`] handle of the hybrid transport.
///
/// Semantically a [`ShardExchange`](super::partitioned::ShardExchange)
/// whose channels to other hosts are sockets: plan-driven shipping,
/// round-tagged reorder buffering, sequence-keyed all-reduce through the
/// leader connection. One OS process per *host* runs one handle per rank
/// it hosts (see [`crate::coordinator::tcp`] for the per-host launcher).
pub struct HybridExchange {
    n: usize,
    k: usize,
    m_edges: usize,
    rank: usize,
    lap: Arc<Csr>,
    plan: ShardPlan,
    /// Channel + socket + recovery state (its own struct so recovery is
    /// reachable while the plan cache is borrowed).
    mesh: Mesh,
    /// Write half of the leader connection (all-reduce up, metrics).
    leader: TcpStream,
    /// Read half of the leader connection (peer table, all-reduce down).
    leader_reader: BufReader<TcpStream>,
    /// Whether this rank shares a host with rank 0 (and hence, by
    /// convention, with the coordinator): decides how all-reduce traffic
    /// is classified in the intra/inter ledger.
    leader_is_local: bool,
    /// Mirror of the global stack holding fresh values for covered nodes.
    mirror: Vec<f64>,
    round: u64,
    red_seq: u64,
    /// Per-operator exchange plans (same derivation as `ShardExchange`).
    op_plans: HashMap<OpKey, ExchangePlan>,
    /// Arena of boundary-payload buffers for the channel path.
    payload_pool: Vec<Vec<f64>>,
    /// Reused frame-body encode buffer for the socket path.
    body_scratch: Vec<u8>,
    /// Persistent scratch for the fresh-masked receive row list.
    fresh_scratch: Vec<usize>,
    stats: CommStats,
    intra_cross: u64,
    intra_floats: u64,
    inter_cross: u64,
    inter_floats: u64,
    payload_bytes: u64,
    header_bytes: u64,
}

impl HybridExchange {
    /// Join the pool: rendezvous through the leader, verify the broadcast
    /// placement against the local hostfile, then build a mesh of
    /// *cross-host* connections only (co-located ranks already share
    /// channels through `link`). `plan` must be this rank's entry of
    /// [`build_shard_plans`](super::partitioned::build_shard_plans) and
    /// `lap` the graph Laplacian, shared (`Arc`) because one per-host
    /// process runs several ranks.
    pub fn connect(
        net: &WorkerNetConfig,
        placement: &Placement,
        link: LocalLink,
        n: usize,
        m_edges: usize,
        lap: Arc<Csr>,
        plan: ShardPlan,
    ) -> Result<HybridExchange, TcpError> {
        let (rank, k) = (net.rank, net.k);
        if k == 0 || rank >= k || k > u16::MAX as usize {
            return Err(TcpError::Protocol { msg: format!("bad rank/pool: rank {rank} of {k}") });
        }
        if placement.k() != k {
            return Err(TcpError::Protocol {
                msg: format!("hostfile places {} ranks, pool has {k}", placement.k()),
            });
        }
        if link.rank != rank {
            return Err(TcpError::Protocol {
                msg: format!("local link is for rank {}, not rank {rank}", link.rank),
            });
        }
        if plan.worker != rank {
            return Err(TcpError::Protocol {
                msg: format!("shard plan is for worker {}, not rank {rank}", plan.worker),
            });
        }
        if lap.rows != n {
            return Err(TcpError::Protocol {
                msg: format!("Laplacian is {}×{}, graph has {n} nodes", lap.rows, lap.cols),
            });
        }
        let co_located: Vec<bool> =
            (0..k).map(|q| q != rank && placement.same_host(rank, q)).collect();
        for (q, tx) in link.peer_txs.iter().enumerate() {
            if tx.is_some() != co_located[q] {
                return Err(TcpError::Protocol {
                    msg: format!(
                        "link wiring does not match the placement: rank {q} is {} but has {} \
                         channel",
                        if co_located[q] { "co-located" } else { "remote" },
                        if tx.is_some() { "a" } else { "no" },
                    ),
                });
            }
        }
        let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };

        // 1. Leader rendezvous: dial (with retry), bind our own mesh
        //    listener on the same interface, advertise it.
        let mut leader = connect_with_retry(&net.leader_addr, net.retries, net.backoff)?;
        leader.set_nodelay(true).map_err(|e| io("leader set_nodelay", e))?;
        leader.set_read_timeout(Some(net.timeout)).map_err(|e| io("leader set timeout", e))?;
        let local_ip = leader.local_addr().map_err(|e| io("leader local_addr", e))?.ip();
        let listener = TcpListener::bind((local_ip, 0)).map_err(|e| io("bind mesh listener", e))?;
        let my_addr = listener.local_addr().map_err(|e| io("listener local_addr", e))?;
        write_frame(
            &mut leader,
            FrameKind::Hello,
            rank as u16,
            0,
            my_addr.to_string().as_bytes(),
            "leader",
        )?;

        // 2. Peer table with the leader's placement column: every worker
        //    cross-checks it against the local hostfile — two processes
        //    disagreeing about co-location would corrupt the byte ledger.
        let mut leader_reader =
            BufReader::new(leader.try_clone().map_err(|e| io("leader try_clone", e))?);
        let table = read_frame(&mut leader_reader, "leader")?;
        if table.kind != FrameKind::PeerTable {
            return Err(TcpError::Protocol {
                msg: format!("expected the peer table, got a {:?} frame", table.kind),
            });
        }
        let text = String::from_utf8(table.body)
            .map_err(|_| TcpError::BadFrame { msg: "peer table is not UTF-8".to_string() })?;
        let lines: Vec<&str> = text.lines().collect();
        if lines.len() != k {
            return Err(TcpError::Protocol {
                msg: format!("peer table lists {} workers, expected {k}", lines.len()),
            });
        }
        let mut addrs: Vec<String> = Vec::with_capacity(k);
        for (q, line) in lines.iter().enumerate() {
            let mut cols = line.split('\t');
            let addr = cols.next().unwrap_or(line);
            match cols.next() {
                Some(host) if host != placement.host(q) => {
                    return Err(TcpError::Protocol {
                        msg: format!(
                            "placement drift: the leader places rank {q} on {host:?}, the local \
                             hostfile says {:?}",
                            placement.host(q)
                        ),
                    });
                }
                Some(_) => {}
                None => {
                    return Err(TcpError::Protocol {
                        msg: "the leader did not broadcast a placement — start it with the \
                              same hostfile (`--transport hybrid --hostfile F`)"
                            .to_string(),
                    });
                }
            }
            addrs.push(addr.to_string());
        }

        // 3. Cross-host mesh only: dial every lower cross-host rank,
        //    accept every higher cross-host rank. Co-located ranks keep
        //    their channels. Connections start at generation 1.
        let mut remotes: Vec<Option<RemotePeer>> = (0..k).map(|_| None).collect();
        for (q, addr) in addrs.iter().enumerate().take(rank) {
            if co_located[q] {
                continue;
            }
            let mut s = connect_with_retry(addr, net.retries, net.backoff)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            write_frame(&mut s, FrameKind::Hello, rank as u16, 1, &[], &format!("rank {q}"))?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            spawn_remote_reader(BufReader::new(read_half), q, 1, link.inbox_tx.clone());
            remotes[q] = Some(RemotePeer {
                stream: s,
                addr: addr.clone(),
                generation: 1,
                up: true,
                replay: VecDeque::new(),
            });
        }
        let expect_accepts =
            (rank + 1..k).filter(|&q| !placement.same_host(rank, q)).count();
        let deadline = Instant::now() + net.timeout;
        for _ in 0..expect_accepts {
            let s = accept_with_deadline(&listener, deadline)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            s.set_read_timeout(Some(net.timeout)).map_err(|e| io("peer set timeout", e))?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            let mut reader = BufReader::new(read_half);
            let hello = read_frame(&mut reader, "peer handshake")?;
            if hello.kind != FrameKind::Hello {
                return Err(TcpError::Protocol {
                    msg: format!("expected a mesh Hello, got a {:?} frame", hello.kind),
                });
            }
            let src = hello.src as usize;
            if src <= rank || src >= k || co_located[src] {
                return Err(TcpError::Protocol {
                    msg: format!("mesh Hello from out-of-range or co-located rank {src}"),
                });
            }
            if remotes[src].is_some() {
                return Err(TcpError::Protocol {
                    msg: format!("duplicate mesh connection from rank {src}"),
                });
            }
            // Handshake done: payload reads block indefinitely in the
            // reader thread (hang protection is the inbox recv timeout).
            s.set_read_timeout(None).map_err(|e| io("peer clear timeout", e))?;
            // Keep the handshake BufReader — it may already hold buffered
            // payload bytes that arrived behind the Hello.
            spawn_remote_reader(reader, src, 1, link.inbox_tx.clone());
            remotes[src] = Some(RemotePeer {
                stream: s,
                addr: addrs[src].clone(),
                generation: 1,
                up: true,
                replay: VecDeque::new(),
            });
        }

        let leader_is_local = placement.same_host(rank, 0);
        let mesh = Mesh {
            rank,
            k,
            listener,
            remotes,
            inbox: link.inbox,
            inbox_tx: link.inbox_tx,
            local_txs: link.peer_txs,
            co_located,
            pending: HashMap::new(),
            consumed: vec![0; k],
            reconnects: 0,
            timeout: net.timeout,
            retries: net.retries,
            backoff: net.backoff,
        };
        Ok(HybridExchange {
            n,
            k,
            m_edges,
            rank,
            lap,
            plan,
            mesh,
            leader,
            leader_reader,
            leader_is_local,
            mirror: Vec::new(),
            round: 0,
            red_seq: 0,
            op_plans: HashMap::new(),
            payload_pool: Vec::new(),
            body_scratch: Vec::new(),
            fresh_scratch: Vec::new(),
            stats: CommStats::default(),
            intra_cross: 0,
            intra_floats: 0,
            inter_cross: 0,
            inter_floats: 0,
            payload_bytes: 0,
            header_bytes: 0,
        })
    }

    /// This worker's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// This worker's shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The exchange plan the transport derived (or had registered) for an
    /// operator, if any — lets tests and benches inspect what ships.
    pub fn plan_for(&self, a: &Csr) -> Option<&ExchangePlan> {
        self.op_plans.get(&op_key(a))
    }

    /// Real cross-worker payloads so far over *both* legs — identical to
    /// `ShardExchange::cross_messages` / `TcpExchange::cross_messages`
    /// on the same run (the placement only decides the split).
    pub fn cross_messages(&self) -> u64 {
        self.intra_cross + self.inter_cross
    }

    /// Real floats moved over both legs so far.
    pub fn cross_floats(&self) -> u64 {
        self.intra_floats + self.inter_floats
    }

    /// Cross-worker payloads that stayed on this host (channel leg).
    pub fn intra_cross(&self) -> u64 {
        self.intra_cross
    }

    /// Floats moved between co-located ranks (channel leg, never
    /// serialized).
    pub fn intra_floats(&self) -> u64 {
        self.intra_floats
    }

    /// Cross-worker payloads that left this host (socket leg).
    pub fn inter_cross(&self) -> u64 {
        self.inter_cross
    }

    /// Floats moved over sockets to other hosts.
    pub fn inter_floats(&self) -> u64 {
        self.inter_floats
    }

    /// Real payload bytes written to cross-host sockets — exactly
    /// [`inter_floats`](Self::inter_floats)` × 8`: the wire-truth
    /// invariant, now counting only bytes that actually leave the host.
    pub fn payload_bytes(&self) -> u64 {
        self.payload_bytes
    }

    /// Fixed framing overhead written to cross-host sockets:
    /// [`HEADER_BYTES`](super::tcp::frame::HEADER_BYTES) per first
    /// transmission of a data frame (replays are not re-counted).
    pub fn header_bytes(&self) -> u64 {
        self.header_bytes
    }

    /// Completed mesh reconnections (0 on a healthy run).
    pub fn reconnects(&self) -> u64 {
        self.mesh.reconnects
    }

    /// Fault-injection hook for the reconnect tests: shut down the mesh
    /// socket to cross-host rank `q` as a transient network failure
    /// would. The next exchange involving `q` detects the dead
    /// connection, reconnects, and replays — completing with identical
    /// iterates — or fails with the typed error past the deadline.
    pub fn drop_mesh_connection(&mut self, q: usize) {
        if let Some(rp) = self.mesh.remotes.get_mut(q).and_then(|r| r.as_mut()) {
            let _ = rp.stream.shutdown(Shutdown::Both);
        }
    }
}

impl HybridExchange {
    /// Report this iteration's metrics to the leader (the
    /// [`METRIC_COUNTERS`] `u64`s followed by the shard's owned θ rows),
    /// tagged with the iteration number. Unlike the pure TCP transport,
    /// the intra/inter columns carry the real placement split.
    pub fn send_metrics(&mut self, iter: u64, thetas: &[f64]) -> Result<(), TcpError> {
        self.body_scratch.clear();
        let counters: [u64; METRIC_COUNTERS] = [
            self.intra_cross + self.inter_cross,
            self.intra_floats + self.inter_floats,
            self.intra_cross,
            self.intra_floats,
            self.inter_cross,
            self.inter_floats,
            self.payload_bytes,
            self.header_bytes,
            self.stats.messages,
            self.stats.floats,
            self.stats.rounds,
            self.stats.allreduces,
            self.stats.skipped_rounds,
            self.stats.saved_messages,
            self.stats.saved_floats,
        ];
        put_u64s(&mut self.body_scratch, &counters);
        put_f64s(&mut self.body_scratch, thetas);
        write_frame(
            &mut self.leader,
            FrameKind::Metric,
            self.rank as u16,
            iter,
            &self.body_scratch,
            "leader",
        )
    }

    /// Ensure an exchange plan exists for `a` (graph-halo rule, identical
    /// to the in-process transport).
    fn ensure_plan(&mut self, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        for &u in &self.plan.owned {
            for kk in a.indptr[u]..a.indptr[u + 1] {
                assert!(
                    self.plan.covered[a.indices[kk]],
                    "operator support escapes the halo at row {u}: the partitioned \
                     transport only ships graph-support operators unless an overlay \
                     plan is registered (Exchange::register_plan)"
                );
            }
        }
        let plan = derive_exchange_plan("graph-support", a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    /// One plan-driven exchange round. Identical structure to
    /// `ShardExchange::exchange_round`, with each peer's leg picked by
    /// placement: co-located peers get the moved-`Vec` channel payload
    /// (arena-recycled, zero serialization), cross-host peers get one
    /// checksummed frame of raw `f64` bit patterns — and the ledger
    /// splits accordingly.
    fn exchange_round(
        &mut self,
        a: &Csr,
        fresh: Option<&[bool]>,
        compute: Option<&[bool]>,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) -> Result<(), TcpError> {
        let ln = self.plan.owned.len();
        assert_eq!(a.rows, self.n, "operator shape mismatch");
        assert_eq!(x.len(), ln * w, "payload shape mismatch");
        assert_eq!(out.len(), ln * w);
        if let Some(m) = fresh {
            assert_eq!(m.len(), self.n, "fresh mask must cover every global node");
        }
        if let Some(c) = compute {
            assert_eq!(c.len(), self.n, "compute mask must cover every global node");
        }
        self.ensure_plan(a);
        self.round += 1;
        let round = self.round;
        let mirror_reset = self.mirror.len() != self.n * w;
        if mirror_reset {
            self.mirror = vec![0.0; self.n * w];
        }
        let key = op_key(a);
        let xplan = &self.op_plans[&key];
        let live = |u: usize| fresh.is_none_or(|m| m[u]);

        // Same guard as the in-process transport: a fresh round right
        // after a mirror (re)allocation would read unseeded halo rows.
        if mirror_reset && fresh.is_some() {
            for (_, rows) in &xplan.recv {
                for &u in rows {
                    assert!(
                        live(u),
                        "fresh exchange after a mirror reset would read unseeded halo \
                         row {u}: issue a full exchange at this width first"
                    );
                }
            }
        }

        // 1. Ship the plan's (fresh) owned rows to each peer, routed by
        //    placement. Skip-empty is decided from the same global plan +
        //    mask on both endpoints, exactly as on the other transports.
        for (peer, rows) in &xplan.send {
            if self.mesh.co_located[*peer] {
                let mut buf = self.payload_pool.pop().unwrap_or_default();
                buf.clear();
                buf.reserve(rows.len() * w);
                let mut shipped = 0u64;
                for &u in rows {
                    if !live(u) {
                        continue;
                    }
                    let li = self.plan.local_of[u];
                    buf.extend_from_slice(&x[li * w..(li + 1) * w]);
                    shipped += 1;
                }
                if shipped == 0 {
                    if self.payload_pool.len() < PAYLOAD_POOL_CAP {
                        self.payload_pool.push(buf);
                    }
                    continue;
                }
                self.mesh.send_local(*peer, round, buf)?;
                self.intra_cross += shipped;
                self.intra_floats += shipped * w as u64;
            } else {
                self.body_scratch.clear();
                let mut shipped = 0u64;
                for &u in rows {
                    if !live(u) {
                        continue;
                    }
                    let li = self.plan.local_of[u];
                    put_f64s(&mut self.body_scratch, &x[li * w..(li + 1) * w]);
                    shipped += 1;
                }
                if shipped == 0 {
                    continue;
                }
                self.mesh.send_remote(*peer, round, &self.body_scratch)?;
                self.inter_cross += shipped;
                self.inter_floats += shipped * w as u64;
                self.payload_bytes += self.body_scratch.len() as u64;
                self.header_bytes += HEADER_BYTES;
            }
        }

        // 2. Refresh the mirror: owned rows from `x`, (fresh) halo rows
        //    from the peers — both legs land in the same reorder-buffered
        //    inbox, so the receive side is placement-agnostic.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            self.mirror[u * w..(u + 1) * w].copy_from_slice(&x[li * w..(li + 1) * w]);
        }
        for (peer, rows) in &xplan.recv {
            let expect: &[usize] = match fresh {
                None => rows,
                Some(_) => {
                    self.fresh_scratch.clear();
                    self.fresh_scratch.extend(rows.iter().copied().filter(|&u| live(u)));
                    &self.fresh_scratch
                }
            };
            if expect.is_empty() {
                continue;
            }
            let data = self.mesh.recv_round(*peer, round)?;
            if data.len() != expect.len() * w {
                return Err(TcpError::Protocol {
                    msg: format!(
                        "halo payload width drifted: rank {peer} round {round} sent {} floats, \
                         expected {}",
                        data.len(),
                        expect.len() * w
                    ),
                });
            }
            for (idx, &u) in expect.iter().enumerate() {
                self.mirror[u * w..(u + 1) * w].copy_from_slice(&data[idx * w..(idx + 1) * w]);
            }
            if self.payload_pool.len() < PAYLOAD_POOL_CAP && data.capacity() > 0 {
                self.payload_pool.push(data);
            }
        }

        // 3. Owned rows via the shared CSR row kernel — bit-for-bit equal
        //    to every other transport. A compute mask skips rows the
        //    caller will not read.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            if compute.is_none_or(|c| c[u]) {
                a.row_matvec_multi(u, &self.mirror, w, &mut out[li * w..(li + 1) * w]);
            }
        }
        self.stats.record_exchange(directed_messages, w);
        Ok(())
    }

    /// Sequence-tagged all-reduce through the leader connection,
    /// classified intra-host when this rank shares the leader's host
    /// (the frames then ride a loopback socket, which the inter-host
    /// byte ledger deliberately excludes).
    fn allreduce_impl(&mut self, locals: &[f64], w: usize) -> Result<Vec<f64>, TcpError> {
        assert_eq!(locals.len(), self.plan.owned.len() * w);
        self.red_seq += 1;
        self.body_scratch.clear();
        put_f64s(&mut self.body_scratch, locals);
        write_frame(
            &mut self.leader,
            FrameKind::ReduceUp,
            self.rank as u16,
            self.red_seq,
            &self.body_scratch,
            "leader",
        )?;
        let down = read_frame(&mut self.leader_reader, "leader")?;
        if down.kind != FrameKind::ReduceDown {
            return Err(TcpError::Protocol {
                msg: format!("expected an all-reduce total, got a {:?} frame", down.kind),
            });
        }
        if down.tag != self.red_seq {
            return Err(TcpError::Protocol {
                msg: format!(
                    "all-reduce sequence drifted: got total {} while at sequence {}",
                    down.tag, self.red_seq
                ),
            });
        }
        let total = bytes_to_f64s(&down.body, "leader reduce-down")?;
        if total.len() != w {
            return Err(TcpError::Protocol {
                msg: format!("all-reduce width drifted: got {} floats, expected {w}", total.len()),
            });
        }
        if self.k > 1 {
            if self.leader_is_local {
                self.intra_cross += 2;
                self.intra_floats += (locals.len() + w) as u64;
            } else {
                self.inter_cross += 2;
                self.inter_floats += (locals.len() + w) as u64;
                self.payload_bytes += ((locals.len() + w) * 8) as u64;
                self.header_bytes += 2 * HEADER_BYTES;
            }
        }
        self.stats.record_allreduce(self.n, w);
        Ok(total)
    }

    /// Surface an unrecoverable transport failure as a loud panic, same
    /// as every other transport (a deadlocked pool would be strictly
    /// worse). Transient socket failures never reach this — they are
    /// absorbed by reconnect-and-replay; what remains is protocol drift
    /// or a peer that stayed dead past the deadline.
    fn die(&self, err: TcpError) -> ! {
        // sddn-lint: allow(panic) reason=transport loss past the reconnect deadline is unrecoverable under the Exchange contract; dying loudly with the peer diagnosis beats deadlocking the pool
        panic!("hybrid transport rank {}: {err}", self.rank)
    }
}

impl Exchange for HybridExchange {
    fn n(&self) -> usize {
        self.n
    }

    fn owned(&self) -> &[usize] {
        &self.plan.owned
    }

    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) = self.exchange_round(a, None, None, directed_messages, x, w, out) {
            self.die(e)
        }
    }

    fn exchange_apply_fresh(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) = self.exchange_round(a, Some(fresh), None, directed_messages, x, w, out) {
            self.die(e)
        }
    }

    fn exchange_apply_fresh_rows(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        compute: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) =
            self.exchange_round(a, Some(fresh), Some(compute), directed_messages, x, w, out)
        {
            self.die(e)
        }
    }

    fn register_plan(&mut self, name: &str, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        let plan = derive_exchange_plan(name, a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        let lap = Arc::clone(&self.lap);
        let dm = 2 * self.m_edges as u64;
        // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph plus diagonal
        self.exchange_apply(&lap, dm, x, w, out);
    }

    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        match self.allreduce_impl(locals, w) {
            Ok(total) => total,
            Err(e) => self.die(e),
        }
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

impl Drop for HybridExchange {
    /// Shut down every socket so blocked reader threads (ours and the
    /// peers') observe the close instead of waiting out their timeouts.
    fn drop(&mut self) {
        for rp in self.mesh.remotes.iter().flatten() {
            let _ = rp.stream.shutdown(Shutdown::Both);
        }
        let _ = self.leader.shutdown(Shutdown::Both);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_hostfile_assigns_ranks_in_file_order() {
        let text = "alpha slots=2   # ranks 0,1\nbeta\n\n# a comment line\ngamma slots=1\nalpha\n";
        let p = parse_hostfile(text).unwrap();
        assert_eq!(p.k(), 5);
        assert_eq!(
            (0..5).map(|r| p.host(r)).collect::<Vec<_>>(),
            ["alpha", "alpha", "beta", "gamma", "alpha"]
        );
        assert_eq!(p.hosts(), ["alpha", "beta", "gamma"]);
        assert_eq!(p.ranks_on("alpha"), [0, 1, 4]);
        assert_eq!(p.ranks_on("beta"), [2]);
        assert!(p.ranks_on("nowhere").is_empty());
        assert!(p.same_host(0, 1));
        assert!(p.same_host(0, 4));
        assert!(!p.same_host(1, 2));
        assert!(p.same_host(2, 2), "a rank shares a host with itself");
        assert_eq!(p.leader_host(), "alpha");
    }

    #[test]
    fn parse_hostfile_rejects_malformed_input() {
        for (text, needle) in [
            ("", "no ranks"),
            ("# only comments\n\n", "no ranks"),
            ("a slots=0", "slots=0"),
            ("a slots=many", "bad slot count"),
            ("a b", "unknown token"),
        ] {
            let err = parse_hostfile(text).unwrap_err();
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn local_links_wire_only_co_located_ranks() {
        let p = parse_hostfile("h0 slots=2\nh1 slots=2\n").unwrap();
        let links = local_links(&p, "h0");
        assert_eq!(links.len(), 2);
        assert_eq!(links[0].rank(), 0);
        assert_eq!(links[1].rank(), 1);
        for link in &links {
            assert_eq!(link.peer_txs.len(), 4);
            assert!(link.peer_txs[link.rank].is_none(), "no self channel");
            assert!(link.peer_txs[2].is_none(), "no channel to another host");
            assert!(link.peer_txs[3].is_none(), "no channel to another host");
        }
        // Rank 0's sender toward rank 1 feeds rank 1's inbox.
        links[0].peer_txs[1]
            .as_ref()
            .unwrap()
            .send(HybridMsg::Local { src: 0, round: 7, vals: vec![1.5, -2.5] })
            .unwrap();
        match links[1].inbox.recv_timeout(Duration::from_secs(1)).unwrap() {
            HybridMsg::Local { src, round, vals } => {
                assert_eq!((src, round), (0, 7));
                assert_eq!(vals, [1.5, -2.5]);
            }
            _ => panic!("expected the channel payload"),
        }
        assert!(local_links(&p, "nowhere").is_empty());
    }

    /// A mesh with no live remote connections, for driving `recv_round`
    /// through hand-injected inbox messages.
    fn bare_mesh(k: usize, rank: usize, co_located: Vec<bool>) -> Mesh {
        let (tx, rx) = channel();
        Mesh {
            rank,
            k,
            listener: TcpListener::bind("127.0.0.1:0").unwrap(),
            remotes: (0..k).map(|_| None).collect(),
            inbox: rx,
            inbox_tx: tx,
            local_txs: vec![None; k],
            co_located,
            pending: HashMap::new(),
            consumed: vec![0; k],
            reconnects: 0,
            timeout: Duration::from_millis(200),
            retries: 0,
            backoff: Duration::from_millis(1),
        }
    }

    #[test]
    fn recv_round_drops_replayed_socket_duplicates() {
        let mut mesh = bare_mesh(2, 0, vec![false, false]);
        let tx = mesh.inbox_tx.clone();
        tx.send(HybridMsg::Remote { src: 1, round: 1, vals: vec![1.0] }).unwrap();
        tx.send(HybridMsg::Remote { src: 1, round: 1, vals: vec![-1.0] }).unwrap();
        tx.send(HybridMsg::Remote { src: 1, round: 2, vals: vec![2.0] }).unwrap();
        assert_eq!(mesh.recv_round(1, 1).unwrap(), [1.0], "first copy wins");
        // The round-1 duplicate is behind the consumed watermark now and
        // must be skipped on the way to round 2.
        assert_eq!(mesh.recv_round(1, 2).unwrap(), [2.0]);
        // A late replay of a consumed round is dropped, not parked.
        tx.send(HybridMsg::Remote { src: 1, round: 1, vals: vec![9.0] }).unwrap();
        tx.send(HybridMsg::Remote { src: 1, round: 3, vals: vec![3.0] }).unwrap();
        assert_eq!(mesh.recv_round(1, 3).unwrap(), [3.0]);
        assert!(mesh.pending.is_empty(), "stale replays must not accumulate");
    }

    #[test]
    fn recv_round_rejects_duplicate_channel_payloads() {
        let mut mesh = bare_mesh(2, 0, vec![false, true]);
        let tx = mesh.inbox_tx.clone();
        // Channels cannot legitimately duplicate — two copies of the same
        // (sender, round) is a wiring bug, not a replay.
        tx.send(HybridMsg::Local { src: 1, round: 5, vals: vec![1.0] }).unwrap();
        tx.send(HybridMsg::Local { src: 1, round: 5, vals: vec![1.0] }).unwrap();
        match mesh.recv_round(1, 6) {
            Err(TcpError::Protocol { msg }) => assert!(msg.contains("duplicate"), "{msg}"),
            other => panic!("expected Protocol, got {other:?}"),
        }
    }

    #[test]
    fn recv_round_times_out_with_the_typed_error() {
        let mut mesh = bare_mesh(2, 0, vec![false, false]);
        let start = Instant::now();
        match mesh.recv_round(1, 4) {
            Err(TcpError::Timeout { who, waiting_for }) => {
                assert_eq!(who, "rank 1");
                assert!(waiting_for.contains("round-4"), "{waiting_for}");
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert!(start.elapsed() >= Duration::from_millis(150), "must wait out the window");
    }

    #[test]
    fn stale_generation_notices_do_not_mark_a_replaced_connection_down() {
        let mut mesh = bare_mesh(2, 0, vec![false, false]);
        // Fake a live generation-2 connection using a loopback socket.
        let hold = TcpListener::bind("127.0.0.1:0").unwrap();
        let s = TcpStream::connect(hold.local_addr().unwrap()).unwrap();
        mesh.remotes[1] = Some(RemotePeer {
            stream: s,
            addr: "127.0.0.1:1".to_string(),
            generation: 2,
            up: true,
            replay: VecDeque::new(),
        });
        mesh.note_down(1, 1); // notice from the replaced generation-1 reader
        assert!(mesh.remotes[1].as_ref().unwrap().up, "stale notice must be ignored");
        mesh.note_down(1, 2);
        assert!(!mesh.remotes[1].as_ref().unwrap().up, "current notice must mark down");
    }
}
