//! Length-prefixed binary framing for the TCP transport.
//!
//! One frame is a fixed 16-byte header followed by `len` body bytes:
//!
//! ```text
//! [len: u32 LE][kind: u8][reserved: u8 = 0][src: u16 LE][tag: u32 LE][crc32: u32 LE]
//! ```
//!
//! There is no serde: payload bodies are raw `f64` bit patterns in
//! little-endian order (the sender's plan order — the same self-framing
//! contract the in-process `ShardExchange` payloads use, see
//! [`super::super::partitioned`]), control bodies are `u64` counters or
//! UTF-8 address strings. `tag` carries the exchange round / reduce
//! sequence / iteration number (bounded to `u32` on the wire — round
//! counters never approach 2³²; the writer rejects larger tags with a
//! typed error instead of silently wrapping), `src` the sender's rank.
//!
//! The trailing `crc32` field is a CRC-32/IEEE checksum over the first 12
//! header bytes followed by the body. Every frame is checksummed on write
//! and verified on read — a mismatch surfaces as [`TcpError::Corrupt`]
//! instead of letting a flipped bit silently perturb an iterate. (The
//! length prefix is covered by the checksum but must be trusted *before*
//! verification to know how many body bytes to read; the independent
//! [`MAX_BODY_BYTES`] cap bounds the damage a corrupted length can do.)
//!
//! Everything here is pure `Read`/`Write` plumbing so the codec is
//! testable against in-memory cursors; socket-specific robustness
//! (connect retry, read timeouts, reconnect) lives in [`super`] and
//! [`crate::net::hybrid`].

use std::fmt;
use std::io::{Read, Write};
use std::time::Duration;

/// Fixed per-frame header overhead in bytes. Wire-truth accounting keeps
/// header bytes separate from payload bytes: payload bytes equal
/// `cross_floats × 8` exactly, headers add `HEADER_BYTES` per data frame.
pub const HEADER_BYTES: u64 = 16;

/// Upper bound on a frame body (256 MiB). A length prefix beyond this is
/// rejected *before* allocating, so a corrupt or hostile peer cannot ask
/// the receiver to reserve gigabytes.
pub const MAX_BODY_BYTES: u32 = 1 << 28;

/// CRC-32/IEEE lookup table (reflected polynomial `0xEDB88320`), built at
/// compile time — the crate stays dependency-free.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32/IEEE over a sequence of byte chunks (checksummed as if they
/// were one contiguous buffer — lets the frame codec cover header and
/// body without concatenating them).
pub fn crc32(chunks: &[&[u8]]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for chunk in chunks {
        for &b in *chunk {
            c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
    }
    !c
}

/// Frame discriminant (byte 4 of the header).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Worker → leader (or worker → worker) handshake; body is the
    /// sender's advertised listener address (UTF-8) or empty.
    Hello,
    /// Leader → worker rendezvous answer: `\n`-joined listener addresses
    /// in rank order (each line optionally `ADDR\tHOST` when the leader
    /// knows the deployment placement — see `net::hybrid`).
    PeerTable,
    /// Worker → worker boundary payload for exchange round `tag`.
    Payload,
    /// Worker → leader all-reduce contribution for sequence `tag`.
    ReduceUp,
    /// Leader → worker all-reduce total for sequence `tag`.
    ReduceDown,
    /// Worker → leader per-iteration metrics snapshot for iteration `tag`.
    Metric,
}

impl FrameKind {
    /// Wire byte for this kind.
    pub fn to_byte(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::PeerTable => 2,
            FrameKind::Payload => 3,
            FrameKind::ReduceUp => 4,
            FrameKind::ReduceDown => 5,
            FrameKind::Metric => 6,
        }
    }

    /// Parse a wire byte; unknown bytes are a framing error.
    pub fn from_byte(b: u8) -> Result<FrameKind, TcpError> {
        match b {
            1 => Ok(FrameKind::Hello),
            2 => Ok(FrameKind::PeerTable),
            3 => Ok(FrameKind::Payload),
            4 => Ok(FrameKind::ReduceUp),
            5 => Ok(FrameKind::ReduceDown),
            6 => Ok(FrameKind::Metric),
            other => Err(TcpError::BadFrame { msg: format!("unknown frame kind byte {other}") }),
        }
    }
}

/// A decoded frame.
#[derive(Debug)]
pub struct Frame {
    /// What the body means.
    pub kind: FrameKind,
    /// Sender rank.
    pub src: u16,
    /// Round / sequence / iteration tag.
    pub tag: u64,
    /// Raw body bytes.
    pub body: Vec<u8>,
}

/// Typed errors of the TCP transport — the socket layer never panics;
/// failures surface as one of these so callers can report *which* peer
/// died or timed out instead of hanging.
#[derive(Debug)]
pub enum TcpError {
    /// An OS-level socket failure, with the operation it interrupted.
    Io {
        /// What the transport was doing (e.g. `"connect 127.0.0.1:4000"`).
        ctx: String,
        /// The underlying error.
        err: std::io::Error,
    },
    /// The peer closed the connection cleanly between frames.
    PeerClosed {
        /// Which connection closed.
        who: String,
    },
    /// A read waited longer than the configured timeout.
    Timeout {
        /// Which connection timed out.
        who: String,
        /// What the transport was waiting for.
        waiting_for: String,
    },
    /// A length prefix exceeded [`MAX_BODY_BYTES`].
    OversizedFrame {
        /// The advertised body length.
        len: u64,
        /// The enforced maximum.
        max: u32,
    },
    /// A malformed frame (truncated mid-frame, bad kind byte, payload
    /// length not a multiple of 8, …).
    BadFrame {
        /// Diagnostic.
        msg: String,
    },
    /// A frame whose CRC-32 checksum did not match its received bytes —
    /// the wire flipped a bit somewhere between sender and receiver.
    Corrupt {
        /// Which connection delivered the corrupt frame.
        who: String,
        /// Checksum the sender stored in the header.
        stored: u32,
        /// Checksum recomputed over the received header and body.
        computed: u32,
    },
    /// A well-formed frame that violates the rendezvous or BSP protocol
    /// (wrong kind, duplicate rank, sequence drift, …).
    Protocol {
        /// Diagnostic.
        msg: String,
    },
}

impl fmt::Display for TcpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TcpError::Io { ctx, err } => write!(f, "io error during {ctx}: {err}"),
            TcpError::PeerClosed { who } => {
                write!(f, "peer worker died: {who} closed the connection")
            }
            TcpError::Timeout { who, waiting_for } => {
                write!(f, "timed out waiting for {waiting_for} from {who}")
            }
            TcpError::OversizedFrame { len, max } => {
                write!(
                    f,
                    "oversized frame: advertised body of {len} bytes exceeds the {max}-byte cap"
                )
            }
            TcpError::BadFrame { msg } => write!(f, "bad frame: {msg}"),
            TcpError::Corrupt { who, stored, computed } => {
                write!(
                    f,
                    "corrupt frame from {who}: header checksum {stored:#010x} \
                     but received bytes checksum to {computed:#010x}"
                )
            }
            TcpError::Protocol { msg } => write!(f, "protocol violation: {msg}"),
        }
    }
}

impl std::error::Error for TcpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TcpError::Io { err, .. } => Some(err),
            _ => None,
        }
    }
}

fn map_read_err(err: std::io::Error, ctx: &str) -> TcpError {
    match err.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            TcpError::Timeout { who: ctx.to_string(), waiting_for: "a frame".to_string() }
        }
        std::io::ErrorKind::UnexpectedEof => TcpError::BadFrame {
            msg: format!("{ctx}: connection cut mid-frame (truncated header or body)"),
        },
        _ => TcpError::Io { ctx: format!("read from {ctx}"), err },
    }
}

/// Encode one frame's header for `body`. Fails (typed, before anything
/// hits the wire) on bodies beyond [`MAX_BODY_BYTES`] and tags beyond the
/// `u32` wire field.
fn encode_header(
    kind: FrameKind,
    src: u16,
    tag: u64,
    body: &[u8],
) -> Result<[u8; HEADER_BYTES as usize], TcpError> {
    if body.len() > MAX_BODY_BYTES as usize {
        return Err(TcpError::OversizedFrame { len: body.len() as u64, max: MAX_BODY_BYTES });
    }
    if tag > u32::MAX as u64 {
        return Err(TcpError::Protocol {
            msg: format!("frame tag {tag} exceeds the u32 wire field"),
        });
    }
    let mut head = [0u8; HEADER_BYTES as usize];
    head[0..4].copy_from_slice(&(body.len() as u32).to_le_bytes());
    head[4] = kind.to_byte();
    head[5] = 0;
    head[6..8].copy_from_slice(&src.to_le_bytes());
    head[8..12].copy_from_slice(&(tag as u32).to_le_bytes());
    let crc = crc32(&[&head[0..12], body]);
    head[12..16].copy_from_slice(&crc.to_le_bytes());
    Ok(head)
}

/// Write one frame. Rejects bodies beyond [`MAX_BODY_BYTES`] (and tags
/// beyond the `u32` wire field) before touching the socket, and stamps
/// the CRC-32 checksum into the header.
pub fn write_frame(
    w: &mut impl Write,
    kind: FrameKind,
    src: u16,
    tag: u64,
    body: &[u8],
    ctx: &str,
) -> Result<(), TcpError> {
    let head = encode_header(kind, src, tag, body)?;
    let io = |err| TcpError::Io { ctx: format!("write to {ctx}"), err };
    w.write_all(&head).map_err(io)?;
    w.write_all(body).map_err(io)?;
    w.flush().map_err(io)?;
    Ok(())
}

/// Read one frame. A clean EOF *between* frames maps to
/// [`TcpError::PeerClosed`]; an EOF *inside* a frame is a
/// [`TcpError::BadFrame`]; a read timeout maps to [`TcpError::Timeout`];
/// an advertised body beyond [`MAX_BODY_BYTES`] is rejected before any
/// allocation; a checksum mismatch is [`TcpError::Corrupt`] (verified
/// before the kind byte is interpreted, so corruption anywhere in the
/// frame reports as corruption, not as a protocol error).
pub fn read_frame(r: &mut impl Read, ctx: &str) -> Result<Frame, TcpError> {
    let mut head = [0u8; HEADER_BYTES as usize];
    // First byte via plain read: Ok(0) is the peer closing cleanly
    // between frames, which read_exact would misreport as truncation.
    let got = r.read(&mut head[..1]).map_err(|err| map_read_err(err, ctx))?;
    if got == 0 {
        return Err(TcpError::PeerClosed { who: ctx.to_string() });
    }
    r.read_exact(&mut head[1..]).map_err(|err| map_read_err(err, ctx))?;
    let mut b4 = [0u8; 4];
    b4.copy_from_slice(&head[0..4]);
    let len = u32::from_le_bytes(b4);
    if len > MAX_BODY_BYTES {
        return Err(TcpError::OversizedFrame { len: len as u64, max: MAX_BODY_BYTES });
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).map_err(|err| map_read_err(err, ctx))?;
    b4.copy_from_slice(&head[12..16]);
    let stored = u32::from_le_bytes(b4);
    let computed = crc32(&[&head[0..12], &body]);
    if stored != computed {
        return Err(TcpError::Corrupt { who: ctx.to_string(), stored, computed });
    }
    let kind = FrameKind::from_byte(head[4])?;
    let mut b2 = [0u8; 2];
    b2.copy_from_slice(&head[6..8]);
    let src = u16::from_le_bytes(b2);
    b4.copy_from_slice(&head[8..12]);
    let tag = u32::from_le_bytes(b4) as u64;
    Ok(Frame { kind, src, tag, body })
}

/// Append `vals` to `body` as little-endian IEEE-754 bit patterns — the
/// bit-exact encoding that keeps TCP iterates identical to the in-process
/// transports.
pub fn put_f64s(body: &mut Vec<u8>, vals: &[f64]) {
    for v in vals {
        body.extend_from_slice(&v.to_bits().to_le_bytes());
    }
}

/// Decode a body of little-endian `f64` bit patterns.
pub fn bytes_to_f64s(body: &[u8], ctx: &str) -> Result<Vec<f64>, TcpError> {
    if body.len() % 8 != 0 {
        return Err(TcpError::BadFrame {
            msg: format!("{ctx}: payload length {} is not a multiple of 8", body.len()),
        });
    }
    let mut out = Vec::with_capacity(body.len() / 8);
    let mut b8 = [0u8; 8];
    for c in body.chunks_exact(8) {
        b8.copy_from_slice(c);
        out.push(f64::from_bits(u64::from_le_bytes(b8)));
    }
    Ok(out)
}

/// Append `vals` to `body` as little-endian `u64`s (metric counters).
pub fn put_u64s(body: &mut Vec<u8>, vals: &[u64]) {
    for v in vals {
        body.extend_from_slice(&v.to_le_bytes());
    }
}

/// Decode a body prefix of `count` little-endian `u64`s; returns the
/// values and the remaining body tail.
pub fn split_u64s<'b>(
    body: &'b [u8],
    count: usize,
    ctx: &str,
) -> Result<(Vec<u64>, &'b [u8]), TcpError> {
    if body.len() < count * 8 {
        return Err(TcpError::BadFrame {
            msg: format!(
                "{ctx}: body of {} bytes is too short for {count} u64 counters",
                body.len()
            ),
        });
    }
    let mut out = Vec::with_capacity(count);
    let mut b8 = [0u8; 8];
    for c in body[..count * 8].chunks_exact(8) {
        b8.copy_from_slice(c);
        out.push(u64::from_le_bytes(b8));
    }
    Ok((out, &body[count * 8..]))
}

fn env_u64(name: &str, default: u64) -> u64 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Read timeout / rendezvous deadline: `SDDN_TCP_TIMEOUT_MS` (default
/// 30 000 ms).
pub fn default_timeout() -> Duration {
    Duration::from_millis(env_u64("SDDN_TCP_TIMEOUT_MS", 30_000))
}

/// Connect *re*-dial count before giving up: `SDDN_TCP_RETRIES` (default
/// 40) — workers dial the leader and each other with linear backoff while
/// the processes race through startup, and the hybrid transport reuses
/// the same knob for mesh reconnects. `0` still means one connect
/// attempt (no re-dials); values beyond `u32::MAX` saturate instead of
/// truncating.
pub fn default_retries() -> u32 {
    parse_retries(std::env::var("SDDN_TCP_RETRIES").ok().as_deref())
}

/// Pure parser behind [`default_retries`], separated so the edge cases
/// (`"0"`, values beyond `u32::MAX`) are testable without racing other
/// tests on process-global environment variables.
pub(crate) fn parse_retries(var: Option<&str>) -> u32 {
    match var.and_then(|s| s.trim().parse::<u128>().ok()) {
        Some(v) => u32::try_from(v).unwrap_or(u32::MAX),
        None => 40,
    }
}

/// Base backoff between connect retries: `SDDN_TCP_RETRY_MS` (default
/// 50 ms); attempt `i` sleeps `i × base`.
pub fn default_retry_backoff() -> Duration {
    Duration::from_millis(env_u64("SDDN_TCP_RETRY_MS", 50))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(kind: FrameKind, src: u16, tag: u64, body: &[u8]) -> Frame {
        let mut wire = Vec::new();
        write_frame(&mut wire, kind, src, tag, body, "test").unwrap();
        assert_eq!(wire.len() as u64, HEADER_BYTES + body.len() as u64);
        let mut cur = Cursor::new(wire);
        let f = read_frame(&mut cur, "test").unwrap();
        assert_eq!(cur.position() as usize, cur.get_ref().len(), "trailing bytes");
        f
    }

    /// A hand-crafted header with a valid checksum (for tests that probe
    /// parse errors past the CRC gate).
    fn checksummed_header(mutate: impl Fn(&mut [u8; 16])) -> Vec<u8> {
        let mut head = [0u8; HEADER_BYTES as usize];
        head[4] = FrameKind::Payload.to_byte();
        mutate(&mut head);
        let crc = crc32(&[&head[0..12], &[]]);
        head[12..16].copy_from_slice(&crc.to_le_bytes());
        head.to_vec()
    }

    #[test]
    fn frames_roundtrip_all_kinds() {
        for (i, kind) in [
            FrameKind::Hello,
            FrameKind::PeerTable,
            FrameKind::Payload,
            FrameKind::ReduceUp,
            FrameKind::ReduceDown,
            FrameKind::Metric,
        ]
        .into_iter()
        .enumerate()
        {
            let body: Vec<u8> = (0..=i as u8).collect();
            let f = roundtrip(kind, i as u16, 0xDEAD_BEEF + i as u64, &body);
            assert_eq!(f.kind, kind);
            assert_eq!(f.src, i as u16);
            assert_eq!(f.tag, 0xDEAD_BEEF + i as u64);
            assert_eq!(f.body, body);
        }
    }

    #[test]
    fn crc32_matches_reference_vectors() {
        // CRC-32/IEEE check values: the canonical "123456789" vector and
        // a couple of fixed points, plus chunking invariance.
        assert_eq!(crc32(&[b"123456789"]), 0xCBF4_3926);
        assert_eq!(crc32(&[b""]), 0);
        assert_eq!(crc32(&[b"12345", b"6789"]), crc32(&[b"123456789"]));
    }

    #[test]
    fn corrupted_body_byte_is_detected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Payload, 3, 7, &[0xABu8; 24], "test").unwrap();
        let at = HEADER_BYTES as usize + 5;
        wire[at] ^= 0x10; // single flipped bit in the body
        let mut cur = Cursor::new(wire);
        match read_frame(&mut cur, "peer 3") {
            Err(TcpError::Corrupt { who, stored, computed }) => {
                assert_eq!(who, "peer 3");
                assert_ne!(stored, computed);
            }
            other => panic!("expected Corrupt, got {other:?}"),
        }
    }

    #[test]
    fn corrupted_header_byte_is_detected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::ReduceUp, 1, 9, &[1u8, 2, 3, 4, 5, 6, 7, 8], "test")
            .unwrap();
        wire[6] ^= 0x01; // src field: would silently misroute without the CRC
        let mut cur = Cursor::new(wire);
        assert!(matches!(read_frame(&mut cur, "peer"), Err(TcpError::Corrupt { .. })));
    }

    #[test]
    fn tag_beyond_u32_is_rejected_before_writing() {
        let mut sink = Vec::new();
        match write_frame(&mut sink, FrameKind::Payload, 0, u32::MAX as u64 + 1, &[], "test") {
            Err(TcpError::Protocol { msg }) => assert!(msg.contains("u32"), "{msg}"),
            other => panic!("expected Protocol, got {other:?}"),
        }
        assert!(sink.is_empty(), "nothing may hit the wire after a tag rejection");
        // The largest representable tag still roundtrips.
        let f = roundtrip(FrameKind::Payload, 0, u32::MAX as u64, &[]);
        assert_eq!(f.tag, u32::MAX as u64);
    }

    #[test]
    fn f64_payloads_are_bit_exact() {
        let vals = [0.0, -0.0, 1.5, f64::NAN, f64::INFINITY, f64::MIN_POSITIVE, -3.25e300];
        let mut body = Vec::new();
        put_f64s(&mut body, &vals);
        assert_eq!(body.len(), vals.len() * 8);
        let back = bytes_to_f64s(&body, "test").unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&back), bits(&vals));
    }

    #[test]
    fn u64_counters_roundtrip() {
        let vals = [0u64, 1, u64::MAX, 42];
        let mut body = Vec::new();
        put_u64s(&mut body, &vals);
        put_f64s(&mut body, &[2.5]);
        let (back, tail) = split_u64s(&body, 4, "test").unwrap();
        assert_eq!(back, vals);
        assert_eq!(bytes_to_f64s(tail, "test").unwrap(), vec![2.5]);
        assert!(split_u64s(&body, 6, "test").is_err());
    }

    #[test]
    fn clean_eof_is_peer_closed() {
        let mut cur = Cursor::new(Vec::<u8>::new());
        match read_frame(&mut cur, "peer 3") {
            Err(TcpError::PeerClosed { who }) => assert_eq!(who, "peer 3"),
            other => panic!("expected PeerClosed, got {other:?}"),
        }
    }

    #[test]
    fn truncated_header_is_bad_frame() {
        // 5 of 16 header bytes, then EOF: a torn frame, not a clean close.
        let mut cur = Cursor::new(vec![1u8, 0, 0, 0, 3]);
        match read_frame(&mut cur, "peer") {
            Err(TcpError::BadFrame { .. }) => {}
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn truncated_body_is_bad_frame() {
        let mut wire = Vec::new();
        write_frame(&mut wire, FrameKind::Payload, 0, 7, &[9u8; 24], "test").unwrap();
        wire.truncate(wire.len() - 10);
        let mut cur = Cursor::new(wire);
        match read_frame(&mut cur, "peer") {
            Err(TcpError::BadFrame { .. }) => {}
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn oversized_length_is_rejected_before_allocating() {
        // Hand-craft a header advertising a 1 GiB body. The length gate
        // runs before the body read (and hence before CRC verification),
        // so no checksum is needed to trip it.
        let mut head = [0u8; HEADER_BYTES as usize];
        head[0..4].copy_from_slice(&(1u32 << 30).to_le_bytes());
        head[4] = FrameKind::Payload.to_byte();
        let mut cur = Cursor::new(head.to_vec());
        match read_frame(&mut cur, "peer") {
            Err(TcpError::OversizedFrame { len, max }) => {
                assert_eq!(len, 1u64 << 30);
                assert_eq!(max, MAX_BODY_BYTES);
            }
            other => panic!("expected OversizedFrame, got {other:?}"),
        }
        // The writer enforces the same cap.
        let big = vec![0u8; MAX_BODY_BYTES as usize + 1];
        let mut sink = Vec::new();
        assert!(matches!(
            write_frame(&mut sink, FrameKind::Payload, 0, 0, &big, "test"),
            Err(TcpError::OversizedFrame { .. })
        ));
        assert!(sink.is_empty(), "nothing may hit the wire after a cap rejection");
    }

    #[test]
    fn unknown_kind_byte_is_bad_frame() {
        // Correctly checksummed frame with an unknown kind byte: the CRC
        // gate passes, the kind parse rejects.
        let wire = checksummed_header(|head| head[4] = 99);
        let mut cur = Cursor::new(wire);
        assert!(matches!(read_frame(&mut cur, "peer"), Err(TcpError::BadFrame { .. })));
    }

    #[test]
    fn unchecksummed_header_is_corrupt() {
        // A 16-byte header with a zeroed crc field (what a pre-checksum
        // sender would emit) must be rejected, not silently accepted.
        let mut head = [0u8; HEADER_BYTES as usize];
        head[4] = FrameKind::Hello.to_byte();
        let mut cur = Cursor::new(head.to_vec());
        assert!(matches!(read_frame(&mut cur, "peer"), Err(TcpError::Corrupt { .. })));
    }

    #[test]
    fn non_multiple_of_8_payload_is_bad_frame() {
        assert!(matches!(bytes_to_f64s(&[0u8; 12], "test"), Err(TcpError::BadFrame { .. })));
    }

    #[test]
    fn retries_zero_means_zero_redials() {
        assert_eq!(parse_retries(Some("0")), 0);
    }

    #[test]
    fn retries_beyond_u32_saturate() {
        // 2^32 used to truncate to 0 via `as u32`, silently turning "retry
        // practically forever" into "never retry".
        assert_eq!(parse_retries(Some("4294967296")), u32::MAX);
        assert_eq!(parse_retries(Some(&u128::MAX.to_string())), u32::MAX);
        assert_eq!(parse_retries(Some("4294967295")), u32::MAX);
    }

    #[test]
    fn retries_default_and_garbage() {
        assert_eq!(parse_retries(None), 40);
        assert_eq!(parse_retries(Some("not-a-number")), 40);
        assert_eq!(parse_retries(Some(" 7 ")), 7);
    }
}
