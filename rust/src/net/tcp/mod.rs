//! TCP process transport: the third [`Exchange`](super::Exchange)
//! implementation, running the `k` workers as separate OS *processes*
//! over sockets — the paper's actual deployment shape (a MatlabMPI pool
//! of machine-separated workers), where the in-process transports only
//! simulate it.
//!
//! The wire protocol is deliberately identical in shape to the
//! [`ShardExchange`](super::partitioned::ShardExchange) channel payloads:
//! plan-driven shipping means sender and receiver derive the same
//! [`ExchangePlan`] from the same global CSR + owner map, so a boundary
//! payload needs no per-row framing — just the round tag and the raw
//! `f64` bit patterns in plan order ([`frame`]). All-reduces ride the
//! leader connection (`ReduceUp`/`ReduceDown`, sequence-tagged), and the
//! leader re-uses the in-process
//! [`run_reducer`](super::partitioned::run_reducer) verbatim, so reduce
//! totals are summed in the identical global node order — the TCP path is
//! bit-for-bit identical to both in-process transports.
//!
//! Robustness the threaded transport never needed lives here: connect
//! retry with linear backoff (workers race through process startup),
//! read timeouts on every rendezvous step and on the reorder-buffered
//! payload inbox, and typed [`TcpError`]s — a dead peer surfaces as
//! `peer worker died`, never a hang.
//!
//! Wire truth extends to real bytes: [`TcpExchange::payload_bytes`] is
//! exactly `cross_floats × 8` (asserted in `tests/tcp_wire.rs`), and
//! header overhead is accounted separately as
//! [`HEADER_BYTES`](frame::HEADER_BYTES) per data frame
//! ([`TcpExchange::header_bytes`]). Control-plane frames (rendezvous,
//! metrics) are not charged — they are the leader's bookkeeping, not the
//! algorithm's communication.
//!
//! Rank bootstrap (leader side in [`crate::coordinator::tcp`]):
//!
//! 1. every worker dials the leader (with retry), binds its own
//!    ephemeral listener, and sends `Hello(rank, listener addr)`;
//! 2. the leader answers every worker with the `PeerTable` (all listener
//!    addresses in rank order) once all `k` Hellos arrived;
//! 3. worker `r` dials every `q < r` (sending `Hello(r)` on the data
//!    connection) and accepts one connection from every `q > r` — a full
//!    mesh with one socket per unordered pair, each read end pumped by a
//!    reader thread into the round-tagged reorder buffer.

pub mod frame;

use self::frame::{
    bytes_to_f64s, put_f64s, put_u64s, read_frame, write_frame, Frame, FrameKind, TcpError,
    HEADER_BYTES,
};
use super::partitioned::{derive_exchange_plan, op_key, ExchangePlan, OpKey, ShardPlan};
use super::{CommStats, Exchange};
use crate::linalg::Csr;
use std::collections::HashMap;
use std::io::BufReader;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Number of `u64` counters leading a [`FrameKind::Metric`] body, ahead
/// of the owned θ rows: `[cross, cross_floats, intra_cross, intra_floats,
/// inter_cross, inter_floats, payload_bytes, header_bytes, messages,
/// floats, rounds, allreduces, skipped_rounds, saved_messages,
/// saved_floats]`. The intra/inter columns split the cross totals by
/// host placement (identical to the totals on the pure TCP transport,
/// which treats every rank as remote); the trailing three columns carry
/// the modeled savings of rounds a staleness/local-steps policy elided.
pub const METRIC_COUNTERS: usize = 15;

/// How a worker process finds and talks to the rest of the pool.
#[derive(Debug, Clone)]
pub struct WorkerNetConfig {
    /// This worker's rank in `0..k`.
    pub rank: usize,
    /// Pool size.
    pub k: usize,
    /// The leader's rendezvous address (`host:port`).
    pub leader_addr: String,
    /// Read timeout / rendezvous deadline.
    pub timeout: Duration,
    /// Connect retry attempts.
    pub retries: u32,
    /// Base backoff between connect retries (attempt `i` sleeps `i ×` this).
    pub backoff: Duration,
}

impl WorkerNetConfig {
    /// Config from the `SDDN_TCP_*` environment knobs (falling back to
    /// the built-in defaults).
    pub fn from_env(rank: usize, k: usize, leader_addr: &str) -> WorkerNetConfig {
        WorkerNetConfig {
            rank,
            k,
            leader_addr: leader_addr.to_string(),
            timeout: frame::default_timeout(),
            retries: frame::default_retries(),
            backoff: frame::default_retry_backoff(),
        }
    }
}

/// What a peer reader thread forwards into the exchange inbox.
enum InboxMsg {
    /// A round-tagged boundary payload, already decoded to floats.
    Payload { src: usize, round: u64, vals: Vec<f64> },
    /// The peer closed its connection cleanly (it finished its run).
    Closed { src: usize },
    /// The peer connection failed.
    Failed { src: usize, err: TcpError },
}

/// Dial `addr` with linear-backoff retry — worker processes race through
/// startup, so the first attempts may find nobody listening yet. With
/// `retries = 0` exactly one connect attempt is made (the knob counts
/// *re*-dials, not attempts). Shared with the hybrid transport, which
/// also reuses it to redial a dropped mesh connection.
pub(crate) fn connect_with_retry(
    addr: &str,
    retries: u32,
    backoff: Duration,
) -> Result<TcpStream, TcpError> {
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(err) => {
                attempt += 1;
                if attempt > retries {
                    return Err(TcpError::Io {
                        ctx: format!("connect {addr} (gave up after {attempt} attempts)"),
                        err,
                    });
                }
                std::thread::sleep(backoff * attempt);
            }
        }
    }
}

/// Accept one connection, polling a nonblocking listener so a missing
/// peer surfaces as [`TcpError::Timeout`] instead of a hang. Shared with
/// the hybrid transport (mesh bootstrap and reconnect re-accept).
pub(crate) fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
) -> Result<TcpStream, TcpError> {
    let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };
    listener.set_nonblocking(true).map_err(|e| io("listener set_nonblocking", e))?;
    loop {
        match listener.accept() {
            Ok((s, _)) => {
                listener.set_nonblocking(false).map_err(|e| io("listener set_blocking", e))?;
                s.set_nonblocking(false).map_err(|e| io("accepted socket set_blocking", e))?;
                return Ok(s);
            }
            Err(err) if err.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    return Err(TcpError::Timeout {
                        who: "mesh listener".to_string(),
                        waiting_for: "a peer data connection".to_string(),
                    });
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(err) => return Err(io("accept", err)),
        }
    }
}

/// Pump one peer connection's read end into the shared inbox. The thread
/// exits when the peer closes, the connection fails, or the exchange is
/// dropped (its inbox receiver disappears).
fn spawn_peer_reader(mut reader: BufReader<TcpStream>, src: usize, tx: Sender<InboxMsg>) {
    std::thread::spawn(move || {
        let ctx = format!("rank {src}");
        loop {
            match read_frame(&mut reader, &ctx) {
                Ok(f) => {
                    if f.kind != FrameKind::Payload || f.src as usize != src {
                        let _ = tx.send(InboxMsg::Failed {
                            src,
                            err: TcpError::Protocol {
                                msg: format!(
                                    "unexpected {:?} frame from rank {} on the rank-{src} \
                                     data connection",
                                    f.kind, f.src
                                ),
                            },
                        });
                        return;
                    }
                    match bytes_to_f64s(&f.body, &ctx) {
                        Ok(vals) => {
                            if tx.send(InboxMsg::Payload { src, round: f.tag, vals }).is_err() {
                                return; // exchange dropped; shutting down
                            }
                        }
                        Err(err) => {
                            let _ = tx.send(InboxMsg::Failed { src, err });
                            return;
                        }
                    }
                }
                Err(TcpError::PeerClosed { .. }) => {
                    let _ = tx.send(InboxMsg::Closed { src });
                    return;
                }
                Err(err) => {
                    let _ = tx.send(InboxMsg::Failed { src, err });
                    return;
                }
            }
        }
    });
}

/// Receive the `round`-tagged payload from `peer`, parking other
/// (possibly future-round) payloads in the reorder buffer. A peer that
/// closed after finishing its run is benign unless it is the one we are
/// waiting on; a timeout or failure surfaces as a typed error instead of
/// a hang.
fn recv_round(
    pending: &mut HashMap<(usize, u64), Vec<f64>>,
    inbox: &Receiver<InboxMsg>,
    peer: usize,
    round: u64,
    timeout: Duration,
) -> Result<Vec<f64>, TcpError> {
    if let Some(d) = pending.remove(&(peer, round)) {
        return Ok(d);
    }
    loop {
        match inbox.recv_timeout(timeout) {
            Ok(InboxMsg::Payload { src, round: r, vals }) => {
                if src == peer && r == round {
                    return Ok(vals);
                }
                if pending.insert((src, r), vals).is_some() {
                    return Err(TcpError::Protocol {
                        msg: format!("duplicate payload from rank {src} round {r}"),
                    });
                }
            }
            // A peer finishing early is fine — its payloads were enqueued
            // (in order) before the close notification. Only the peer we
            // still need data from closing is fatal.
            Ok(InboxMsg::Closed { src }) if src != peer => continue,
            Ok(InboxMsg::Closed { src }) => {
                return Err(TcpError::PeerClosed { who: format!("rank {src}") });
            }
            Ok(InboxMsg::Failed { src, err }) => {
                return Err(TcpError::Protocol {
                    msg: format!("data connection to rank {src} failed: {err}"),
                });
            }
            Err(RecvTimeoutError::Timeout) => {
                return Err(TcpError::Timeout {
                    who: format!("rank {peer}"),
                    waiting_for: format!("the round-{round} boundary payload"),
                });
            }
            Err(RecvTimeoutError::Disconnected) => {
                return Err(TcpError::PeerClosed {
                    who: "every peer data connection".to_string(),
                });
            }
        }
    }
}

/// Per-process [`Exchange`] handle over TCP sockets.
///
/// Semantically a [`ShardExchange`](super::partitioned::ShardExchange)
/// whose channels are sockets: plan-driven shipping, round-tagged reorder
/// buffering, sequence-keyed all-reduce through the leader. Owns its
/// shard plan and Laplacian (worker processes rebuild both
/// deterministically from the experiment config).
pub struct TcpExchange {
    n: usize,
    k: usize,
    m_edges: usize,
    rank: usize,
    lap: Arc<Csr>,
    plan: ShardPlan,
    /// Write halves of the peer mesh, indexed by rank (`None` for self).
    peers: Vec<Option<TcpStream>>,
    /// Reader threads pump every peer read end into this inbox.
    inbox: Receiver<InboxMsg>,
    /// Write half of the leader connection (all-reduce up, metrics).
    leader: TcpStream,
    /// Read half of the leader connection (peer table, all-reduce down).
    leader_reader: BufReader<TcpStream>,
    /// Reorder buffer for early payloads, keyed `(sender, round)`.
    pending: HashMap<(usize, u64), Vec<f64>>,
    /// Mirror of the global stack holding fresh values for covered nodes.
    mirror: Vec<f64>,
    round: u64,
    red_seq: u64,
    /// Per-operator exchange plans (same derivation as `ShardExchange`).
    op_plans: HashMap<OpKey, ExchangePlan>,
    /// Reused frame-body encode buffer.
    body_scratch: Vec<u8>,
    /// Persistent scratch for the fresh-masked receive row list.
    fresh_scratch: Vec<usize>,
    stats: CommStats,
    cross: u64,
    cross_floats: u64,
    payload_bytes: u64,
    header_bytes: u64,
    timeout: Duration,
}

impl TcpExchange {
    /// Join the pool: rendezvous through the leader, then build the full
    /// worker mesh (see the module docs for the bootstrap sequence).
    /// `plan` must be this rank's entry of
    /// [`build_shard_plans`](super::partitioned::build_shard_plans) and
    /// `lap` the graph Laplacian — both rebuilt deterministically by the
    /// worker process.
    pub fn connect(
        net: &WorkerNetConfig,
        n: usize,
        m_edges: usize,
        lap: Csr,
        plan: ShardPlan,
    ) -> Result<TcpExchange, TcpError> {
        let (rank, k) = (net.rank, net.k);
        if k == 0 || rank >= k || k > u16::MAX as usize {
            return Err(TcpError::Protocol { msg: format!("bad rank/pool: rank {rank} of {k}") });
        }
        if plan.worker != rank {
            return Err(TcpError::Protocol {
                msg: format!("shard plan is for worker {}, not rank {rank}", plan.worker),
            });
        }
        let io = |ctx: &str, err| TcpError::Io { ctx: ctx.to_string(), err };

        // 1. Leader rendezvous: dial (with retry), bind our own listener
        //    on the same interface, advertise it.
        let mut leader =
            connect_with_retry(&net.leader_addr, net.retries, net.backoff)?;
        leader.set_nodelay(true).map_err(|e| io("leader set_nodelay", e))?;
        leader.set_read_timeout(Some(net.timeout)).map_err(|e| io("leader set timeout", e))?;
        let local_ip = leader.local_addr().map_err(|e| io("leader local_addr", e))?.ip();
        let listener = TcpListener::bind((local_ip, 0)).map_err(|e| io("bind mesh listener", e))?;
        let my_addr = listener.local_addr().map_err(|e| io("listener local_addr", e))?;
        write_frame(
            &mut leader,
            FrameKind::Hello,
            rank as u16,
            0,
            my_addr.to_string().as_bytes(),
            "leader",
        )?;

        // 2. Peer table: every listener is bound before the leader
        //    broadcasts, so the mesh below cannot dial into the void.
        let mut leader_reader =
            BufReader::new(leader.try_clone().map_err(|e| io("leader try_clone", e))?);
        let table = read_frame(&mut leader_reader, "leader")?;
        if table.kind != FrameKind::PeerTable {
            return Err(TcpError::Protocol {
                msg: format!("expected the peer table, got a {:?} frame", table.kind),
            });
        }
        let text = String::from_utf8(table.body)
            .map_err(|_| TcpError::BadFrame { msg: "peer table is not UTF-8".to_string() })?;
        // Placement-aware leaders append a `\tHOST` column per line (the
        // hybrid transport consumes it); the plain TCP mesh only needs
        // the address.
        let addrs: Vec<&str> = text.lines().map(|l| l.split('\t').next().unwrap_or(l)).collect();
        if addrs.len() != k {
            return Err(TcpError::Protocol {
                msg: format!("peer table lists {} workers, expected {k}", addrs.len()),
            });
        }

        // 3. Full mesh: dial every lower rank, accept every higher rank.
        let (tx, inbox) = channel::<InboxMsg>();
        let mut peers: Vec<Option<TcpStream>> = (0..k).map(|_| None).collect();
        for (q, addr) in addrs.iter().enumerate().take(rank) {
            let mut s = connect_with_retry(addr, net.retries, net.backoff)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            write_frame(&mut s, FrameKind::Hello, rank as u16, 0, &[], &format!("rank {q}"))?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            spawn_peer_reader(BufReader::new(read_half), q, tx.clone());
            peers[q] = Some(s);
        }
        let deadline = Instant::now() + net.timeout;
        for _ in 0..(k - 1 - rank) {
            let s = accept_with_deadline(&listener, deadline)?;
            s.set_nodelay(true).map_err(|e| io("peer set_nodelay", e))?;
            s.set_read_timeout(Some(net.timeout)).map_err(|e| io("peer set timeout", e))?;
            let read_half = s.try_clone().map_err(|e| io("peer try_clone", e))?;
            let mut reader = BufReader::new(read_half);
            let hello = read_frame(&mut reader, "peer handshake")?;
            if hello.kind != FrameKind::Hello {
                return Err(TcpError::Protocol {
                    msg: format!("expected a mesh Hello, got a {:?} frame", hello.kind),
                });
            }
            let src = hello.src as usize;
            if src <= rank || src >= k {
                return Err(TcpError::Protocol {
                    msg: format!("mesh Hello from out-of-range rank {src}"),
                });
            }
            if peers[src].is_some() {
                return Err(TcpError::Protocol {
                    msg: format!("duplicate mesh connection from rank {src}"),
                });
            }
            // Handshake done: payload reads block indefinitely in the
            // reader thread (hang protection is the inbox recv timeout).
            s.set_read_timeout(None).map_err(|e| io("peer clear timeout", e))?;
            // Keep the handshake BufReader — it may already hold buffered
            // payload bytes that arrived behind the Hello.
            spawn_peer_reader(reader, src, tx.clone());
            peers[src] = Some(s);
        }
        drop(tx); // readers hold their clones; a drained inbox means "all peers gone"

        if lap.rows != n {
            return Err(TcpError::Protocol {
                msg: format!("Laplacian is {}×{}, graph has {n} nodes", lap.rows, lap.cols),
            });
        }
        Ok(TcpExchange {
            n,
            k,
            m_edges,
            rank,
            lap: Arc::new(lap),
            plan,
            peers,
            inbox,
            leader,
            leader_reader,
            pending: HashMap::new(),
            mirror: Vec::new(),
            round: 0,
            red_seq: 0,
            op_plans: HashMap::new(),
            body_scratch: Vec::new(),
            fresh_scratch: Vec::new(),
            stats: CommStats::default(),
            cross: 0,
            cross_floats: 0,
            payload_bytes: 0,
            header_bytes: 0,
            timeout: net.timeout,
        })
    }

    /// This worker's rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// This worker's shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Real cross-worker socket payloads so far (one per shipped boundary
    /// row, plus 2 per all-reduce through the leader) — same ledger as
    /// [`ShardExchange::cross_messages`](super::partitioned::ShardExchange::cross_messages).
    pub fn cross_messages(&self) -> u64 {
        self.cross
    }

    /// Real floats moved over the sockets so far.
    pub fn cross_floats(&self) -> u64 {
        self.cross_floats
    }

    /// Real *payload* bytes written to data-plane sockets — exactly
    /// [`cross_floats`](Self::cross_floats)` × 8` (the wire-truth
    /// invariant, extended to observed bytes).
    pub fn payload_bytes(&self) -> u64 {
        self.payload_bytes
    }

    /// Fixed framing overhead written to data-plane sockets:
    /// [`HEADER_BYTES`](frame::HEADER_BYTES) per payload / all-reduce
    /// frame, accounted separately from payload bytes.
    pub fn header_bytes(&self) -> u64 {
        self.header_bytes
    }

    /// Report this iteration's metrics to the leader (counters + the
    /// shard's owned θ rows), tagged with the iteration number.
    ///
    /// The metric body carries [`METRIC_COUNTERS`] `u64`s; on the pure
    /// TCP transport every cross-worker payload rides a socket, so the
    /// intra-host columns are 0 and the inter-host columns equal the
    /// totals (the hybrid transport splits them by placement).
    pub fn send_metrics(&mut self, iter: u64, thetas: &[f64]) -> Result<(), TcpError> {
        self.body_scratch.clear();
        put_u64s(
            &mut self.body_scratch,
            &[
                self.cross,
                self.cross_floats,
                0,
                0,
                self.cross,
                self.cross_floats,
                self.payload_bytes,
                self.header_bytes,
                self.stats.messages,
                self.stats.floats,
                self.stats.rounds,
                self.stats.allreduces,
                self.stats.skipped_rounds,
                self.stats.saved_messages,
                self.stats.saved_floats,
            ],
        );
        put_f64s(&mut self.body_scratch, thetas);
        write_frame(
            &mut self.leader,
            FrameKind::Metric,
            self.rank as u16,
            iter,
            &self.body_scratch,
            "leader",
        )
    }

    /// Ensure an exchange plan exists for `a` (graph-halo rule, identical
    /// to the in-process transport).
    fn ensure_plan(&mut self, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        for &u in &self.plan.owned {
            for kk in a.indptr[u]..a.indptr[u + 1] {
                assert!(
                    self.plan.covered[a.indices[kk]],
                    "operator support escapes the halo at row {u}: the partitioned \
                     transport only ships graph-support operators unless an overlay \
                     plan is registered (Exchange::register_plan)"
                );
            }
        }
        let plan = derive_exchange_plan("graph-support", a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    /// One plan-driven exchange round over the sockets. Identical
    /// structure to `ShardExchange::exchange_round`, with frame encoding
    /// in place of channel sends and byte-level wire accounting.
    /// `compute` (when given) restricts the step-3 row kernels to the
    /// masked owned rows, leaving the rest of `out` unspecified.
    fn exchange_round(
        &mut self,
        a: &Csr,
        fresh: Option<&[bool]>,
        compute: Option<&[bool]>,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) -> Result<(), TcpError> {
        let ln = self.plan.owned.len();
        assert_eq!(a.rows, self.n, "operator shape mismatch");
        assert_eq!(x.len(), ln * w, "payload shape mismatch");
        assert_eq!(out.len(), ln * w);
        if let Some(m) = fresh {
            assert_eq!(m.len(), self.n, "fresh mask must cover every global node");
        }
        if let Some(c) = compute {
            assert_eq!(c.len(), self.n, "compute mask must cover every global node");
        }
        self.ensure_plan(a);
        self.round += 1;
        let round = self.round;
        let mirror_reset = self.mirror.len() != self.n * w;
        if mirror_reset {
            self.mirror = vec![0.0; self.n * w];
        }
        let key = op_key(a);
        let xplan = &self.op_plans[&key];
        let live = |u: usize| fresh.is_none_or(|m| m[u]);

        // Same guard as the in-process transport: a fresh round right
        // after a mirror (re)allocation would read unseeded halo rows.
        if mirror_reset && fresh.is_some() {
            for (_, rows) in &xplan.recv {
                for &u in rows {
                    assert!(
                        live(u),
                        "fresh exchange after a mirror reset would read unseeded halo \
                         row {u}: issue a full exchange at this width first"
                    );
                }
            }
        }

        // 1. Ship the plan's (fresh) owned rows to each peer as one
        //    round-tagged Payload frame of raw f64 bit patterns.
        for (peer, rows) in &xplan.send {
            self.body_scratch.clear();
            let mut shipped = 0u64;
            for &u in rows {
                if !live(u) {
                    continue;
                }
                let li = self.plan.local_of[u];
                put_f64s(&mut self.body_scratch, &x[li * w..(li + 1) * w]);
                shipped += 1;
            }
            if shipped == 0 {
                continue;
            }
            let stream = match self.peers[*peer].as_mut() {
                Some(s) => s,
                None => {
                    return Err(TcpError::Protocol {
                        msg: format!("no data connection to rank {peer}"),
                    })
                }
            };
            write_frame(
                stream,
                FrameKind::Payload,
                self.rank as u16,
                round,
                &self.body_scratch,
                &format!("rank {peer}"),
            )?;
            self.cross += shipped;
            self.cross_floats += shipped * w as u64;
            self.payload_bytes += self.body_scratch.len() as u64;
            self.header_bytes += HEADER_BYTES;
        }

        // 2. Refresh the mirror: owned rows from `x`, (fresh) halo rows
        //    from the peers, reorder-buffered by round tag.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            self.mirror[u * w..(u + 1) * w].copy_from_slice(&x[li * w..(li + 1) * w]);
        }
        for (peer, rows) in &xplan.recv {
            let expect: &[usize] = match fresh {
                None => rows,
                Some(_) => {
                    self.fresh_scratch.clear();
                    self.fresh_scratch.extend(rows.iter().copied().filter(|&u| live(u)));
                    &self.fresh_scratch
                }
            };
            if expect.is_empty() {
                continue;
            }
            let data = recv_round(&mut self.pending, &self.inbox, *peer, round, self.timeout)?;
            if data.len() != expect.len() * w {
                return Err(TcpError::Protocol {
                    msg: format!(
                        "halo payload width drifted: rank {peer} round {round} sent {} floats, \
                         expected {}",
                        data.len(),
                        expect.len() * w
                    ),
                });
            }
            for (idx, &u) in expect.iter().enumerate() {
                self.mirror[u * w..(u + 1) * w].copy_from_slice(&data[idx * w..(idx + 1) * w]);
            }
        }

        // 3. Owned rows via the shared CSR row kernel — bit-for-bit equal
        //    to both in-process transports. A compute mask skips rows the
        //    caller will not read.
        for (li, &u) in self.plan.owned.iter().enumerate() {
            if compute.is_none_or(|c| c[u]) {
                a.row_matvec_multi(u, &self.mirror, w, &mut out[li * w..(li + 1) * w]);
            }
        }
        self.stats.record_exchange(directed_messages, w);
        Ok(())
    }

    /// Sequence-tagged all-reduce through the leader connection.
    fn allreduce_impl(&mut self, locals: &[f64], w: usize) -> Result<Vec<f64>, TcpError> {
        assert_eq!(locals.len(), self.plan.owned.len() * w);
        self.red_seq += 1;
        self.body_scratch.clear();
        put_f64s(&mut self.body_scratch, locals);
        write_frame(
            &mut self.leader,
            FrameKind::ReduceUp,
            self.rank as u16,
            self.red_seq,
            &self.body_scratch,
            "leader",
        )?;
        let down: Frame = read_frame(&mut self.leader_reader, "leader")?;
        if down.kind != FrameKind::ReduceDown {
            return Err(TcpError::Protocol {
                msg: format!("expected an all-reduce total, got a {:?} frame", down.kind),
            });
        }
        if down.tag != self.red_seq {
            return Err(TcpError::Protocol {
                msg: format!(
                    "all-reduce sequence drifted: got total {} while at sequence {}",
                    down.tag, self.red_seq
                ),
            });
        }
        let total = bytes_to_f64s(&down.body, "leader reduce-down")?;
        if total.len() != w {
            return Err(TcpError::Protocol {
                msg: format!("all-reduce width drifted: got {} floats, expected {w}", total.len()),
            });
        }
        if self.k > 1 {
            self.cross += 2;
            self.cross_floats += (locals.len() + w) as u64;
            self.payload_bytes += ((locals.len() + w) * 8) as u64;
            self.header_bytes += 2 * HEADER_BYTES;
        }
        self.stats.record_allreduce(self.n, w);
        Ok(total)
    }

    /// Surface a socket failure as a loud panic: inside the [`Exchange`]
    /// contract a mid-round transport loss is unrecoverable, and the
    /// in-process transports die the same way (a deadlocked pool would be
    /// strictly worse). The typed error keeps the *which peer, what
    /// operation* diagnosis in the message.
    fn die(&self, err: TcpError) -> ! {
        // sddn-lint: allow(panic) reason=socket failure mid-round is unrecoverable under the Exchange contract; dying loudly with the peer diagnosis beats deadlocking the pool
        panic!("tcp transport rank {}: {err}", self.rank)
    }
}

impl Exchange for TcpExchange {
    fn n(&self) -> usize {
        self.n
    }

    fn owned(&self) -> &[usize] {
        &self.plan.owned
    }

    fn exchange_apply(
        &mut self,
        a: &Csr,
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) = self.exchange_round(a, None, None, directed_messages, x, w, out) {
            self.die(e)
        }
    }

    fn exchange_apply_fresh(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) = self.exchange_round(a, Some(fresh), None, directed_messages, x, w, out) {
            self.die(e)
        }
    }

    fn exchange_apply_fresh_rows(
        &mut self,
        a: &Csr,
        fresh: &[bool],
        compute: &[bool],
        directed_messages: u64,
        x: &[f64],
        w: usize,
        out: &mut [f64],
    ) {
        if let Err(e) =
            self.exchange_round(a, Some(fresh), Some(compute), directed_messages, x, w, out)
        {
            self.die(e)
        }
    }

    fn register_plan(&mut self, name: &str, a: &Csr) {
        let key = op_key(a);
        if self.op_plans.contains_key(&key) {
            return;
        }
        let plan = derive_exchange_plan(name, a, &self.plan.owner, self.plan.worker);
        self.op_plans.insert(key, plan);
    }

    fn laplacian_apply_into(&mut self, x: &[f64], w: usize, out: &mut [f64]) {
        let lap = Arc::clone(&self.lap);
        let dm = 2 * self.m_edges as u64;
        // sddn-lint: graph-support Laplacian sparsity is exactly the comm graph plus diagonal
        self.exchange_apply(&lap, dm, x, w, out);
    }

    fn allreduce_sum(&mut self, locals: &[f64], w: usize) -> Vec<f64> {
        match self.allreduce_impl(locals, w) {
            Ok(total) => total,
            Err(e) => self.die(e),
        }
    }

    fn stats(&self) -> &CommStats {
        &self.stats
    }

    fn stats_mut(&mut self) -> &mut CommStats {
        &mut self.stats
    }
}

impl Drop for TcpExchange {
    /// Shut down every socket so blocked reader threads (ours and the
    /// peers') observe the close instead of waiting out their timeouts.
    fn drop(&mut self) {
        for s in self.peers.iter().flatten() {
            let _ = s.shutdown(Shutdown::Both);
        }
        let _ = self.leader.shutdown(Shutdown::Both);
    }
}
