//! Communication accounting — the measurement behind Fig. 2(c).

/// Counters for message-passing activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Point-to-point messages sent (each neighbor payload = 1 message).
    pub messages: u64,
    /// Total floats moved (messages × payload width).
    pub floats: u64,
    /// Synchronous rounds.
    pub rounds: u64,
    /// All-reduce operations (tree broadcasts count as 2 rounds each).
    pub allreduces: u64,
}

impl CommStats {
    /// One edge-exchange round over `m` undirected edges with `w`-float
    /// payloads: `2m` directed messages.
    pub fn record_edge_round(&mut self, m: usize, w: usize) {
        self.messages += 2 * m as u64;
        self.floats += 2 * m as u64 * w as u64;
        self.rounds += 1;
    }

    /// One tree all-reduce over `n` nodes with `w`-float payloads:
    /// `2(n−1)` messages, 2 rounds.
    pub fn record_allreduce(&mut self, n: usize, w: usize) {
        let msgs = 2 * (n as u64 - 1);
        self.messages += msgs;
        self.floats += msgs * w as u64;
        self.rounds += 2;
        self.allreduces += 1;
    }

    /// Bytes on the wire assuming f64 payloads.
    pub fn bytes(&self) -> u64 {
        self.floats * 8
    }

    /// Difference (self − earlier); useful for per-iteration deltas.
    pub fn since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            messages: self.messages - earlier.messages,
            floats: self.floats - earlier.floats,
            rounds: self.rounds - earlier.rounds,
            allreduces: self.allreduces - earlier.allreduces,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut s = CommStats::default();
        s.record_edge_round(10, 4);
        assert_eq!(s.messages, 20);
        assert_eq!(s.floats, 80);
        assert_eq!(s.bytes(), 640);
        s.record_allreduce(5, 1);
        assert_eq!(s.messages, 28);
        assert_eq!(s.allreduces, 1);
        assert_eq!(s.rounds, 3);
    }

    #[test]
    fn since_delta() {
        let mut s = CommStats::default();
        s.record_edge_round(3, 1);
        let snap = s;
        s.record_edge_round(3, 1);
        let d = s.since(&snap);
        assert_eq!(d.messages, 6);
        assert_eq!(d.rounds, 1);
    }
}
