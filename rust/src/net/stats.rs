//! Communication accounting — the measurement behind Fig. 2(c).

/// Counters for message-passing activity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStats {
    /// Point-to-point messages sent (each neighbor payload = 1 message).
    pub messages: u64,
    /// Total floats moved (messages × payload width).
    pub floats: u64,
    /// Synchronous rounds.
    pub rounds: u64,
    /// All-reduce operations (tree broadcasts count as 2 rounds each).
    pub allreduces: u64,
    /// Exchange rounds a staleness/local-steps policy elided entirely
    /// (no wire activity; consumers reused τ-old boundary data or a
    /// purely local iterate). Not counted in `rounds`.
    pub skipped_rounds: u64,
    /// Point-to-point messages the skipped rounds *would* have moved
    /// under the strict BSP contract — the modeled traffic savings.
    pub saved_messages: u64,
    /// Floats the skipped rounds would have moved (`saved_messages × w`).
    pub saved_floats: u64,
}

impl CommStats {
    /// One exchange round moving `directed_messages` point-to-point
    /// messages of `w` floats. Generalizes [`Self::record_edge_round`] to
    /// operators whose support is not the plain edge set (e.g. the
    /// preprocessed squared-chain overlays).
    pub fn record_exchange(&mut self, directed_messages: u64, w: usize) {
        self.messages += directed_messages;
        self.floats += directed_messages * w as u64;
        self.rounds += 1;
    }

    /// One exchange round a relaxed-consistency policy skipped: under
    /// strict BSP it would have moved `directed_messages` messages of
    /// `w` floats, but nothing touched the wire. Only the savings
    /// counters move — `messages`/`floats`/`rounds` stay untouched so
    /// wire-truth assertions (`payload_bytes == cross_floats × 8` on
    /// rounds that ship) keep holding verbatim.
    pub fn record_skipped_exchange(&mut self, directed_messages: u64, w: usize) {
        self.skipped_rounds += 1;
        self.saved_messages += directed_messages;
        self.saved_floats += directed_messages * w as u64;
    }

    /// One edge-exchange round over `m` undirected edges with `w`-float
    /// payloads: `2m` directed messages.
    pub fn record_edge_round(&mut self, m: usize, w: usize) {
        self.record_exchange(2 * m as u64, w);
    }

    /// One tree all-reduce over `n` nodes with `w`-float payloads:
    /// `2(n−1)` messages, 2 rounds.
    ///
    /// Degenerate groups are free: with `n ≤ 1` a lone node (or an empty
    /// group) already holds the global sum, so the operation is counted in
    /// `allreduces` but moves zero messages and spends zero rounds. (The
    /// naive `2(n−1)` would underflow at `n = 0`.)
    pub fn record_allreduce(&mut self, n: usize, w: usize) {
        self.allreduces += 1;
        if n <= 1 {
            return;
        }
        let msgs = 2 * (n as u64 - 1);
        self.messages += msgs;
        self.floats += msgs * w as u64;
        self.rounds += 2;
    }

    /// Bytes on the wire assuming f64 payloads.
    pub fn bytes(&self) -> u64 {
        self.floats * 8
    }

    /// Difference (self − earlier); useful for per-iteration deltas.
    pub fn since(&self, earlier: &CommStats) -> CommStats {
        CommStats {
            messages: self.messages - earlier.messages,
            floats: self.floats - earlier.floats,
            rounds: self.rounds - earlier.rounds,
            allreduces: self.allreduces - earlier.allreduces,
            skipped_rounds: self.skipped_rounds - earlier.skipped_rounds,
            saved_messages: self.saved_messages - earlier.saved_messages,
            saved_floats: self.saved_floats - earlier.saved_floats,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut s = CommStats::default();
        s.record_edge_round(10, 4);
        assert_eq!(s.messages, 20);
        assert_eq!(s.floats, 80);
        assert_eq!(s.bytes(), 640);
        s.record_allreduce(5, 1);
        assert_eq!(s.messages, 28);
        assert_eq!(s.allreduces, 1);
        assert_eq!(s.rounds, 3);
    }

    #[test]
    fn since_delta() {
        let mut s = CommStats::default();
        s.record_edge_round(3, 1);
        let snap = s;
        s.record_edge_round(3, 1);
        let d = s.since(&snap);
        assert_eq!(d.messages, 6);
        assert_eq!(d.rounds, 1);
    }

    #[test]
    fn exchange_with_custom_message_count() {
        let mut s = CommStats::default();
        s.record_exchange(7, 3);
        assert_eq!(s.messages, 7);
        assert_eq!(s.floats, 21);
        assert_eq!(s.rounds, 1);
    }

    #[test]
    fn skipped_exchange_moves_only_savings_counters() {
        let mut s = CommStats::default();
        s.record_exchange(10, 2);
        let shipped = s;
        s.record_skipped_exchange(10, 2);
        // Wire-truth counters untouched by a skipped round.
        assert_eq!(s.messages, shipped.messages);
        assert_eq!(s.floats, shipped.floats);
        assert_eq!(s.rounds, shipped.rounds);
        assert_eq!(s.bytes(), shipped.bytes());
        // Savings modeled exactly.
        assert_eq!(s.skipped_rounds, 1);
        assert_eq!(s.saved_messages, 10);
        assert_eq!(s.saved_floats, 20);
        let d = s.since(&shipped);
        assert_eq!(d.skipped_rounds, 1);
        assert_eq!(d.saved_messages, 10);
        assert_eq!(d.saved_floats, 20);
        assert_eq!(d.messages, 0);
        assert_eq!(d.rounds, 0);
    }

    #[test]
    fn allreduce_singleton_is_zero_message_noop() {
        let mut s = CommStats::default();
        s.record_allreduce(1, 9);
        assert_eq!(s.allreduces, 1);
        assert_eq!(s.messages, 0);
        assert_eq!(s.floats, 0);
        assert_eq!(s.rounds, 0);
    }

    #[test]
    fn allreduce_empty_group_does_not_underflow() {
        let mut s = CommStats::default();
        s.record_allreduce(0, 4);
        // Before the guard, `2 * (n - 1)` wrapped to u64::MAX-ish counts.
        assert_eq!(s.messages, 0);
        assert_eq!(s.floats, 0);
        assert_eq!(s.rounds, 0);
        assert_eq!(s.allreduces, 1);
        // The very next real all-reduce accounts normally.
        s.record_allreduce(3, 2);
        assert_eq!(s.messages, 4);
        assert_eq!(s.floats, 8);
        assert_eq!(s.rounds, 2);
        assert_eq!(s.allreduces, 2);
    }
}
