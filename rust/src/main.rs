//! `sddnewton` — CLI launcher for the distributed SDD-Newton system.
//!
//! Subcommands:
//!   run         — run an experiment preset (or JSON config) and write traces
//!   campaign    — run several presets and write a report bundle
//!   comm        — Fig. 2(c) communication-overhead sweep
//!   partitioned — run every configured algorithm on the sharded worker
//!                 runtime and check bit-for-bit parity with the bulk path
//!                 (`--transport tcp` deploys the workers as OS processes
//!                 over loopback TCP and extends the check to socket bytes;
//!                 `--transport hybrid --hostfile F` deploys one process
//!                 per hostfile host, channels within a host and TCP
//!                 across hosts, and splits the wire check by placement)
//!   worker      — one TCP worker rank (`--rank R`), or one hybrid host
//!                 process (`--host NAME --hostfile F`); spawned by
//!                 `partitioned`, or by hand for multi-host runs
//!   solve       — demo the distributed SDDM solver on a random Laplacian
//!   bench-validate — check BENCH_*.json perf-trajectory files against
//!                 the schema (CI gate; see docs/BENCHMARKS.md)
//!   info        — platform + artifact inventory
//!
//! (clap is unavailable offline; the parser is hand-rolled.)

use sddnewton::config::{AlgoKind, ExperimentConfig, Json};
use sddnewton::coordinator::{Campaign, Partition};
use sddnewton::harness::{self, report, TcpJobSpec};
use sddnewton::util::Pcg64;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("campaign") => cmd_campaign(&args[1..]),
        Some("comm") => cmd_comm(&args[1..]),
        Some("partitioned") => cmd_partitioned(&args[1..]),
        Some("worker") => cmd_worker(&args[1..]),
        Some("solve") => cmd_solve(&args[1..]),
        Some("bench-validate") => cmd_bench_validate(&args[1..]),
        Some("bench-diff") => cmd_bench_diff(&args[1..]),
        Some("info") => cmd_info(),
        Some("help") | Some("-h") | Some("--help") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "sddnewton — distributed Newton for consensus optimization\n\
         \n\
         USAGE:\n\
           sddnewton run --experiment <preset> [--iters N] [--algorithms a,b,c]\n\
                         [--backend native|pjrt] [--seed S] [--threads T]\n\
                         [--out trace.csv] [--plot]\n\
           sddnewton run --config <file.json> [--out trace.csv]\n\
           sddnewton campaign [--out results/] [preset...]\n\
           sddnewton comm [--experiment <preset>] [--targets 1e-1,1e-2,...] [--out comm.csv]\n\
           sddnewton partitioned [--experiment <preset>] [--workers K] [--iters N]\n\
                         [--partitioning contiguous|round_robin|bfs] [--algorithms a,b,c]\n\
                         [--transport channels|tcp|hybrid] [--listen HOST:PORT]\n\
                         [--stale-tau T]  (bounded-staleness halo bound; 0 = exact BSP)\n\
                         [--hostfile F]   (hybrid: rank→host placement)\n\
           sddnewton worker (--rank R | --host NAME --hostfile F) --connect HOST:PORT\n\
                         --workers K [--experiment <preset>] [--config file.json]\n\
                         [--algorithms a,b,c] [--seed S] [--algo-index I]\n\
                         [--iters N] [--partitioning P] [--solver-seed S] [--stale-tau T]\n\
           sddnewton solve [--nodes N] [--edges M] [--eps E] [--seed S] [--threads T]\n\
           sddnewton bench-validate [--dir bench_results] [--allow-empty]\n\
           sddnewton bench-diff <baseline> <candidate> [--tol FRAC]\n\
                         (BENCH_*.json files or directories; exit 1 on regression)\n\
           sddnewton info\n\
         \n\
         PRESETS: {}",
        ExperimentConfig::preset_names().join(", ")
    );
}

/// Tiny flag parser: --key value pairs plus positionals.
struct Flags {
    kv: std::collections::HashMap<String, String>,
    flags: std::collections::HashSet<String>,
    positional: Vec<String>,
}

fn parse_flags(args: &[String], boolean: &[&str]) -> Result<Flags, String> {
    let mut kv = std::collections::HashMap::new();
    let mut flags = std::collections::HashSet::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if boolean.contains(&key) {
                flags.insert(key.to_string());
                i += 1;
            } else {
                let v = args.get(i + 1).ok_or(format!("--{key} needs a value"))?;
                kv.insert(key.to_string(), v.clone());
                i += 2;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Flags { kv, flags, positional })
}

fn build_config(f: &Flags) -> Result<ExperimentConfig, String> {
    let mut cfg = if let Some(path) = f.kv.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| e.to_string())?;
        ExperimentConfig::from_json(&doc)?
    } else {
        let name = f.kv.get("experiment").map(String::as_str).unwrap_or("smoke");
        ExperimentConfig::preset(name).ok_or(format!("unknown preset '{name}'"))?
    };
    if let Some(n) = f.kv.get("iters") {
        cfg.max_iters = n.parse().map_err(|_| "bad --iters")?;
    }
    if let Some(s) = f.kv.get("seed") {
        cfg.seed = s.parse().map_err(|_| "bad --seed")?;
    }
    if let Some(b) = f.kv.get("backend") {
        cfg.backend = b.clone();
    }
    if let Some(t) = f.kv.get("threads") {
        let threads: usize = t.parse().map_err(|_| "bad --threads")?;
        cfg.parallelism = sddnewton::par::Parallelism { threads };
    }
    if let Some(list) = f.kv.get("algorithms") {
        cfg.algorithms = list
            .split(',')
            .map(|id| AlgoKind::from_id(id.trim()).ok_or(format!("unknown algorithm '{id}'")))
            .collect::<Result<_, _>>()?;
    }
    Ok(cfg)
}

fn cmd_run(args: &[String]) -> i32 {
    let f = match parse_flags(args, &["plot"]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = match build_config(&f) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    println!("running experiment '{}' …", cfg.name);
    let res = harness::run_experiment(&cfg);
    print!("{}", report::summary_table(&res));
    let tol = 1e-4;
    println!("\niterations to reach relative gap ≤ {tol:.0e}:");
    for (name, iters) in report::iters_table(&res, tol) {
        match iters {
            Some(k) => println!("  {name:<28} {k}"),
            None => println!("  {name:<28} —"),
        }
    }
    if f.flags.contains("plot") {
        println!("\n{}", report::ascii_plot(&res.traces, res.f_star, 72, 20));
    }
    if let Some(path) = f.kv.get("out") {
        if let Err(e) = report::write_csv(&res, path) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_campaign(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let out = f.kv.get("out").cloned().unwrap_or_else(|| "results".to_string());
    let names: Vec<&str> = if f.positional.is_empty() {
        vec!["fig1-synthetic", "fig1-mnist-l2", "fig3-london", "fig3-rl"]
    } else {
        f.positional.iter().map(String::as_str).collect()
    };
    let campaign = match Campaign::from_presets(&names, &out) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match campaign.run() {
        Ok(outcomes) => {
            for o in outcomes {
                println!("--- {} ({:.1}s) → {}", o.name, o.seconds, o.csv_path.display());
                print!("{}", o.summary);
            }
            0
        }
        Err(e) => {
            eprintln!("campaign failed: {e}");
            1
        }
    }
}

fn cmd_comm(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg = match build_config(&f) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !f.kv.contains_key("experiment") && !f.kv.contains_key("config") {
        cfg = ExperimentConfig::preset("fig2-comm").unwrap();
    }
    cfg.max_iters = cfg.max_iters.max(400);
    let targets: Vec<f64> = f
        .kv
        .get("targets")
        .map(|t| t.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![1e-1, 1e-2, 1e-3, 1e-4, 1e-5]);
    println!("communication overhead sweep on '{}' targets {targets:?}", cfg.name);
    let rows = harness::experiments::comm_overhead_experiment(&cfg, &targets);
    println!("{:<28} {}", "algorithm", targets.iter().map(|t| format!("{t:>12.0e}")).collect::<String>());
    for (name, cells) in &rows {
        let mut line = format!("{name:<28} ");
        for (_, msgs) in cells {
            match msgs {
                Some(m) => line.push_str(&format!("{m:>12}")),
                None => line.push_str(&format!("{:>12}", "—")),
            }
        }
        println!("{line}");
    }
    if let Some(path) = f.kv.get("out") {
        if let Err(e) = report::write_comm_csv(&rows, path) {
            eprintln!("writing {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    0
}

fn cmd_partitioned(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = match build_config(&f) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let workers: usize = f.kv.get("workers").and_then(|v| v.parse().ok()).unwrap_or(4);
    let iters = f
        .kv
        .get("iters")
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| cfg.max_iters.min(10));
    let scheme = f.kv.get("partitioning").map(String::as_str).unwrap_or("contiguous");
    let mut rng = Pcg64::new(cfg.seed);
    let g = harness::experiments::build_graph(&cfg, &mut rng);
    let problem = harness::experiments::build_problem(&cfg, &mut rng);
    let part = match scheme {
        "contiguous" => Partition::contiguous(g.n, workers),
        "round_robin" => Partition::round_robin(g.n, workers),
        "bfs" | "bfs_blocks" => Partition::bfs_blocks(&g, workers),
        other => {
            eprintln!("unknown partitioning '{other}'");
            return 2;
        }
    };
    let transport = f.kv.get("transport").map(String::as_str).unwrap_or("channels");
    let stale_tau: u64 = f.kv.get("stale-tau").and_then(|v| v.parse().ok()).unwrap_or(0);
    println!(
        "'{}' on {} workers ({scheme}, {} cut edges, {transport}, τ={stale_tau}), \
         {iters} iterations — bulk vs sharded parity",
        cfg.name,
        workers,
        part.cut_edges(&g)
    );
    match transport {
        "channels" => {}
        "tcp" => return cmd_partitioned_tcp(&f, &cfg, workers, iters, scheme),
        "hybrid" => return cmd_partitioned_hybrid(&f, &cfg, workers, iters, scheme),
        other => {
            eprintln!("unknown transport '{other}' (expected channels|tcp|hybrid)");
            return 2;
        }
    }
    println!(
        "{:<28} {:>8} {:>14} {:>11} {:>11} {:>12}",
        "algorithm", "parity", "modeled msgs", "wire real", "wire model", "objective"
    );
    let mut drifted = false;
    for kind in &cfg.algorithms {
        let (trace, out) = harness::experiments::run_cross_transport_stale(
            kind, &problem, &g, &part, iters, stale_tau, &mut rng,
        );
        let ledger_ok = trace
            .records
            .last()
            .map(|r| r.comm == out.comm)
            .unwrap_or(false);
        // Real channel traffic must equal the modeled ledger mapped
        // through the partition (the plan-driven wire model).
        let bulk_stats = trace.records.last().map(|r| r.comm).unwrap_or_default();
        let wire_model = harness::experiments::modeled_cross_messages(
            kind,
            &g,
            &part,
            iters,
            &bulk_stats,
        );
        let wire_ok = out.cross_messages == wire_model;
        // Bit-pattern equality: still exact, but NaN-safe should a
        // deliberately untuned step diverge identically on both paths.
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        let ok = bits(&out.thetas) == bits(&trace.final_thetas) && ledger_ok && wire_ok;
        drifted |= !ok;
        println!(
            "{:<28} {:>8} {:>14} {:>11} {:>11} {:>12.5e}",
            trace.algorithm,
            if ok { "ok" } else { "DRIFT" },
            out.comm.messages,
            out.cross_messages,
            wire_model,
            out.records.last().map(|r| r.objective).unwrap_or(f64::NAN),
        );
    }
    if drifted {
        eprintln!(
            "transport parity violated — sharded run drifted from the bulk path \
             (iterates, ledger, or wire-vs-model)"
        );
        return 1;
    }
    0
}

/// Build the per-algorithm [`TcpJobSpec`] a `partitioned --transport tcp`
/// run (and its worker processes) must agree on.
fn tcp_spec(
    f: &Flags,
    cfg: &ExperimentConfig,
    workers: usize,
    iters: usize,
    scheme: &str,
    idx: usize,
) -> TcpJobSpec {
    TcpJobSpec {
        experiment: f.kv.get("experiment").cloned().unwrap_or_else(|| "smoke".to_string()),
        config_path: f.kv.get("config").cloned(),
        algorithms: f.kv.get("algorithms").cloned(),
        seed: f.kv.get("seed").and_then(|s| s.parse().ok()),
        algo_index: idx,
        iters,
        workers,
        partitioning: scheme.to_string(),
        // Deterministic per-algorithm solver seed: every side of the
        // parity comparison (references here, each worker process)
        // rebuilds the randomized inner solver from this exact seed.
        solver_seed: cfg.seed.wrapping_add(0x51D0 + idx as u64),
        hostfile: None,
        stale_tau: f.kv.get("stale-tau").and_then(|v| v.parse().ok()).unwrap_or(0),
    }
}

/// `partitioned --transport tcp`: run every configured algorithm on a
/// pool of worker OS processes over loopback TCP and check three-way
/// parity (bulk, in-process shards, TCP pool) plus socket-byte wire truth.
fn cmd_partitioned_tcp(
    f: &Flags,
    cfg: &ExperimentConfig,
    workers: usize,
    iters: usize,
    scheme: &str,
) -> i32 {
    let listen = f.kv.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:0".to_string());
    let bin = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot locate own binary for worker spawning: {e}");
            return 1;
        }
    };
    println!(
        "{:<28} {:>8} {:>11} {:>11} {:>13} {:>10} {:>12}",
        "algorithm", "parity", "wire real", "wire model", "payload B", "header B", "objective"
    );
    let mut drifted = false;
    for idx in 0..cfg.algorithms.len() {
        let spec = tcp_spec(f, cfg, workers, iters, scheme, idx);
        let parity = match harness::run_tcp_cross_transport(&spec, &listen, Some(&bin)) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("tcp run failed for algorithm {idx}: {e}");
                return 1;
            }
        };
        let ok = parity.ok();
        drifted |= !ok;
        println!(
            "{:<28} {:>8} {:>11} {:>11} {:>13} {:>10} {:>12.5e}",
            parity.algorithm,
            if ok { "ok" } else { "DRIFT" },
            parity.tcp.cross_messages,
            parity.modeled_cross,
            parity.tcp.payload_bytes,
            parity.tcp.header_bytes,
            parity.tcp.records.last().map(|r| r.objective).unwrap_or(f64::NAN),
        );
    }
    if drifted {
        eprintln!(
            "tcp transport parity violated — the process pool drifted from the \
             in-process paths (iterates, ledger, wire model, or socket bytes)"
        );
        return 1;
    }
    0
}

/// `partitioned --transport hybrid --hostfile F`: one host process per
/// hostfile host (channels within a host, TCP across hosts) and the TCP
/// parity check with the wire truth split into intra-host and inter-host
/// ledgers.
fn cmd_partitioned_hybrid(
    f: &Flags,
    cfg: &ExperimentConfig,
    workers: usize,
    iters: usize,
    scheme: &str,
) -> i32 {
    let Some(hostfile) = f.kv.get("hostfile").cloned() else {
        eprintln!("--transport hybrid needs --hostfile F (rank→host placement)");
        return 2;
    };
    let placement = match std::fs::read_to_string(&hostfile)
        .map_err(|e| format!("{hostfile}: {e}"))
        .and_then(|text| {
            sddnewton::net::hybrid::parse_hostfile(&text).map_err(|e| format!("{hostfile}: {e}"))
        }) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if placement.k() != workers {
        eprintln!("hostfile places {} ranks but --workers is {workers}", placement.k());
        return 2;
    }
    let listen = f.kv.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:0".to_string());
    let bin = match std::env::current_exe() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot locate own binary for host spawning: {e}");
            return 1;
        }
    };
    println!(
        "hosts: {}",
        placement
            .hosts()
            .iter()
            .map(|h| format!("{h}[{}]", placement.ranks_on(h).len()))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!(
        "{:<28} {:>8} {:>11} {:>11} {:>11} {:>13} {:>10}",
        "algorithm", "parity", "intra", "inter", "wire model", "payload B", "header B"
    );
    let mut drifted = false;
    for idx in 0..cfg.algorithms.len() {
        let mut spec = tcp_spec(f, cfg, workers, iters, scheme, idx);
        spec.hostfile = Some(hostfile.clone());
        let parity =
            match harness::run_hybrid_cross_transport(&spec, &placement, &listen, Some(&bin)) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("hybrid run failed for algorithm {idx}: {e}");
                    return 1;
                }
            };
        let ok = parity.ok();
        drifted |= !ok;
        println!(
            "{:<28} {:>8} {:>11} {:>11} {:>11} {:>13} {:>10}",
            parity.algorithm,
            if ok { "ok" } else { "DRIFT" },
            parity.hybrid.intra_cross,
            parity.hybrid.inter_cross,
            parity.modeled_cross,
            parity.hybrid.payload_bytes,
            parity.hybrid.header_bytes,
        );
    }
    if drifted {
        eprintln!(
            "hybrid transport parity violated — the host-aware pool drifted from the \
             in-process paths (iterates, ledger, split accounting, or socket bytes)"
        );
        return 1;
    }
    0
}

/// One TCP worker rank (`--rank R`) or one hybrid host process
/// (`--host NAME --hostfile F`): rebuild the job from the spec flags and
/// serve the shard(s) until the run completes (spawned by `partitioned`,
/// or started by hand on each machine of a multi-host pool).
fn cmd_worker(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(connect) = f.kv.get("connect").cloned() else {
        eprintln!("worker needs --connect HOST:PORT");
        return 2;
    };
    let rank = f.kv.get("rank").and_then(|v| v.parse::<usize>().ok());
    let host = f.kv.get("host").cloned();
    if rank.is_none() && host.is_none() {
        eprintln!("worker needs --rank R (tcp) or --host NAME --hostfile F (hybrid)");
        return 2;
    }
    let spec = TcpJobSpec {
        experiment: f.kv.get("experiment").cloned().unwrap_or_else(|| "smoke".to_string()),
        config_path: f.kv.get("config").cloned(),
        algorithms: f.kv.get("algorithms").cloned(),
        seed: f.kv.get("seed").and_then(|s| s.parse().ok()),
        algo_index: f.kv.get("algo-index").and_then(|v| v.parse().ok()).unwrap_or(0),
        iters: f.kv.get("iters").and_then(|v| v.parse().ok()).unwrap_or(10),
        workers: f.kv.get("workers").and_then(|v| v.parse().ok()).unwrap_or(4),
        partitioning: f
            .kv
            .get("partitioning")
            .cloned()
            .unwrap_or_else(|| "contiguous".to_string()),
        solver_seed: f.kv.get("solver-seed").and_then(|v| v.parse().ok()).unwrap_or(0),
        hostfile: f.kv.get("hostfile").cloned(),
        stale_tau: f.kv.get("stale-tau").and_then(|v| v.parse().ok()).unwrap_or(0),
    };
    if let Some(host) = host {
        return match harness::hybrid_host_main(&spec, &host, &connect) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("host {host} failed: {e}");
                1
            }
        };
    }
    let rank = rank.expect("checked above");
    let net = sddnewton::net::tcp::WorkerNetConfig::from_env(rank, spec.workers, &connect);
    match harness::tcp_worker_main(&spec, &net) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker {rank} failed: {e}");
            1
        }
    }
}

fn cmd_solve(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let n: usize = f.kv.get("nodes").and_then(|v| v.parse().ok()).unwrap_or(100);
    let m: usize = f.kv.get("edges").and_then(|v| v.parse().ok()).unwrap_or(250);
    let eps: f64 = f.kv.get("eps").and_then(|v| v.parse().ok()).unwrap_or(1e-6);
    let seed: u64 = f.kv.get("seed").and_then(|v| v.parse().ok()).unwrap_or(1);
    if let Some(t) = f.kv.get("threads").and_then(|v| v.parse::<usize>().ok()) {
        sddnewton::par::set_threads(t);
    }
    let mut rng = Pcg64::new(seed);
    let g = sddnewton::graph::generate::random_connected(n, m, &mut rng);
    let l = sddnewton::graph::laplacian_csr(&g);
    let solver = sddnewton::algorithms::solvers::sddm_for_graph(&g, eps, &mut rng);
    println!(
        "graph n={n} m={m}  chain depth d={}  λ₂(walk)={:.4}",
        solver.chain.depth, solver.chain.lambda2
    );
    let x_true = rng.normal_vec(n);
    let b = l.matvec(&x_true);
    let mut comm = sddnewton::net::CommGraph::new(&g);
    let t = sddnewton::util::Timer::start();
    let out = solver.solve(&b, 1, &mut comm);
    println!(
        "solved to rel residual {:.2e} in {} Richardson sweeps, {:.2} ms",
        out.rel_residual,
        out.sweeps,
        t.millis()
    );
    let stats = comm.stats();
    println!(
        "communication: {} messages, {} floats, {} rounds, {} all-reduces",
        stats.messages, stats.floats, stats.rounds, stats.allreduces
    );
    i32::from(!out.converged)
}

/// Validate every `BENCH_*.json` in the trajectory directory against the
/// schema the benches write. Exits non-zero when the directory holds no
/// reports (unless `--allow-empty`) or any report is malformed — the CI
/// gate that keeps the committed perf trajectory machine-readable.
fn cmd_bench_validate(args: &[String]) -> i32 {
    let f = match parse_flags(args, &["allow-empty"]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let dir = f.kv.get("dir").cloned().unwrap_or_else(|| {
        std::env::var("SDDN_BENCH_DIR").unwrap_or_else(|_| "bench_results".to_string())
    });
    let entries = match std::fs::read_dir(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bench-validate: cannot read {dir}: {e}");
            return 1;
        }
    };
    let mut names: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    names.sort();
    if names.is_empty() {
        if f.flags.contains("allow-empty") {
            println!("bench-validate: no BENCH_*.json files in {dir} (allowed)");
            return 0;
        }
        eprintln!("bench-validate: no BENCH_*.json files in {dir}");
        return 1;
    }
    let mut bad = 0;
    for path in &names {
        let verdict = std::fs::read_to_string(path)
            .map_err(|e| e.to_string())
            .and_then(|text| {
                Json::parse(text.trim()).map_err(|e| e.to_string())
            })
            .and_then(|doc| sddnewton::benchkit::validate_report(&doc));
        match verdict {
            Ok(()) => println!("ok      {}", path.display()),
            Err(e) => {
                eprintln!("INVALID {}: {e}", path.display());
                bad += 1;
            }
        }
    }
    println!("bench-validate: {} file(s), {bad} invalid", names.len());
    i32::from(bad > 0)
}

/// Parse one `BENCH_*.json` file.
fn load_bench_report(path: &std::path::Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(text.trim()).map_err(|e| format!("{}: {e}", path.display()))
}

/// Latest `BENCH_*.json` per bench name under `dir` (files sort by name,
/// and names embed the UTC date plus a same-day dedupe suffix, so the
/// lexicographically last file for a bench is its newest trajectory
/// point).
fn latest_bench_reports(
    dir: &std::path::Path,
) -> Result<std::collections::BTreeMap<String, (std::path::PathBuf, Json)>, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut names: Vec<std::path::PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    names.sort();
    let mut latest = std::collections::BTreeMap::new();
    for path in names {
        let doc = load_bench_report(&path)?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{}: missing bench name", path.display()))?
            .to_string();
        latest.insert(bench, (path, doc));
    }
    Ok(latest)
}

/// `bench-diff <baseline> <candidate> [--tol FRAC]`: compare BENCH_*.json
/// performance reports (single files, or directories paired by bench name
/// taking each bench's newest point) and exit 1 when any metric regresses
/// beyond the tolerance. The regression gate for perf-sensitive PRs.
fn cmd_bench_diff(args: &[String]) -> i32 {
    let f = match parse_flags(args, &[]) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let [baseline, candidate] = f.positional.as_slice() else {
        eprintln!("bench-diff needs exactly two positionals: <baseline> <candidate> (file or dir)");
        return 2;
    };
    let tol: f64 = match f.kv.get("tol").map(|v| v.parse()) {
        None => 0.05,
        Some(Ok(t)) if t >= 0.0 => t,
        _ => {
            eprintln!("bad --tol (expected a non-negative fraction, e.g. 0.05)");
            return 2;
        }
    };
    let base_path = std::path::Path::new(baseline);
    let cand_path = std::path::Path::new(candidate);

    // Resolve to (bench name → pair of parsed docs).
    let pairs: Vec<(String, Json, Json)> = if base_path.is_dir() || cand_path.is_dir() {
        if !(base_path.is_dir() && cand_path.is_dir()) {
            eprintln!("bench-diff: mixed file/directory arguments — pass two files or two dirs");
            return 2;
        }
        let base = match latest_bench_reports(base_path) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("bench-diff: {e}");
                return 1;
            }
        };
        let mut cand = match latest_bench_reports(cand_path) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("bench-diff: {e}");
                return 1;
            }
        };
        let mut v = Vec::new();
        for (bench, (bpath, bdoc)) in base {
            match cand.remove(&bench) {
                Some((cpath, cdoc)) => {
                    println!("pair {bench}: {} vs {}", bpath.display(), cpath.display());
                    v.push((bench, bdoc, cdoc));
                }
                None => println!("skip {bench}: no candidate report (new baselines are fine)"),
            }
        }
        if v.is_empty() {
            eprintln!("bench-diff: no bench appears in both directories");
            return 1;
        }
        v
    } else {
        let bdoc = match load_bench_report(base_path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench-diff: {e}");
                return 1;
            }
        };
        let cdoc = match load_bench_report(cand_path) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench-diff: {e}");
                return 1;
            }
        };
        let bench = bdoc.get("bench").and_then(Json::as_str).unwrap_or("?").to_string();
        vec![(bench, bdoc, cdoc)]
    };

    println!(
        "{:<20} {:<28} {:>14} {:>14} {:>9}  verdict",
        "bench", "metric", "baseline", "candidate", "worse %"
    );
    let mut regressed = false;
    for (bench, bdoc, cdoc) in &pairs {
        let diff = match sddnewton::benchkit::diff_reports(bdoc, cdoc, tol) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("bench-diff: {bench}: {e}");
                return 1;
            }
        };
        for row in &diff.rows {
            println!(
                "{:<20} {:<28} {:>14.6} {:>14.6} {:>8.2}%  {}",
                row.bench,
                row.key,
                row.baseline,
                row.candidate,
                row.worse_frac * 100.0,
                if row.regressed { "REGRESSED" } else { "ok" },
            );
        }
        for key in &diff.missing {
            println!("{bench:<20} {key:<28} {:>14} {:>14} {:>9}  VANISHED", "-", "-", "-");
        }
        regressed |= diff.regressed();
    }
    if regressed {
        eprintln!("bench-diff: regression beyond {:.1}% tolerance", tol * 100.0);
        return 1;
    }
    println!("bench-diff: all metrics within {:.1}% tolerance", tol * 100.0);
    0
}

fn cmd_info() -> i32 {
    println!("sddnewton {}", env!("CARGO_PKG_VERSION"));
    println!("parallelism: {} threads (SDDN_THREADS / --threads to override)",
        sddnewton::par::threads());
    #[cfg(feature = "pjrt")]
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("pjrt platform: {} ({} devices)", c.platform_name(), c.device_count()),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt support not compiled in (enable the `pjrt` cargo feature)");
    let dir = harness::experiments::artifacts_dir();
    match std::fs::read_to_string(dir.join("manifest.json")) {
        Ok(text) => match Json::parse(&text) {
            Ok(m) => {
                let obj = m.as_obj().cloned().unwrap_or_default();
                println!("artifacts in {} ({}):", dir.display(), obj.len());
                for (name, meta) in obj {
                    println!(
                        "  {name} [{}]",
                        meta.get("kind").and_then(Json::as_str).unwrap_or("?")
                    );
                }
            }
            Err(e) => println!("manifest parse error: {e}"),
        },
        Err(_) => println!("no artifacts built (run `make artifacts`)"),
    }
    println!("presets: {}", ExperimentConfig::preset_names().join(", "));
    0
}
