//! Stub PJRT backend compiled when the `pjrt` feature is off (the
//! default — the sandbox cannot fetch the `xla` crate). `for_problem`
//! always fails with a descriptive error so `harness::make_backend`
//! falls back to [`super::NativeBackend`]; the type otherwise mirrors the
//! real backend's API so callers compile unchanged.

use crate::problems::ConsensusProblem;
use std::path::Path;

/// Error raised by every stub operation.
#[derive(Debug, Clone)]
pub struct PjrtError(pub String);

impl std::fmt::Display for PjrtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PjrtError {}

/// Placeholder for the PJRT-backed [`super::LocalBackend`]. Cannot be
/// constructed without the `pjrt` feature.
pub struct PjrtBackend {
    _unconstructible: std::convert::Infallible,
}

impl PjrtBackend {
    /// Always fails: PJRT support is not compiled in.
    pub fn for_problem(
        _problem: &ConsensusProblem,
        dir: impl AsRef<Path>,
    ) -> Result<PjrtBackend, PjrtError> {
        Err(PjrtError(format!(
            "pjrt support not compiled in (build with `--features pjrt` and a vendored \
             xla crate); artifacts dir: {}",
            dir.as_ref().display()
        )))
    }
}

impl super::backend::LocalBackend for PjrtBackend {
    fn primal_recover_all(&self, _problem: &ConsensusProblem, _v: &[f64], _out: &mut [f64]) {
        match self._unconstructible {}
    }

    fn hess_apply_all(
        &self,
        _problem: &ConsensusProblem,
        _thetas: &[f64],
        _z: &[f64],
        _out: &mut [f64],
    ) {
        match self._unconstructible {}
    }

    fn name(&self) -> &'static str {
        "pjrt-stub"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn stub_reports_unavailability() {
        let mut rng = Pcg64::new(1);
        let prob = datasets::synthetic_regression(3, 2, 30, 0.2, 0.05, &mut rng);
        let err = PjrtBackend::for_problem(&prob, "/nonexistent-dir").unwrap_err();
        assert!(err.to_string().contains("pjrt support not compiled in"));
    }
}
