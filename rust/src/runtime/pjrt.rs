//! PJRT backend: executes the AOT-compiled JAX/Pallas artifacts from rust.
//!
//! Build-time python (`make artifacts`) lowered the L2 model to HLO text;
//! here we load it (`HloModuleProto::from_text_file`), compile it on the
//! PJRT CPU client, and drive it with the problem's sufficient statistics.
//! Python is never on this path.

use crate::config::json::Json;
use crate::problems::logistic::Reg;
use crate::problems::{ConsensusProblem, ExportData};
use std::path::{Path, PathBuf};

use super::backend::LocalBackend;

/// PJRT-path error (anyhow is unavailable offline).
#[derive(Debug, Clone)]
pub struct PjrtError(pub String);

impl std::fmt::Display for PjrtError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for PjrtError {}

type Result<T> = std::result::Result<T, PjrtError>;

macro_rules! perr {
    ($($t:tt)*) => { PjrtError(format!($($t)*)) };
}

macro_rules! pbail {
    ($($t:tt)*) => { return Err(perr!($($t)*)) };
}

/// Compiled artifact pair + cached constant inputs for one problem.
enum Mode {
    Quad {
        /// CG-based recover artifact (fallback / ablation).
        recover: xla::PjRtLoadedExecutable,
        /// Precomputed-inverse recover artifact: one batched matmul per
        /// call. `P_i⁻¹` is computed once at startup (§Perf).
        recover_pre: Option<xla::PjRtLoadedExecutable>,
        hess: xla::PjRtLoadedExecutable,
        /// P stacked (n,p,p), built once.
        p_lit: xla::Literal,
        /// P⁻¹ stacked (n,p,p), built once.
        pinv_lit: Option<xla::Literal>,
        /// c stacked (n,p).
        c_lit: xla::Literal,
    },
    Logreg {
        recover: xla::PjRtLoadedExecutable,
        hess: xla::PjRtLoadedExecutable,
        /// B stacked (n, m_pad, p) with zero-padded rows.
        b_lit: xla::Literal,
        /// labels (n, m_pad).
        a_lit: xla::Literal,
        /// reg_scale (n, 1) = μ_i · m_i (true counts, not padded).
        rs_lit: xla::Literal,
        /// Warm-start state: the previous primal iterate (reset to zero
        /// whenever `v = 0`, i.e. a fresh λ = 0 run).
        warm: std::cell::RefCell<Vec<f64>>,
    },
}

/// The PJRT-backed [`LocalBackend`].
pub struct PjrtBackend {
    mode: Mode,
    n: usize,
    p: usize,
}

fn lit2(data: &[f64], d0: usize, d1: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), d0 * d1);
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64])
        .map_err(|e| perr!("reshape ({d0},{d1}): {e}"))
}

fn lit3(data: &[f64], d0: usize, d1: usize, d2: usize) -> Result<xla::Literal> {
    assert_eq!(data.len(), d0 * d1 * d2);
    xla::Literal::vec1(data)
        .reshape(&[d0 as i64, d1 as i64, d2 as i64])
        .map_err(|e| perr!("reshape ({d0},{d1},{d2}): {e}"))
}

fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| perr!("non-utf8 path"))?,
    )
    .map_err(|e| perr!("parsing HLO text {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| perr!("compiling {}: {e}", path.display()))
}

/// Find a manifest entry matching a predicate; returns (name, entry).
fn find_entry<'j>(
    manifest: &'j Json,
    pred: impl Fn(&Json) -> bool,
) -> Option<(&'j str, &'j Json)> {
    manifest
        .as_obj()?
        .iter()
        .find(|(_, v)| pred(v))
        .map(|(k, v)| (k.as_str(), v))
}

impl PjrtBackend {
    /// Build a backend for `problem` from the artifacts in `dir`.
    /// Fails (so callers can fall back to [`super::NativeBackend`]) when no
    /// artifact matches the problem's shape/regularizer.
    pub fn for_problem(problem: &ConsensusProblem, dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        let dir: PathBuf = dir.as_ref().to_path_buf();
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| perr!("reading {}/manifest.json: {e}", dir.display()))?;
        let manifest =
            Json::parse(&manifest_text).map_err(|e| perr!("manifest parse: {e}"))?;
        let (n, p) = (problem.n(), problem.p);
        let client = xla::PjRtClient::cpu().map_err(|e| perr!("pjrt cpu client: {e}"))?;

        match problem.locals[0].export() {
            ExportData::Quadratic { .. } => {
                let want = |kind: &'static str| {
                    move |e: &Json| {
                        e.get("kind").and_then(Json::as_str) == Some(kind)
                            && e.get("n").and_then(Json::as_usize) == Some(n)
                            && e.get("p").and_then(Json::as_usize) == Some(p)
                    }
                };
                let (_, rec) = find_entry(&manifest, want("quad_recover"))
                    .ok_or_else(|| perr!("no quad_recover artifact for n={n} p={p}"))?;
                let (_, hes) = find_entry(&manifest, want("quad_hess"))
                    .ok_or_else(|| perr!("no quad_hess artifact for n={n} p={p}"))?;
                let recover = compile(&client, &dir.join(rec.get("file").unwrap().as_str().unwrap()))?;
                let hess = compile(&client, &dir.join(hes.get("file").unwrap().as_str().unwrap()))?;
                let recover_pre = find_entry(&manifest, want("quad_recover_pre"))
                    .map(|(_, e)| compile(&client, &dir.join(e.get("file").unwrap().as_str().unwrap())))
                    .transpose()?;

                // Stack P and c; precompute P⁻¹ once (startup, not hot path).
                let mut pdata = vec![0.0; n * p * p];
                let mut pinv_data = vec![0.0; n * p * p];
                let mut cdata = vec![0.0; n * p];
                for (i, l) in problem.locals.iter().enumerate() {
                    match l.export() {
                        ExportData::Quadratic { p_mat, c } => {
                            pdata[i * p * p..(i + 1) * p * p].copy_from_slice(&p_mat.data);
                            cdata[i * p..(i + 1) * p].copy_from_slice(c);
                            if recover_pre.is_some() {
                                let inv = crate::linalg::cholesky::spd_inverse(p_mat)
                                    .map_err(|e| perr!("P_{i} not SPD: {e}"))?;
                                pinv_data[i * p * p..(i + 1) * p * p]
                                    .copy_from_slice(&inv.data);
                            }
                        }
                        _ => pbail!("mixed problem kinds"),
                    }
                }
                let pinv_lit = if recover_pre.is_some() {
                    Some(lit3(&pinv_data, n, p, p)?)
                } else {
                    None
                };
                Ok(PjrtBackend {
                    mode: Mode::Quad {
                        recover,
                        recover_pre,
                        hess,
                        p_lit: lit3(&pdata, n, p, p)?,
                        pinv_lit,
                        c_lit: lit2(&cdata, n, p)?,
                    },
                    n,
                    p,
                })
            }
            ExportData::Logistic { reg, .. } => {
                let reg_tag = match reg {
                    Reg::L2 => "l2",
                    Reg::SmoothL1 { .. } => "sl1",
                };
                let m_max = problem
                    .locals
                    .iter()
                    .map(|l| match l.export() {
                        ExportData::Logistic { a, .. } => a.len(),
                        _ => 0,
                    })
                    .max()
                    .unwrap();
                let want = |kind: &'static str| {
                    move |e: &Json| {
                        e.get("kind").and_then(Json::as_str) == Some(kind)
                            && e.get("n").and_then(Json::as_usize) == Some(n)
                            && e.get("p").and_then(Json::as_usize) == Some(p)
                            && e.get("m").and_then(Json::as_usize).map(|m| m >= m_max) == Some(true)
                            && e.get("reg").and_then(Json::as_str) == Some(reg_tag)
                    }
                };
                let (_, rec) = find_entry(&manifest, want("logreg_recover")).ok_or_else(|| {
                    perr!("no logreg_recover artifact for n={n} p={p} m>={m_max} reg={reg_tag}")
                })?;
                let m_pad = rec.get("m").unwrap().as_usize().unwrap();
                let (_, hes) = find_entry(&manifest, move |e: &Json| {
                    e.get("kind").and_then(Json::as_str) == Some("logreg_hess")
                        && e.get("n").and_then(Json::as_usize) == Some(n)
                        && e.get("p").and_then(Json::as_usize) == Some(p)
                        && e.get("m").and_then(Json::as_usize) == Some(m_pad)
                        && e.get("reg").and_then(Json::as_str) == Some(reg_tag)
                })
                .ok_or_else(|| perr!("no matching logreg_hess artifact"))?;
                let recover = compile(&client, &dir.join(rec.get("file").unwrap().as_str().unwrap()))?;
                let hess = compile(&client, &dir.join(hes.get("file").unwrap().as_str().unwrap()))?;

                // Stack B (rows = examples, zero-padded), a, reg_scale.
                let mut bdata = vec![0.0; n * m_pad * p];
                let mut adata = vec![0.0; n * m_pad];
                let mut rsdata = vec![0.0; n];
                for (i, l) in problem.locals.iter().enumerate() {
                    match l.export() {
                        ExportData::Logistic { b, a, mu, .. } => {
                            // b is p×m_i column-major examples; artifact wants (m, p) rows.
                            for j in 0..a.len() {
                                for r in 0..p {
                                    bdata[i * m_pad * p + j * p + r] = b[(r, j)];
                                }
                                adata[i * m_pad + j] = a[j];
                            }
                            rsdata[i] = mu * a.len() as f64;
                        }
                        _ => pbail!("mixed problem kinds"),
                    }
                }
                Ok(PjrtBackend {
                    mode: Mode::Logreg {
                        recover,
                        hess,
                        b_lit: lit3(&bdata, n, m_pad, p)?,
                        a_lit: lit2(&adata, n, m_pad)?,
                        rs_lit: lit2(&rsdata, n, 1)?,
                        warm: std::cell::RefCell::new(vec![0.0; n * p]),
                    },
                    n,
                    p,
                })
            }
            ExportData::Opaque => pbail!("problem does not export data for PJRT"),
        }
    }

    fn run1(&self, exe: &xla::PjRtLoadedExecutable, args: &[&xla::Literal]) -> Result<Vec<f64>> {
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| perr!("pjrt execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| perr!("pjrt device→host: {e}"))?;
        let out = result.to_tuple1().map_err(|e| perr!("pjrt untuple: {e}"))?;
        out.to_vec::<f64>().map_err(|e| perr!("pjrt literal→vec: {e}"))
    }
}

impl LocalBackend for PjrtBackend {
    fn primal_recover_all(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]) {
        let (n, p) = (self.n, self.p);
        debug_assert_eq!(problem.n(), n);
        let v_lit = lit2(v, n, p).expect("literal");
        let res = match &self.mode {
            Mode::Quad { recover, recover_pre, p_lit, pinv_lit, c_lit, .. } => {
                match (recover_pre, pinv_lit) {
                    (Some(pre), Some(pinv)) => self.run1(pre, &[pinv, c_lit, &v_lit]),
                    _ => self.run1(recover, &[p_lit, c_lit, &v_lit]),
                }
            }
            Mode::Logreg { recover, b_lit, a_lit, rs_lit, warm, .. } => {
                // Fresh λ = 0 run (v = 0): reset the warm start.
                if v.iter().all(|&x| x == 0.0) {
                    warm.borrow_mut().fill(0.0);
                }
                let t0_lit = lit2(&warm.borrow(), n, p).expect("literal");
                let res = self.run1(recover, &[b_lit, a_lit, &v_lit, rs_lit, &t0_lit]);
                if let Ok(ref y) = res {
                    warm.borrow_mut().copy_from_slice(y);
                }
                res
            }
        }
        .expect("pjrt execution failed");
        out.copy_from_slice(&res);
    }

    fn hess_apply_all(
        &self,
        problem: &ConsensusProblem,
        thetas: &[f64],
        z: &[f64],
        out: &mut [f64],
    ) {
        let (n, p) = (self.n, self.p);
        debug_assert_eq!(problem.n(), n);
        let res = match &self.mode {
            Mode::Quad { hess, p_lit, .. } => {
                let z_lit = lit2(z, n, p).expect("literal");
                self.run1(hess, &[p_lit, &z_lit])
            }
            Mode::Logreg { hess, b_lit, a_lit, rs_lit, .. } => {
                let t_lit = lit2(thetas, n, p).expect("literal");
                let z_lit = lit2(z, n, p).expect("literal");
                self.run1(hess, &[b_lit, a_lit, &t_lit, &z_lit, rs_lit])
            }
        }
        .expect("pjrt execution failed");
        out.copy_from_slice(&res);
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::datasets;
    use crate::runtime::NativeBackend;
    use crate::util::Pcg64;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn pjrt_quad_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let mut rng = Pcg64::new(201);
        // Must match the smoke artifact shape n=8, p=5.
        let prob = datasets::synthetic_regression(8, 5, 160, 0.2, 0.05, &mut rng);
        let pjrt = match PjrtBackend::for_problem(&prob, artifacts_dir()) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let v = rng.normal_vec(8 * 5);
        let mut out_p = vec![0.0; 40];
        let mut out_n = vec![0.0; 40];
        pjrt.primal_recover_all(&prob, &v, &mut out_p);
        NativeBackend.primal_recover_all(&prob, &v, &mut out_n);
        for (a, b) in out_p.iter().zip(&out_n) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        let z = rng.normal_vec(40);
        let mut hz_p = vec![0.0; 40];
        let mut hz_n = vec![0.0; 40];
        pjrt.hess_apply_all(&prob, &out_p, &z, &mut hz_p);
        NativeBackend.hess_apply_all(&prob, &out_n, &z, &mut hz_n);
        for (a, b) in hz_p.iter().zip(&hz_n) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
    }

    #[test]
    fn pjrt_logreg_matches_native() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let mut rng = Pcg64::new(202);
        // Smoke logistic artifact shape: n=6, p=8, m_pad=16 (examples/node ≤ 16).
        let prob = datasets::mnist_like(
            6,
            8,
            90,
            0,
            crate::problems::logistic::Reg::L2,
            0.05,
            &mut rng,
        );
        let pjrt = match PjrtBackend::for_problem(&prob, artifacts_dir()) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("skipping: {e}");
                return;
            }
        };
        let v: Vec<f64> = rng.normal_vec(6 * 8).iter().map(|x| 0.3 * x).collect();
        let mut out_p = vec![0.0; 48];
        let mut out_n = vec![0.0; 48];
        pjrt.primal_recover_all(&prob, &v, &mut out_p);
        NativeBackend.primal_recover_all(&prob, &v, &mut out_n);
        for (a, b) in out_p.iter().zip(&out_n) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        let z = rng.normal_vec(48);
        let mut hz_p = vec![0.0; 48];
        let mut hz_n = vec![0.0; 48];
        pjrt.hess_apply_all(&prob, &out_n, &z, &mut hz_p);
        NativeBackend.hess_apply_all(&prob, &out_n, &z, &mut hz_n);
        for (a, b) in hz_p.iter().zip(&hz_n) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn missing_artifacts_reported() {
        let mut rng = Pcg64::new(203);
        let prob = datasets::synthetic_regression(3, 2, 30, 0.2, 0.05, &mut rng);
        let res = PjrtBackend::for_problem(&prob, "/nonexistent-dir");
        assert!(res.is_err());
    }
}
