//! The `LocalBackend` abstraction and its native implementation.

use crate::problems::ConsensusProblem;

/// Batched per-node compute used on the hot path of the dual Newton
/// methods. Inputs/outputs are stacked row-major `n × p`.
///
/// Deliberately *not* `Send`/`Sync`: the PJRT client wraps raw pointers;
/// the bulk-synchronous driver runs on one thread and the threaded
/// runtime (`net::threaded`) uses per-node native programs instead.
pub trait LocalBackend {
    /// For every node `i`: `out_i = argmin_θ f_i(θ) + θᵀ v_i` (Eq. 6).
    fn primal_recover_all(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]);

    /// For every node `i`: `out_i = ∇²f_i(θ_i) z_i` (the `b` vectors of
    /// Eq. 9).
    fn hess_apply_all(&self, problem: &ConsensusProblem, thetas: &[f64], z: &[f64], out: &mut [f64]);

    /// Shard variant of [`Self::primal_recover_all`]: recover only the
    /// listed global nodes; `v`/`out` are stacked `nodes.len() × p` in
    /// list order. Used by the partitioned worker runtime. Default: the
    /// per-node oracles (the same computation the batched native path
    /// performs, so shard and whole-problem results are bit-identical).
    fn primal_recover_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        v: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(v.len(), nodes.len() * p);
        assert_eq!(out.len(), nodes.len() * p);
        for (li, &u) in nodes.iter().enumerate() {
            let y = problem.locals[u].primal_recover(&v[li * p..(li + 1) * p]);
            out[li * p..(li + 1) * p].copy_from_slice(&y);
        }
    }

    /// Shard variant of [`Self::hess_apply_all`], same conventions.
    fn hess_apply_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        thetas: &[f64],
        z: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(out.len(), nodes.len() * p);
        for (li, &u) in nodes.iter().enumerate() {
            let b = problem.locals[u]
                .hess_vec(&thetas[li * p..(li + 1) * p], &z[li * p..(li + 1) * p]);
            out[li * p..(li + 1) * p].copy_from_slice(&b);
        }
    }

    /// Per-node dense Hessians for the listed nodes: `out` holds
    /// `nodes.len()` row-major `p×p` blocks. Feeds the kernel-consistency
    /// correction's p²-wide all-reduce in the sharded SDD-Newton step (the
    /// all-reduce itself is accounted by the caller). Default: the local
    /// oracles.
    fn hess_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        thetas: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(thetas.len(), nodes.len() * p);
        assert_eq!(out.len(), nodes.len() * p * p);
        for (li, &u) in nodes.iter().enumerate() {
            let h = problem.locals[u].hessian(&thetas[li * p..(li + 1) * p]);
            out[li * p * p..(li + 1) * p * p].copy_from_slice(&h.data);
        }
    }

    /// Aggregated Hessian `Σ_i ∇²f_i(θ_i)` (p×p). Used by the kernel-
    /// consistency correction of the incremental SDD-Newton step; the
    /// corresponding all-reduce is accounted by the caller. Default: sum
    /// the local oracles.
    fn hess_sum(&self, problem: &ConsensusProblem, thetas: &[f64]) -> crate::linalg::Matrix {
        let p = problem.p;
        let mut sum = crate::linalg::Matrix::zeros(p, p);
        for (i, l) in problem.locals.iter().enumerate() {
            sum.add_scaled(1.0, &l.hessian(&thetas[i * p..(i + 1) * p]));
        }
        sum
    }

    /// Human-readable backend name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Pure-rust backend delegating to the `LocalObjective` oracles. This is
/// the correctness reference for the PJRT artifacts.
///
/// The per-node oracles are independent (`LocalObjective: Send + Sync`),
/// so both batched entry points fan the nodes out over the
/// [`crate::par`] substrate when the batch is large enough; each node's
/// output block is owned by exactly one thread, so results are identical
/// to the serial sweep for any thread count.
pub struct NativeBackend;

/// Work heuristic for the per-node fan-out: primal recovery / Hessian
/// application cost at least O(p²) per node.
fn node_batch_threads(n: usize, p: usize) -> usize {
    crate::par::plan_for(n.saturating_mul(p).saturating_mul(p.max(16)))
}

impl LocalBackend for NativeBackend {
    fn primal_recover_all(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]) {
        let p = problem.p;
        assert_eq!(v.len(), problem.n() * p);
        assert_eq!(out.len(), problem.n() * p);
        let threads = node_batch_threads(problem.n(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let i = i0 + k;
                let y = problem.locals[i].primal_recover(&v[i * p..(i + 1) * p]);
                orow.copy_from_slice(&y);
            }
        });
    }

    fn hess_apply_all(
        &self,
        problem: &ConsensusProblem,
        thetas: &[f64],
        z: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(out.len(), problem.n() * p);
        let threads = node_batch_threads(problem.n(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let i = i0 + k;
                let b = problem.locals[i]
                    .hess_vec(&thetas[i * p..(i + 1) * p], &z[i * p..(i + 1) * p]);
                orow.copy_from_slice(&b);
            }
        });
    }

    fn primal_recover_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        v: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(v.len(), nodes.len() * p);
        assert_eq!(out.len(), nodes.len() * p);
        let threads = node_batch_threads(nodes.len(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let li = i0 + k;
                let y = problem.locals[nodes[li]].primal_recover(&v[li * p..(li + 1) * p]);
                orow.copy_from_slice(&y);
            }
        });
    }

    fn hess_apply_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        thetas: &[f64],
        z: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(out.len(), nodes.len() * p);
        let threads = node_batch_threads(nodes.len(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let li = i0 + k;
                let b = problem.locals[nodes[li]]
                    .hess_vec(&thetas[li * p..(li + 1) * p], &z[li * p..(li + 1) * p]);
                orow.copy_from_slice(&b);
            }
        });
    }

    fn hess_nodes(
        &self,
        problem: &ConsensusProblem,
        nodes: &[usize],
        thetas: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(thetas.len(), nodes.len() * p);
        assert_eq!(out.len(), nodes.len() * p * p);
        let threads = node_batch_threads(nodes.len(), p);
        crate::par::par_chunks_mut(out, p * p, threads, |i0, block| {
            for (k, oblk) in block.chunks_mut(p * p).enumerate() {
                let li = i0 + k;
                let h = problem.locals[nodes[li]].hessian(&thetas[li * p..(li + 1) * p]);
                oblk.copy_from_slice(&h.data);
            }
        });
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn native_backend_matches_locals() {
        let mut rng = Pcg64::new(71);
        let prob = datasets::synthetic_regression(4, 6, 80, 0.1, 0.05, &mut rng);
        let v = rng.normal_vec(4 * 6);
        let mut out = vec![0.0; 24];
        NativeBackend.primal_recover_all(&prob, &v, &mut out);
        for i in 0..4 {
            let y = prob.locals[i].primal_recover(&v[i * 6..(i + 1) * 6]);
            assert_eq!(&out[i * 6..(i + 1) * 6], y.as_slice());
        }
        let z = rng.normal_vec(24);
        let mut hz = vec![0.0; 24];
        NativeBackend.hess_apply_all(&prob, &out, &z, &mut hz);
        for i in 0..4 {
            let b = prob.locals[i].hess_vec(&out[i * 6..(i + 1) * 6], &z[i * 6..(i + 1) * 6]);
            assert_eq!(&hz[i * 6..(i + 1) * 6], b.as_slice());
        }
    }

    #[test]
    fn node_shards_match_whole_problem_batches() {
        let mut rng = Pcg64::new(72);
        let (n, p) = (6usize, 4usize);
        let prob = datasets::synthetic_regression(n, p, 90, 0.1, 0.05, &mut rng);
        let v = rng.normal_vec(n * p);
        let mut full = vec![0.0; n * p];
        NativeBackend.primal_recover_all(&prob, &v, &mut full);
        let z = rng.normal_vec(n * p);
        let mut hz_full = vec![0.0; n * p];
        NativeBackend.hess_apply_all(&prob, &full, &z, &mut hz_full);

        // A non-contiguous shard must reproduce exactly the rows the
        // whole-problem batch produced for those nodes.
        let nodes = [1usize, 3, 4];
        let gather = |src: &[f64]| -> Vec<f64> {
            nodes.iter().flat_map(|&u| src[u * p..(u + 1) * p].to_vec()).collect()
        };
        let (vs, ts, zs) = (gather(&v), gather(&full), gather(&z));
        let mut shard = vec![0.0; nodes.len() * p];
        NativeBackend.primal_recover_nodes(&prob, &nodes, &vs, &mut shard);
        assert_eq!(shard, gather(&full));
        let mut hz_shard = vec![0.0; nodes.len() * p];
        NativeBackend.hess_apply_nodes(&prob, &nodes, &ts, &zs, &mut hz_shard);
        assert_eq!(hz_shard, gather(&hz_full));
    }
}
