//! The `LocalBackend` abstraction and its native implementation.

use crate::problems::ConsensusProblem;

/// Batched per-node compute used on the hot path of the dual Newton
/// methods. Inputs/outputs are stacked row-major `n × p`.
///
/// Deliberately *not* `Send`/`Sync`: the PJRT client wraps raw pointers;
/// the bulk-synchronous driver runs on one thread and the threaded
/// runtime (`net::threaded`) uses per-node native programs instead.
pub trait LocalBackend {
    /// For every node `i`: `out_i = argmin_θ f_i(θ) + θᵀ v_i` (Eq. 6).
    fn primal_recover_all(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]);

    /// For every node `i`: `out_i = ∇²f_i(θ_i) z_i` (the `b` vectors of
    /// Eq. 9).
    fn hess_apply_all(&self, problem: &ConsensusProblem, thetas: &[f64], z: &[f64], out: &mut [f64]);

    /// Aggregated Hessian `Σ_i ∇²f_i(θ_i)` (p×p). Used by the kernel-
    /// consistency correction of the SDD-Newton step (see
    /// `algorithms::sdd_newton`); the corresponding all-reduce is accounted
    /// by the caller. Default: sum the local oracles.
    fn hess_sum(&self, problem: &ConsensusProblem, thetas: &[f64]) -> crate::linalg::Matrix {
        let p = problem.p;
        let mut sum = crate::linalg::Matrix::zeros(p, p);
        for (i, l) in problem.locals.iter().enumerate() {
            sum.add_scaled(1.0, &l.hessian(&thetas[i * p..(i + 1) * p]));
        }
        sum
    }

    /// Human-readable backend name for logs/reports.
    fn name(&self) -> &'static str;
}

/// Pure-rust backend delegating to the `LocalObjective` oracles. This is
/// the correctness reference for the PJRT artifacts.
///
/// The per-node oracles are independent (`LocalObjective: Send + Sync`),
/// so both batched entry points fan the nodes out over the
/// [`crate::par`] substrate when the batch is large enough; each node's
/// output block is owned by exactly one thread, so results are identical
/// to the serial sweep for any thread count.
pub struct NativeBackend;

/// Work heuristic for the per-node fan-out: primal recovery / Hessian
/// application cost at least O(p²) per node.
fn node_batch_threads(n: usize, p: usize) -> usize {
    crate::par::plan_for(n.saturating_mul(p).saturating_mul(p.max(16)))
}

impl LocalBackend for NativeBackend {
    fn primal_recover_all(&self, problem: &ConsensusProblem, v: &[f64], out: &mut [f64]) {
        let p = problem.p;
        assert_eq!(v.len(), problem.n() * p);
        assert_eq!(out.len(), problem.n() * p);
        let threads = node_batch_threads(problem.n(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let i = i0 + k;
                let y = problem.locals[i].primal_recover(&v[i * p..(i + 1) * p]);
                orow.copy_from_slice(&y);
            }
        });
    }

    fn hess_apply_all(
        &self,
        problem: &ConsensusProblem,
        thetas: &[f64],
        z: &[f64],
        out: &mut [f64],
    ) {
        let p = problem.p;
        assert_eq!(out.len(), problem.n() * p);
        let threads = node_batch_threads(problem.n(), p);
        crate::par::par_chunks_mut(out, p, threads, |i0, block| {
            for (k, orow) in block.chunks_mut(p).enumerate() {
                let i = i0 + k;
                let b = problem.locals[i]
                    .hess_vec(&thetas[i * p..(i + 1) * p], &z[i * p..(i + 1) * p]);
                orow.copy_from_slice(&b);
            }
        });
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::datasets;
    use crate::util::Pcg64;

    #[test]
    fn native_backend_matches_locals() {
        let mut rng = Pcg64::new(71);
        let prob = datasets::synthetic_regression(4, 6, 80, 0.1, 0.05, &mut rng);
        let v = rng.normal_vec(4 * 6);
        let mut out = vec![0.0; 24];
        NativeBackend.primal_recover_all(&prob, &v, &mut out);
        for i in 0..4 {
            let y = prob.locals[i].primal_recover(&v[i * 6..(i + 1) * 6]);
            assert_eq!(&out[i * 6..(i + 1) * 6], y.as_slice());
        }
        let z = rng.normal_vec(24);
        let mut hz = vec![0.0; 24];
        NativeBackend.hess_apply_all(&prob, &out, &z, &mut hz);
        for i in 0..4 {
            let b = prob.locals[i].hess_vec(&out[i * 6..(i + 1) * 6], &z[i * 6..(i + 1) * 6]);
            assert_eq!(&hz[i * 6..(i + 1) * 6], b.as_slice());
        }
    }
}
