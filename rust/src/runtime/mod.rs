//! Runtime: the per-node compute backends.
//!
//! The dual-Newton algorithms touch node data only through
//! [`LocalBackend`]: batched primal recovery (Eq. 6) and batched local
//! Hessian application (the `b` vectors of Eq. 9). Two implementations:
//!
//! - [`backend::NativeBackend`] — pure-rust reference (`problems::*`);
//! - [`pjrt::PjrtBackend`] — loads the AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) and executes them on the PJRT CPU client.
//!   Python never runs here; the HLO was produced once at build time.

pub mod backend;

// The PJRT path needs a vendored `xla` crate; offline builds compile a
// stub whose `for_problem` always errs, so the harness's native fallback
// kicks in without any caller changes.
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use backend::{LocalBackend, NativeBackend};
pub use pjrt::PjrtBackend;
